#!/usr/bin/env bash
# Tier-1 verify entrypoint (the exact command from ROADMAP.md).
#
# Usage: scripts/ci.sh [extra pytest args]
#        scripts/ci.sh --bench-smoke   # round-fusion perf smoke: runs
#                                      # bench_round_e2e at tiny shapes and
#                                      # writes BENCH_round_e2e.json at the
#                                      # repo root (perf trajectory tracking)
#        scripts/ci.sh --participation-smoke
#                                      # fault-injection sweep: dropout x
#                                      # staleness across fedgalore vs the
#                                      # fedavg-LoRA baseline; writes
#                                      # BENCH_participation.json and gates
#                                      # on its acceptance keys
#        scripts/ci.sh --robust-smoke  # adversary sweep: NaN/scale attacks
#                                      # vs quarantine + robust factored
#                                      # aggregation, engine AND runtime
#                                      # (with a coverage floor on the
#                                      # population adversary layer when
#                                      # pytest-cov is installed); writes
#                                      # BENCH_robust.json and gates on
#                                      # honest bit-identity (both drivers),
#                                      # NaN containment, bounded attack
#                                      # degradation, hetero-basis attack
#                                      # parity, and pipelined-quarantine
#                                      # throughput
#        scripts/ci.sh --sync-smoke    # batched-bucket 𝒮 + pipelined-scan
#                                      # leg: runs the sync parity suites
#                                      # (with a coverage floor on
#                                      # state_sync/ajive when pytest-cov is
#                                      # installed), then gates the 𝒮-stage
#                                      # budget and pipelined ≥ sequential
#                                      # keys on BENCH_round_e2e.json
#        scripts/ci.sh --serve-smoke   # multi-tenant serving leg: runs the
#                                      # serving suite (batched hetero-adapter
#                                      # kernel, scan≡eager decode parity,
#                                      # SlotServer continuous batching; with
#                                      # a coverage floor on launch/serve +
#                                      # launch/adapters when pytest-cov is
#                                      # installed), then runs bench_serve
#                                      # --smoke and gates decode parity,
#                                      # scan ≥ eager throughput, hetero-batch
#                                      # ≥ 0.8x single-adapter tokens/s, and
#                                      # continuous-batching parity on
#                                      # BENCH_serve.json
# Dev-only deps (pytest, hypothesis, pytest-cov) are listed in
# requirements-dev.txt; tests that need hypothesis self-skip when it is
# absent, and the --sync-smoke coverage floor downgrades to plain pytest
# without pytest-cov.
set -euo pipefail
cd "$(dirname "$0")/.."

# --- environment hygiene (mirrors benchmarks/run.py:_env_hygiene) ------------
# tcmalloc, when the image ships it: glibc malloc fragments badly under the
# round's large donated-buffer churn; the report threshold silences tcmalloc's
# per-allocation warnings for the multi-GB cohort buffers.
if [[ -z "${LD_PRELOAD:-}" ]]; then
    for _tc in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
               /usr/lib/libtcmalloc.so.4; do
        [[ -f "$_tc" ]] && export LD_PRELOAD="$_tc" && break
    done
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
# Absl/TF C++ banner noise off by default (keeps pytest/bench output greppable).
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
# REPRO_HOST_DEVICES=N fakes an N-device host platform (multi-device mesh
# tests and sharded smoke runs on CPU-only hosts).
if [[ -n "${REPRO_HOST_DEVICES:-}" ]]; then
    export XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi
# REPRO_STEP_MARKERS=1 adds per-step trace markers for profiles. Opt-in only:
# the flag is rejected by CPU builds of XLA ("Unknown flags in XLA_FLAGS").
if [[ "${REPRO_STEP_MARKERS:-0}" == "1" ]]; then
    export XLA_FLAGS="${XLA_FLAGS:-} --xla_step_marker_location=1"
fi

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_round_e2e --smoke --out BENCH_round_e2e.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_round_e2e.json"))["acceptance"]
print("round_e2e acceptance:", json.dumps(acc, indent=1))
# Perf gates (not just recordings): the headline C=512 factored round must
# stay within the recorded budget, and the lift-free delta-context round
# must be no slower than the transient-lift oracle at the compute-bound
# cohort shape.
assert acc["cohort_cmax_within_budget"], (
    f"C={acc['cohort_cmax']} factored round regressed: "
    f"{acc['cohort_cmax_round_s']:.2f}s > "
    f"budget {acc['cohort_cmax_round_s_budget']:.2f}s")
assert acc["liftfree_speedup_cmax"] >= 1.0, (
    f"lift-free round slower than transient-lift at C={acc['cohort_cmax']}: "
    f"{acc['liftfree_speedup_cmax']:.2f}x")
EOF
    exit 0
fi

if [[ "${1:-}" == "--sync-smoke" ]]; then
    shift
    # Sync parity subset: bucketed 𝒮 ≡ per-leaf, pipelined ≡ sequential,
    # batched-eigh kernel vs LAPACK. pytest-cov (when installed) enforces a
    # line-coverage floor on the two modules this suite locks in.
    COV_ARGS=()
    if PYTHONPATH=src python -c "import pytest_cov" 2>/dev/null; then
        COV_ARGS=(--cov=repro.core.state_sync --cov=repro.core.ajive
                  --cov-report=term --cov-fail-under=80)
    else
        echo "pytest-cov not installed — sync parity runs without the" \
             "coverage floor"
    fi
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        ${COV_ARGS[@]+"${COV_ARGS[@]}"} \
        tests/test_sync_batched.py tests/test_batched_eigh.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_round_e2e --smoke --no-runtime \
        --out BENCH_round_e2e.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_round_e2e.json"))["acceptance"]
keys = {k: acc[k] for k in ("sync_stage_clients", "sync_stage_s",
                            "sync_stage_budget_s",
                            "sync_stage_within_budget",
                            "pipeline_speedup_by_clients",
                            "pipelined_ge_sequential")}
print("sync acceptance:", json.dumps(keys, indent=1))
# Perf gates: the batched-bucket 𝒮 stage stays within its budget at the
# breakdown cohort, and the pipelined K-round scan is no slower than the
# sequential oracle (up to the recorded scheduler-noise tolerance) at
# every cohort size.
assert acc["sync_stage_within_budget"], (
    f"S stage at C={acc['sync_stage_clients']} over budget: "
    f"{acc['sync_stage_s'] * 1e3:.2f}ms > "
    f"{acc['sync_stage_budget_s'] * 1e3:.0f}ms")
assert acc["pipelined_ge_sequential"], (
    "pipelined scan slower than sequential beyond the "
    f"{acc['pipeline_noise_tol']:.2f}x noise tolerance: "
    f"{json.dumps(acc['pipeline_speedup_by_clients'])}")
EOF
    exit 0
fi

if [[ "${1:-}" == "--participation-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_participation --smoke \
        --out BENCH_participation.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_participation.json"))["acceptance"]
print("participation acceptance:", json.dumps(acc, indent=1))
# Robustness gates: the masked fused round must be bit-identical to the
# unmasked round under full participation, drift must stay bounded through
# the stale-merge path, and fedgalore must degrade no worse than the
# fedavg-LoRA baseline across the dropout x staleness fault grid.
assert acc["masked_round_parity"], "full-participation mask != unmasked round"
assert acc["stale_drift_bounded"], (
    f"stale aggregation error unbounded: {acc['max_stale_weight_err']:.4f}")
assert acc["fedgalore_degradation_ok"], (
    f"fedgalore degrades more than baseline under faults: "
    f"{acc['fedgalore_worst_degradation']:.4f} vs "
    f"{acc['baseline_worst_degradation']:.4f} (+tol)")
EOF
    exit 0
fi

if [[ "${1:-}" == "--robust-smoke" ]]; then
    shift
    # Robustness suite first: operator/property invariants + the guarded
    # engine/runtime rounds, with a line-coverage floor on the population
    # adversary layer (cohort plans, corruption schedules) when pytest-cov
    # is installed.
    COV_ARGS=()
    if PYTHONPATH=src python -c "import pytest_cov" 2>/dev/null; then
        COV_ARGS=(--cov=repro.core.population
                  --cov-report=term --cov-fail-under=70)
    else
        echo "pytest-cov not installed — robust suite runs without the" \
             "coverage floor"
    fi
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        ${COV_ARGS[@]+"${COV_ARGS[@]}"} \
        tests/test_robust.py tests/test_robust_properties.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_robust --smoke --out BENCH_robust.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_robust.json"))["acceptance"]
print("robust acceptance:", json.dumps(acc, indent=1))
# Defense-in-depth gates: the all-honest guarded round must be bit-identical
# to the unguarded round (engine AND sharded runtime), every NaN-adversary
# run under a defense must stay finite end-to-end, for each attack the best
# defended cell must stay within the degradation bound while the undefended
# cell degrades strictly more (or diverges), the hetero-basis (svd-refresh)
# defended runs must track their shared-basis twins, and the quarantined
# pipelined scan must be no slower than the sequential oracle.
assert acc["attacks_landed"], "adversary plans drew zero corrupted clients"
assert acc["honest_bit_identity"], "honest guarded round != unguarded round"
assert acc["nan_quarantined"], "NaN adversary leaked past the quarantine"
assert acc["attack_degradation_bounded"], (
    f"attack degradation unbounded: {json.dumps(acc['degradation'])}")
assert acc["runtime_attacks_landed"], "runtime schedule drew zero attacks"
assert acc["runtime_honest_bit_identity"], (
    "honest guarded runtime round != unguarded runtime round")
assert acc["hetero_attack_parity"], (
    "hetero-basis defended runs diverged from shared-basis twins: "
    f"{json.dumps(acc['hetero_parity_rel'])} vs bound "
    f"{acc['hetero_bound']}")
assert acc["quarantine_pipelined_ge_sequential"], (
    "quarantined pipelined scan slower than sequential beyond the "
    f"{acc['pipe_noise_tol']:.2f}x noise tolerance: "
    f"{json.dumps(acc['quarantine_pipeline'])}")
EOF
    exit 0
fi

if [[ "${1:-}" == "--serve-smoke" ]]; then
    shift
    # Serving suite first: batched hetero-adapter kernel vs per-request
    # reference, scan≡eager bit-identity, adapter-store spill round-trips,
    # SlotServer churn parity — with a line-coverage floor on the serving
    # layer when pytest-cov is installed.
    COV_ARGS=()
    if PYTHONPATH=src python -c "import pytest_cov" 2>/dev/null; then
        COV_ARGS=(--cov=repro.launch.serve --cov=repro.launch.adapters
                  --cov-report=term --cov-fail-under=80)
    else
        echo "pytest-cov not installed — serving suite runs without the" \
             "coverage floor"
    fi
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
        ${COV_ARGS[@]+"${COV_ARGS[@]}"} \
        tests/test_serve.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_serve --smoke --out BENCH_serve.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_serve.json"))["acceptance"]
print("serve acceptance:", json.dumps(acc, indent=1))
# Serving gates: the fused scan decode must emit the exact greedy tokens of
# the eager loop and be no slower, a heterogeneous-adapter batch (every row
# its own factor pair over one shared base GEMM) must hold >= 0.8x the
# single-adapter throughput, and continuous batching must reproduce straight
# generation per request through retire/admit churn.
assert acc["decode_parity"], "scan decode != eager greedy tokens"
assert acc["scan_speedup_b4_n64"] >= 1.0, (
    f"fused scan decode slower than eager loop: "
    f"x{acc['scan_speedup_b4_n64']:.2f}")
assert acc["hetero_tput_ratio_g16_b8"] >= 0.8, (
    f"hetero-adapter batch below 0.8x single-adapter throughput at "
    f"G={acc['hetero_gate_adapters']}: x{acc['hetero_tput_ratio_g16_b8']:.2f}")
assert acc["continuous_parity"], (
    "SlotServer continuous batching != straight generate per request")
EOF
    exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
