#!/usr/bin/env bash
# Tier-1 verify entrypoint (the exact command from ROADMAP.md).
#
# Usage: scripts/ci.sh [extra pytest args]
#        scripts/ci.sh --bench-smoke   # round-fusion perf smoke: runs
#                                      # bench_round_e2e at tiny shapes and
#                                      # writes BENCH_round_e2e.json at the
#                                      # repo root (perf trajectory tracking)
# Dev-only deps (pytest, hypothesis) are listed in requirements-dev.txt;
# tests that need hypothesis self-skip when it is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--bench-smoke" ]]; then
    shift
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m \
        benchmarks.bench_round_e2e --smoke --out BENCH_round_e2e.json "$@"
    python - <<'EOF'
import json
acc = json.load(open("BENCH_round_e2e.json"))["acceptance"]
print("round_e2e acceptance:", json.dumps(acc, indent=1))
# Perf gates (not just recordings): the headline C=512 factored round must
# stay within the recorded budget, and the lift-free delta-context round
# must be no slower than the transient-lift oracle at the compute-bound
# cohort shape.
assert acc["cohort_cmax_within_budget"], (
    f"C={acc['cohort_cmax']} factored round regressed: "
    f"{acc['cohort_cmax_round_s']:.2f}s > "
    f"budget {acc['cohort_cmax_round_s_budget']:.2f}s")
assert acc["liftfree_speedup_cmax"] >= 1.0, (
    f"lift-free round slower than transient-lift at C={acc['cohort_cmax']}: "
    f"{acc['liftfree_speedup_cmax']:.2f}x")
EOF
    exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
