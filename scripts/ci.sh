#!/usr/bin/env bash
# Tier-1 verify entrypoint (the exact command from ROADMAP.md).
#
# Usage: scripts/ci.sh [extra pytest args]
# Dev-only deps (pytest, hypothesis) are listed in requirements-dev.txt;
# tests that need hypothesis self-skip when it is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
