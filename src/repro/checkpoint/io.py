"""Pytree checkpointing: npz payload + JSON treedef manifest.

Keys are slash-joined tree paths, values are host numpy arrays; restore
rebuilds against a template pytree (so NamedTuple states and dtypes are
preserved) and can re-shard onto a mesh via ``jax.device_put`` with the
template's shardings.

Crash safety: both the npz payload and the JSON manifest are written to a
temp file and moved into place with ``os.replace`` (atomic on POSIX), so a
writer killed mid-save leaves either the previous complete checkpoint or a
stray ``*.tmp*`` file — never a half-written payload under the final name.
``latest_step`` additionally validates each candidate payload (zip central
directory + per-member CRC) and skips truncated or missing ones, so a
client-state store interrupted mid-spill falls back to the last good step
instead of crashing the run.
"""
from __future__ import annotations

import json
import os
import re
import zipfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":       # npz has no bf16: lossless up
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _payload_valid(path: str) -> bool:
    """Whether an npz payload is present and structurally complete (zip
    central directory readable, every member's CRC checks out). A truncated
    write — e.g. a spill interrupted by a crash before ``os.replace`` of a
    *previous* format, or a copy cut short — fails here instead of blowing
    up inside ``np.load`` at restore time."""
    if not os.path.isfile(path):
        return False
    try:
        with zipfile.ZipFile(path) as zf:
            return zf.testzip() is None and len(zf.namelist()) >= 0
    except (zipfile.BadZipFile, OSError, EOFError):
        return False


def save(directory: str, step: int, tree: PyTree, name: str = "ckpt",
         keep_last: Optional[int] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    manifest = {"step": step, "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()}}
    mpath = os.path.join(directory, f"{name}_{step:08d}.json")
    mtmp = mpath + ".tmp"
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, mpath)
    if keep_last is not None:
        gc_steps(directory, name=name, keep_last=keep_last)
    return path


def gc_steps(directory: str, name: str = "ckpt", keep_last: int = 1) -> None:
    """Retention GC: keep only the newest ``keep_last`` steps that have a
    *valid* payload; everything older is deleted (payload + manifest), and
    so are steps whose payload is missing or truncated — a dead step can
    never be restored, so it only wastes disk. Validity is re-checked here
    rather than trusted from the save order, which guarantees the newest
    restorable step is never collected even if later saves were interrupted.
    """
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    if not os.path.isdir(directory):
        return
    steps = set()
    for f in os.listdir(directory):
        m = re.fullmatch(rf"{name}_(\d+)\.(npz|json)", f)
        if m:
            steps.add(int(m.group(1)))
    valid = [s for s in steps
             if _payload_valid(os.path.join(directory,
                                            f"{name}_{s:08d}.npz"))]
    keep = set(sorted(valid)[-keep_last:])
    for s in steps - keep:
        for ext in ("npz", "json", "meta.json"):
            p = os.path.join(directory, f"{name}_{s:08d}.{ext}")
            if os.path.isfile(p):
                os.remove(p)


def restore(directory: str, step: int, template: PyTree,
            name: str = "ckpt", reject_nonfinite: bool = True) -> PyTree:
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    if not _payload_valid(path):
        raise FileNotFoundError(
            f"checkpoint payload missing or truncated: {path} "
            f"(use latest_step() to locate the last complete step)")
    data = np.load(path)
    leaves = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = leaves[0], leaves[1]
    out = []
    for path_t, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        raw = data[key]
        if (reject_nonfinite and np.issubdtype(raw.dtype, np.floating)
                and not np.isfinite(raw).all()):
            # A shard that passed the zip CRC can still carry NaN/inf (e.g.
            # truncated-then-padded bytes, or state spilled mid-blowup) —
            # restoring it would feed poison straight back into the client
            # state store / federation. Fail loudly instead.
            raise ValueError(
                f"checkpoint payload contains non-finite values: {path} "
                f"(key {key!r}); refusing to restore corrupted state")
        arr = jnp.asarray(raw, dtype=leaf.dtype)
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            try:
                arr = jax.device_put(arr, leaf.sharding)
            except Exception:
                pass
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    """Largest step with a *complete* payload; steps whose npz is missing or
    truncated (a crash between manifest and payload, or mid-payload under a
    non-atomic writer) are skipped."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(rf"{name}_(\d+)\.npz", f)
        if m and _payload_valid(os.path.join(directory, f)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
