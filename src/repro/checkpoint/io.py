"""Pytree checkpointing: npz payload + JSON treedef manifest.

Keys are slash-joined tree paths, values are host numpy arrays; restore
rebuilds against a template pytree (so NamedTuple states and dtypes are
preserved) and can re-shard onto a mesh via ``jax.device_put`` with the
template's shardings.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":       # npz has no bf16: lossless up
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save(directory: str, step: int, tree: PyTree, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    manifest = {"step": step, "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()}}
    with open(os.path.join(directory, f"{name}_{step:08d}.json"), "w") as f:
        json.dump(manifest, f)
    return path


def restore(directory: str, step: int, template: PyTree,
            name: str = "ckpt") -> PyTree:
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    leaves = jax.tree_util.tree_flatten_with_path(template)
    paths, treedef = leaves[0], leaves[1]
    out = []
    for path_t, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        arr = jnp.asarray(data[key], dtype=leaf.dtype)
        if hasattr(leaf, "sharding") and leaf.sharding is not None:
            try:
                arr = jax.device_put(arr, leaf.sharding)
            except Exception:
                pass
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(rf"{name}_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None
