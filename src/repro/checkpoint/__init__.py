from .io import gc_steps, latest_step, restore, save

__all__ = ["save", "restore", "latest_step", "gc_steps"]
