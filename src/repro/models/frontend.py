"""Modality frontend STUBS (the assignment's one allowed carve-out).

The VLM/audio entries specify the transformer backbone only; the real
frontends (Pixtral ViT + projector, EnCodec conv codec) are not implemented.
These helpers produce deterministic synthetic patch/frame embeddings of the
right shape for examples, tests, and the federated benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig


def stub_patch_embeddings(key: jax.Array, cfg: ArchConfig, batch: int,
                          class_id: jnp.ndarray = None) -> jnp.ndarray:
    """(B, frontend_tokens, d_model) synthetic patch embeddings. When
    ``class_id`` (B,) is given, embeddings carry a class-dependent signal so
    classification benchmarks have learnable structure."""
    n = cfg.frontend_tokens
    base = jax.random.normal(key, (batch, n, cfg.d_model), jnp.float32)
    if class_id is not None:
        proto_key = jax.random.PRNGKey(7)
        protos = jax.random.normal(proto_key, (1024, cfg.d_model), jnp.float32)
        base = base + 2.0 * protos[class_id][:, None, :]
    return base.astype(jnp.bfloat16)


def stub_frame_embeddings(key: jax.Array, cfg: ArchConfig,
                          batch: int, n_frames: int) -> jnp.ndarray:
    """(B, n_frames, d_model) synthetic audio-frame embeddings."""
    return jax.random.normal(key, (batch, n_frames, cfg.d_model),
                             jnp.float32).astype(jnp.bfloat16)
