"""Mamba (selective SSM) layer — the recurrent half of Jamba's 1:7 interleave.

Selective scan: h_t = exp(Δ_t ⊙ A) h_{t-1} + (Δ_t ⊙ x_t) B_tᵀ ;  y_t = h_t C_t + D x_t.
Train/prefill run a `lax.scan` over time carrying (B, d_inner, d_state) —
no (L, d_inner, d_state) tensor is ever materialized (VMEM-friendly; a
chunked Pallas kernel is the §Perf upgrade path). Decode carries the SSM
state plus a (d_conv-1)-tap shift register for the causal depthwise conv.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense, dense_init


def mamba_dims(d_model: int, expand: int = 2, d_state: int = 16,
               d_conv: int = 4):
    d_inner = expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    return d_inner, dt_rank, d_state, d_conv


def mamba_init(key, d_model: int, *, expand: int = 2, d_state: int = 16,
               d_conv: int = 4, dtype=jnp.float32):
    d_inner, dt_rank, d_state, d_conv = mamba_dims(d_model, expand, d_state, d_conv)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": dense_init(ks[1], (d_conv, d_inner), scale=0.1, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], (d_inner, dt_rank + 2 * d_state), dtype=dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_inner), dtype=dtype),
        "dt_bias": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(a),                       # (d_inner, d_state) fp32
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_inner, d_model), dtype=dtype),
    }


class MambaState(NamedTuple):
    conv: jnp.ndarray   # (B, d_conv-1, d_inner) last inputs
    h: jnp.ndarray      # (B, d_inner, d_state) fp32 SSM state


def mamba_state_init(batch: int, d_model: int, *, expand: int = 2,
                     d_state: int = 16, d_conv: int = 4,
                     dtype=jnp.bfloat16) -> MambaState:
    d_inner, _, d_state, d_conv = mamba_dims(d_model, expand, d_state, d_conv)
    return MambaState(conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
                      h=jnp.zeros((batch, d_inner, d_state), jnp.float32))


def _causal_depthwise_conv(x, w, b, init_taps=None):
    """x (B, L, C), w (K, C): causal depthwise conv along L."""
    k = w.shape[0]
    if init_taps is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_taps.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, L+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _ssm_params(p, xc, dt_rank, d_state):
    """xc (..., d_inner) -> Δ (..., d_inner), B (..., d_state), C (..., d_state)."""
    proj = xc @ p["x_proj"]
    dt, b, c = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    delta = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    return delta, b.astype(jnp.float32), c.astype(jnp.float32)


def mamba_forward(p, x, state: MambaState = None, *, d_model: int,
                  expand: int = 2, d_state: int = 16, d_conv: int = 4,
                  return_state: bool = False):
    """x (B, L, D) -> (B, L, D) [, final MambaState]."""
    d_inner, dt_rank, d_state, d_conv = mamba_dims(d_model, expand, d_state, d_conv)
    b_, l, _ = x.shape
    xz = dense(x, p["in_proj"])
    xc, z = jnp.split(xz, 2, axis=-1)
    init_taps = None if state is None else state.conv
    xc = jax.nn.silu(_causal_depthwise_conv(xc, p["conv_w"], p["conv_b"],
                                            init_taps))
    delta, bmat, cmat = _ssm_params(p, xc, dt_rank, d_state)
    a = -jnp.exp(p["a_log"])                        # (d_inner, d_state)

    h0 = (jnp.zeros((b_, d_inner, d_state), jnp.float32)
          if state is None else state.h)

    def step(h, inp):
        xc_t, d_t, b_t, c_t = inp                  # (B,di) (B,di) (B,ds) (B,ds)
        da = jnp.exp(d_t[..., None] * a[None])      # (B, di, ds)
        dbx = (d_t * xc_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                      # (B, L, d_inner)
    y = y + p["d_skip"][None, None, :] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["out_proj"])
    if return_state:
        taps = jnp.concatenate([init_taps if init_taps is not None
                                else jnp.zeros((b_, d_conv - 1, d_inner), x.dtype),
                                xz[..., :d_inner]], axis=1)[:, -(d_conv - 1):, :]
        return out, MambaState(conv=taps.astype(jnp.bfloat16), h=h_final)
    return out


def mamba_decode(p, x, state: MambaState, *, d_model: int, expand: int = 2,
                 d_state: int = 16, d_conv: int = 4):
    """One-token decode. x (B, 1, D)."""
    d_inner, dt_rank, d_state, d_conv = mamba_dims(d_model, expand, d_state, d_conv)
    b_ = x.shape[0]
    xz = dense(x[:, 0, :], p["in_proj"])                  # (B, 2*di)
    xc_new, z = jnp.split(xz, 2, axis=-1)
    taps = jnp.concatenate([state.conv.astype(xc_new.dtype),
                            xc_new[:, None, :]], axis=1)   # (B, d_conv, di)
    xc = jnp.einsum("bkc,kc->bc", taps, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    delta, bmat, cmat = _ssm_params(p, xc, dt_rank, d_state)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(delta[..., None] * a[None])
    h = da * state.h + (delta * xc.astype(jnp.float32))[..., None] * bmat[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, cmat)
    y = y + p["d_skip"][None, :] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(y, p["out_proj"])[:, None, :]
    new_state = MambaState(conv=taps[:, 1:, :].astype(state.conv.dtype), h=h)
    return out, new_state
