"""Shared building blocks: norms, activations, MLPs, embeddings, RoPE —
plus the **lift-free delta context** (:class:`LowRankDelta` / :func:`dense`)
that lets a factored federated client run its forward/backward without ever
materializing ``base_scale·W + lift(R̃)`` or a dense ``m×n`` gradient."""
from __future__ import annotations

import contextlib
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..kernels import ops as kops


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


# ------------------------------------------------- lift-free delta context --
#
# A factored client's effective weight is W_eff = scale·W + lift(R̃, B): a
# rank-r delta around the broadcast base. Materializing W_eff costs an
# O(m·n·r) lift GEMM + an O(m·n) transient per target leaf per local step,
# and AD through it produces a dense m×n cotangent that the optimizer
# immediately re-projects to rank r. Neither needs to exist: a LowRankDelta
# *replaces the weight leaf itself* inside the loss closure, and every
# `x @ w`-style read routes through `dense()` /
# `__rmatmul__`, which computes the split-matmul apply
#
#   right (m ≥ n):  y = scale·(x@W) + (x@R̃)@Bᵀ        R̃ (m, r), B (n, r)
#   left  (m < n):  y = scale·(x@W) + (x@B)@R̃          B (m, r), R̃ (r, n)
#
# under a custom_vjp whose backward emits the cotangent for R̃ **already in
# rank-r coordinates** (right: xᵀ(∂y B); left: (xB)ᵀ∂y — never the dense
# xᵀ∂y) plus an exact dense-gradient norm probe for global-norm clipping.
# Being a pytree node, the context survives `lax.scan` over stacked layer
# params, vmap over clients, and remat — each transformation just maps the
# five fields. LoRA / dense methods never construct LowRankDelta leaves, so
# `dense(x, plain_array)` is exactly `x @ w` for them.

_LOWRANK_PALLAS_OVERRIDE = [None]   # None = auto (TPU backend only)


class lowrank_pallas_override:
    """Force the fused ``lowrank_linear`` kernel on/off inside ``dense``
    (None = auto: TPU only; tests force True to run the kernel in interpret
    mode). Usable as a context manager around tracing."""

    def __init__(self, flag):
        self.flag = flag

    def __enter__(self):
        _LOWRANK_PALLAS_OVERRIDE.append(self.flag)
        return self

    def __exit__(self, *exc):
        _LOWRANK_PALLAS_OVERRIDE.pop()
        return False


def _use_lowrank_pallas() -> bool:
    flag = _LOWRANK_PALLAS_OVERRIDE[-1]
    if flag is not None:
        return flag
    return jax.default_backend() == "tpu"


class LowRankDelta(NamedTuple):
    """A factored target leaf: the base weight plus its never-lifted rank-r
    delta. All five fields are pytree children (arrays), so the node slices
    cleanly under ``lax.scan`` over stacked (nb, m, n) layer params."""
    w: jnp.ndarray       # (..., m, n) broadcast base weight
    basis: jnp.ndarray   # (..., n, r) right | (..., m, r) left (orthonormal)
    rt: jnp.ndarray      # (..., m, r) right | (..., r, n) left — the delta R̃
    nsq: jnp.ndarray     # (...,) zeros — dense-grad ‖·‖² probe (cotangent out)
    scale: jnp.ndarray   # (...,) base_scale = (1-ηλ)^t

    @property
    def shape(self):
        return self.w.shape

    @property
    def dtype(self):
        return self.w.dtype

    @property
    def ndim(self):
        return self.w.ndim

    @property
    def side(self) -> str:
        """proj_type=std side rule on the ambient shape (right iff m >= n)."""
        m, n = self.w.shape[-2:]
        return "right" if m >= n else "left"

    def __rmatmul__(self, x):
        """``x @ delta_leaf`` — arbitrary losses work without edits."""
        return dense(x, self)

    def read(self):
        """Materialize the effective leaf ``scale·w + lift(rt)`` for
        non-matmul consumption (e.g. stacked bias blocks added to
        activations). The custom VJP still returns the rank-r cotangent and
        the exact norm probe — here the leaf is read directly, so the dense
        gradient IS the incoming cotangent and the probe is just ``‖∂y‖²``.
        The transient lift this reintroduces is O(dim·r) for the skinny
        leaves that take this path, not the O(m·n·r) projection lift."""
        return lowrank_read(self.side, self.w, self.basis, self.rt,
                            self.nsq, self.scale)

    def __add__(self, other):
        return self.read() + other

    def __radd__(self, other):
        return other + self.read()


def _lift(rt, basis, side):
    """project_back with leading batch dims (core.projector conventions,
    inlined to keep this module dependency-free of core)."""
    if side == "right":
        return jnp.einsum("...mr,...nr->...mn", rt, basis)
    return jnp.einsum("...mr,...rn->...mn", basis, rt)


def _project(g, basis, side):
    if side == "right":
        return jnp.einsum("...mn,...nr->...mr", g, basis)
    return jnp.einsum("...mr,...mn->...rn", basis, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def lowrank_read(side, w, basis, rt, nsq, scale):
    """Materialized delta-leaf read ``scale·w + lift(rt, basis)`` — the
    fallback for target leaves consumed other than by matmul. Backward:
    cotangent for ``rt`` arrives projected (``project(∂y, B)``), the norm
    probe is the exact ``‖∂y‖²`` (the dense gradient of a directly-read leaf
    is its own cotangent)."""
    del nsq
    lead = w.shape[:-2]
    s = jnp.asarray(scale, jnp.float32).reshape(lead + (1, 1))
    out = s * w.astype(jnp.float32) + _lift(rt.astype(jnp.float32),
                                            basis.astype(jnp.float32), side)
    return out.astype(w.dtype)


def _lowrank_read_fwd(side, w, basis, rt, nsq, scale):
    return lowrank_read(side, w, basis, rt, nsq, scale), (w, basis, rt, scale)


def _lowrank_read_bwd(side, res, dy):
    w, basis, rt, scale = res
    dy32 = dy.astype(jnp.float32)
    drt = _project(dy32, basis.astype(jnp.float32), side)
    dnsq = jnp.sum(dy32 * dy32, axis=(-2, -1))
    return (jnp.zeros_like(w), jnp.zeros_like(basis), drt, dnsq,
            jnp.zeros_like(scale))


lowrank_read.defvjp(_lowrank_read_fwd, _lowrank_read_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def lowrank_apply(side, use_pallas, x, w, basis, rt, nsq, scale):
    """The lift-free delta read: ``x @ (scale·w + lift(rt, basis))`` computed
    as split matmuls (fused Pallas kernel on TPU). ``nsq`` (zeros) is the
    norm probe: its cotangent is the exact squared Frobenius norm of the
    dense weight gradient ``xᵀ∂y`` — computed from token Grams, so
    global-norm clipping matches the transient-lift path bit-for-bit in
    exact arithmetic without the m×n cotangent ever existing. Caveat: AD
    sums the probe across *uses* of a leaf, so a weight read more than once
    per forward (e.g. MLA blockwise ``kv_b``, once per chunk) yields
    ``Σᵤ‖gᵤ‖²`` instead of the exact ``‖Σᵤgᵤ‖²`` — the sign-indefinite
    cross-use terms are missing, so it is neither a bound nor exact.
    ``make_fed_round_step`` gates such configurations (MLA + attn_chunk)
    off the lift-free path; every single-read weight is exact."""
    del nsq
    if use_pallas:
        return kops.lowrank_linear(x, w, basis, rt, scale, side=side)
    x32 = x.astype(jnp.float32)
    base = scale * (x32 @ w.astype(jnp.float32))
    b32 = basis.astype(jnp.float32)
    r32 = rt.astype(jnp.float32)
    delta = (x32 @ r32) @ b32.T if side == "right" else (x32 @ b32) @ r32
    return (base + delta).astype(jnp.result_type(x.dtype, w.dtype))


_SQNORM_TILE = 1024


def _sqnorm_gram(x2, dy2, tile: int = _SQNORM_TILE):
    """Exact ``‖x2ᵀ dy2‖²_F = Σᵢⱼ (x2 x2ᵀ)ᵢⱼ (dy2 dy2ᵀ)ᵢⱼ`` without the
    (m, n) product. Short token counts take one (t, t) Gram pair; longer
    ones scan over row tiles so the transient working set is O(nt·tile²)
    per step instead of O(t²) — the probe must never cost more memory than
    the m×n object it replaces. Zero-padding the tail tile is sound (zero
    rows contribute zero to both Grams)."""
    t, _ = x2.shape
    if t <= tile:
        return jnp.sum((x2 @ x2.T) * (dy2 @ dy2.T))
    nt = -(-t // tile)
    pad = nt * tile - t
    xp = jnp.pad(x2, ((0, pad), (0, 0))).reshape(nt, tile, -1)
    dyp = jnp.pad(dy2, ((0, pad), (0, 0))).reshape(nt, tile, -1)

    def row(acc, xi_dyi):
        xi, dyi = xi_dyi
        # all j-tiles against this i-tile in one batched contraction
        cx = jnp.einsum("tm,jsm->jts", xi, xp)
        cd = jnp.einsum("tn,jsn->jts", dyi, dyp)
        return acc + jnp.sum(cx * cd), None

    acc, _ = jax.lax.scan(row, jnp.zeros((), jnp.float32), (xp, dyp))
    return acc


def _lowrank_fwd(side, use_pallas, x, w, basis, rt, nsq, scale):
    y = lowrank_apply(side, use_pallas, x, w, basis, rt, nsq, scale)
    return y, (x, w, basis, rt, scale)


def _lowrank_bwd(side, use_pallas, res, dy):
    del use_pallas
    x, w, basis, rt, scale = res
    m, n = w.shape
    dy32 = dy.astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    b32 = basis.astype(jnp.float32)
    r32 = rt.astype(jnp.float32)
    # dx through the effective weight, split low-rank (never lift(rt)).
    if side == "right":
        dx = scale * (dy32 @ w.astype(jnp.float32).T) + (dy32 @ b32) @ r32.T
    else:
        dx = scale * (dy32 @ w.astype(jnp.float32).T) + (dy32 @ r32.T) @ b32.T
    # Projected cotangent for R̃ — rank-r coordinates, no dense xᵀ∂y:
    #   right: xᵀ(∂y B) (m, r);  left: (x B)ᵀ ∂y (r, n).
    x2 = x32.reshape((-1, m))
    dy2 = dy32.reshape((-1, n))
    if side == "right":
        drt = x2.T @ (dy2 @ b32)
    else:
        drt = (x2 @ b32).T @ dy2
    # Exact ‖xᵀ∂y‖²_F via token Grams: O(t²(m+n)) flops with t = tokens, no
    # m×n object, transients bounded by the token tile. DCE'd entirely when
    # the caller never reads the probe cotangent (clip_norm=None).
    dnsq = _sqnorm_gram(x2, dy2)
    # w / basis / scale are never differentiated by the lift-free step; the
    # zero cotangents exist only to satisfy the VJP signature and are dead
    # code after DCE (asserted GEMM-free by the shape-probe test).
    return (dx.astype(x.dtype), jnp.zeros_like(w), jnp.zeros_like(basis),
            drt, dnsq, jnp.zeros_like(scale))


lowrank_apply.defvjp(_lowrank_fwd, _lowrank_bwd)


# ------------------------------------------- multi-adapter serving context --
#
# The serving counterpart of LowRankDelta: one shared base weight plus a
# TABLE of G adapters' factors, where each row of the batch selects its own
# adapter by the (B,) ids operand installed via `adapter_ids(...)`. The
# forward is the same split-matmul apply as the training leaf — per row:
#
#   y[b] = scales[g]·(x[b] @ W) + split-matmul(x[b], bases[g], rts[g]),
#   g = ids[b]
#
# routed through the scalar-prefetch Pallas kernel on TPU (only the selected
# adapters' blocks are DMA'd from the (G, ·, r) tables) and a gather+einsum
# reference elsewhere. Forward-only by design: serving never differentiates
# the leaf. Ragged per-adapter ranks arrive zero-padded to the table's
# r_max (zero columns contribute exactly zero delta).

_ADAPTER_IDS = [None]   # (B,) int32 adapter index per batch row


@contextlib.contextmanager
def adapter_ids(ids):
    """Install the per-row adapter-id operand consumed by ``dense`` when it
    meets a :class:`MultiAdapterDelta` leaf. The ids array is traced state:
    enter inside the same jit/scan trace that runs the forward."""
    _ADAPTER_IDS.append(None if ids is None else jnp.asarray(ids, jnp.int32))
    try:
        yield
    finally:
        _ADAPTER_IDS.pop()


class MultiAdapterDelta(NamedTuple):
    """A served target leaf: broadcast base weight plus a G-adapter factor
    table. All fields are pytree children with a common leading stack axis
    where the ambient params are stacked — (nb, m, n) bases pair with
    (nb, G, dim, r) tables, so the node slices cleanly under the model's
    ``lax.scan`` over stacked layer params."""
    w: jnp.ndarray        # (..., m, n) shared base weight
    bases: jnp.ndarray    # (..., G, n, r) right | (..., G, m, r) left
    rts: jnp.ndarray      # (..., G, m, r) right | (..., G, r, n) left
    scales: jnp.ndarray   # (..., G) per-adapter base_scale

    @property
    def shape(self):
        return self.w.shape

    @property
    def dtype(self):
        return self.w.dtype

    @property
    def ndim(self):
        return self.w.ndim

    @property
    def side(self) -> str:
        m, n = self.w.shape[-2:]
        return "right" if m >= n else "left"

    def __rmatmul__(self, x):
        """``x @ leaf`` — decode projections (``x @ p["wq"]``) route here."""
        return dense(x, self)


def multi_adapter_apply(leaf: MultiAdapterDelta, x, ids):
    """Batched heterogeneous-adapter apply for one leaf. x (B, t, m) or
    (B, m); ids (B,). The leaf must be sliced to its per-layer view (2-D
    base) by the ambient scan before application."""
    if leaf.w.ndim != 2:
        raise ValueError(
            "multi-adapter leaf applied with a stacked base "
            f"{leaf.w.shape} — expected the scan-sliced per-layer view")
    if x.shape[0] != ids.shape[0]:
        raise ValueError(
            f"adapter ids cover {ids.shape[0]} rows but the batch has "
            f"{x.shape[0]} — one id per decode row is required")
    if _use_lowrank_pallas():
        return kops.lowrank_linear_batched(x, leaf.w, leaf.bases, leaf.rts,
                                           leaf.scales, ids, side=leaf.side)
    from ..kernels.ref import lowrank_linear_batched_ref
    return lowrank_linear_batched_ref(x, leaf.w, leaf.bases, leaf.rts,
                                      leaf.scales, ids, side=leaf.side)


def dense(x, w):
    """Delta-aware linear apply: ``x @ w`` for plain weights; the lift-free
    split-matmul read (projected-cotangent backward) when ``w`` is a
    :class:`LowRankDelta` leaf; the per-row heterogeneous-adapter apply when
    ``w`` is a :class:`MultiAdapterDelta` serving leaf (batch ids from the
    ambient :func:`adapter_ids` context). Model projections route through
    this so ``loss_fn(params, batch)`` signatures never change."""
    if isinstance(w, LowRankDelta):
        return lowrank_apply(w.side, _use_lowrank_pallas(), x, w.w, w.basis,
                             w.rt, w.nsq, w.scale)
    if isinstance(w, MultiAdapterDelta):
        ids = _ADAPTER_IDS[-1]
        if ids is None:
            raise ValueError(
                "MultiAdapterDelta leaf read outside an adapter_ids(...) "
                "context — the serving driver must install the per-row "
                "adapter ids around the forward")
        return multi_adapter_apply(w, x, ids)
    return x @ w


_BATCH_AXES_OVERRIDE = [None]   # None = use (pod, data) from the mesh


@contextlib.contextmanager
def batch_axes_override(axes):
    """Override (or disable, with ()) what 'batch' resolves to in constrain().

    The federated train step vmaps clients with ``spmd_axis_name`` pinning
    the CLIENT dim to the data axes; inner per-client batch constraints must
    then be disabled or they would claim the same mesh axes twice.
    """
    _BATCH_AXES_OVERRIDE.append(axes)
    try:
        yield
    finally:
        _BATCH_AXES_OVERRIDE.pop()


def constrain(x: jnp.ndarray, *spec):
    """Best-effort sharding constraint: 'batch' resolves to whichever of
    (pod, data) exist on the ambient mesh; 'model' must exist; no-op when
    tracing without a mesh (host-scale runs) or when a dim doesn't divide.

    These hints pin the batch dimension of attention intermediates — without
    them SPMD can replicate the (L, L) score tensors across the data axis
    (§Perf iteration B measured a 16× bytes regression from exactly that).
    """
    from jax.sharding import PartitionSpec
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        axis_names = mesh.axis_names
    except Exception:  # noqa: BLE001
        return x
    if "model" not in axis_names:
        return x
    if _BATCH_AXES_OVERRIDE[-1] is not None:
        batch_axes = tuple(_BATCH_AXES_OVERRIDE[-1])
    else:
        batch_axes = tuple(n for n in ("pod", "data") if n in axis_names)
    sizes = dict(mesh.shape)
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            s = batch_axes if batch_axes else None
        if s is not None:
            names = (s,) if isinstance(s, str) else tuple(s)
            total = 1
            for nm in names:
                total *= sizes[nm]
            if dim % total != 0:
                s = None
        resolved.append(s)
    try:
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*resolved))
    except Exception:  # noqa: BLE001
        return x


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_init(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def glu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype)}


def glu_mlp(p, x, act: str = "silu"):
    """Gated MLP (SwiGLU family) — llama/mistral/command-r style."""
    gate = ACTS[act](dense(x, p["w_gate"]))
    return dense(gate * dense(x, p["w_up"]), p["w_down"])


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(k2, (d_ff, d_model), dtype=dtype)}


def mlp(p, x, act: str = "gelu"):
    """Plain 2-layer MLP (starcoder2 / musicgen style)."""
    return dense(ACTS[act](dense(x, p["w_up"])), p["w_down"])


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) absolute."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Any-length sinusoidal embeddings (musicgen — no learned table)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
