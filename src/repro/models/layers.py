"""Shared building blocks: norms, activations, MLPs, embeddings, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


import contextlib

_BATCH_AXES_OVERRIDE = [None]   # None = use (pod, data) from the mesh


@contextlib.contextmanager
def batch_axes_override(axes):
    """Override (or disable, with ()) what 'batch' resolves to in constrain().

    The federated train step vmaps clients with ``spmd_axis_name`` pinning
    the CLIENT dim to the data axes; inner per-client batch constraints must
    then be disabled or they would claim the same mesh axes twice.
    """
    _BATCH_AXES_OVERRIDE.append(axes)
    try:
        yield
    finally:
        _BATCH_AXES_OVERRIDE.pop()


def constrain(x: jnp.ndarray, *spec):
    """Best-effort sharding constraint: 'batch' resolves to whichever of
    (pod, data) exist on the ambient mesh; 'model' must exist; no-op when
    tracing without a mesh (host-scale runs) or when a dim doesn't divide.

    These hints pin the batch dimension of attention intermediates — without
    them SPMD can replicate the (L, L) score tensors across the data axis
    (§Perf iteration B measured a 16× bytes regression from exactly that).
    """
    from jax.sharding import PartitionSpec
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        axis_names = mesh.axis_names
    except Exception:  # noqa: BLE001
        return x
    if "model" not in axis_names:
        return x
    if _BATCH_AXES_OVERRIDE[-1] is not None:
        batch_axes = tuple(_BATCH_AXES_OVERRIDE[-1])
    else:
        batch_axes = tuple(n for n in ("pod", "data") if n in axis_names)
    sizes = dict(mesh.shape)
    resolved = []
    for dim, s in zip(x.shape, spec):
        if s == "batch":
            s = batch_axes if batch_axes else None
        if s is not None:
            names = (s,) if isinstance(s, str) else tuple(s)
            total = 1
            for nm in names:
                total *= sizes[nm]
            if dim % total != 0:
                s = None
        resolved.append(s)
    try:
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*resolved))
    except Exception:  # noqa: BLE001
        return x


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_init(d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def glu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(k3, (d_ff, d_model), dtype=dtype)}


def glu_mlp(p, x, act: str = "silu"):
    """Gated MLP (SwiGLU family) — llama/mistral/command-r style."""
    gate = ACTS[act](x @ p["w_gate"])
    return (gate * (x @ p["w_up"])) @ p["w_down"]


def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_up": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "w_down": dense_init(k2, (d_ff, d_model), dtype=dtype)}


def mlp(p, x, act: str = "gelu"):
    """Plain 2-layer MLP (starcoder2 / musicgen style)."""
    return ACTS[act](x @ p["w_up"]) @ p["w_down"]


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) absolute."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Any-length sinusoidal embeddings (musicgen — no learned table)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
