"""Attention: GQA (full / sliding-window causal) and MLA (DeepSeek-V2).

Decode uses a ring-buffer KV cache (size = window for sliding-window archs,
so long_500k decode keeps O(window) memory). MLA decode uses the *absorbed*
form: scores and context are computed in the compressed kv_lora space, so the
per-token cache is (kv_lora + rope_dim) — the whole point of MLA.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, constrain, dense, dense_init

NEG_INF = -1e30


# ------------------------------------------------------------------- GQA ----

def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
         "wk": dense_init(ks[1], (d_model, n_kv * head_dim), dtype=dtype),
         "wv": dense_init(ks[2], (d_model, n_kv * head_dim), dtype=dtype),
         "wo": dense_init(ks[3], (n_heads * head_dim, d_model), dtype=dtype)}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv * head_dim,), dtype)
    return p


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                window: int = 0) -> jnp.ndarray:
    """(..., Lq, Lk) boolean mask: attend iff k_pos <= q_pos and, for
    sliding-window attention, q_pos - k_pos < window."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    mask = diff >= 0
    if window:
        mask = mask & (diff < window)
    return mask


def attend(q, k, v, mask) -> jnp.ndarray:
    """q (B,Lq,H,hd), k/v (B,Lk,Hkv,hd) with H % Hkv == 0; mask (B|1,Lq,Lk).

    Matmuls take bf16 operands with fp32 accumulation
    (``preferred_element_type``) — no materialized fp32 copy of K/V, which
    matters enormously when K/V is a 32k-slot decode cache (§Perf iteration:
    removing the cache-sized converts cut the decode memory term ~2×).
    Softmax stays fp32; the probabilities are cast back to the value dtype.
    """
    b, lq, h, hd = q.shape
    hkv = k.shape[2]
    groups = h // hkv
    qg = q.reshape(b, lq, hkv, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return ctx.reshape(b, lq, h, hd).astype(q.dtype)


def blockwise_attend(q, k, v, *, window=0, chunk_q=2048, chunk_k=2048,
                     q_start=0) -> jnp.ndarray:
    """Flash-style blockwise causal attention in pure XLA (§Perf iteration B).

    Both the query and key sequences are chunked; (q-chunk, k-chunk) pairs
    that are *entirely* masked — future blocks under causality, stale blocks
    under a sliding window — are skipped STATICALLY, so the saved FLOPs and
    bytes are real in the compiled HLO (≈2× for causal, window/L for SWA).
    Per-pair online-softmax statistics keep the working set at
    (B, H, chunk_q, chunk_k); the full (L, L) score tensor never exists.
    The Pallas kernel (kernels/flash_attention.py) is the TPU-native twin of
    this computation with explicit VMEM tiling.
    """
    b, lq, h, hd = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    cq, ck = min(chunk_q, lq), min(chunk_k, lk)
    assert lq % cq == 0 and lk % ck == 0
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, lq, hkv, g, hd)

    outs = []
    for qi in range(lq // cq):
        q_blk = qg[:, qi * cq:(qi + 1) * cq]
        q_lo = q_start + qi * cq
        q_hi = q_lo + cq - 1
        m_i = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l_i = jnp.zeros((b, hkv, g, cq), jnp.float32)
        acc = jnp.zeros((b, hkv, g, cq, hd), jnp.float32)
        for ki in range(lk // ck):
            k_lo, k_hi = ki * ck, ki * ck + ck - 1
            if k_lo > q_hi:
                continue                      # fully in the future
            if window and k_hi < q_lo - window + 1:
                continue                      # fully outside the window
            k_blk = k[:, k_lo:k_lo + ck]
            v_blk = v[:, k_lo:k_lo + ck]
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            crosses_causal = k_hi > q_lo
            crosses_window = window and k_lo < q_hi - window + 1
            if crosses_causal or crosses_window:
                qp = q_lo + jnp.arange(cq)
                kp = k_lo + jnp.arange(ck)
                mask = causal_mask(qp, kp, window)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l_i = alpha * l_i + jnp.sum(p_, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p_.astype(v.dtype), v_blk,
                preferred_element_type=jnp.float32)
            m_i = m_new
        out = acc / jnp.maximum(l_i, 1e-30)[..., None]
        outs.append(out)
    full = jnp.concatenate(outs, axis=3)      # (b, hkv, g, lq, hd)
    return full.transpose(0, 3, 1, 2, 4).reshape(b, lq, h, hd).astype(q.dtype)


def gqa_forward(p, x, positions, *, n_heads, n_kv, head_dim, rope=True,
                rope_theta=1e4, window=0, attn_chunk=0):
    """Training/prefill attention over a full sequence. x (B,L,D)."""
    b, l, _ = x.shape
    q = dense(x, p["wq"]) + p.get("bq", 0)
    k = dense(x, p["wk"]) + p.get("bk", 0)
    v = dense(x, p["wv"]) + p.get("bv", 0)
    q = constrain(_split_heads(q, n_heads, head_dim),
                  "batch", None, "model", None)
    k = constrain(_split_heads(k, n_kv, head_dim),
                  "batch", None, "model", None)
    v = constrain(_split_heads(v, n_kv, head_dim),
                  "batch", None, "model", None)
    if rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if attn_chunk and l >= attn_chunk:
        c = min(attn_chunk, l // 2)
        ctx = blockwise_attend(q, k, v, window=window, chunk_q=c, chunk_k=c)
    else:
        mask = causal_mask(positions, positions, window)
        if mask.ndim == 2:
            mask = mask[None]
        ctx = attend(q, k, v, mask)
    ctx = constrain(ctx, "batch", None, "model", None)
    return dense(ctx.reshape(b, l, n_heads * head_dim), p["wo"]), (k, v)


class KVCache(NamedTuple):
    k: jnp.ndarray      # (B, S, Hkv, hd)
    v: jnp.ndarray      # (B, S, Hkv, hd)
    pos: jnp.ndarray    # (B, S) absolute position of each slot, -1 = empty


def kv_cache_init(batch: int, size: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(k=jnp.zeros((batch, size, n_kv, head_dim), dtype),
                   v=jnp.zeros((batch, size, n_kv, head_dim), dtype),
                   pos=jnp.full((batch, size), -1, jnp.int32))


def kv_cache_write(cache: KVCache, k_new, v_new, t0) -> KVCache:
    """Ring-buffer write of (B, Ln, Hkv, hd) starting at absolute pos t0.

    ``t0`` scalar: every row writes the same slots (the homogeneous decode
    batch — unchanged fast path). ``t0`` (B,): per-row start positions, the
    continuous-batching layout where each slot sits at its own depth."""
    b, ln = k_new.shape[:2]
    size = cache.k.shape[1]
    if jnp.ndim(t0):
        pos = t0[:, None] + jnp.arange(ln)[None, :]          # (B, Ln)
        slots = pos % size
        rows = jnp.arange(b)[:, None]
        k = cache.k.at[rows, slots].set(k_new.astype(cache.k.dtype))
        v = cache.v.at[rows, slots].set(v_new.astype(cache.v.dtype))
        p = cache.pos.at[rows, slots].set(pos.astype(jnp.int32))
        return KVCache(k=k, v=v, pos=p)
    pos = t0 + jnp.arange(ln)
    slots = pos % size
    k = cache.k.at[:, slots].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[:, slots].set(v_new.astype(cache.v.dtype))
    p = cache.pos.at[:, slots].set(jnp.broadcast_to(pos, (b, ln)).astype(jnp.int32))
    return KVCache(k=k, v=v, pos=p)


def gqa_decode(p, x, cache: KVCache, t, *, n_heads, n_kv, head_dim,
               rope=True, rope_theta=1e4, window=0):
    """One-token decode. x (B,1,D); t scalar absolute position, or (B,)
    per-row positions (continuous-batching slots at different depths)."""
    b = x.shape[0]
    q = x @ p["wq"] + p.get("bq", 0)
    k = x @ p["wk"] + p.get("bk", 0)
    v = x @ p["wv"] + p.get("bv", 0)
    q = _split_heads(q, n_heads, head_dim)
    k = _split_heads(k, n_kv, head_dim)
    v = _split_heads(v, n_kv, head_dim)
    pos1 = (t[:, None].astype(jnp.int32) if jnp.ndim(t)
            else jnp.full((1,), t, jnp.int32))
    if rope:
        q = apply_rope(q, pos1, rope_theta)
        k = apply_rope(k, pos1, rope_theta)
    cache = kv_cache_write(cache, k, v, t)
    q_pos = jnp.broadcast_to(pos1, (b, 1))
    mask = causal_mask(q_pos, cache.pos, window) & (cache.pos[:, None, :] >= 0)
    ctx = attend(q, cache.k, cache.v, mask)
    return ctx.reshape(b, 1, n_heads * head_dim) @ p["wo"], cache


# ------------------------------------------------------------------- MLA ----

def mla_init(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             qk_nope: int, qk_rope: int, v_dim: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return {
        "q_a": dense_init(ks[0], (d_model, q_lora), dtype=dtype),
        "q_a_norm": jnp.ones((q_lora,), dtype),
        "q_b": dense_init(ks[1], (q_lora, n_heads * (qk_nope + qk_rope)), dtype=dtype),
        "kv_a": dense_init(ks[2], (d_model, kv_lora + qk_rope), dtype=dtype),
        "kv_a_norm": jnp.ones((kv_lora,), dtype),
        "kv_b": dense_init(ks[3], (kv_lora, n_heads * (qk_nope + v_dim)), dtype=dtype),
        "wo": dense_init(ks[4], (n_heads * v_dim, d_model), dtype=dtype),
    }


def _mla_qkv(p, x, positions, n_heads, qk_nope, qk_rope, kv_lora, rope_theta):
    from .layers import rms_norm
    b, l, _ = x.shape
    q = dense(rms_norm(dense(x, p["q_a"]), p["q_a_norm"]), p["q_b"])
    q = constrain(q.reshape(b, l, n_heads, qk_nope + qk_rope),
                  "batch", None, "model", None)
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = apply_rope(q_pe, positions, rope_theta)

    kv = dense(x, p["kv_a"])
    c_kv = constrain(rms_norm(kv[..., :kv_lora], p["kv_a_norm"]),
                     "batch", None, None)                  # (B,L,kv_lora)
    k_pe = kv[..., kv_lora:][:, :, None, :]                 # (B,L,1,rope)
    k_pe = apply_rope(k_pe, positions, rope_theta)[:, :, 0]  # (B,L,rope)
    return q_nope, q_pe, c_kv, k_pe


def mla_forward(p, x, positions, *, n_heads, qk_nope, qk_rope, kv_lora,
                v_dim, rope_theta=1e4, window=0, attn_chunk=0):
    """Training/prefill MLA with expanded K/V (compute-friendly at long Lq).

    With ``attn_chunk`` the KV expansion happens PER CHUNK inside the
    blockwise loop — the full (B, L, H, d) expanded K/V tensors (128 heads!)
    are never materialized, and causally-dead blocks are skipped statically.
    """
    b, l, _ = x.shape
    q_nope, q_pe, c_kv, k_pe = _mla_qkv(p, x, positions, n_heads, qk_nope,
                                        qk_rope, kv_lora, rope_theta)
    scale = 1.0 / jnp.sqrt(qk_nope + qk_rope).astype(jnp.float32)

    if attn_chunk and l >= attn_chunk:
        ctx = _mla_blockwise(q_nope, q_pe, c_kv, k_pe, p["kv_b"], qk_nope,
                             scale, window, min(attn_chunk, l // 2))
    else:
        # Expand on the activation side (kv_b consumed as one delta-aware
        # matmul, then reshape/split the result — identical per-element dots
        # to the weight-side reshape + einsum it replaces).
        kv_full = dense(c_kv, p["kv_b"]).reshape(b, l, n_heads,
                                                 qk_nope + v_dim)
        k_nope = kv_full[..., :qk_nope]
        v = kv_full[..., qk_nope:]
        scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhd,bkd->bhqk", q_pe, k_pe,
                               preferred_element_type=jnp.float32)) * scale
        mask = causal_mask(positions, positions, window)
        if mask.ndim == 2:
            mask = mask[None]
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    out = dense(ctx.reshape(b, l, n_heads * v_dim), p["wo"])
    return out, (c_kv, k_pe)


def _mla_blockwise(q_nope, q_pe, c_kv, k_pe, kv_b, qk_nope, scale, window,
                   chunk):
    b, lq, h, _ = q_nope.shape
    v_dim = kv_b.shape[-1] // h - qk_nope
    cq = ck = min(chunk, lq)
    outs = []
    for qi in range(lq // cq):
        qn_blk = q_nope[:, qi * cq:(qi + 1) * cq]
        qp_blk = q_pe[:, qi * cq:(qi + 1) * cq]
        q_lo, q_hi = qi * cq, qi * cq + cq - 1
        m_i = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l_i = jnp.zeros((b, h, cq), jnp.float32)
        acc = jnp.zeros((b, h, cq, v_dim), jnp.float32)
        for ki in range(lq // ck):
            k_lo, k_hi = ki * ck, ki * ck + ck - 1
            if k_lo > q_hi:
                continue                       # fully in the future
            if window and k_hi < q_lo - window + 1:
                continue                       # fully outside the window
            ckv_blk = c_kv[:, k_lo:k_lo + ck]
            kpe_blk = k_pe[:, k_lo:k_lo + ck]
            # Per-chunk activation-side expansion (kv_b may be a lift-free
            # LowRankDelta; note the per-chunk reads make the clip-norm
            # probe a per-use sum — see models.layers).
            kv_blk = dense(ckv_blk, kv_b).reshape(b, ck, h, qk_nope + v_dim)
            k_nope_blk = kv_blk[..., :qk_nope]
            v_blk = kv_blk[..., qk_nope:]
            s = (jnp.einsum("bqhd,bshd->bhqs", qn_blk, k_nope_blk,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bqhd,bsd->bhqs", qp_blk, kpe_blk,
                              preferred_element_type=jnp.float32)) * scale
            if k_hi > q_lo or (window and k_lo < q_hi - window + 1):
                mask = causal_mask(q_lo + jnp.arange(cq),
                                   k_lo + jnp.arange(ck), window)
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_i - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l_i = alpha * l_i + jnp.sum(p_, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p_.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            m_i = m_new
        outs.append(acc / jnp.maximum(l_i, 1e-30)[..., None])
    full = jnp.concatenate(outs, axis=2)          # (b, h, lq, v_dim)
    return full.transpose(0, 2, 1, 3).astype(q_nope.dtype)


class MLACache(NamedTuple):
    ckv: jnp.ndarray    # (B, S, kv_lora)
    kpe: jnp.ndarray    # (B, S, rope_dim)
    pos: jnp.ndarray    # (B, S)


def mla_cache_init(batch: int, size: int, kv_lora: int, rope_dim: int,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(ckv=jnp.zeros((batch, size, kv_lora), dtype),
                    kpe=jnp.zeros((batch, size, rope_dim), dtype),
                    pos=jnp.full((batch, size), -1, jnp.int32))


def mla_cache_write(cache: MLACache, c_kv, k_pe, t0) -> MLACache:
    b, ln = c_kv.shape[:2]
    size = cache.ckv.shape[1]
    if jnp.ndim(t0):
        pos = t0[:, None] + jnp.arange(ln)[None, :]          # (B, Ln)
        slots = pos % size
        rows = jnp.arange(b)[:, None]
        return MLACache(
            ckv=cache.ckv.at[rows, slots].set(c_kv.astype(cache.ckv.dtype)),
            kpe=cache.kpe.at[rows, slots].set(k_pe.astype(cache.kpe.dtype)),
            pos=cache.pos.at[rows, slots].set(pos.astype(jnp.int32)))
    pos = t0 + jnp.arange(ln)
    slots = pos % size
    return MLACache(
        ckv=cache.ckv.at[:, slots].set(c_kv.astype(cache.ckv.dtype)),
        kpe=cache.kpe.at[:, slots].set(k_pe.astype(cache.kpe.dtype)),
        pos=cache.pos.at[:, slots].set(
            jnp.broadcast_to(pos, (b, ln)).astype(jnp.int32)))


def mla_decode(p, x, cache: MLACache, t, *, n_heads, qk_nope, qk_rope,
               kv_lora, v_dim, rope_theta=1e4, window=0):
    """Absorbed-form single-token MLA decode: attention runs entirely in the
    compressed space — per-step FLOPs O(H·S·(kv_lora + rope)) and the cache
    stores only (kv_lora + rope) per position. ``t`` scalar, or (B,)
    per-row positions for continuous-batching slots."""
    b = x.shape[0]
    pos1 = (t[:, None].astype(jnp.int32) if jnp.ndim(t)
            else jnp.full((1,), t, jnp.int32))
    q_nope, q_pe, c_kv_new, k_pe_new = _mla_qkv(
        p, x, pos1, n_heads, qk_nope, qk_rope, kv_lora, rope_theta)
    cache = mla_cache_write(cache, c_kv_new, k_pe_new, t)

    kvb = p["kv_b"].reshape(kv_lora, n_heads, qk_nope + v_dim)
    w_uk, w_uv = kvb[..., :qk_nope], kvb[..., qk_nope:]
    # Absorb W_uk into the query:  q_c[b,h,c] = Σ_d q_nope[b,h,d] W_uk[c,h,d]
    q_c = jnp.einsum("bqhd,chd->bqhc", q_nope, w_uk,
                     preferred_element_type=jnp.float32)
    scale = 1.0 / jnp.sqrt(qk_nope + qk_rope).astype(jnp.float32)
    # Mixed-dtype dots with fp32 accumulation: the CACHE operand stays in
    # its storage dtype (never materializing an fp32 copy of 32k slots); the
    # small query-side operands stay fp32 (CPU's DotThunk lacks some
    # bf16xbf16 contractions, and the bytes live in the cache side anyway).
    scores = (jnp.einsum("bqhc,bsc->bhqs", q_c,
                         cache.ckv, preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_pe.astype(jnp.float32),
                           cache.kpe, preferred_element_type=jnp.float32)
              ) * scale
    q_pos = jnp.broadcast_to(pos1, (b, 1))
    mask = causal_mask(q_pos, cache.pos, window) & (cache.pos[:, None, :] >= 0)
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhqs,bsc->bqhc", w, cache.ckv,
                       preferred_element_type=jnp.float32)
    # Absorb W_uv on the way out.
    ctx = jnp.einsum("bqhc,chd->bqhd", ctx_c, w_uv.astype(jnp.float32))
    out = ctx.reshape(b, 1, n_heads * v_dim).astype(x.dtype) @ p["wo"]
    return out, cache
