"""RWKV6 ("Finch") — attention-free token mixing with data-dependent decay.

Time-mix per head (size 64): state S ∈ R^{dk×dv} evolves as

    y_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

where the decay w_t = exp(-exp(w_base + lora(x̄_t))) is *data-dependent*
(the RWKV6 innovation vs RWKV5's static decay). Token shift uses the
data-dependent lerp (ddlerp) between x_t and x_{t-1}. Channel-mix is the
squared-ReLU RWKV FFN. Train/prefill scan over time with an O(dk·dv) carry;
decode is a single recurrence step — O(1) in sequence length, which is what
makes long_500k native for this arch.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

HEAD_SIZE = 64
DDLERP_DIM = 32
DECAY_DIM = 64


def rwkv_heads(d_model: int) -> int:
    assert d_model % HEAD_SIZE == 0
    return d_model // HEAD_SIZE


def time_mix_init(key, d_model: int, dtype=jnp.float32):
    h = rwkv_heads(d_model)
    ks = jax.random.split(key, 10)
    return {
        # static token-shift lerp weights for (r, k, v, g, w)
        "mu": 0.5 * jnp.ones((5, d_model), jnp.float32),
        # ddlerp low-rank dynamic adjustment
        "maa_w1": dense_init(ks[0], (d_model, 5 * DDLERP_DIM), dtype=dtype),
        "maa_w2": dense_init(ks[1], (5, DDLERP_DIM, d_model), scale=0.02,
                             dtype=dtype),
        "wr": dense_init(ks[2], (d_model, d_model), dtype=dtype),
        "wk": dense_init(ks[3], (d_model, d_model), dtype=dtype),
        "wv": dense_init(ks[4], (d_model, d_model), dtype=dtype),
        "wg": dense_init(ks[5], (d_model, d_model), dtype=dtype),
        "wo": dense_init(ks[6], (d_model, d_model), dtype=dtype),
        # data-dependent decay: w_t = exp(-exp(base + lora))
        "decay_base": -6.0 * jnp.ones((d_model,), jnp.float32),
        "decay_w1": dense_init(ks[7], (d_model, DECAY_DIM), dtype=dtype),
        "decay_w2": dense_init(ks[8], (DECAY_DIM, d_model), scale=0.02,
                               dtype=dtype),
        "bonus_u": jnp.zeros((h, HEAD_SIZE), jnp.float32),
        "ln_x": jnp.ones((d_model,), jnp.float32),
    }


def channel_mix_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"mu": 0.5 * jnp.ones((2, d_model), jnp.float32),
            "wk": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "wv": dense_init(ks[1], (d_ff, d_model), dtype=dtype),
            "wr": dense_init(ks[2], (d_model, d_model), dtype=dtype)}


class RwkvState(NamedTuple):
    shift_t: jnp.ndarray   # (B, D) previous token input to time-mix
    shift_c: jnp.ndarray   # (B, D) previous token input to channel-mix
    wkv: jnp.ndarray       # (B, H, dk, dv) fp32 recurrent state


def rwkv_state_init(batch: int, d_model: int, dtype=jnp.bfloat16) -> RwkvState:
    h = rwkv_heads(d_model)
    return RwkvState(shift_t=jnp.zeros((batch, d_model), dtype),
                     shift_c=jnp.zeros((batch, d_model), dtype),
                     wkv=jnp.zeros((batch, h, HEAD_SIZE, HEAD_SIZE), jnp.float32))


def _shifted(x, prev):
    """x (B, L, D) -> x_{t-1} with ``prev`` (B, D) as the t=0 predecessor."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, x_prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,g,w).

    fp32 on purpose: a bf16 variant was tried in the §Perf loop and REFUTED —
    the inserted converts and layout copies cost more bytes than the halved
    element size saved (1.07e12 → 1.36e12 B/step/device on rwkv6@train_4k).
    """
    dx = (x_prev - x).astype(jnp.float32)
    base = x.astype(jnp.float32) + dx * p["mu"][:, None, None, :]  # (5,B,L,D)
    dyn = jnp.tanh((x + 0.5 * dx).astype(jnp.float32) @ p["maa_w1"])
    dyn = dyn.reshape(x.shape[:-1] + (5, DDLERP_DIM))
    adj = jnp.einsum("blfd,fdm->fblm", dyn, p["maa_w2"].astype(jnp.float32))
    return base + dx[None] * adj                                   # (5,B,L,D)


def _group_norm_heads(x, scale, h):
    """Per-head RMS normalization of the wkv output. x (B, L, D)."""
    b, l, d = x.shape
    xh = x.reshape(b, l, h, HEAD_SIZE).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(b, l, d) * scale).astype(x.dtype)


def time_mix_forward(p, x, state: RwkvState, d_model: int,
                     return_state: bool = False):
    """x (B, L, D). Scan over time with (B, H, dk, dv) carry."""
    h = rwkv_heads(d_model)
    b, l, d = x.shape
    x_prev = _shifted(x, state.shift_t)
    xr, xk, xv, xg, xw = _ddlerp(p, x, x_prev)      # each (B, L, D) fp32

    r = dense(xr.astype(x.dtype), p["wr"]).reshape(b, l, h, HEAD_SIZE)
    k = dense(xk.astype(x.dtype), p["wk"]).reshape(b, l, h, HEAD_SIZE)
    v = dense(xv.astype(x.dtype), p["wv"]).reshape(b, l, h, HEAD_SIZE)
    g = jax.nn.silu(dense(xg.astype(x.dtype), p["wg"]))
    decay = p["decay_base"] + jnp.tanh(xw @ p["decay_w1"].astype(jnp.float32)) \
        @ p["decay_w2"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(b, l, h, HEAD_SIZE)       # (0,1)
    u = p["bonus_u"]

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                    # (B,H,hs) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       s + u[None, :, :, None] * kv)
        s = w_t.astype(jnp.float32)[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_final, ys = jax.lax.scan(step, state.wkv, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, l, d)
    y = _group_norm_heads(y.astype(x.dtype), p["ln_x"], h)
    out = dense(y * g.astype(y.dtype), p["wo"])
    if return_state:
        return out, state._replace(shift_t=x[:, -1, :], wkv=s_final)
    return out


def channel_mix_forward(p, x, state: RwkvState, return_state: bool = False):
    x_prev = _shifted(x, state.shift_c)
    dx = (x_prev - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + dx * p["mu"][0][None, None, :]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + dx * p["mu"][1][None, None, :]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"])))
    out = jax.nn.sigmoid(dense(xr, p["wr"])) * dense(k, p["wv"])
    if return_state:
        return out, state._replace(shift_c=x[:, -1, :])
    return out
