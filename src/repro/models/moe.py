"""Mixture-of-Experts with TPU-native sort-based dispatch.

Routing uses softmax-then-top-k with renormalization. Dispatch avoids
all_to_all in the baseline implementation: assignments are sorted by expert,
tokens are gathered into a dense (E, C, D) buffer (capacity-dropping), expert
GLU MLPs run as one batched einsum over the expert axis — which shards
naturally over the `model` mesh axis (expert parallelism) — and results
scatter-add back weighted by the gates. Shared experts (DeepSeek-V2 style)
run densely over all tokens.

An auxiliary load-balance loss (mean fraction·prob product, Switch-style) is
returned for the training objective.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ACTS, constrain, dense_init


def moe_init(key, d_model: int, n_experts: int, d_ff: int,
             n_shared: int = 0, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], (d_model, n_experts), dtype=jnp.float32),
         "w_gate": dense_init(ks[1], (n_experts, d_model, d_ff), dtype=dtype),
         "w_up": dense_init(ks[2], (n_experts, d_model, d_ff), dtype=dtype),
         "w_down": dense_init(ks[3], (n_experts, d_ff, d_model), dtype=dtype)}
    if n_shared:
        sdff = shared_d_ff or (n_shared * d_ff)
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {"w_gate": dense_init(kk[0], (d_model, sdff), dtype=dtype),
                       "w_up": dense_init(kk[1], (d_model, sdff), dtype=dtype),
                       "w_down": dense_init(kk[2], (sdff, d_model), dtype=dtype)}
    return p


def capacity(n_tokens: int, n_experts: int, k: int,
             capacity_factor: float = 1.25, multiple: int = 8) -> int:
    c = int(math.ceil(n_tokens * k * capacity_factor / n_experts))
    c = max(c, k, 1)
    return int(math.ceil(c / multiple) * multiple)


def route(router_w, x, k: int):
    """x (N, D) -> gates (N, k), experts (N, k), aux load-balance loss."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (N, E)
    top_vals, top_idx = jax.lax.top_k(probs, k)
    gates = top_vals / (jnp.sum(top_vals, axis=-1, keepdims=True) + 1e-9)
    n_experts = router_w.shape[1]
    # Switch-style aux loss: E * Σ_e f_e · p_e
    assign_frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, n_experts), axis=1), axis=0)
    prob_frac = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(assign_frac * prob_frac)
    return gates, top_idx, aux


def dispatch_gather(x, top_idx, cap: int, n_experts: int):
    """Sort-based capacity dispatch, GATHER-only construction.

    After sorting assignments by expert, expert e's tokens occupy the
    contiguous range [starts[e], ends[e]); slot c of expert e is simply
    sorted position starts[e] + c. The (E, C, D) buffer is then one gather —
    no 3-D scatter (§Perf iteration A: the scatter lowering materialized a
    buffer-sized u32 index shadow plus an (N·k, D) select; gather-based
    dispatch removed both).

    x (N, D), top_idx (N, k) -> buffer (E, C, D) + bookkeeping
    (tok (E, C) source-token map with N = padding sentinel, valid (E, C)).
    """
    n, k = top_idx.shape
    flat_expert = top_idx.reshape(-1)                    # (N*k,)
    token_id = jnp.repeat(jnp.arange(n), k)              # (N*k,)
    order = jnp.argsort(flat_expert)                     # stable
    sorted_expert = flat_expert[order]
    sorted_token = token_id[order]
    starts = jnp.searchsorted(sorted_expert, jnp.arange(n_experts))
    ends = jnp.searchsorted(sorted_expert, jnp.arange(n_experts),
                            side="right")
    j = starts[:, None] + jnp.arange(cap)[None, :]       # (E, C) sorted pos
    valid = j < ends[:, None]
    j_safe = jnp.where(valid, j, n * k)                  # sentinel = pad row
    tok = jnp.where(valid, sorted_token[jnp.where(valid, j, 0)], n)
    x_pad = jnp.concatenate([x, jnp.zeros((1, x.shape[-1]), x.dtype)])
    buf = x_pad[tok]                                     # (E, C, D) gather
    return buf, (order, tok, j_safe, valid)


def combine_scatter(expert_out, bookkeeping, gates, n_tokens: int):
    """Weighted scatter-add of expert outputs back to token positions."""
    order, tok, j_safe, valid = bookkeeping
    flat_gates = gates.reshape(-1)[order]                # (N*k,) sorted order
    gates_pad = jnp.concatenate([flat_gates,
                                 jnp.zeros((1,), flat_gates.dtype)])
    gate_ec = gates_pad[j_safe]                          # (E, C), 0 at pads
    weighted = expert_out * gate_ec[..., None].astype(expert_out.dtype)
    out = jnp.zeros((n_tokens + 1, expert_out.shape[-1]), expert_out.dtype)
    out = out.at[tok].add(weighted)
    return out[:n_tokens]


def moe_forward(p, x, *, k: int, act: str = "silu",
                capacity_factor: float = 1.25):
    """x (B, L, D) -> (B, L, D), aux_loss."""
    b, l, d = x.shape
    n = b * l
    xf = x.reshape(n, d)
    n_experts = p["router"].shape[1]
    gates, top_idx, aux = route(p["router"], xf, k)
    cap = capacity(n, n_experts, k, capacity_factor)
    buf, book = dispatch_gather(xf, top_idx, cap, n_experts)
    # Expert-parallel anchor: dispatch buffers shard over the model axis so
    # the batched expert GLUs run as true expert parallelism (the sort/scatter
    # dispatch ops otherwise break sharding propagation).
    buf = constrain(buf, "model", None, None)
    # Batched expert GLU: (E,C,D)@(E,D,F) -> (E,C,F)
    gate_h = ACTS[act](jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    up_h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_e = constrain(jnp.einsum("ecf,efd->ecd", gate_h * up_h, p["w_down"]),
                      "model", None, None)
    out = combine_scatter(out_e, book, gates.astype(out_e.dtype), n)
    out = constrain(out, "batch", None)
    if "shared" in p:
        from .layers import glu_mlp
        out = out + glu_mlp(p["shared"], xf, act)
    return out.reshape(b, l, d), aux
