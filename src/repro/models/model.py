"""Config-driven decoder model: one implementation covering all ten assigned
architectures (dense GQA, MoE, MLA+MoE, Mamba/attention hybrid, RWKV6,
VLM/audio backbones).

Layers are grouped into the config's repeating block (``block_period``) and
executed with ``lax.scan`` over stacked block parameters — compile time stays
flat in depth (72-layer Jamba lowers as one scanned block of 8), and
activation rematerialization wraps the scanned body.

Three entry points, matching the input-shape matrix:
  * ``loss_fn``      — next-token CE training step objective (train_4k)
  * ``prefill``      — full-sequence forward that fills decode caches (prefill_32k)
  * ``decode_step``  — one token with KV cache / recurrent state
                       (decode_32k, long_500k)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import mamba as mamba_lib
from . import moe as moe_lib
from . import rwkv as rwkv_lib
from .layers import (apply_norm, constrain, dense_init, glu_mlp, glu_mlp_init,
                     mlp, mlp_init, norm_init, sinusoidal_positions)
from ..configs.base import ArchConfig

PyTree = Any


# ------------------------------------------------------------------ init ----

def _init_layer(key, cfg: ArchConfig, mix: str, ffn: str) -> Dict:
    dtype = cfg.param_dtype
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.d_model, cfg.norm)}
    if mix == "attn":
        p["attn"] = attn_lib.gqa_init(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd, cfg.qkv_bias,
                                      dtype)
    elif mix == "mla":
        p["attn"] = attn_lib.mla_init(
            ks[0], cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank, qk_nope=cfg.qk_nope_dim,
            qk_rope=cfg.qk_rope_dim, v_dim=cfg.v_head_dim, dtype=dtype)
    elif mix == "mamba":
        p["mamba"] = mamba_lib.mamba_init(
            ks[0], cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv, dtype=dtype)
    elif mix == "rwkv":
        p["tmix"] = rwkv_lib.time_mix_init(ks[0], cfg.d_model, dtype)

    p["norm2"] = norm_init(cfg.d_model, cfg.norm)
    if ffn == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], cfg.d_model, cfg.n_experts,
                                    cfg.moe_d_ff or cfg.d_ff,
                                    cfg.n_shared_experts,
                                    dtype=dtype)
    elif ffn == "cmix":
        p["cmix"] = rwkv_lib.channel_mix_init(ks[1], cfg.d_model, cfg.d_ff,
                                              dtype)
    elif cfg.mlp_kind == "glu":
        p["mlp"] = glu_mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> PyTree:
    dtype = cfg.param_dtype
    kinds = cfg.layer_kinds()
    period, n_blocks = cfg.block_period(), cfg.n_blocks()
    k_embed, k_head, k_layers = jax.random.split(key, 3)

    blocks = []
    for j in range(period):
        mix, ffn = kinds[j]
        keys = jax.random.split(jax.random.fold_in(k_layers, j), n_blocks)
        stacked = jax.vmap(lambda kk: _init_layer(kk, cfg, mix, ffn))(keys)
        blocks.append(stacked)

    params = {
        "embed": {"w": dense_init(k_embed, (cfg.vocab_size, cfg.d_model),
                                  dtype=dtype)},
        "blocks": blocks,
        "final_norm": norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": dense_init(k_head,
                                             (cfg.d_model, cfg.vocab_size),
                                             dtype=dtype)}
    return params



def _scan_blocks(cfg: ArchConfig, body, carry, xs):
    """lax.scan over stacked blocks, or a Python loop when cfg.unroll_blocks
    (straight-line HLO for accurate cost_analysis — see ArchConfig)."""
    if not cfg.unroll_blocks:
        return jax.lax.scan(body, carry, xs)
    n = cfg.n_blocks()
    ys = []
    for i in range(n):
        xs_i = jax.tree_util.tree_map(lambda x: x[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked


# --------------------------------------------------------------- forward ----

def _apply_mixer(lp, cfg: ArchConfig, mix: str, h, positions):
    x = apply_norm(h, lp["norm1"], cfg.norm)
    if mix == "attn":
        out, _ = attn_lib.gqa_forward(
            lp["attn"], x, positions, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope=(cfg.pos_emb == "rope"),
            rope_theta=cfg.rope_theta, window=cfg.sliding_window,
            attn_chunk=cfg.attn_chunk)
    elif mix == "mla":
        out, _ = attn_lib.mla_forward(
            lp["attn"], x, positions, n_heads=cfg.n_heads,
            qk_nope=cfg.qk_nope_dim, qk_rope=cfg.qk_rope_dim,
            kv_lora=cfg.kv_lora_rank, v_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta, window=cfg.sliding_window,
            attn_chunk=cfg.attn_chunk)
    elif mix == "mamba":
        out = mamba_lib.mamba_forward(
            lp["mamba"], x, d_model=cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv)
    else:  # rwkv
        st = rwkv_lib.rwkv_state_init(x.shape[0], cfg.d_model)
        out = rwkv_lib.time_mix_forward(lp["tmix"], x, st, cfg.d_model)
    return h + out


def _apply_ffn(lp, cfg: ArchConfig, ffn: str, h):
    x = apply_norm(h, lp["norm2"], cfg.norm)
    aux = jnp.zeros([], jnp.float32)
    if ffn == "moe":
        out, aux = moe_lib.moe_forward(lp["moe"], x,
                                       k=cfg.experts_per_token, act=cfg.act,
                                       capacity_factor=cfg.capacity_factor)
    elif ffn == "cmix":
        st = rwkv_lib.rwkv_state_init(x.shape[0], cfg.d_model)
        out = rwkv_lib.channel_mix_forward(lp["cmix"], x, st)
    elif cfg.mlp_kind == "glu":
        out = glu_mlp(lp["mlp"], x, cfg.act)
    else:
        out = mlp(lp["mlp"], x, cfg.act)
    return h + out, aux


def _embed(params, cfg: ArchConfig, tokens, embeds):
    # Anchor the activation sharding right after the table gather — gathers
    # from a (model, data)-sharded table are where SPMD otherwise loses the
    # batch/client partitioning (§Perf iteration A).
    h = constrain(params["embed"]["w"][tokens], "batch", None, None)
    if embeds is not None:
        h = jnp.concatenate([embeds.astype(h.dtype), h], axis=1)
    if cfg.pos_emb == "sinusoidal":
        pos = jnp.arange(h.shape[1])
        h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)
    return h


def _logits(params, cfg: ArchConfig, h):
    h = apply_norm(h, params["final_norm"], cfg.norm)
    w = (params["embed"]["w"].T if cfg.tie_embeddings
         else params["lm_head"]["w"])
    # vocab-sharded logits, batch/client pinned (under the fed-train vmap the
    # spmd_axis_name prepends the client axis to this constraint).
    return constrain((h @ w).astype(jnp.float32), "batch", None, "model")


def forward(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray,
            embeds: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence causal forward. Returns (logits fp32, moe aux loss)."""
    kinds = cfg.layer_kinds()[: cfg.block_period()]
    h = _embed(params, cfg, tokens, embeds)
    positions = jnp.arange(h.shape[1])

    def block_body(carry, block_params):
        h, aux = carry
        for j, (mix, ffn) in enumerate(kinds):
            lp = block_params[j]
            h = _apply_mixer(lp, cfg, mix, h, positions)
            h, a = _apply_ffn(lp, cfg, ffn, h)
            aux = aux + a
        return (h, aux), None

    body = jax.checkpoint(block_body) if cfg.remat else block_body
    (h, aux), _ = _scan_blocks(cfg, body, (h, jnp.zeros([], jnp.float32)),
                               params["blocks"])
    return _logits(params, cfg, h), aux


def loss_fn(params: PyTree, cfg: ArchConfig, batch: Dict,
            aux_coef: float = 0.01) -> jnp.ndarray:
    """Next-token cross-entropy; labels == -1 are masked (e.g. frontend
    positions in VLM batches)."""
    logits, aux = forward(params, cfg, batch["tokens"], batch.get("embeds"))
    labels = batch["labels"]
    n_front = logits.shape[1] - labels.shape[1]
    if n_front:
        logits = logits[:, n_front:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return ce + aux_coef * aux


# ---------------------------------------------------------------- decode ----

class DecodeState(NamedTuple):
    t: jnp.ndarray          # int32 absolute position: scalar (homogeneous
                            # batch) or (B,) per-slot (continuous batching)
    layers: PyTree          # list (period) of stacked per-block states


def _layer_state_init(cfg: ArchConfig, mix: str, batch: int, cache_len: int):
    if mix == "attn":
        return attn_lib.kv_cache_init(batch, cache_len, cfg.n_kv_heads, cfg.hd)
    if mix == "mla":
        return attn_lib.mla_cache_init(batch, cache_len, cfg.kv_lora_rank,
                                       cfg.qk_rope_dim)
    if mix == "mamba":
        return mamba_lib.mamba_state_init(
            batch, cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv)
    return rwkv_lib.rwkv_state_init(batch, cfg.d_model)


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      per_slot: bool = False) -> DecodeState:
    """cache_len: KV slots. For sliding-window archs pass the window size —
    the ring buffer keeps memory O(window) at any context length.
    ``per_slot`` starts ``t`` as a (B,) vector — each batch row advances at
    its own depth (the continuous-batching slot layout)."""
    kinds = cfg.layer_kinds()[: cfg.block_period()]
    n_blocks = cfg.n_blocks()
    layers = []
    for mix, _ in kinds:
        one = _layer_state_init(cfg, mix, batch, cache_len)
        layers.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_blocks,) + x.shape), one))
    t = jnp.zeros((batch,) if per_slot else [], jnp.int32)
    return DecodeState(t=t, layers=layers)


def _mixer_decode(lp, st, cfg: ArchConfig, mix: str, h, t):
    x = apply_norm(h, lp["norm1"], cfg.norm)
    if mix == "attn":
        out, st = attn_lib.gqa_decode(
            lp["attn"], x, st, t, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, rope=(cfg.pos_emb == "rope"), rope_theta=cfg.rope_theta,
            window=cfg.sliding_window)
    elif mix == "mla":
        out, st = attn_lib.mla_decode(
            lp["attn"], x, st, t, n_heads=cfg.n_heads,
            qk_nope=cfg.qk_nope_dim, qk_rope=cfg.qk_rope_dim,
            kv_lora=cfg.kv_lora_rank, v_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta, window=cfg.sliding_window)
    elif mix == "mamba":
        out, st = mamba_lib.mamba_decode(
            lp["mamba"], x, st, d_model=cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv)
    else:
        out, st = rwkv_lib.time_mix_forward(lp["tmix"], x, st, cfg.d_model,
                                            return_state=True)
    return h + out, st


def _ffn_decode(lp, st, cfg: ArchConfig, ffn: str, h):
    x = apply_norm(h, lp["norm2"], cfg.norm)
    if ffn == "moe":
        out, _ = moe_lib.moe_forward(lp["moe"], x, k=cfg.experts_per_token,
                                     act=cfg.act,
                                     capacity_factor=cfg.capacity_factor)
    elif ffn == "cmix":
        out, st = rwkv_lib.channel_mix_forward(lp["cmix"], x, st,
                                               return_state=True)
    elif cfg.mlp_kind == "glu":
        out = glu_mlp(lp["mlp"], x, cfg.act)
    else:
        out = mlp(lp["mlp"], x, cfg.act)
    return h + out, st


def decode_step(params: PyTree, cfg: ArchConfig, token: jnp.ndarray,
                state: DecodeState) -> Tuple[jnp.ndarray, DecodeState]:
    """One new token for every sequence in the batch. token (B,) int32."""
    kinds = cfg.layer_kinds()[: cfg.block_period()]
    h = params["embed"]["w"][token][:, None, :]      # (B, 1, D)
    if cfg.pos_emb == "sinusoidal":
        if jnp.ndim(state.t):                        # (B,) per-slot positions
            h = h + sinusoidal_positions(state.t[:, None],
                                         cfg.d_model).astype(h.dtype)
        else:
            pos = state.t[None]
            h = h + sinusoidal_positions(pos, cfg.d_model)[None].astype(h.dtype)

    def block_body(h, xs):
        block_params, block_state = xs
        new_states = []
        for j, (mix, ffn) in enumerate(kinds):
            lp, st = block_params[j], block_state[j]
            h, st = _mixer_decode(lp, st, cfg, mix, h, state.t)
            h, st = _ffn_decode(lp, st, cfg, ffn, h)
            new_states.append(st)
        return h, new_states

    h, new_layers = _scan_blocks(cfg, block_body, h,
                                 (params["blocks"], state.layers))
    logits = _logits(params, cfg, h)[:, 0, :]
    return logits, DecodeState(t=state.t + 1, layers=new_layers)


def prefill(params: PyTree, cfg: ArchConfig, tokens: jnp.ndarray,
            state: DecodeState,
            embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, DecodeState]:
    """Process a prompt, filling caches. Returns (last-position logits, state).

    Assumes a fresh state (t=0) and prompt length ≤ cache size for attention
    archs (ring-buffer semantics cover the sliding-window case).
    """
    kinds = cfg.layer_kinds()[: cfg.block_period()]
    h = _embed(params, cfg, tokens, embeds)
    l_total = h.shape[1]
    positions = jnp.arange(l_total)

    def block_body(h, xs):
        block_params, block_state = xs
        new_states = []
        for j, (mix, ffn) in enumerate(kinds):
            lp, st = block_params[j], block_state[j]
            x = apply_norm(h, lp["norm1"], cfg.norm)
            if mix == "attn":
                out, (k, v) = attn_lib.gqa_forward(
                    lp["attn"], x, positions, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope=(cfg.pos_emb == "rope"),
                    rope_theta=cfg.rope_theta, window=cfg.sliding_window,
                    attn_chunk=cfg.attn_chunk)
                st = attn_lib.kv_cache_write(st, k, v, 0)
            elif mix == "mla":
                out, (ckv, kpe) = attn_lib.mla_forward(
                    lp["attn"], x, positions, n_heads=cfg.n_heads,
                    qk_nope=cfg.qk_nope_dim, qk_rope=cfg.qk_rope_dim,
                    kv_lora=cfg.kv_lora_rank, v_dim=cfg.v_head_dim,
                    rope_theta=cfg.rope_theta, window=cfg.sliding_window,
                    attn_chunk=cfg.attn_chunk)
                st = attn_lib.mla_cache_write(st, ckv, kpe, 0)
            elif mix == "mamba":
                out, st = mamba_lib.mamba_forward(
                    lp["mamba"], x, st, d_model=cfg.d_model,
                    expand=cfg.mamba_expand, d_state=cfg.mamba_d_state,
                    d_conv=cfg.mamba_d_conv, return_state=True)
            else:
                out, st = rwkv_lib.time_mix_forward(
                    lp["tmix"], x, st, cfg.d_model, return_state=True)
            h = h + out
            h, st = _ffn_decode(lp, st, cfg, ffn, h)
            new_states.append(st)
        return h, new_states

    h, new_layers = _scan_blocks(cfg, block_body, h,
                                 (params["blocks"], state.layers))
    logits = _logits(params, cfg, h[:, -1:, :])[:, 0, :]
    return logits, DecodeState(t=jnp.asarray(l_total, jnp.int32),
                               layers=new_layers)
