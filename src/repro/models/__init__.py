from . import attention, frontend, layers, mamba, model, moe, rwkv
from .model import (DecodeState, decode_step, forward, init_decode_state,
                    init_params, loss_fn, prefill)

__all__ = [
    "attention", "frontend", "layers", "mamba", "model", "moe", "rwkv",
    "DecodeState", "decode_step", "forward", "init_decode_state",
    "init_params", "loss_fn", "prefill",
]
