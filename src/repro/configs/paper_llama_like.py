"""Paper-scale NLG backbone (Llama-2-7B-like) for the MetaMathQA-analogue
federated benchmarks [Touvron 2023b, paper §6]. 32L d=4096 32H MHA."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paper-llama-like",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    act="silu",
    norm="rmsnorm",
    pos_emb="rope",
    citation="paper §6 / Touvron 2023b",
))
