"""Architecture config schema + registry.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
(``repro/configs/<id>.py``) citing its source. ``layer_kinds()`` expands the
per-layer (mixer, ffn) pattern; ``block_period()`` finds the repeating block
so the model can ``lax.scan`` over stacked blocks (essential for compiling
60–72-layer models quickly and for clean HLO).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    pos_emb: str = "rope"          # rope | sinusoidal | none
    rope_theta: float = 1e4
    sliding_window: int = 0        # 0 = full attention
    # Blockwise (flash-style) attention chunk for train/prefill when
    # L > attn_chunk: statically skips causally/window-dead blocks and never
    # materializes the (L, L) score tensor (§Perf iteration B). 0 = disabled.
    attn_chunk: int = 4096
    norm: str = "rmsnorm"
    act: str = "silu"
    mlp_kind: str = "glu"          # glu | plain
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1             # every n-th layer is MoE (jamba: 2)
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- hybrid (Jamba) ---
    attn_period: int = 0           # attention at i % period == offset; rest Mamba
    attn_offset: int = 0
    mamba_expand: int = 2
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    # --- SSM (RWKV6) ---
    rwkv: bool = False
    # --- modality frontend (stub) ---
    frontend: str = "none"         # none | vision | audio
    frontend_tokens: int = 0       # patch/frame embeddings prepended
    # --- execution ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # Unroll the block scan into straight-line HLO. XLA's cost_analysis counts
    # a while-loop body ONCE regardless of trip count, so the dry-run lowers
    # an unrolled twin of each step to get true per-step FLOPs / collective
    # bytes (memory analysis still uses the scanned, remat'd program).
    unroll_blocks: bool = False
    citation: str = ""

    # ------------------------------------------------------------ derived --
    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def layer_kinds(self) -> List[Tuple[str, str]]:
        kinds = []
        for i in range(self.n_layers):
            if self.rwkv:
                kinds.append(("rwkv", "cmix"))
                continue
            if self.attn_period and i % self.attn_period != self.attn_offset:
                mix = "mamba"
            else:
                mix = "mla" if self.mla else "attn"
            if self.n_experts and (i % self.moe_every) == (self.moe_every - 1):
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mix, ffn))
        return kinds

    def block_period(self) -> int:
        kinds = self.layer_kinds()
        n = len(kinds)
        for p in range(1, n + 1):
            if n % p == 0 and all(kinds[i] == kinds[i % p] for i in range(n)):
                return p
        return n

    def n_blocks(self) -> int:
        return self.n_layers // self.block_period()

    # -------------------------------------------------------- accounting ---
    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D model FLOPs)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for mix, ffn in self.layer_kinds():
            if mix == "attn":
                total += d * self.n_heads * self.hd * 2          # wq, wo
                total += d * self.n_kv_heads * self.hd * 2       # wk, wv
            elif mix == "mla":
                total += d * self.q_lora_rank
                total += self.q_lora_rank * self.n_heads * (self.qk_nope_dim
                                                            + self.qk_rope_dim)
                total += d * (self.kv_lora_rank + self.qk_rope_dim)
                total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim
                                                             + self.v_head_dim)
                total += self.n_heads * self.v_head_dim * d
            elif mix == "mamba":
                di = self.mamba_expand * d
                dtr = max(1, -(-d // 16))
                total += d * 2 * di + di * (dtr + 2 * self.mamba_d_state)
                total += dtr * di + di * self.mamba_d_state + di * d
            elif mix == "rwkv":
                total += 5 * d * d + d * (5 * 32) + 5 * 32 * d + d * 64 + 64 * d
            if ffn == "moe":
                total += d * self.n_experts * self.moe_d_ff * 3
                total += d * self.n_experts                       # router
                if self.n_shared_experts:
                    total += d * self.n_shared_experts * self.moe_d_ff * 3
            elif ffn == "mlp":
                mult = 3 if self.mlp_kind == "glu" else 2
                total += d * self.d_ff * mult
            elif ffn == "cmix":
                total += d * self.d_ff * 2 + d * d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dense_total = self.param_count()
        moe_layers = sum(1 for _, f in self.layer_kinds() if f == "moe")
        all_exp = moe_layers * d * self.n_experts * self.moe_d_ff * 3
        act_exp = moe_layers * d * self.experts_per_token * self.moe_d_ff * 3
        return int(dense_total - all_exp + act_exp)

    def sub_quadratic(self) -> bool:
        """True if long-context decode is O(window) or O(1) per step."""
        return self.rwkv or bool(self.attn_period) or bool(self.sliding_window)


# -------------------------------------------------------------- registry ----

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    from . import (granite_moe_1b_a400m, deepseek_v2_236b, command_r_35b,  # noqa
                   mistral_nemo_12b, qwen1_5_0_5b, pixtral_12b,
                   jamba_1_5_large_398b, starcoder2_7b, musicgen_medium,
                   rwkv6_1_6b, paper_roberta_like, paper_vit_like,
                   paper_llama_like)


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced config for CPU smoke tests: ≤2 layers·period, d_model ≤ 512,
    ≤4 experts — same family/topology, tiny dims."""
    d_model = min(cfg.d_model, 256)
    n_heads = max(1, min(cfg.n_heads, 4))
    if cfg.rwkv:
        d_model = 128            # multiple of HEAD_SIZE
        n_heads = 2
    head_dim = d_model // n_heads
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    # Hybrid archs compress the interleave pattern to 2 layers
    # (1 Mamba + 1 attention) so every mixer kind is exercised.
    attn_period = 2 if cfg.attn_period else 0
    attn_offset = 1 if cfg.attn_period else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2,
        attn_period=attn_period,
        attn_offset=attn_offset,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=None if cfg.head_dim is None else head_dim,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_d_ff=min(cfg.moe_d_ff, 128) if cfg.moe_d_ff else 0,
        q_lora_rank=min(cfg.q_lora_rank, 64) if cfg.q_lora_rank else 0,
        kv_lora_rank=min(cfg.kv_lora_rank, 32) if cfg.kv_lora_rank else 0,
        qk_nope_dim=32 if cfg.mla else cfg.qk_nope_dim,
        qk_rope_dim=16 if cfg.mla else cfg.qk_rope_dim,
        v_head_dim=32 if cfg.mla else cfg.v_head_dim,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        frontend_tokens=min(cfg.frontend_tokens, 16) if cfg.frontend_tokens else 0,
        dtype="float32",
        remat=False,
    )
