"""starcoder2-7b [arXiv:2402.19173].

32L d_model=4608 36H (GQA kv=4, head_dim=128) d_ff=18432 vocab=49152 —
GQA + RoPE, LayerNorm + plain GELU MLP with bias, native sliding window 4096
(so long_500k decode is in-family, no override needed).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    qkv_bias=True,
    sliding_window=4096,
    act="gelu",
    mlp_kind="plain",
    norm="layernorm",
    pos_emb="rope",
    citation="arXiv:2402.19173",
))
