"""deepseek-v2-236b [arXiv:2405.04434].

60L d_model=5120 128H MLA (kv_lora=512, q_lora=1536, qk 128 nope + 64 rope,
v 128) d_ff=1536 (routed expert width) vocab=102400; MoE 160 routed experts
top-6 + 2 shared experts per layer.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,         # MLA: logical value; the cache is kv_lora-compressed
    d_ff=1536,
    vocab_size=102400,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    experts_per_token=6,
    n_shared_experts=2,
    moe_d_ff=1536,
    moe_every=1,
    act="silu",
    norm="rmsnorm",
    pos_emb="rope",
    citation="arXiv:2405.04434",
))
