"""Paper-scale NLU backbone (RoBERTa-base-like causal variant) used by the
GLUE-analogue federated benchmarks [Liu 2019, paper §6]. 12L d=768 12H."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paper-roberta-like",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=50265,
    act="gelu",
    mlp_kind="plain",
    norm="layernorm",
    pos_emb="sinusoidal",
    citation="paper §6 / Liu 2019",
))
