"""Architecture configs (one module per assigned arch) + input shapes."""
from .base import (ArchConfig, get_config, list_configs, register,
                   smoke_variant)
from .shapes import (LONG_CONTEXT_WINDOW, SHAPES, ShapeSpec, cache_len,
                     input_specs, shape_variant)

# The ten architectures assigned to this paper (public pool).
ASSIGNED_ARCHS = [
    "granite-moe-1b-a400m",
    "deepseek-v2-236b",
    "command-r-35b",
    "mistral-nemo-12b",
    "qwen1.5-0.5b",
    "pixtral-12b",
    "jamba-1.5-large-398b",
    "starcoder2-7b",
    "musicgen-medium",
    "rwkv6-1.6b",
]

__all__ = [
    "ArchConfig", "get_config", "list_configs", "register", "smoke_variant",
    "SHAPES", "ShapeSpec", "input_specs", "shape_variant", "cache_len",
    "LONG_CONTEXT_WINDOW", "ASSIGNED_ARCHS",
]
