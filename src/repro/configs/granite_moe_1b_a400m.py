"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8.
Every layer is MoE with 512-wide experts; embeddings tied (model card).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    n_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    moe_every=1,
    tie_embeddings=True,
    act="silu",
    norm="rmsnorm",
    pos_emb="rope",
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
