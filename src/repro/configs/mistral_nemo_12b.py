"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072,
128k context (rope theta 1M). The long_500k decode shape runs the
sliding-window variant (window applied by the shape override, DESIGN.md §5).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    act="silu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=1e6,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
))
