"""The four assigned input shapes and ShapeDtypeStruct input specs.

``input_specs(cfg, shape)`` returns weak-type-correct, shardable
ShapeDtypeStruct stand-ins for every model input (never allocating) — the
dry-run lowers against these.

long_500k requires sub-quadratic attention: RWKV6 is O(1)-state, Jamba is
Mamba + sparse attention, starcoder2 has a native 4096 window; every other
(full-attention) arch runs a **sliding-window variant** (window=8192) at this
shape — applied by ``shape_variant`` and recorded per-arch in EXPERIMENTS.md.
Decode caches for windowed attention are ring buffers of size=window, so
long-context decode memory is O(window), not O(context).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .base import ArchConfig

LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_variant(cfg: ArchConfig, shape: ShapeSpec) -> ArchConfig:
    """Arch adjustments a shape requires (the long_500k SWA carve-out)."""
    if shape.name == "long_500k" and not cfg.rwkv and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def cache_len(cfg: ArchConfig, shape: ShapeSpec) -> int:
    """KV slots needed for a decode shape: the window for SWA ring buffers,
    the full context otherwise."""
    if cfg.sliding_window:
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict:
    """ShapeDtypeStruct inputs for (arch, shape). Keys by shape kind:

      train   -> {tokens, labels[, embeds]}
      prefill -> {tokens[, embeds]}
      decode  -> {token, state}
    """
    cfg = shape_variant(cfg, shape)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        n_text = s - cfg.frontend_tokens
        spec = {"tokens": _sds((b, n_text), jnp.int32)}
        if cfg.frontend_tokens:
            spec["embeds"] = _sds((b, cfg.frontend_tokens, cfg.d_model),
                                  jnp.bfloat16)
        if shape.kind == "train":
            spec["labels"] = _sds((b, n_text), jnp.int32)
        return spec
    # decode: one new token + a full cache/state at seq_len context
    from ..models import model as model_lib
    state = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, b, cache_len(cfg, shape)))
    # A mid-stream decode state: position counter at seq_len.
    return {"token": _sds((b,), jnp.int32), "state": state}
