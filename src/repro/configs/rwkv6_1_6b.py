"""rwkv6-1.6b "Finch" [arXiv:2404.05892].

24L d_model=2048 (attention-free; 32 heads of size 64) d_ff=7168 vocab=65536.
RWKV6 time-mix with data-dependent decay + ddlerp token shift; squared-ReLU
channel-mix FFN. O(1)-state decode makes long_500k native.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,             # d_model / 64 RWKV heads
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    rwkv=True,
    act="relu",
    norm="layernorm",
    pos_emb="none",
    citation="arXiv:2404.05892",
))
