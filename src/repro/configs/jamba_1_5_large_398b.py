"""jamba-1.5-large-398b [arXiv:2403.19887].

72L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=24576 vocab=65536.
Hybrid: attention every 8th layer (1:7 Mamba:attention interleave, attention
at block offset 3), MoE (16 experts top-2) on every other layer. No explicit
positional embedding — the Mamba recurrence carries position.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    attn_period=8,
    attn_offset=3,
    mamba_expand=2,
    mamba_d_state=16,
    mamba_d_conv=4,
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_every=2,
    act="silu",
    norm="rmsnorm",
    pos_emb="none",
    citation="arXiv:2403.19887",
))
