"""musicgen-medium [arXiv:2306.05284].

48L d_model=1536 24H (MHA, kv=24) d_ff=6144 vocab=2048 — decoder-only
transformer over EnCodec tokens, sinusoidal positions, LayerNorm + GELU MLP.
The EnCodec audio codec (mel/conv frontend and the 4-codebook delay pattern)
is the STUB per the assignment carve-out: the backbone consumes/produces
single-stream codebook tokens (vocab 2048).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    mlp_kind="plain",
    norm="layernorm",
    pos_emb="sinusoidal",
    frontend="audio",
    frontend_tokens=0,          # tokens ARE EnCodec codes; no embed prefix
    citation="arXiv:2306.05284",
))
