"""pixtral-12b [hf:mistralai/Pixtral-12B-2409].

Language decoder = mistral-nemo-12b dims (40L d_model=5120 32H GQA kv=8
d_ff=14336 vocab=131072). The Pixtral ViT vision encoder + projector is a
STUB per the assignment carve-out: ``input_specs`` supplies precomputed patch
embeddings (frontend_tokens × d_model) that are prepended to the token stream.
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    act="silu",
    norm="rmsnorm",
    pos_emb="rope",
    rope_theta=1e9,
    frontend="vision",
    frontend_tokens=1024,      # 1024 patch embeddings per image
    citation="hf:mistralai/Pixtral-12B-2409",
))
