"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — GQA, no bias,
tied embeddings (model card).
"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    tie_embeddings=True,
    act="silu",
    norm="layernorm",
    pos_emb="rope",
    rope_theta=8e6,
    citation="hf:CohereForAI/c4ai-command-r-v01",
))
