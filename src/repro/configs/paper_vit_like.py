"""Paper-scale vision backbone (ViT-base-like) for the DomainNet-analogue
federated benchmarks [Dosovitskiy 2020, paper §6]. Patch embeddings come from
the stub frontend; the backbone is the transformer."""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paper-vit-like",
    family="vlm",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=1000,        # classification head vocabulary
    act="gelu",
    mlp_kind="plain",
    norm="layernorm",
    pos_emb="sinusoidal",
    frontend="vision",
    frontend_tokens=196,
    citation="paper §6 / Dosovitskiy 2020",
))
