"""Sticky multi-tenant adapter factors for the serving path.

:class:`AdapterStore` keeps per-tenant factored deltas ``(basis, R̃,
base_scale)`` as rows of a :class:`~repro.core.population.ClientStateStore`
— the same sharded-numpy + atomic-spill wire format the federated
population uses for client state, so a trained population's sticky rows
are directly servable (:meth:`AdapterStore.from_client_state`). A tenant
that was never stored reads back as zeros, which decodes as the pristine
base model (``scale_minus_1 = 0`` ⇒ scale 1, delta 0).

``wrap`` lifts a base param tree into :class:`MultiAdapterDelta` serving
leaves: each target projection carries a ``(G, dim, r)`` factor table and
the decode batch's per-row adapter ids (installed by the serving driver
via :func:`repro.models.layers.adapter_ids`) select which tenant's delta
each row applies — one shared base GEMM, G tenants per compiled batch.

Ragged ranks: tenants may store factors with r_g < the table rank; they
are zero-padded per shape bucket (``galore.bucket_by_shape``) and the
zero columns contribute exactly zero delta at apply time.

MLA's ``kv_b`` is excluded from the serving wrap (``serving_target_fn``):
``mla_decode`` consumes it through an absorbed-matmul ``reshape`` that a
factored leaf cannot satisfy, so it stays dense at serve time even though
training targets it (docs/SERVING.md).
"""
from __future__ import annotations

from typing import Any, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core import projector as proj
from ..core.fed import merge_dense, split_trainable
from ..core.galore import bucket_by_shape
from ..core.population import ClientStateStore
from ..models import layers
from .steps import galore_target_fn

PyTree = Any


def serving_target_fn(cfg):
    """The training target set minus MLA's ``kv_b`` (see module docstring)."""
    base = galore_target_fn(cfg)

    def fn(path: str, leaf) -> bool:
        if path.split("/")[-1] == "kv_b":
            return False
        return base(path, leaf)

    return fn


def _pad_bucketed(leaves: List, axes: List[int], rank: int) -> List:
    """Zero-pad ragged-rank factor leaves to the store rank along their
    rank axis. Leaves sharing a (shape, axis) layout are padded as one
    stacked block — one np op per shape bucket (the serving-side mirror
    of the refresh bucket layout)."""
    keys = [(tuple(np.shape(x)), ax) for x, ax in zip(leaves, axes)]
    buckets, _ = bucket_by_shape(keys)
    out = list(leaves)
    for (shape, ax), idxs in buckets:
        block = np.stack([np.asarray(leaves[i], np.float32) for i in idxs])
        have = shape[ax]
        if have > rank:
            raise ValueError(f"factor rank {have} exceeds store rank {rank}")
        if have < rank:
            widths = [(0, 0)] * block.ndim
            widths[ax % (block.ndim - 1) + 1] = (0, rank - have)
            block = np.pad(block, widths)
        for j, i in enumerate(idxs):
            out[i] = block[j]
    return out


class AdapterStore:
    """Spill-backed per-tenant serving factors keyed by adapter id.

    ``params``/``target_fn`` fix the leaf layout: every target leaf
    ``(..., m, n)`` gets a basis row ``(..., dim, rank)`` and an R̃ row
    (``(..., m, rank)`` right / ``(..., rank, n)`` left, GaLore ``std``
    side convention). ``directory`` enables LRU spill through the atomic
    checkpoint writer — populations larger than host memory serve fine.
    """

    def __init__(self, params: PyTree, target_fn, n_adapters: int,
                 rank: int, directory: Optional[str] = None,
                 shard_size: int = 1024,
                 max_resident_shards: Optional[int] = None):
        self.n_adapters = int(n_adapters)
        self.rank = int(rank)
        self._target_fn = target_fn
        trainable, _ = split_trainable(params, target_fn)
        w_leaves, tdef = jax.tree_util.tree_flatten(trainable)
        if not w_leaves:
            raise ValueError("target_fn selected no servable leaves")
        self._tdef = tdef
        self._sides = [proj.proj_side(w.shape) for w in w_leaves]
        self._basis_specs, self._rt_specs = [], []
        for w, side in zip(w_leaves, self._sides):
            lead, (m, n) = tuple(w.shape[:-2]), w.shape[-2:]
            if side == proj.RIGHT:
                self._basis_specs.append(lead + (n, self.rank))
                self._rt_specs.append(lead + (m, self.rank))
            else:
                self._basis_specs.append(lead + (m, self.rank))
                self._rt_specs.append(lead + (self.rank, n))
        template = {
            "basis": tdef.unflatten(
                [np.zeros(s, np.float32) for s in self._basis_specs]),
            "rt": tdef.unflatten(
                [np.zeros(s, np.float32) for s in self._rt_specs]),
            "scale_minus_1": np.zeros((), np.float32),
        }
        self.store = ClientStateStore(
            self.n_adapters, template, directory=directory,
            shard_size=shard_size, max_resident_shards=max_resident_shards)

    # rank axis per leaf: basis pads its last axis; R̃ pads last on the
    # right side, -2 on the left.
    def _rt_axes(self) -> List[int]:
        return [-1 if s == proj.RIGHT else -2 for s in self._sides]

    def put(self, adapter_id: int, rt: PyTree, basis: PyTree,
            scale: float = 1.0) -> None:
        """Store one tenant's factors. ``rt``/``basis`` trees follow the
        trainable split layout; their leaves may carry a smaller (ragged)
        rank r_g <= the store rank — zero-padded on write."""
        b_leaves = jax.tree_util.tree_flatten(basis)[0]
        r_leaves = jax.tree_util.tree_flatten(rt)[0]
        if len(b_leaves) != len(self._sides) or \
                len(r_leaves) != len(self._sides):
            raise ValueError("factor tree layout != store template")
        b_leaves = _pad_bucketed(b_leaves, [-1] * len(b_leaves), self.rank)
        r_leaves = _pad_bucketed(r_leaves, self._rt_axes(), self.rank)
        row = {"basis": self._tdef.unflatten(b_leaves),
               "rt": self._tdef.unflatten(r_leaves),
               "scale_minus_1": np.float32(scale) - np.float32(1.0)}
        stacked = jax.tree_util.tree_map(
            lambda x: np.asarray(x, np.float32)[None], row)
        self.store.scatter(np.asarray([adapter_id]), stacked)

    def flush(self) -> None:
        self.store.flush()

    def wrap(self, params: PyTree, ids=None) -> PyTree:
        """Params with each target leaf replaced by a MultiAdapterDelta
        carrying the gathered factor tables for ``ids`` (default: all
        adapters, in id order). Decode-row adapter ids then index INTO
        THIS TABLE (positions in ``ids``), not the global id space."""
        ids = (np.arange(self.n_adapters) if ids is None
               else np.asarray(ids, np.int64))
        rows = self.store.gather(ids)
        scales = np.asarray(rows["scale_minus_1"], np.float32) + 1.0  # (G,)
        trainable, frozen = split_trainable(params, self._target_fn)
        w_leaves, tdef = jax.tree_util.tree_flatten(trainable)
        b_leaves = jax.tree_util.tree_flatten(rows["basis"])[0]
        r_leaves = jax.tree_util.tree_flatten(rows["rt"])[0]
        wrapped = []
        for w, b, r in zip(w_leaves, b_leaves, r_leaves):
            # gathered (G, ..., dim, r) -> table (..., G, dim, r): the G
            # axis sits just before the factor matrix so the leaf slices
            # cleanly under the model's scan over stacked layer params.
            bases = jnp.asarray(np.moveaxis(b, 0, b.ndim - 3))
            rts = jnp.asarray(np.moveaxis(r, 0, r.ndim - 3))
            sc = jnp.broadcast_to(jnp.asarray(scales),
                                  tuple(w.shape[:-2]) + scales.shape)
            wrapped.append(layers.MultiAdapterDelta(
                w=w, bases=bases, rts=rts, scales=sc))
        return merge_dense(frozen, tdef.unflatten(wrapped))

    def random_factors(self, rng: np.random.Generator,
                       rt_scale: float = 0.02):
        """A random (basis, rt) tree pair in this store's layout — demo
        tenants and test fixtures."""
        basis = self._tdef.unflatten(
            [rng.standard_normal(s).astype(np.float32) / np.sqrt(s[-2])
             for s in self._basis_specs])
        rt = self._tdef.unflatten(
            [rt_scale * rng.standard_normal(s).astype(np.float32)
             for s in self._rt_specs])
        return basis, rt

    @classmethod
    def from_client_state(cls, params: PyTree, target_fn,
                          client_store: ClientStateStore, basis: PyTree,
                          ids, base_scale: float = 1.0,
                          rank: Optional[int] = None, **kw) -> "AdapterStore":
        """Serve a trained population directly: client ``i``'s sticky
        factored accumulator (row key ``"delta"``, the R̃_i the rounds
        scatter) becomes adapter ``i``'s R̃, paired with the round's
        shared ``basis`` tree and the engine's ``base_scale``
        ((1-ηλ)^T). Adapter ids == population client ids."""
        ids = np.asarray(ids, np.int64)
        rows = client_store.gather(ids)
        deltas = rows["delta"]
        if rank is None:
            rank = max(b.shape[-1]
                       for b in jax.tree_util.tree_flatten(basis)[0])
        store = cls(params, target_fn, n_adapters=client_store.n_clients,
                    rank=rank, **kw)
        for g, cid in enumerate(ids):
            rt_i = jax.tree_util.tree_map(lambda x: x[g], deltas)
            store.put(int(cid), rt_i, basis, scale=base_scale)
        return store


def demo_wrap(params: PyTree, cfg, n_adapters: int, rank: int = 4,
              key=None, rt_scale: float = 0.02) -> PyTree:
    """Wrap ``params`` with ``n_adapters`` random distinct tenants — the
    CLI demo path (``serve --adapters G``)."""
    store = AdapterStore(params, serving_target_fn(cfg), n_adapters, rank)
    seed = 0 if key is None else int(jax.random.randint(key, (), 0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    for g in range(n_adapters):
        basis, rt = store.random_factors(rng, rt_scale=rt_scale)
        store.put(g, rt, basis)
    return store.wrap(params)
