from .mesh import TPU_V5E, make_host_mesh, make_production_mesh
from .steps import (TrainSpec, galore_target_fn, init_train_state,
                    make_decode_step, make_fed_local_step,
                    make_fed_round_step, make_galore_tx, make_prefill_step)

__all__ = [
    "TPU_V5E", "make_host_mesh", "make_production_mesh", "TrainSpec",
    "galore_target_fn", "init_train_state", "make_decode_step",
    "make_fed_local_step", "make_fed_round_step", "make_galore_tx",
    "make_prefill_step",
]
