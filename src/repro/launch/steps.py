"""Step functions lowered by the dry-run / executed by train.py & serve.py.

The train step IS the paper's client workload: one FedGaLore local step —
dense gradients on the target modules, GaLoreAdamW update in the rank-r
subspace, frozen base weights. Clients are vmapped over the (pod, data) mesh
axes; the frozen base is FSDP-sharded (identical across clients, so weight
sharding is sound), while each client's trainable copy shards over the model
axis only.

``make_fed_round_step`` additionally lowers a *whole round*: T local steps
(scan) + FedAvg aggregation (weighted mean over the client axis) + the
server-side state filter 𝒮 (Algorithm 1, line 12) run **inside the mesh** —
factored on the projected ṽ (shared-basis rounds) or via heterogeneous-basis
r×r transfer Grams (``refresh_mode='svd'``, diverged bases), followed by the
synced-state install and seed bump for the next round. The paper's full
𝒯→𝒜→𝒮 pipeline is one SPMD program: the round never drops out of the mesh
onto the host. Passing ``state_sync=None`` lowers the legacy 𝒯→𝒜 program
(raw end-of-round states returned; the caller syncs on the host — the eager
reference path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..core import projector as proj
from ..core import state_sync as sync_lib
from ..core.fed import merge_dense, split_trainable
from ..models import model as model_lib
from ..optim.base import apply_updates

PyTree = Any


def galore_target_fn(cfg: ArchConfig) -> Callable:
    """The paper's target modules, adapted per family (DESIGN.md §4):
    attention + dense-MLP projections; Mamba in/out projections; RWKV6
    time-mix/channel-mix matrices. Experts, routers, embeddings frozen."""

    def fn(path: str, leaf) -> bool:
        if leaf.ndim < 2:
            return False
        if "embed" in path or "lm_head" in path:
            return False
        if "/moe/" in path or "/shared/" in path:
            return False
        last = path.split("/")[-1]
        if "/attn/" in path:
            return True
        if "/mlp/" in path:
            return True
        if "/mamba/" in path:
            return last in ("in_proj", "out_proj")
        if "/tmix/" in path:
            return last in ("wr", "wk", "wv", "wg", "wo")
        if "/cmix/" in path:
            return last in ("wk", "wv", "wr")
        return False

    return fn


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    rank: int = 64
    lr: float = 1e-4
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    refresh_every: int = 200
    local_steps: int = 8                # T (round step only)
    seed: int = 0
    refresh_mode: str = "random"        # production steady-state step
    # Fused/bucketed GaLore execution (core.galore module docstring):
    # fused=True batches same-shape target blocks per step; use_pallas=None
    # auto-selects the fused Pallas kernel on TPU (interpret fallback on CPU
    # when forced True).
    fused: bool = True
    use_pallas: Optional[bool] = None
    # Mesh axes carrying the client dimension. jax.vmap(spmd_axis_name=...)
    # pins every per-client intermediate's leading dim to these axes —
    # without it SPMD replicated the client dim across the data axis
    # (§Perf iteration A measured 16× inflated loss-tensor bytes).
    client_axes: tuple = ("data",)


def make_galore_tx(cfg: ArchConfig, spec: TrainSpec):
    gcfg = gal.GaloreConfig(rank=spec.rank, refresh_every=spec.refresh_every,
                            adaptive_steps=0, refresh_mode=spec.refresh_mode,
                            fused=spec.fused, use_pallas=spec.use_pallas)
    return gal.galore_adamw(gcfg, spec.lr, spec.weight_decay,
                            target_fn=lambda p, l: True,  # trainable tree is
                            seed=spec.seed,               # already filtered
                            clip_norm=spec.clip_norm)


def init_train_state(key, cfg: ArchConfig, spec: TrainSpec):
    """(trainable, frozen, opt_state) for ONE client."""
    params = model_lib.init_params(key, cfg)
    trainable, frozen = split_trainable(params, galore_target_fn(cfg))
    tx = make_galore_tx(cfg, spec)
    opt_state = tx.init(trainable)
    return trainable, frozen, opt_state


def make_fed_local_step(cfg: ArchConfig, spec: TrainSpec,
                        n_clients: int) -> Callable:
    """One GaLoreAdamW local step for every client in parallel.

    Args (client-stacked leaves marked ×C):
      trainable ×C, frozen (shared), opt_state ×C,
      batch {tokens ×C (c, b, L), labels ×C, embeds? ×C}
    Returns (trainable ×C, opt_state ×C, loss (C,)).
    """
    tx = make_galore_tx(cfg, spec)

    def client_step(trainable, frozen, opt_state, batch):
        def loss_of(tr):
            params = merge_dense(frozen, tr)
            return model_lib.loss_fn(params, cfg, batch)
        loss, grads = jax.value_and_grad(loss_of)(trainable)
        updates, opt_state = tx.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, loss

    from ..models.layers import batch_axes_override

    def step(trainable, frozen, opt_state, batch):
        with batch_axes_override(()):
            return jax.vmap(client_step, in_axes=(0, None, 0, 0),
                            spmd_axis_name=spec.client_axes)(
                trainable, frozen, opt_state, batch)

    return step


def sync_client_states(out_st, w, n_clients: int, state_sync: str,
                       factored: bool, bases_shared: bool):
    """Server-side 𝒮 + next-round install on client-stacked optimizer states
    (the in-mesh tail of the round program; also usable eagerly).

    Synchronizes each adapted block's projected ṽ — factored on the shared
    seeded basis, or via heterogeneous r×r transfer Grams when client bases
    diverged (``bases_shared=False``), or through the dense per-client lift
    oracle (``factored=False``) — installs the broadcast result in every
    client slot, and bumps the round seed. No dense ``(C, m, n)`` view is
    built on any factored path.
    """
    g_stack = gal.galore_state_of(out_st)
    if state_sync != "none":
        bases = gal.extract_bases(g_stack)
        v_upload = gal.extract_projected_v(g_stack)
        vs, treedef = jax.tree_util.tree_flatten(v_upload,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(bases, is_leaf=lambda x: x is None)
        out = []
        for v_stack, b_stack in zip(vs, bs):
            if v_stack is None:
                out.append(None)
                continue
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT
            if not factored:
                synced = _dense_sync_block(state_sync, v_stack, b_stack, w,
                                           rank, side)
            elif bases_shared:
                # Factored 𝒮: sync the (C, ., r) uplink directly; the shared
                # seeded basis cancels, so no (C, m, n) lift and no (n, n)
                # projector. Result is the O(dim·r) projected state.
                synced = jnp.maximum(sync_lib.sync_block_synced_factored(
                    state_sync, v_stack, side, w, rank), 0.0)
            else:
                # Diverged bases (data-driven refreshes): the lift → 𝒮 →
                # re-project round-trip closes over r×r transfer Grams —
                # the dense per-client lift stays a parity oracle.
                synced = jnp.maximum(sync_lib.sync_block_hetero_factored(
                    state_sync, v_stack, b_stack, side, w, rank), 0.0)
            # every client slot shares the synced projected state (a
            # broadcast view of the O(dim·r) buffer, not a dense tensor)
            out.append(jnp.broadcast_to(synced[None],
                                        (n_clients,) + synced.shape))
        synced_tree = jax.tree_util.tree_unflatten(treedef, out)
        g_new = gal.with_projected_v(g_stack, synced_tree)
    else:
        g_new = g_stack
    g_new = gal.GaloreState(
        count=g_new.count, seed=g_new.seed + 1, blocks=g_new.blocks)
    return gal.replace_galore_state(out_st, g_new)


def _dense_sync_block(state_sync, v_stack, b_stack, w, rank, side):
    """Dense reference 𝒮 (parity oracle): lift each client's ṽ with its
    *own* end-of-round basis (correct under diverged bases), run the
    configured protocol on the lifted views, re-project onto the
    client-0 basis."""
    def sync_one(v_cl, b_cl):
        # v_cl (C, m, r) | (C, r, n); b_cl (C, dim, r)
        v32 = v_cl.astype(jnp.float32)
        b32 = b_cl.astype(jnp.float32)
        if side == proj.RIGHT:
            views = jnp.einsum("kmr,knr->kmn", v32, b32)
        else:
            views = jnp.einsum("kmr,krn->kmn", b32, v32)
        lifted = sync_lib.sync_lifted_views(state_sync, views, w, rank)
        return jnp.maximum(sync_lib.project_state(lifted, b_cl[0], side), 0.0)

    if v_stack.ndim == 4:         # stacked scan blocks: (C, nb, ., r)
        return jax.vmap(sync_one, in_axes=(1, 1))(v_stack, b_stack)
    return sync_one(v_stack, b_stack)


def make_fed_round_step(cfg: ArchConfig, spec: TrainSpec, n_clients: int,
                        state_sync: Optional[str] = None,
                        factored_sync: bool = True) -> Callable:
    """A full federated round (Algorithm 1) as one SPMD program:

      broadcast (implicit: clients start from identical trainables) →
      T local GaLoreAdamW steps (lax.scan) →
      FedAvg aggregation = mean over the client axis (XLA: all-reduce over
      the (pod, data) mesh axes) →
      𝒮 (when ``state_sync`` is a protocol name): factored sync of the
      projected second moments, install + seed bump — all inside the mesh;
      the returned states are ready for the next round.

    ``state_sync=None`` preserves the legacy 𝒯→𝒜 program: raw end-of-round
    states are returned and the caller runs 𝒮 on the host (the eager
    reference path, and the dry-run default).
    """
    tx = make_galore_tx(cfg, spec)

    def client_round(trainable, frozen, opt_state, batches):
        def one(carry, batch):
            tr, st = carry
            def loss_of(t):
                return model_lib.loss_fn(merge_dense(frozen, t), cfg, batch)
            loss, grads = jax.value_and_grad(loss_of)(tr)
            updates, st = tx.update(grads, st, tr)
            return (apply_updates(tr, updates), st), loss
        (trainable, opt_state), losses = jax.lax.scan(
            one, (trainable, opt_state), batches)
        return trainable, opt_state, losses

    def round_step(global_trainable, frozen, opt_states, batches, weights):
        # broadcast: stack the global trainable along the client axis
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape),
            global_trainable)
        from ..models.layers import batch_axes_override
        with batch_axes_override(()):
            out_tr, out_st, losses = jax.vmap(
                client_round, in_axes=(0, None, 0, 0),
                spmd_axis_name=spec.client_axes)(stacked, frozen,
                                                 opt_states, batches)
        w = weights / jnp.sum(weights)
        # 𝒜: weighted average over the client axis -> all-reduce on the mesh
        new_global = jax.tree_util.tree_map(
            lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0)
                                    ).astype(x.dtype), out_tr)
        if state_sync is not None:
            # 𝒮 in-mesh: the round program returns next-round-ready states;
            # the pre-sync ṽ is consumed internally, never materialized as
            # an output.
            out_st = sync_client_states(
                out_st, w, n_clients, state_sync, factored=factored_sync,
                bases_shared=(spec.refresh_mode != "svd"))
            return new_global, out_st, losses, None
        # 𝒮 payload for the host-side filter: projected second moments ṽ
        # (client-stacked, O(n·r))
        v_upload = gal.extract_projected_v(gal.galore_state_of(out_st))
        return new_global, out_st, losses, v_upload

    return round_step


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    def prefill_step(params, tokens, embeds=None):
        state = model_lib.init_decode_state(cfg, tokens.shape[0], cache_len)
        return model_lib.prefill(params, cfg, tokens, state, embeds)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode(params, token, state):
        return model_lib.decode_step(params, cfg, token, state)
    return decode
