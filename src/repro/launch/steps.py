"""Step functions lowered by the dry-run / executed by train.py & serve.py.

The train step IS the paper's client workload: one FedGaLore local step —
dense gradients on the target modules, GaLoreAdamW update in the rank-r
subspace, frozen base weights. Clients are vmapped over the (pod, data) mesh
axes; the frozen base is FSDP-sharded (identical across clients, so weight
sharding is sound), while each client's trainable copy shards over the model
axis only.

``make_fed_round_step`` additionally lowers a *whole round*: T local steps
(scan) + FedAvg aggregation (weighted mean over the client axis) + the
server-side state filter 𝒮 (Algorithm 1, line 12) run **inside the mesh** —
factored on the projected ṽ (shared-basis rounds) or via heterogeneous-basis
r×r transfer Grams (``refresh_mode='svd'``, diverged bases), followed by the
synced-state install and seed bump for the next round. The paper's full
𝒯→𝒜→𝒮 pipeline is one SPMD program: the round never drops out of the mesh
onto the host. Passing ``state_sync=None`` lowers the legacy 𝒯→𝒜 program
(raw end-of-round states returned; the caller syncs on the host — the eager
reference path).

Client memory model of the round program (mirrors ``core.fed``): with the
default ``factored_clients=True`` every client's round state is the rank-r
factored accumulator ``R_i`` around the broadcast global base, and with the
default ``lift_free=True`` the local step is **lift-free**: target leaves
enter the model as ``models.layers.LowRankDelta`` nodes whose delta-aware
projections compute ``base_scale·(x@W) + split-matmul(R_i)`` directly
(``kernels.lowrank_linear`` on TPU) and whose custom VJP returns the ``R_i``
cotangent already in rank-r coordinates — no ``base_scale·W + lift(R_i)``
transient, no dense m×n gradient, exact global-norm clipping via the VJP's
dense-norm probes. 𝒜 collapses to ``base_scale·W + Σ wᵢ lift(Rᵢ)`` with no
dense (C, m, n) weight stack anywhere in the program. ``lift_free=False``
keeps the transient-lift read (the parity oracle); ``refresh_mode='svd'``
forces it too, since data-driven refreshes need the dense per-client
gradient. In-step seeded-random refreshes are hoisted before the forward
(``galore.maybe_refresh_instep``) so cotangents arrive on the refreshed
basis. ``client_chunk=B`` streams the cohort through the round in C/B
sequential chunks (a ``lax.scan`` over the chunked client axis), bounding
the dense forward/backward working set by B clients. Stacked client
optimizer states ride the GaLore count/seed UNBATCHED (``galore.
stack_opt_state`` layout) so the in-step ``count % τ`` refresh stays a real
``lax.cond`` under the client vmap. The factored client path requires every
refresh to land on local step 0 (where R_i ≡ 0): ``refresh_every %
local_steps == 0``; otherwise the dense client round (retained under
``factored_clients=False`` as the parity oracle) is used.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import aggregation as agg_lib
from ..core import galore as gal
from ..core import projector as proj
from ..core import state_sync as sync_lib
from ..core.fed import merge_dense, split_trainable
from ..models import model as model_lib
from ..optim.base import apply_updates

PyTree = Any


def galore_target_fn(cfg: ArchConfig) -> Callable:
    """The paper's target modules, adapted per family (DESIGN.md §4):
    attention + dense-MLP projections; Mamba in/out projections; RWKV6
    time-mix/channel-mix matrices. Experts, routers, embeddings frozen."""

    def fn(path: str, leaf) -> bool:
        if leaf.ndim < 2:
            return False
        if "embed" in path or "lm_head" in path:
            return False
        if "/moe/" in path or "/shared/" in path:
            return False
        last = path.split("/")[-1]
        if "/attn/" in path or "/mlp/" in path:
            # Stacked scan-block layout: the projection weights are the 3-D
            # (nb, m, n) leaves (one projector per layer). The 2-D leaves
            # under these prefixes are stacked bias/norm VECTORS (bq/bk/bv,
            # q_a_norm, …) — excluded from the target split, i.e. FROZEN
            # alongside embeddings/routers (the paper's target modules are
            # the projections only).
            return leaf.ndim >= 3
        if "/mamba/" in path:
            return last in ("in_proj", "out_proj")
        if "/tmix/" in path:
            return last in ("wr", "wk", "wv", "wg", "wo")
        if "/cmix/" in path:
            return last in ("wk", "wv", "wr")
        return False

    return fn


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    rank: int = 64
    lr: float = 1e-4
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    refresh_every: int = 200
    local_steps: int = 8                # T (round step only)
    seed: int = 0
    refresh_mode: str = "random"        # production steady-state step
    # Fused/bucketed GaLore execution (core.galore module docstring):
    # fused=True batches same-shape target blocks per step; use_pallas=None
    # auto-selects the fused Pallas kernel on TPU (interpret fallback on CPU
    # when forced True).
    fused: bool = True
    use_pallas: Optional[bool] = None
    # Lift-free factored local steps (module docstring): delta-aware forward
    # + projected-cotangent backward instead of the per-leaf transient lift.
    # Auto-disabled when the factored client model doesn't apply or
    # refresh_mode='svd' needs dense gradients. False = transient-lift
    # oracle.
    lift_free: bool = True
    # Mesh axes carrying the client dimension. jax.vmap(spmd_axis_name=...)
    # pins every per-client intermediate's leading dim to these axes —
    # without it SPMD replicated the client dim across the data axis
    # (§Perf iteration A measured 16× inflated loss-tensor bytes).
    client_axes: tuple = ("data",)


def make_galore_cfg(spec: TrainSpec) -> gal.GaloreConfig:
    return gal.GaloreConfig(rank=spec.rank, refresh_every=spec.refresh_every,
                            adaptive_steps=0, refresh_mode=spec.refresh_mode,
                            fused=spec.fused, use_pallas=spec.use_pallas)


def make_galore_tx(cfg: ArchConfig, spec: TrainSpec):
    return gal.galore_adamw(make_galore_cfg(spec), spec.lr, spec.weight_decay,
                            target_fn=lambda p, l: True,  # trainable tree is
                            seed=spec.seed,               # already filtered
                            clip_norm=spec.clip_norm)


def init_train_state(key, cfg: ArchConfig, spec: TrainSpec):
    """(trainable, frozen, opt_state) for ONE client."""
    params = model_lib.init_params(key, cfg)
    trainable, frozen = split_trainable(params, galore_target_fn(cfg))
    tx = make_galore_tx(cfg, spec)
    opt_state = tx.init(trainable)
    return trainable, frozen, opt_state


def make_fed_local_step(cfg: ArchConfig, spec: TrainSpec,
                        n_clients: int) -> Callable:
    """One GaLoreAdamW local step for every client in parallel.

    Args (client-stacked leaves marked ×C):
      trainable ×C, frozen (shared), opt_state ×C (``galore.stack_opt_state``
      layout — the GaLore count/seed ride unbatched through the client vmap),
      batch {tokens ×C (c, b, L), labels ×C, embeds? ×C}
    Returns (trainable ×C, opt_state ×C, loss (C,)).
    """
    tx = make_galore_tx(cfg, spec)

    def client_step(trainable, frozen, opt_state, batch):
        def loss_of(tr):
            params = merge_dense(frozen, tr)
            return model_lib.loss_fn(params, cfg, batch)
        loss, grads = jax.value_and_grad(loss_of)(trainable)
        updates, opt_state = tx.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, loss

    from ..models.layers import batch_axes_override

    def step(trainable, frozen, opt_state, batch):
        axes = gal.client_opt_axes(opt_state)
        with batch_axes_override(()):
            return jax.vmap(client_step, in_axes=(0, None, axes, 0),
                            out_axes=(0, axes, 0),
                            spmd_axis_name=spec.client_axes)(
                trainable, frozen, opt_state, batch)

    return step


def sync_client_states(out_st, w, n_clients: int, state_sync: str,
                       factored: bool, bases_shared: bool,
                       exclude_zero_weights: bool = False,
                       bucketed: bool = True,
                       robust_agg: str = "none",
                       robust_trim: float = 0.2,
                       robust_iters: int = 8,
                       robust_tol: float = 1e-6):
    """Server-side 𝒮 + next-round install on client-stacked optimizer states
    (the in-mesh tail of the round program; also usable eagerly).

    Synchronizes each adapted block's projected ṽ — factored on the shared
    seeded basis, or via heterogeneous r×r transfer Grams when client bases
    diverged (``bases_shared=False``), or through the dense per-client lift
    oracle (``factored=False``) — installs the broadcast result in every
    client slot, and bumps the round seed. No dense ``(C, m, n)`` view is
    built on any factored path. ``exclude_zero_weights`` (the
    participation-masked round) additionally drops zero-weight clients from
    the AJIVE joint-basis estimate — without it they only vanish from the
    final weighted mean, not from the unweighted joint-subspace phases.
    ``bucketed`` runs shape-identical leaves as one vmapped program per
    bucket (`state_sync.map_sync_leaves`); False keeps the per-leaf loop as
    the parity oracle. ``robust_agg`` is robust 𝒮: the weighted-mean
    reductions over the projected-moment stacks inside the factored sync
    protocols are swapped for the robust estimator (trimmed-mean /
    geomedian; heterogeneous bases are first re-based onto the client-0
    basis via the r×r transfer Grams) — ``'none'`` lowers exactly the plain
    program, bitwise.
    """
    g_stack = gal.galore_state_of(out_st)
    if state_sync != "none":
        bases = gal.extract_bases(g_stack)
        v_upload = gal.extract_projected_v(g_stack)
        vs, treedef = jax.tree_util.tree_flatten(v_upload,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(bases, is_leaf=lambda x: x is None)

        def leaf_fn(v_stack, b_stack):
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT
            if not factored:
                return _dense_sync_block(state_sync, v_stack, b_stack, w,
                                         rank, side)
            if bases_shared:
                # Factored 𝒮: sync the (C, ., r) uplink directly; the shared
                # seeded basis cancels, so no (C, m, n) lift and no (n, n)
                # projector. Result is the O(dim·r) projected state.
                return jnp.maximum(sync_lib.sync_block_synced_factored(
                    state_sync, v_stack, side, w, rank,
                    exclude_zero_weights=exclude_zero_weights,
                    robust=robust_agg, trim=robust_trim, iters=robust_iters,
                    tol=robust_tol), 0.0)
            # Diverged bases (data-driven refreshes): the lift → 𝒮 →
            # re-project round-trip closes over r×r transfer Grams —
            # the dense per-client lift stays a parity oracle.
            return jnp.maximum(sync_lib.sync_block_hetero_factored(
                state_sync, v_stack, b_stack, side, w, rank,
                exclude_zero_weights=exclude_zero_weights,
                robust=robust_agg, trim=robust_trim, iters=robust_iters,
                tol=robust_tol), 0.0)

        synced_leaves = sync_lib.map_sync_leaves(leaf_fn, vs, bs,
                                                 bucketed=bucketed)
        # every client slot shares the synced projected state (a broadcast
        # view of the O(dim·r) buffer, not a dense tensor)
        out = [None if s is None else
               jnp.broadcast_to(s[None], (n_clients,) + s.shape)
               for s in synced_leaves]
        synced_tree = jax.tree_util.tree_unflatten(treedef, out)
        g_new = gal.with_projected_v(g_stack, synced_tree)
    else:
        g_new = g_stack
    g_new = gal.GaloreState(
        count=g_new.count, seed=g_new.seed + 1, blocks=g_new.blocks)
    return gal.replace_galore_state(out_st, g_new)


def _dense_sync_block(state_sync, v_stack, b_stack, w, rank, side):
    """Dense reference 𝒮 (parity oracle): lift each client's ṽ with its
    *own* end-of-round basis (correct under diverged bases), run the
    configured protocol on the lifted views, re-project onto the
    client-0 basis."""
    def sync_one(v_cl, b_cl):
        # v_cl (C, m, r) | (C, r, n); b_cl (C, dim, r)
        v32 = v_cl.astype(jnp.float32)
        b32 = b_cl.astype(jnp.float32)
        if side == proj.RIGHT:
            views = jnp.einsum("kmr,knr->kmn", v32, b32)
        else:
            views = jnp.einsum("kmr,krn->kmn", b32, v32)
        lifted = sync_lib.sync_lifted_views(state_sync, views, w, rank)
        return jnp.maximum(sync_lib.project_state(lifted, b_cl[0], side), 0.0)

    if v_stack.ndim == 4:         # stacked scan blocks: (C, nb, ., r)
        return jax.vmap(sync_one, in_axes=(1, 1))(v_stack, b_stack)
    return sync_one(v_stack, b_stack)


def make_fed_round_step(cfg: ArchConfig, spec: TrainSpec, n_clients: int,
                        state_sync: Optional[str] = None,
                        factored_sync: bool = True,
                        factored_clients: bool = True,
                        client_chunk: Optional[int] = None,
                        lift_free: Optional[bool] = None,
                        exclude_zero_weights: bool = False,
                        robust_agg: str = "none",
                        quarantine: bool = False,
                        quarantine_zmax: float = 6.0,
                        robust_trim: float = 0.2,
                        robust_iters: int = 8,
                        robust_tol: float = 1e-6,
                        bucketed_sync: bool = True,
                        return_weights: bool = False) -> Callable:
    """A full federated round (Algorithm 1) as one SPMD program:

      broadcast (implicit: clients start from the shared global base) →
      T local GaLoreAdamW steps (lax.scan), streamed over cohort chunks →
      𝒜: factored ``base_scale·W + Σ wᵢ lift(Rᵢ)`` (or the dense weighted
      mean over the client axis under ``factored_clients=False``) →
      𝒮 (when ``state_sync`` is a protocol name): factored sync of the
      projected second moments, install + seed bump — all inside the mesh;
      the returned states are ready for the next round.

    ``factored_clients`` selects the rank-r factored client memory model
    (module docstring); it requires in-step refreshes to land on local step 0
    (``refresh_every % local_steps == 0``) and every trainable leaf to be a
    target block, falling back to the dense client round otherwise.
    ``lift_free`` (None = ``spec.lift_free``) additionally runs the factored
    local phase through the delta context — zero lift GEMMs and zero dense
    gradient cotangents; auto-disabled for ``refresh_mode='svd'``.
    ``client_chunk=B`` (must divide ``n_clients``, and B must still cover the
    client mesh axes) runs the local phase in C/B sequential chunks.
    ``state_sync=None`` preserves the legacy 𝒯→𝒜 program: raw end-of-round
    states are returned and the caller runs 𝒮 on the host (the eager
    reference path, and the dry-run default). It is also the building block
    of the runtime's pipelined scan (`fedsim.runtime.ShardedFederation.
    run_rounds`), which defers each round's `sync_client_states` to the top
    of the next round's body. ``bucketed_sync`` selects the bucketed/vmapped
    𝒮 leaf execution (see `sync_client_states`).
    ``exclude_zero_weights`` lowers the participation-masked round variant:
    the caller feeds pre-masked weights (zero for non-participants — the
    in-program normalization renormalizes over the participants) and 𝒮
    drops the zero-weight clients from the AJIVE joint basis. Kept off by
    default so the unmasked program stays byte-for-byte what it was before
    the participation layer.
    ``quarantine`` / ``robust_agg`` lower the guarded round variant
    (mirroring ``core.fed``): after the local phase, every client's factored
    contribution is screened (non-finite reduction + ``quarantine_zmax`` ×
    weighted-median norm-outlier test, in factored coordinates) and failures
    fold into the zero-weight mask path — renormalized out of 𝒜, sanitized
    stacks, excluded from the AJIVE score Gram; ``robust_agg`` swaps the
    weighted mean in 𝒜 for a robust reduction
    (``aggregation.robust_factored_lift`` — heterogeneous-basis 'svd' rounds
    re-base every client's stack onto the client-0 basis via the r×r
    transfer Grams, so the coordinate-wise modes stay well-defined), and the
    same mode robustifies 𝒮's projected-moment reductions
    (``sync_client_states``). Both require the factored client round.
    All-honest cohorts short-circuit bitwise onto the unguarded math; the
    defaults lower a program byte-for-byte identical to the pre-defense one.

    The returned ``round_step`` additionally accepts an optional trailing
    ``attack`` operand — the engine-parity ``(C,)`` per-client corruption
    multiplier, applied to each client's factored accumulators AND projected
    moments after the local phase, *before* the quarantine screen (exactly
    ``core.fed.FedEngine._apply_guard``'s injection order). ``attack=None``
    (the default) lowers a program with no injection code at all, so honest
    callers are untouched. Injection requires the factored client round.

    ``return_weights`` appends the post-quarantine renormalized effective
    weight vector as a final output — the pipelined-scan building block:
    the runtime's quarantined ``run_rounds`` carries these weights so the
    deferred next-round 𝒮 reduces over the surviving clients only, letting
    the quarantined scan pipeline one round deep like the honest path.
    """
    tx = make_galore_tx(cfg, spec)
    gcfg = make_galore_cfg(spec)
    if robust_agg not in agg_lib.ROBUST_MODES:
        raise ValueError(f"robust_agg={robust_agg!r} not in "
                         f"{agg_lib.ROBUST_MODES}")
    guard = quarantine or robust_agg != "none"
    # Factored deltas are exact only while the basis is fixed whenever any
    # R_i ≠ 0, i.e. refreshes only at local step 0 (count ≡ 0 mod τ there).
    factored_ok = (factored_clients
                   and spec.refresh_every % spec.local_steps == 0)
    # Lift-free needs every in-step refresh to be seeded-random (the hoisted
    # refresh never sees a gradient): 'svd' mode keeps the transient read.
    # MLA with blockwise attention reads kv_b once per chunk, which breaks
    # the clip-norm probe's exactness (per-use ‖·‖² sum misses cross-chunk
    # terms — models.layers.lowrank_apply): keep the transient read there.
    if lift_free is None:
        lift_free = spec.lift_free
    multi_read = (cfg.attn_chunk and any(
        mix == "mla" for mix, _ in cfg.layer_kinds()))
    liftfree_ok = (lift_free and spec.refresh_mode != "svd"
                   and not multi_read)
    chunk = client_chunk or n_clients
    if n_clients % chunk:
        raise ValueError(f"client_chunk={chunk} must divide n_clients="
                         f"{n_clients}")
    n_chunks = n_clients // chunk

    def client_round(trainable, frozen, opt_state, batches):
        def one(carry, batch):
            tr, st = carry
            def loss_of(t):
                return model_lib.loss_fn(merge_dense(frozen, t), cfg, batch)
            loss, grads = jax.value_and_grad(loss_of)(tr)
            updates, st = tx.update(grads, st, tr)
            return (apply_updates(tr, updates), st), loss
        (trainable, opt_state), losses = jax.lax.scan(
            one, (trainable, opt_state), batches)
        return trainable, opt_state, losses

    def client_round_factored(deltas, frozen, opt_state, batches,
                              global_trainable):
        def one(carry, batch):
            dl, scale, st = carry
            tr = gal.lift_client_trainable(global_trainable, dl,
                                           gal.galore_state_of(st), scale)
            def loss_of(t):
                return model_lib.loss_fn(merge_dense(frozen, t), cfg, batch)
            loss, grads = jax.value_and_grad(loss_of)(tr)
            dl, scale, st = gal.factored_adamw_step(
                gcfg, grads, st, dl, scale, lr=spec.lr,
                weight_decay=spec.weight_decay, clip_norm=spec.clip_norm)
            return (dl, scale, st), loss
        (deltas, scale, opt_state), losses = jax.lax.scan(
            one, (deltas, jnp.ones([], jnp.float32), opt_state), batches)
        return deltas, opt_state, losses, scale

    def client_round_liftfree(deltas, frozen, opt_state, batches,
                              global_trainable):
        """The lift-free local phase: hoisted seeded-random refresh, delta-
        context forward (LowRankDelta leaves — no per-leaf transient lift),
        projected-cotangent backward, factored AdamW on the LiftFreeGrads
        bundle (projection GEMM skipped, clipping via the norm probes)."""
        def one(carry, batch):
            dl, scale, st = carry
            g0 = gal.maybe_refresh_instep(gcfg, gal.galore_state_of(st))
            st = gal.replace_galore_state(st, g0)
            def loss_of(t):
                return model_lib.loss_fn(merge_dense(frozen, t), cfg, batch)
            loss, grads = gal.liftfree_value_and_grad(
                loss_of, global_trainable, dl, g0, scale)
            dl, scale, st = gal.factored_adamw_step(
                gcfg, grads, st, dl, scale, lr=spec.lr,
                weight_decay=spec.weight_decay, clip_norm=spec.clip_norm)
            return (dl, scale, st), loss
        (deltas, scale, opt_state), losses = jax.lax.scan(
            one, (deltas, jnp.ones([], jnp.float32), opt_state), batches)
        return deltas, opt_state, losses, scale

    from ..models.layers import batch_axes_override

    def _stream(local_fn, opt_states, batches):
        """Run the B-client local phase over the cohort: directly for a
        single chunk, as a ``lax.scan`` over C/B (opt_chunk, batch_chunk)
        slices otherwise, reassembling the full (C, …) stacks."""
        if n_chunks == 1:
            return local_fn(opt_states, batches)
        opt_c = gal.chunk_opt_state(opt_states, n_chunks, chunk)
        cb = jax.tree_util.tree_map(
            lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), batches)
        _, out = jax.lax.scan(
            lambda carry, xs: (carry, local_fn(*xs)), None, (opt_c, cb))
        unchunk = lambda x: x.reshape((n_clients,) + x.shape[2:])
        merged = (jax.tree_util.tree_map(unchunk, out[0]),
                  gal.unchunk_opt_state(out[1], n_clients), unchunk(out[2]))
        if len(out) == 4:                         # factored: (C,) base scales
            merged += (out[3].reshape((n_clients,)),)
        return merged

    def _local_phase_factored(global_trainable, frozen, opt_states, batches,
                              axes):
        """Chunk-streamed factored local phase: (C,…) states/batches →
        (C,…) factored deltas + end-of-round states + losses + per-client
        base scales."""
        g_blocks = gal.galore_state_of(opt_states).blocks
        deltas0 = jax.tree_util.tree_map(
            lambda st: jnp.zeros((chunk,) + st.m.shape[1:], jnp.float32),
            g_blocks,
            is_leaf=lambda x: isinstance(x, (gal.GaloreBlockState,
                                             gal.DenseMoments)))

        client_fn = (client_round_liftfree if liftfree_ok
                     else client_round_factored)

        def local_fn(opt_chunk, batch_chunk):
            with batch_axes_override(()):
                return jax.vmap(
                    client_fn, in_axes=(0, None, axes, 0, None),
                    out_axes=(0, axes, 0, 0),
                    spmd_axis_name=spec.client_axes)(
                    deltas0, frozen, opt_chunk, batch_chunk,
                    global_trainable)

        return _stream(local_fn, opt_states, batches)

    def _local_phase_dense(global_trainable, frozen, opt_states, batches,
                           axes):
        """Chunk-streamed dense local phase (the parity-oracle client model:
        per-client weight stacks)."""
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (chunk,) + x.shape),
            global_trainable)

        def local_fn(opt_chunk, batch_chunk):
            with batch_axes_override(()):
                return jax.vmap(
                    client_round, in_axes=(0, None, axes, 0),
                    out_axes=(0, axes, 0),
                    spmd_axis_name=spec.client_axes)(
                    stacked, frozen, opt_chunk, batch_chunk)

        return _stream(local_fn, opt_states, batches)

    def round_step(global_trainable, frozen, opt_states, batches, weights,
                   attack=None):
        w = weights / jnp.sum(weights)
        axes = gal.client_opt_axes(opt_states)
        use_factored = (factored_ok and gal.all_blocks_projected(
            gal.galore_state_of(opt_states)))
        if attack is not None and not use_factored:
            raise ValueError("the attack operand requires the factored "
                             "client round")
        if use_factored:
            out_d, out_st, losses, base_scales = _local_phase_factored(
                global_trainable, frozen, opt_states, batches, axes)
            if attack is not None:
                # Adversary injection (engine parity): multiply each
                # client's uplink — factored accumulators AND projected
                # moments — by its attack entry, before the screen.
                tmap = jax.tree_util.tree_map
                ab = lambda x: attack.astype(jnp.float32).reshape(
                    (-1,) + (1,) * (x.ndim - 1))
                out_d = tmap(lambda x: (x.astype(jnp.float32)
                                        * ab(x)).astype(x.dtype), out_d)
                g_st = gal.galore_state_of(out_st)
                v_atk = tmap(
                    lambda x: None if x is None
                    else (x.astype(jnp.float32) * ab(x)).astype(x.dtype),
                    gal.extract_projected_v(g_st),
                    is_leaf=lambda x: x is None)
                out_st = gal.replace_galore_state(
                    out_st, gal.with_projected_v(g_st, v_atk))
            if guard and quarantine:
                # In-round quarantine: screen the factored uplink, fold
                # failures into the zero-weight mask path (sanitized
                # stacks, renormalized weights, moments zeroed out of the
                # score Gram). All-pass verdicts leave every operand
                # bitwise untouched.
                g_st = gal.galore_state_of(out_st)
                v_tree = gal.extract_projected_v(g_st)
                keep = agg_lib.screen_factored_clients(
                    out_d, v_tree, base_scales, w, zmax=quarantine_zmax)
                out_d = agg_lib.mask_client_rows(out_d, keep)
                v_tree = agg_lib.mask_client_rows(v_tree, keep)
                base_scales = jnp.where(keep, base_scales, 1.0)
                w = agg_lib.quarantine_weights(w, keep)
                out_st = gal.replace_galore_state(
                    out_st, gal.with_projected_v(g_st, v_tree))
            # 𝒜 factored: reduce in projected coordinates (shared seeded
            # basis) or contract per-client lifts ('svd' diverges bases).
            bases = gal.extract_bases(gal.galore_state_of(out_st))
            hetero = spec.refresh_mode == "svd"
            sbar = jnp.einsum("c,c->", w, base_scales.astype(jnp.float32))

            def one(x, d_stack, b_stack):
                side = (proj.RIGHT if d_stack.shape[-1] == b_stack.shape[-1]
                        else proj.LEFT)
                lifted = agg_lib.robust_factored_lift(
                    d_stack, b_stack, side, w, robust_agg, hetero=hetero,
                    trim=robust_trim, iters=robust_iters, tol=robust_tol)
                return (sbar * x.astype(jnp.float32)
                        + lifted).astype(x.dtype)

            new_global = jax.tree_util.tree_map(one, global_trainable,
                                                out_d, bases)
        else:
            if guard:
                raise ValueError(
                    "quarantine/robust_agg require the factored client "
                    "round (factored_clients with step-0-aligned refreshes "
                    "and all-target trainables)")
            out_tr, out_st, losses = _local_phase_dense(
                global_trainable, frozen, opt_states, batches, axes)
            # 𝒜: weighted average over the client axis -> all-reduce on mesh
            new_global = jax.tree_util.tree_map(
                lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0)
                                        ).astype(x.dtype), out_tr)
        if state_sync is not None:
            # 𝒮 in-mesh: the round program returns next-round-ready states;
            # the pre-sync ṽ is consumed internally, never materialized as
            # an output. A quarantine-guarded round excludes zero-weight
            # clients from the joint basis even when the caller didn't ask
            # for the masked variant (exact no-op on all-positive weights).
            out_st = sync_client_states(
                out_st, w, n_clients, state_sync, factored=factored_sync,
                bases_shared=(spec.refresh_mode != "svd"),
                exclude_zero_weights=exclude_zero_weights or quarantine,
                bucketed=bucketed_sync, robust_agg=robust_agg,
                robust_trim=robust_trim, robust_iters=robust_iters,
                robust_tol=robust_tol)
            if return_weights:
                return new_global, out_st, losses, None, w
            return new_global, out_st, losses, None
        # 𝒮 payload for the host-side filter: projected second moments ṽ
        # (client-stacked, O(n·r))
        v_upload = gal.extract_projected_v(gal.galore_state_of(out_st))
        if return_weights:
            return new_global, out_st, losses, v_upload, w
        return new_global, out_st, losses, v_upload

    return round_step


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    def prefill_step(params, tokens, embeds=None):
        state = model_lib.init_decode_state(cfg, tokens.shape[0], cache_len)
        return model_lib.prefill(params, cfg, tokens, state, embeds)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode(params, token, state):
        return model_lib.decode_step(params, cfg, token, state)
    return decode
