"""Step functions lowered by the dry-run / executed by train.py & serve.py.

The train step IS the paper's client workload: one FedGaLore local step —
dense gradients on the target modules, GaLoreAdamW update in the rank-r
subspace, frozen base weights. Clients are vmapped over the (pod, data) mesh
axes; the frozen base is FSDP-sharded (identical across clients, so weight
sharding is sound), while each client's trainable copy shards over the model
axis only.

``make_fed_round_step`` additionally lowers a *whole round*: T local steps
(scan) + FedAvg aggregation (weighted mean over the client axis) + projected
second-moment extraction for server-side AJIVE sync — the paper's full
𝒯→𝒜→𝒮 pipeline as one SPMD program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..core.fed import merge_dense, split_trainable
from ..models import model as model_lib
from ..optim.base import apply_updates

PyTree = Any


def galore_target_fn(cfg: ArchConfig) -> Callable:
    """The paper's target modules, adapted per family (DESIGN.md §4):
    attention + dense-MLP projections; Mamba in/out projections; RWKV6
    time-mix/channel-mix matrices. Experts, routers, embeddings frozen."""

    def fn(path: str, leaf) -> bool:
        if leaf.ndim < 2:
            return False
        if "embed" in path or "lm_head" in path:
            return False
        if "/moe/" in path or "/shared/" in path:
            return False
        last = path.split("/")[-1]
        if "/attn/" in path:
            return True
        if "/mlp/" in path:
            return True
        if "/mamba/" in path:
            return last in ("in_proj", "out_proj")
        if "/tmix/" in path:
            return last in ("wr", "wk", "wv", "wg", "wo")
        if "/cmix/" in path:
            return last in ("wk", "wv", "wr")
        return False

    return fn


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    rank: int = 64
    lr: float = 1e-4
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0
    refresh_every: int = 200
    local_steps: int = 8                # T (round step only)
    seed: int = 0
    refresh_mode: str = "random"        # production steady-state step
    # Fused/bucketed GaLore execution (core.galore module docstring):
    # fused=True batches same-shape target blocks per step; use_pallas=None
    # auto-selects the fused Pallas kernel on TPU (interpret fallback on CPU
    # when forced True).
    fused: bool = True
    use_pallas: Optional[bool] = None
    # Mesh axes carrying the client dimension. jax.vmap(spmd_axis_name=...)
    # pins every per-client intermediate's leading dim to these axes —
    # without it SPMD replicated the client dim across the data axis
    # (§Perf iteration A measured 16× inflated loss-tensor bytes).
    client_axes: tuple = ("data",)


def make_galore_tx(cfg: ArchConfig, spec: TrainSpec):
    gcfg = gal.GaloreConfig(rank=spec.rank, refresh_every=spec.refresh_every,
                            adaptive_steps=0, refresh_mode=spec.refresh_mode,
                            fused=spec.fused, use_pallas=spec.use_pallas)
    return gal.galore_adamw(gcfg, spec.lr, spec.weight_decay,
                            target_fn=lambda p, l: True,  # trainable tree is
                            seed=spec.seed,               # already filtered
                            clip_norm=spec.clip_norm)


def init_train_state(key, cfg: ArchConfig, spec: TrainSpec):
    """(trainable, frozen, opt_state) for ONE client."""
    params = model_lib.init_params(key, cfg)
    trainable, frozen = split_trainable(params, galore_target_fn(cfg))
    tx = make_galore_tx(cfg, spec)
    opt_state = tx.init(trainable)
    return trainable, frozen, opt_state


def make_fed_local_step(cfg: ArchConfig, spec: TrainSpec,
                        n_clients: int) -> Callable:
    """One GaLoreAdamW local step for every client in parallel.

    Args (client-stacked leaves marked ×C):
      trainable ×C, frozen (shared), opt_state ×C,
      batch {tokens ×C (c, b, L), labels ×C, embeds? ×C}
    Returns (trainable ×C, opt_state ×C, loss (C,)).
    """
    tx = make_galore_tx(cfg, spec)

    def client_step(trainable, frozen, opt_state, batch):
        def loss_of(tr):
            params = merge_dense(frozen, tr)
            return model_lib.loss_fn(params, cfg, batch)
        loss, grads = jax.value_and_grad(loss_of)(trainable)
        updates, opt_state = tx.update(grads, opt_state, trainable)
        trainable = apply_updates(trainable, updates)
        return trainable, opt_state, loss

    from ..models.layers import batch_axes_override

    def step(trainable, frozen, opt_state, batch):
        with batch_axes_override(()):
            return jax.vmap(client_step, in_axes=(0, None, 0, 0),
                            spmd_axis_name=spec.client_axes)(
                trainable, frozen, opt_state, batch)

    return step


def make_fed_round_step(cfg: ArchConfig, spec: TrainSpec,
                        n_clients: int) -> Callable:
    """A full federated round (Algorithm 1) as one SPMD program:

      broadcast (implicit: clients start from identical trainables) →
      T local GaLoreAdamW steps (lax.scan) →
      FedAvg aggregation = mean over the client axis (XLA: all-reduce over
      the (pod, data) mesh axes) →
      upload ṽ: client-stacked projected second moments returned for the
      host-side AJIVE filter.
    """
    tx = make_galore_tx(cfg, spec)

    def client_round(trainable, frozen, opt_state, batches):
        def one(carry, batch):
            tr, st = carry
            def loss_of(t):
                return model_lib.loss_fn(merge_dense(frozen, t), cfg, batch)
            loss, grads = jax.value_and_grad(loss_of)(tr)
            updates, st = tx.update(grads, st, tr)
            return (apply_updates(tr, updates), st), loss
        (trainable, opt_state), losses = jax.lax.scan(
            one, (trainable, opt_state), batches)
        return trainable, opt_state, losses

    def round_step(global_trainable, frozen, opt_states, batches, weights):
        # broadcast: stack the global trainable along the client axis
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape),
            global_trainable)
        from ..models.layers import batch_axes_override
        with batch_axes_override(()):
            out_tr, out_st, losses = jax.vmap(
                client_round, in_axes=(0, None, 0, 0),
                spmd_axis_name=spec.client_axes)(stacked, frozen,
                                                 opt_states, batches)
        w = weights / jnp.sum(weights)
        # 𝒜: weighted average over the client axis -> all-reduce on the mesh
        new_global = jax.tree_util.tree_map(
            lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0)
                                    ).astype(x.dtype), out_tr)
        # 𝒮 payload: projected second moments ṽ (client-stacked, O(n·r))
        g_state = gal.galore_state_of(out_st)
        v_upload = gal.extract_projected_v(g_state)
        return new_global, out_st, losses, v_upload

    return round_step


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    def prefill_step(params, tokens, embeds=None):
        state = model_lib.init_decode_state(cfg, tokens.shape[0], cache_len)
        return model_lib.prefill(params, cfg, tokens, state, embeds)
    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    def decode(params, token, state):
        return model_lib.decode_step(params, cfg, token, state)
    return decode
