"""Production mesh construction (TPU v5e pod targets).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the pod axis carries pure data/client parallelism —
in the federated mapping, clients live on (pod, data) and the only cross-pod
traffic is the per-round aggregation all-reduce + state-sync gather.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (CPU tests / examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
TPU_V5E = {
    "peak_bf16_flops": 197e12,    # per chip
    "hbm_bw": 819e9,              # bytes/s per chip
    "ici_bw": 50e9,               # bytes/s per link
}
