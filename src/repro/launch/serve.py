"""Batched serving driver: prefill a batch of prompts, decode greedily.

Serves the (possibly fine-tuned) global model — the inference side of the
input-shape matrix (prefill_32k / decode_32k / long_500k lower these exact
step functions on the production mesh; here they run host-scale).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, smoke_variant
from ..models import model as model_lib


def generate(params, cfg, prompts, new_tokens: int, cache_len: int,
             temperature: float = 0.0, key=None):
    """prompts (B, L) -> (B, L + new_tokens). Greedy when temperature == 0."""
    b = prompts.shape[0]
    state = model_lib.init_decode_state(cfg, b, cache_len)
    logits, state = model_lib.prefill(params, cfg, prompts, state)

    def sample(lg, k):
        if temperature <= 0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok = sample(logits, key)
    out = [tok]

    step = jax.jit(lambda p, t, s: model_lib.decode_step(p, cfg, t, s))
    for i in range(new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, state = step(params, tok, state)
        tok = sample(logits, sub)
        out.append(tok)
    return jnp.concatenate([prompts, jnp.stack(out, axis=1)], axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="KV slots (0 = prompt+new)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache = args.cache_len or (args.prompt_len + args.new_tokens)

    t0 = time.time()
    out = generate(params, cfg, prompts, args.new_tokens, cache,
                   args.temperature, key)
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(json.dumps({"arch": cfg.name, "batch": args.batch,
                      "prompt_len": args.prompt_len,
                      "new_tokens": args.new_tokens,
                      "sec": round(dt, 2),
                      "tokens_per_sec": round(tput, 1),
                      "sample_row": out[0, -args.new_tokens:].tolist()}))


if __name__ == "__main__":
    main()
