"""Multi-tenant low-rank serving: fused scan decode + continuous batching.

Three serving paths over the same model, slowest to fastest:

- :func:`generate`       eager per-token loop — one jitted ``decode_step``
                         dispatch per token. Kept as the parity oracle
                         (greedy scan decode must match it bit-for-bit).
- :func:`generate_scan`  the whole decode loop as ONE jitted ``lax.scan``:
                         no per-token Python dispatch, decode state donated
                         so KV ring buffers update in place, sampling keys
                         derived in-scan with ``jax.random.fold_in``.
- :class:`SlotServer`    continuous batching on top of the scan: requests
                         occupy slots of a fixed decode batch, finished
                         sequences retire mid-stream via in-scan EOS/length
                         masks, and queued requests are admitted into freed
                         slots between scan segments (per-request prefill +
                         jitted in-mesh slot insert).

Per-row heterogeneous adapters ride along on all three paths: pass
``adapters`` (B,) int ids and params whose target leaves are
``MultiAdapterDelta`` tables (built by :mod:`repro.launch.adapters`) — each
decode row then applies its own factored ``(basis, R̃)`` delta over one
shared base GEMM, so one compiled batch serves many tenants.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 --mode scan
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import time
import warnings
from typing import Any, Dict, List

import numpy as np

import jax
import jax.numpy as jnp

# Decode state is donated into the scan programs; on CPU some leaves can't
# alias (dtype/layout mismatch) and jax warns per compile. Harmless here —
# donation is for the TPU path — so keep serving logs clean.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")

from ..configs import get_config, smoke_variant
from ..models import layers
from ..models import model as model_lib

PAD_ID = 0   # emitted by retired slots inside a segment; never surfaced


def _env_hygiene() -> None:
    """Launcher hygiene, applied BEFORE jax touches the backend (mirrors
    benchmarks/run.py and the shell block in scripts/ci.sh): tcmalloc
    preload can't be done from in-process (LD_PRELOAD is read at exec), but
    the allocator threshold, C++ log level, and XLA host-device plumbing
    are env-var driven and honored at first backend initialization — which
    happens at the first jax *operation*, after this runs."""
    os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                          "60000000000")
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    flags = []
    host_devices = os.environ.get("REPRO_HOST_DEVICES")
    if host_devices:
        flags.append(f"--xla_force_host_platform_device_count={host_devices}")
    # Opt-in only: rejected by CPU builds of XLA (unknown-flag error).
    if os.environ.get("REPRO_STEP_MARKERS") == "1":
        flags.append("--xla_step_marker_location=1")
    if flags:
        prev = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (prev + " " + " ".join(flags)).strip()


def _sample(logits, key, temperature):
    """Greedy argmax when temperature <= 0 (key unused), else categorical."""
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


# --------------------------------------------------------------------------
# Cached jitted programs. ArchConfig is a frozen (hashable) dataclass, so it
# keys lru_cache directly; jit's own cache handles shape polymorphism under
# each entry. ``ids`` is always an argument (None for single-tenant params —
# a leafless pytree, so it costs nothing and avoids a second trace).
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg):
    def run(params, prompt, state, ids):
        with layers.adapter_ids(ids):
            return model_lib.prefill(params, cfg, prompt, state)
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _eager_step_fn(cfg):
    def run(params, tok, state, ids):
        with layers.adapter_ids(ids):
            return model_lib.decode_step(params, cfg, tok, state)
    return jax.jit(run)


@functools.lru_cache(maxsize=None)
def _scan_decode_fn(cfg, steps: int, temperature: float):
    """The fused decode loop: ``steps`` tokens after the prefill-sampled
    one, as a single device program. State is donated — the KV ring
    buffers alias in place instead of round-tripping per token."""
    def run(params, tok0, state, key, ids):
        def body(carry, i):
            tok, st = carry
            with layers.adapter_ids(ids):
                logits, st = model_lib.decode_step(params, cfg, tok, st)
            nxt = _sample(logits, jax.random.fold_in(key, i), temperature)
            return (nxt, st), nxt
        (_, _), toks = jax.lax.scan(body, (tok0, state), jnp.arange(steps))
        return jnp.moveaxis(toks, 0, 1)            # (B, steps)
    return jax.jit(run, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _insert_fn(cfg):
    """In-mesh slot insert: write one prefilled request's cache rows, its
    absolute position, and its first token into slot ``slot`` of the live
    batched decode state. Layer-state leaves are stacked (nb, B, ...), so
    the slot axis is 1."""
    def run(state, tok, slot, sub_state, sub_tok):
        new_layers = jax.tree_util.tree_map(
            lambda big, small: jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=1),
            state.layers, sub_state.layers)
        new_t = state.t.at[slot].set(sub_state.t)
        return (model_lib.DecodeState(t=new_t, layers=new_layers),
                tok.at[slot].set(sub_tok[0]))
    return jax.jit(run, donate_argnums=(0, 1))


@functools.lru_cache(maxsize=None)
def _segment_fn(cfg, segment: int, temperature: float, eos_id: int):
    """One continuous-batching segment: ``segment`` fused decode steps with
    in-scan retirement — a row that emits ``eos_id`` or exhausts its budget
    goes inactive and emits PAD_ID for the rest of the segment (its state
    keeps advancing harmlessly; admission overwrites the whole slot)."""
    def run(params, tok, state, active, remaining, ids, key, base):
        def body(carry, i):
            tok, st, act, rem = carry
            with layers.adapter_ids(ids):
                logits, st = model_lib.decode_step(params, cfg, tok, st)
            nxt = _sample(logits, jax.random.fold_in(key, base + i),
                          temperature)
            nxt = jnp.where(act, nxt, PAD_ID)
            rem = jnp.where(act, rem - 1, rem)
            act = act & (rem > 0)
            if eos_id >= 0:
                act = act & (nxt != eos_id)
            return (nxt, st, act, rem), nxt
        (tok, state, active, remaining), toks = jax.lax.scan(
            body, (tok, state, active, remaining), jnp.arange(segment))
        return tok, state, active, remaining, jnp.moveaxis(toks, 0, 1)
    return jax.jit(run, donate_argnums=(1, 2))


# --------------------------------------------------------------------------
# Whole-sequence drivers
# --------------------------------------------------------------------------

def generate(params, cfg, prompts, new_tokens: int, cache_len: int,
             temperature: float = 0.0, key=None, adapters=None):
    """prompts (B, L) -> (B, L + new_tokens). Greedy when temperature == 0.

    The eager per-token loop — the parity oracle for :func:`generate_scan`.
    ``adapters`` (B,) int ids select each row's factor set when params
    carry ``MultiAdapterDelta`` leaves.
    """
    b = prompts.shape[0]
    ids = None if adapters is None else jnp.asarray(adapters, jnp.int32)
    state = model_lib.init_decode_state(cfg, b, cache_len)
    logits, state = _prefill_fn(cfg)(params, prompts, state, ids)

    key = key if key is not None else jax.random.PRNGKey(0)
    tok = _sample(logits, key, temperature)
    out = [tok]

    step = _eager_step_fn(cfg)
    for _ in range(new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, state = step(params, tok, state, ids)
        tok = _sample(logits, sub, temperature)
        out.append(tok)
    return jnp.concatenate([prompts, jnp.stack(out, axis=1)], axis=1)


def generate_scan(params, cfg, prompts, new_tokens: int, cache_len: int,
                  temperature: float = 0.0, key=None, adapters=None):
    """Fused twin of :func:`generate`: the decode loop is ONE jitted
    ``lax.scan`` dispatch. Greedy output is bit-identical to the eager
    oracle; at temperature > 0 both are valid draws from the same model
    but use different key chains (in-scan ``fold_in`` here, sequential
    splits there)."""
    b = prompts.shape[0]
    ids = None if adapters is None else jnp.asarray(adapters, jnp.int32)
    state = model_lib.init_decode_state(cfg, b, cache_len)
    logits, state = _prefill_fn(cfg)(params, prompts, state, ids)
    key = key if key is not None else jax.random.PRNGKey(0)
    tok0 = _sample(logits, key, temperature)
    if new_tokens <= 1:
        return jnp.concatenate([prompts, tok0[:, None]], axis=1)
    toks = _scan_decode_fn(cfg, new_tokens - 1, float(temperature))(
        params, tok0, state, key, ids)
    return jnp.concatenate([prompts, tok0[:, None], toks], axis=1)


# --------------------------------------------------------------------------
# Continuous batching
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request: ``prompt`` (L,) int tokens, decode budget
    ``max_new``, and the adapter id its rows should apply."""
    rid: int
    prompt: Any
    max_new: int
    adapter: int = 0


class SlotServer:
    """Slot-based continuous batching over the fused segment scan.

    A fixed decode batch of ``slots`` rows runs ``segment``-step fused
    scans. Rows retire mid-segment (EOS or budget) via in-scan masks;
    between segments the host drains finished slots and admits queued
    requests into the free ones — per-request prefill, then a jitted
    in-mesh insert of the slot's cache rows, position, and first token.
    Nothing about an admit recompiles: the segment program is fixed-shape.
    """

    def __init__(self, params, cfg, *, slots: int, cache_len: int,
                 segment: int = 8, eos_id: int = -1,
                 temperature: float = 0.0, seed: int = 0):
        self.params, self.cfg = params, cfg
        self.slots = int(slots)
        self.cache_len = int(cache_len)
        self.segment = int(segment)
        self.eos_id = int(eos_id)          # -1 = no EOS, budget-only
        self.temperature = float(temperature)
        self.key = jax.random.PRNGKey(seed)
        self.state = model_lib.init_decode_state(cfg, self.slots, cache_len,
                                                 per_slot=True)
        self.tok = jnp.zeros((self.slots,), jnp.int32)
        self.ids = jnp.zeros((self.slots,), jnp.int32)
        # Canonicalize the carry dtypes to decode_step's fixed point: some
        # recurrent-state leaves (e.g. RWKV shift buffers initialized in
        # the param dtype) are promoted to fp32 by the step — the segment
        # scan requires carry-in == carry-out types.
        with layers.adapter_ids(self.ids):
            spec = jax.eval_shape(
                lambda p, t, s: model_lib.decode_step(p, cfg, t, s)[1],
                params, self.tok, self.state)
        self.state = jax.tree_util.tree_map(
            lambda x, sp: x.astype(sp.dtype), self.state, spec)
        self.active = np.zeros(self.slots, bool)
        self.remaining = np.zeros(self.slots, np.int32)
        self.rid = np.full(self.slots, -1, np.int64)
        self.queue: List[Request] = []
        self.outputs: Dict[int, List[int]] = {}
        self._step_base = 0
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_tokens": 0, "decode_tokens": 0,
                      "segments": 0, "admitted": 0}

    def submit(self, request: Request) -> None:
        self.queue.append(request)

    def _admit(self) -> None:
        """Fill free slots from the queue (per-request prefill + insert)."""
        for slot in range(self.slots):
            if not self.queue:
                return
            if self.active[slot]:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            sub_state = model_lib.init_decode_state(self.cfg, 1,
                                                    self.cache_len)
            sub_ids = jnp.full((1,), req.adapter, jnp.int32)
            t0 = time.perf_counter()
            logits, sub_state = _prefill_fn(self.cfg)(
                self.params, prompt, sub_state, sub_ids)
            self.key, sub = jax.random.split(self.key)
            tok1 = _sample(logits, sub, self.temperature)
            jax.block_until_ready(tok1)
            self.stats["prefill_s"] += time.perf_counter() - t0
            self.stats["prefill_tokens"] += int(prompt.shape[1])
            self.state, self.tok = _insert_fn(self.cfg)(
                self.state, self.tok, jnp.asarray(slot, jnp.int32),
                sub_state, tok1)
            self.ids = self.ids.at[slot].set(req.adapter)
            first = int(tok1[0])
            self.outputs[req.rid] = [first]
            done = (req.max_new <= 1 or
                    (self.eos_id >= 0 and first == self.eos_id))
            self.rid[slot] = -1 if done else req.rid
            self.active[slot] = not done
            self.remaining[slot] = max(req.max_new - 1, 0)
            self.stats["admitted"] += 1

    def _run_segment(self) -> None:
        """One fused segment over the live batch; drain outputs after."""
        seg = _segment_fn(self.cfg, self.segment, self.temperature,
                          self.eos_id)
        act_before = self.active.copy()
        rem_before = self.remaining.copy()
        rid_before = self.rid.copy()
        t0 = time.perf_counter()
        self.tok, self.state, act, rem, toks = seg(
            self.params, self.tok, self.state,
            jnp.asarray(self.active), jnp.asarray(self.remaining),
            self.ids, self.key, jnp.asarray(self._step_base, jnp.int32))
        jax.block_until_ready(toks)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.stats["segments"] += 1
        self._step_base += self.segment
        toks_np = np.asarray(toks)
        self.active = np.array(act)            # copies: host mirrors stay
        self.remaining = np.array(rem, np.int32)   # writable for _admit
        for slot in np.nonzero(act_before)[0]:
            take = min(self.segment, int(rem_before[slot]))
            for t in toks_np[slot, :take]:
                self.outputs[int(rid_before[slot])].append(int(t))
                self.stats["decode_tokens"] += 1
                if self.eos_id >= 0 and int(t) == self.eos_id:
                    break
            if not self.active[slot]:
                self.rid[slot] = -1            # retired: slot is free

    def run(self, requests=()) -> Dict[str, Any]:
        """Serve ``requests`` (plus anything already queued) to completion.

        Returns ``{"outputs": {rid: [new tokens...]}, "stats": {...}}`` —
        outputs include the prefill-sampled first token, truncated at EOS.
        """
        for r in requests:
            self.submit(r)
        while self.queue or self.active.any():
            self._admit()
            if self.active.any():
                self._run_segment()
        return {"outputs": self.outputs, "stats": self.stat_summary()}

    def stat_summary(self) -> Dict[str, Any]:
        s = dict(self.stats)
        s["prefill_tok_s"] = (s["prefill_tokens"] / s["prefill_s"]
                              if s["prefill_s"] > 0 else 0.0)
        s["decode_tok_s"] = (s["decode_tokens"] / s["decode_s"]
                             if s["decode_s"] > 0 else 0.0)
        return s


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv=None):
    _env_hygiene()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", choices=("eager", "scan", "continuous"),
                    default="scan")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch (slot count in continuous mode)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=0,
                    help="KV slots (0 = prompt+new)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--adapters", type=int, default=0,
                    help="G distinct demo adapters (0 = plain params)")
    ap.add_argument("--adapter-rank", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous mode: requests to serve (0 = 2x slots)")
    ap.add_argument("--segment", type=int, default=8)
    ap.add_argument("--eos-id", type=int, default=-1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(key, cfg)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    cache = args.cache_len or (args.prompt_len + args.new_tokens)

    row_ids = None
    if args.adapters:
        from . import adapters as adapters_lib
        params = adapters_lib.demo_wrap(params, cfg, args.adapters,
                                        rank=args.adapter_rank,
                                        key=jax.random.fold_in(key, 2))
        row_ids = jnp.arange(args.batch, dtype=jnp.int32) % args.adapters

    res = {"arch": cfg.name, "mode": args.mode, "batch": args.batch,
           "prompt_len": args.prompt_len, "new_tokens": args.new_tokens,
           "adapters": args.adapters}

    if args.mode == "continuous":
        n_req = args.requests or 2 * args.batch
        prompts_np = np.asarray(
            jax.random.randint(jax.random.fold_in(key, 3),
                               (n_req, args.prompt_len), 0, cfg.vocab_size))
        reqs = [Request(rid=i, prompt=prompts_np[i], max_new=args.new_tokens,
                        adapter=(i % args.adapters) if args.adapters else 0)
                for i in range(n_req)]
        server = SlotServer(params, cfg, slots=args.batch, cache_len=cache,
                            segment=args.segment, eos_id=args.eos_id,
                            temperature=args.temperature, seed=args.seed)
        out = server.run(reqs)
        s = out["stats"]
        total = s["prefill_s"] + s["decode_s"]
        res.update({
            "requests": n_req, "segments": s["segments"],
            "prefill_sec": round(s["prefill_s"], 4),
            "decode_sec": round(s["decode_s"], 4),
            "prefill_tokens_per_sec": round(s["prefill_tok_s"], 1),
            "decode_tokens_per_sec": round(s["decode_tok_s"], 1),
            "sec": round(total, 2),
            "tokens_per_sec": round(s["decode_tokens"] / total, 1)
            if total > 0 else 0.0,
            "sample_row": out["outputs"][0]})
        print(json.dumps(res))
        return

    ids = row_ids
    pre = _prefill_fn(cfg)
    if args.mode == "scan" and args.new_tokens > 1:
        dec = _scan_decode_fn(cfg, args.new_tokens - 1,
                              float(args.temperature))
    timing = {}

    def run_once(record: bool):
        state = model_lib.init_decode_state(cfg, args.batch, cache)
        jax.block_until_ready((params, prompts))   # fence before the clock
        t0 = time.perf_counter()
        logits, state = pre(params, prompts, state, ids)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()
        tok0 = _sample(logits, key, args.temperature)
        if args.mode == "scan":
            if args.new_tokens > 1:
                toks = dec(params, tok0, state, key, ids)
                jax.block_until_ready(toks)
                out = jnp.concatenate([prompts, tok0[:, None], toks], axis=1)
            else:
                out = jnp.concatenate([prompts, tok0[:, None]], axis=1)
        else:
            step = _eager_step_fn(cfg)
            k, tok, outl = key, tok0, [tok0]
            for _ in range(args.new_tokens - 1):
                k, sub = jax.random.split(k)
                logits_i, state = step(params, tok, state, ids)
                tok = _sample(logits_i, sub, args.temperature)
                outl.append(tok)
            jax.block_until_ready(tok)
            out = jnp.concatenate([prompts, jnp.stack(outl, axis=1)], axis=1)
        t2 = time.perf_counter()
        if record:
            timing["prefill_s"] = t1 - t0
            timing["decode_s"] = t2 - t1
        return out

    run_once(record=False)                 # compile warmup, not timed
    out = run_once(record=True)

    pf, dc = timing["prefill_s"], timing["decode_s"]
    total = pf + dc
    res.update({
        "prefill_sec": round(pf, 4), "decode_sec": round(dc, 4),
        "prefill_tokens_per_sec":
            round(args.batch * args.prompt_len / pf, 1) if pf > 0 else 0.0,
        "decode_tokens_per_sec":
            round(args.batch * args.new_tokens / dc, 1) if dc > 0 else 0.0,
        "sec": round(total, 2),
        "tokens_per_sec": round(args.batch * args.new_tokens / total, 1)
        if total > 0 else 0.0,
        "sample_row": out[0, -args.new_tokens:].tolist()})
    print(json.dumps(res))


if __name__ == "__main__":
    main()
