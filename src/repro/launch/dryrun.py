import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) combination this lowers and
compiles the corresponding step function against ShapeDtypeStruct inputs
(no allocation), then reports:

  * ``compiled.memory_analysis()``  — per-device bytes (proves it fits)
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the post-SPMD HLO text (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single --out dryrun.json
  python -m repro.launch.dryrun --arch deepseek-v2-236b --shape train_4k --mesh multi
"""

import argparse
import json
import re
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ASSIGNED_ARCHS, SHAPES, cache_len, get_config,
                       input_specs, shape_variant)
from ..sharding.rules import ShardingRules, path_of
from .mesh import make_production_mesh
from . import steps as steps_lib

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_OP_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-buffer bytes of every collective op in post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        tuple_part, dtype, dims, op = m.groups()
        if tuple_part is not None:
            total = sum(_bytes_of(d, s)
                        for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            total = _bytes_of(dtype, dims)
        out[op] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _client_axes(mesh) -> tuple:
    return tuple(n for n in ("pod", "data") if n in mesh.shape)


def _stack_sds(tree, c: int):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((c,) + x.shape, x.dtype), tree)


def _stack_opt_sds(opt_state, c: int):
    """Client-stack an optimizer-state ShapeDtypeStruct tree in the runtime's
    layout (``galore.stack_opt_state``): per-client moments/bases gain the
    leading client dim; the GaLore count/seed stay unbatched scalars."""
    from ..core import galore as gal
    return gal.map_opt_layout(
        opt_state,
        batched=lambda x: jax.ShapeDtypeStruct((c,) + x.shape, x.dtype))


def _client_shardings(mesh, rules_tp, tree, batch_axes):
    """Client-stacked leaves: client dim over (pod,data); inner dims by the
    TP-only param rules."""
    def one(path, leaf):
        spec = rules_tp.param_spec(path_of(path), leaf.shape[1:])
        dims = list(spec) + [None] * (leaf.ndim - 1 - len(spec))
        return NamedSharding(mesh, P(batch_axes, *dims))
    return jax.tree_util.tree_map_with_path(one, tree)


def _client_opt_shardings(mesh, tree, batch_axes, model_axis="model"):
    """Client-stacked optimizer states (``_stack_opt_sds`` layout): per-client
    ≥2-D leaves put the client dim over (pod,data) and shard the largest
    trailing dim over model when divisible; the unbatched GaLore count/seed
    scalars — and any other sub-2-D leaf — replicate (P())."""
    msize = mesh.shape[model_axis]

    def one(leaf):
        if leaf.ndim >= 2 :
            dims = [None] * leaf.ndim
            dims[0] = batch_axes
            # pick the largest remaining dim divisible by the model axis
            cands = sorted(range(1, leaf.ndim),
                           key=lambda i: -leaf.shape[i])
            for i in cands:
                if leaf.shape[i] % msize == 0 and leaf.shape[i] >= msize:
                    dims[i] = model_axis
                    break
            return NamedSharding(mesh, P(*dims))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, tree)


def lower_combination(arch: str, shape_name: str, mesh,
                      train_spec: Optional[steps_lib.TrainSpec] = None,
                      donate: bool = True, unroll: bool = False,
                      depth_blocks: Optional[int] = None):
    """Returns the lowered (unverified) computation for one combination.

    ``unroll`` lowers straight-line HLO (accurate cost_analysis);
    ``depth_blocks`` truncates the model to that many repeating blocks —
    used with ``unroll`` for the 1-block/2-block cost extrapolation.
    """
    import dataclasses as _dc
    shape = SHAPES[shape_name]
    cfg = shape_variant(get_config(arch), shape)
    if unroll:
        cfg = _dc.replace(cfg, unroll_blocks=True, remat=False)
    if depth_blocks is not None:
        cfg = _dc.replace(cfg, n_layers=cfg.block_period() * depth_blocks)
    rules = ShardingRules(mesh, fsdp=True)
    rules_tp = ShardingRules(mesh, fsdp=False)
    batch_axes = _client_axes(mesh)
    spec = train_spec or steps_lib.TrainSpec()
    spec = _dc.replace(spec, client_axes=batch_axes)

    if shape.kind == "train":
        n_clients = 1
        for a in batch_axes:
            n_clients *= mesh.shape[a]
        per_client = max(shape.global_batch // n_clients, 1)
        abstract = jax.eval_shape(
            lambda: steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, spec))
        trainable, frozen, opt_state = abstract
        trainable_c = _stack_sds(trainable, n_clients)
        opt_c = _stack_opt_sds(opt_state, n_clients)
        batch = input_specs(cfg, shape)
        n_text = batch["tokens"].shape[1]
        cbatch = {"tokens": jax.ShapeDtypeStruct((n_clients, per_client, n_text),
                                                 jnp.int32),
                  "labels": jax.ShapeDtypeStruct((n_clients, per_client, n_text),
                                                 jnp.int32)}
        if "embeds" in batch:
            e = batch["embeds"]
            cbatch["embeds"] = jax.ShapeDtypeStruct(
                (n_clients, per_client) + e.shape[1:], e.dtype)
        step = steps_lib.make_fed_local_step(cfg, spec, n_clients)
        in_shardings = (
            _client_shardings(mesh, rules_tp, trainable_c, batch_axes),
            rules.params_shardings(frozen),
            _client_opt_shardings(mesh, opt_c, batch_axes),
            jax.tree_util.tree_map(
                lambda x: NamedSharding(mesh, P(batch_axes,
                                                *([None] * (x.ndim - 1)))),
                cbatch),
        )
        jitted = jax.jit(step, in_shardings=in_shardings,
                         donate_argnums=(0, 2) if donate else ())
        with mesh:
            lowered = jitted.lower(trainable_c, frozen, opt_c, cbatch)
        return lowered

    # inference shapes: full params, standard sharding
    from ..models import model as model_lib
    params = jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))
    p_shard = rules.params_shardings(params)

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        step = steps_lib.make_prefill_step(cfg, cache_len(cfg, shape))
        args = (params, batch["tokens"])
        in_sh = (p_shard, NamedSharding(mesh, rules.batch_spec(
            batch["tokens"].shape)))
        if "embeds" in batch:
            args = args + (batch["embeds"],)
            in_sh = in_sh + (NamedSharding(mesh, rules.batch_spec(
                batch["embeds"].shape)),)
        jitted = jax.jit(step, in_shardings=in_sh)
        with mesh:
            lowered = jitted.lower(*args)
        return lowered

    # decode
    specs = input_specs(cfg, shape)
    step = steps_lib.make_decode_step(cfg)
    state_sh = rules.decode_state_shardings(specs["state"])
    tok_sh = NamedSharding(mesh, rules.batch_spec(specs["token"].shape))
    jitted = jax.jit(step, in_shardings=(p_shard, tok_sh, state_sh),
                     donate_argnums=(2,) if donate else ())
    with mesh:
        lowered = jitted.lower(params, specs["token"], specs["state"])
    return lowered


def lower_fed_round(arch: str, mesh,
                    train_spec: Optional[steps_lib.TrainSpec] = None,
                    unroll: bool = False, depth_blocks: Optional[int] = None):
    """Lower the ENTIRE federated round (Algorithm 1) as one SPMD program:
    T local GaLoreAdamW steps (scan) + FedAvg all-reduce over the client
    axes + the ṽ upload for server-side AJIVE — the paper's 𝒯→𝒜→𝒮 pipeline
    on the production mesh (train_4k geometry, per-client batch split by T).
    """
    import dataclasses as _dc
    shape = SHAPES["train_4k"]
    cfg = shape_variant(get_config(arch), shape)
    if unroll:
        cfg = _dc.replace(cfg, unroll_blocks=True, remat=False)
    if depth_blocks is not None:
        cfg = _dc.replace(cfg, n_layers=cfg.block_period() * depth_blocks)
    rules = ShardingRules(mesh, fsdp=True)
    rules_tp = ShardingRules(mesh, fsdp=False)
    batch_axes = _client_axes(mesh)
    spec = train_spec or steps_lib.TrainSpec()
    spec = _dc.replace(spec, client_axes=batch_axes)

    n_clients = 1
    for a in batch_axes:
        n_clients *= mesh.shape[a]
    t_steps = spec.local_steps
    per_client = max(shape.global_batch // (n_clients * t_steps), 1)
    trainable, frozen, opt_state = jax.eval_shape(
        lambda: steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, spec))
    opt_c = _stack_opt_sds(opt_state, n_clients)
    cbatch = {
        "tokens": jax.ShapeDtypeStruct(
            (n_clients, t_steps, per_client, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct(
            (n_clients, t_steps, per_client, shape.seq_len), jnp.int32)}
    weights = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    step = steps_lib.make_fed_round_step(cfg, spec, n_clients)
    in_sh = (
        rules_tp.params_shardings(trainable),           # global: TP only
        rules.params_shardings(frozen),
        _client_opt_shardings(mesh, opt_c, batch_axes),
        jax.tree_util.tree_map(
            lambda x: NamedSharding(mesh, P(batch_axes,
                                            *([None] * (x.ndim - 1)))),
            cbatch),
        NamedSharding(mesh, P()),
    )
    jitted = jax.jit(step, in_shardings=in_sh)
    with mesh:
        return jitted.lower(trainable, frozen, opt_c, cbatch, weights)


def analyze(lowered, verbose: bool = True) -> Dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    result = {
        "compile_s": round(compile_s, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                            None),
        },
    }
    if verbose:
        print("  memory_analysis:", mem)
        print(f"  cost_analysis: flops={result['flops']:.3e} "
              f"bytes={result['bytes_accessed']:.3e}")
        print(f"  collectives: {coll}")
        print(f"  compile: {compile_s:.1f}s")
    return result


def analyze_combination(arch: str, shape_name: str, mesh, spec,
                        verbose: bool = True) -> Dict:
    """Full dry-run for one combination.

    The scanned (deployable, remat'd) program provides memory_analysis. True
    per-step FLOPs/bytes/collectives come from two *shallow unrolled* twins —
    1 block and 2 blocks of the repeating layer pattern, straight-line HLO —
    extrapolated as  cost(1) + (n_blocks-1)·(cost(2)-cost(1)). XLA counts
    while-loop bodies once regardless of trip count, and fully unrolling a
    60-layer MoE is compile-prohibitive; block extrapolation is exact because
    every block is structurally identical.
    """
    cfg = shape_variant(get_config(arch), SHAPES[shape_name])
    n_blocks = cfg.n_blocks()

    lowered = lower_combination(arch, shape_name, mesh, spec)
    res = analyze(lowered, verbose=False)

    r1 = analyze(lower_combination(arch, shape_name, mesh, spec, unroll=True,
                                   depth_blocks=1), verbose=False)
    if n_blocks > 1:
        r2 = analyze(lower_combination(arch, shape_name, mesh, spec,
                                       unroll=True, depth_blocks=2),
                     verbose=False)
    else:
        r2 = r1

    def extrap(f1, f2):
        # per-block delta clamped at 0: fusion across the 1->2 block boundary
        # can make cost(2) marginally smaller than cost(1) for tiny programs.
        return f1 + (n_blocks - 1) * max(f2 - f1, 0.0)

    coll = {k: int(extrap(r1["collective_bytes"][k],
                          r2["collective_bytes"][k]))
            for k in r1["collective_bytes"]}
    out = {
        "compile_s": res["compile_s"] + r1["compile_s"] + r2["compile_s"],
        "flops": extrap(r1["flops"], r2["flops"]),
        "bytes_accessed": extrap(r1["bytes_accessed"], r2["bytes_accessed"]),
        "collective_bytes": coll,
        "memory": res["memory"],
        "scanned_flops": res["flops"],
        "n_blocks": n_blocks,
    }
    if verbose:
        print(f"  memory(argument/temp): {out['memory']['argument_bytes']:.3e} "
              f"/ {out['memory']['temp_bytes']:.3e} B")
        print(f"  cost (unrolled): flops={out['flops']:.3e} "
              f"bytes={out['bytes_accessed']:.3e}")
        print(f"  collectives: {out['collective_bytes']}")
        print(f"  compile: {out['compile_s']:.1f}s")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape)")
    ap.add_argument("--round", dest="fed_round", action="store_true",
                    help="lower the FULL federated round (T local steps + "
                         "FedAvg all-reduce + ṽ upload) instead of one step")
    ap.add_argument("--out", default=None, help="write JSON results")
    ap.add_argument("--rank", type=int, default=64)
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    spec = steps_lib.TrainSpec(rank=args.rank)

    if args.fed_round:
        assert args.arch, "--round requires --arch"
        tag = f"{args.arch}@fed_round@{args.mesh}"
        print(f"== {tag} ==", flush=True)
        lowered = lower_fed_round(args.arch, mesh, spec)
        res = analyze(lowered)
        if args.out:
            with open(args.out, "w") as f:
                json.dump({tag: res}, f, indent=1)
        print("ALL OK")
        return

    combos = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos.append((args.arch, args.shape))

    results = {}
    failures = []
    for arch, shape_name in combos:
        tag = f"{arch}@{shape_name}@{args.mesh}"
        print(f"== {tag} ==", flush=True)
        try:
            results[tag] = analyze_combination(arch, shape_name, mesh, spec)
        except Exception as e:  # noqa: BLE001 — report, keep going
            print(f"  FAILED: {type(e).__name__}: {e}")
            failures.append(tag)
            results[tag] = {"error": f"{type(e).__name__}: {e}"}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("ALL OK")


if __name__ == "__main__":
    main()
