"""Federated training launcher.

Runs FedGaLore (or any registered baseline) on a synthetic task with the
Dirichlet(α) protocol — the host-scale end-to-end driver. On real hardware
the same step functions lower onto the production mesh (see dryrun.py); here
the mesh is whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen1.5-0.5b --smoke --method fedgalore --rounds 20 \
      --clients 8 --participate 4 --alpha 0.5
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke_variant
from ..core.fed import FedConfig, FedEngine, METHODS
from ..data import FederatedBatcher, seq_classification
from ..models import model as model_lib
from .steps import galore_target_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant (CPU-scale)")
    ap.add_argument("--method", default="fedgalore", choices=list(METHODS))
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--participate", type=int, default=0,
                    help="clients per round (0 = all)")
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Dirichlet alpha (None = IID)")
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--examples", type=int, default=4096)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init_params(key, cfg)

    task = seq_classification(args.examples, args.classes, args.seq,
                              cfg.vocab_size, seed=args.seed)
    batcher = FederatedBatcher(task, args.clients, args.batch,
                               alpha=args.alpha, seed=args.seed)

    def loss(p, batch):
        return model_lib.loss_fn(p, cfg, batch)

    fed_cfg = FedConfig(method=args.method, rank=args.rank, lr=args.lr,
                        local_steps=args.local_steps, rounds=args.rounds,
                        seed=args.seed)
    engine = FedEngine(fed_cfg, loss, params,
                       target_fn=galore_target_fn(cfg))

    eval_batch = {k: jnp.asarray(v) for k, v in
                  batcher.eval_batch(min(256, args.examples)).items()}

    history = []
    for rnd in range(args.rounds):
        t0 = time.time()
        clients = (batcher.sample_clients(args.participate)
                   if args.participate else None)
        batches = batcher.round_batches(args.local_steps, clients)
        batches = {k: jnp.asarray(v) for k, v in batches.items()}
        metrics = engine.run_round(batches)
        gp = engine.global_params()
        logits, _ = model_lib.forward(gp, cfg, eval_batch["tokens"],
                                      eval_batch.get("embeds"))
        lab = np.asarray(eval_batch["labels"][:, -1])
        acc = float((np.asarray(logits[:, -1]).argmax(-1) == lab).mean())
        val = float(model_lib.loss_fn(gp, cfg, eval_batch))
        row = {"round": rnd, "local_loss": metrics["mean_final_loss"],
               "val_loss": val, "val_acc": acc,
               "sec": round(time.time() - t0, 2)}
        history.append(row)
        print(json.dumps(row), flush=True)
        if args.ckpt_dir:
            from ..checkpoint import save
            save(args.ckpt_dir, rnd, gp)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
