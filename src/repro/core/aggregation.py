"""Server aggregation operators 𝒜 (Definition 3.2 + Table 1).

Operate on *stacked* client pytrees: every leaf has a leading client axis K,
so each operator is a single vectorized reduction (and maps 1:1 onto a
weighted ``psum`` over the client mesh axis in the sharded runtime).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import projector as proj
from .lora import LoraPair, is_lora_pair, svd_truncate

PyTree = Any


def _norm_weights(weights: jnp.ndarray) -> jnp.ndarray:
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


def _wavg(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Weighted average over the leading client axis."""
    return jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0)).astype(x.dtype)


def weighted_average(stacked: PyTree, weights) -> PyTree:
    """Canonical FedAvg: θ̄ = Σ p̃ᵢ θᵢ (Lemma 4.1's convex combination)."""
    w = _norm_weights(weights)
    return jax.tree_util.tree_map(lambda x: _wavg(x, w), stacked)


def factor_average(stacked_adapters: PyTree, weights) -> PyTree:
    """FedIT: average A and B factors separately.

    ΔW̄ = (Σ p̃ᵢ Bᵢ)(Σ p̃ᵢ Aᵢ) — stays rank ≤ r but is a biased estimate of the
    mean lift (the cross terms are dropped), the update-space-mismatch culprit.
    """
    w = _norm_weights(weights)

    def agg(ad):
        if ad is None:
            return None
        return LoraPair(a=_wavg(ad.a, w), b=_wavg(ad.b, w))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def lift_average(stacked_adapters: PyTree, weights, scale: float = 1.0) -> PyTree:
    """FLoRA / FR-LoRA: lift each client adapter to ΔWᵢ = scale·BᵢAᵢ and average
    in the ambient space. Rank can grow to K·r (update-space mismatch, §4.1).

    Returns a pytree of dense deltas (None for non-adapted leaves).
    """
    w = _norm_weights(weights)

    def agg(ad):
        if ad is None:
            return None
        # einsum over client axis: Σ_k w_k B_k A_k, never materializing all K
        # lifts; the ellipsis carries stacked (nb, ·, ·) scan-block leaves.
        return scale * jnp.einsum("k,k...mr,k...rn->...mn", w,
                                  ad.b.astype(jnp.float32),
                                  ad.a.astype(jnp.float32))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def lora_fair_refine(stacked_adapters: PyTree, weights, scale: float = 1.0,
                     ridge: float = 1e-6) -> PyTree:
    """LoRA-Fair: factor averaging followed by a server-side refinement of B̄
    toward the true mean lift:  B̄' = argmin_B ||scale·B Ā − ΔW̄_lift||²_F,
    solved in closed form with a ridge term (batched over stacked
    scan-block leading dims).
    """
    w = _norm_weights(weights)
    swap = lambda x: jnp.swapaxes(x, -1, -2)

    def agg(ad):
        if ad is None:
            return None
        a_bar = _wavg(ad.a, w).astype(jnp.float32)             # (..., r, n)
        mean_lift = jnp.einsum("k,k...mr,k...rn->...mn", w,
                               ad.b.astype(jnp.float32),
                               ad.a.astype(jnp.float32))        # (..., m, n)
        r = a_bar.shape[-2]
        gram = a_bar @ swap(a_bar) + ridge * jnp.eye(r, dtype=jnp.float32)
        b_ref = swap(jnp.linalg.solve(gram, a_bar @ swap(mean_lift))) \
            / max(scale, 1e-12)
        return LoraPair(a=a_bar.astype(ad.a.dtype), b=b_ref.astype(ad.b.dtype))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def fr_lora_merge(base_params: PyTree, stacked_adapters: PyTree, weights,
                  scale: float = 1.0) -> PyTree:
    """FR-LoRA: lift-average the client adapters and merge the full-rank delta
    into the base weights (the residual beyond rank r is *kept*, in W0, rather
    than truncated). Fresh zero adapters start the next round.
    """
    deltas = lift_average(stacked_adapters, weights, scale)

    def merge(p, d):
        if d is None:
            return p
        return p + d.astype(p.dtype)

    return jax.tree_util.tree_map(merge, base_params, deltas,
                                  is_leaf=lambda x: x is None)


def dense_delta_average(stacked_deltas: PyTree, weights) -> PyTree:
    """FedAvg on dense target-module deltas (FedAvg-Full / FedGaLore line 11)."""
    return weighted_average(stacked_deltas, weights)


def factored_lift_average(delta_stack: jnp.ndarray, basis: jnp.ndarray,
                          side: str, weights) -> jnp.ndarray:
    """𝒜 for rank-r factored client deltas on a **shared** basis:
    ``Σᵢ wᵢ lift(Rᵢ, B) = lift(Σᵢ wᵢ Rᵢ, B)`` — an O(C·r·dim) reduction in
    projected coordinates plus ONE rank-r lift, instead of the O(C·m·n)
    dense-stack average. delta_stack (C, m, r) right | (C, r, n) left;
    returns the dense (m, n) weighted mean delta (fp32)."""
    w = _norm_weights(weights)
    rbar = jnp.einsum("k,k...->...", w, delta_stack.astype(jnp.float32))
    return proj.project_back(rbar, basis.astype(jnp.float32), side)


def factored_lift_average_hetero(delta_stack: jnp.ndarray,
                                 basis_stack: jnp.ndarray, side: str,
                                 weights) -> jnp.ndarray:
    """𝒜 for factored deltas with **per-client** bases (the adaptive round-0
    data-driven refresh, or ``refresh_mode='svd'``): ``Σᵢ wᵢ lift(Rᵢ, Bᵢ)``
    contracted client-by-client — O(C·m·n·r) FLOPs but only the (m, n) output
    is ever materialized (no (C, m, n) stack). basis_stack (C, dim, r);
    stacked scan blocks (C, nb, ·, r) vmap over nb."""
    if delta_stack.ndim == 4:
        return jax.vmap(
            lambda d, b: factored_lift_average_hetero(d, b, side, weights),
            in_axes=1, out_axes=0)(delta_stack, basis_stack)
    w = _norm_weights(weights)
    d32 = delta_stack.astype(jnp.float32)
    b32 = basis_stack.astype(jnp.float32)
    if side == proj.RIGHT:
        return jnp.einsum("k,kmr,knr->mn", w, d32, b32)
    return jnp.einsum("k,kmr,krn->mn", w, b32, d32)


# ------------------------------------------------- robust factored 𝒜 --------
#
# Defense layer against corrupted client uploads (Koo et al.'s robust
# federated LoRA direction): every operator runs on the rank-r factored
# (C, ·, r) stacks — (C, nb, ·, r) scan-block leaves included — in
# O(C·r·(m+n)), never densifying. Client norms are basis-independent
# (the shared per-round bases are orthonormal, so ‖lift(R, B)‖_F = ‖R‖_F),
# which is what makes median-norm screening/clipping sound in factored
# coordinates even across heterogeneous client bases.

ROBUST_MODES = ("none", "norm_clip", "trimmed_mean", "geomedian")


def client_sq_norms(stack: jnp.ndarray) -> jnp.ndarray:
    """Per-client squared Frobenius norms of a (C, ...) stack, fp32, with
    non-finite entries contributing zero (their clients are flagged by the
    finiteness screen separately — a NaN must not poison the median)."""
    s32 = stack.astype(jnp.float32)
    s32 = jnp.where(jnp.isfinite(s32), s32, 0.0)
    return jnp.sum(s32 * s32, axis=tuple(range(1, s32.ndim)))


def weighted_quantile(x: jnp.ndarray, w: jnp.ndarray, q: float) -> jnp.ndarray:
    """q-quantile of (C,) values under non-negative weights (zero-weight
    entries — masked or quarantined clients — are excluded). jit-safe:
    sort + cumulative weights + searchsorted, no data-dependent shapes."""
    x32 = jnp.asarray(x, jnp.float32)
    w32 = jnp.asarray(w, jnp.float32)
    order = jnp.argsort(x32)
    cw = jnp.cumsum(w32[order])
    idx = jnp.searchsorted(cw, q * cw[-1], side="left")
    return x32[order][jnp.clip(idx, 0, x32.shape[0] - 1)]


def median_norm_clip_factors(delta_stack: jnp.ndarray,
                             weights, eps: float = 1e-12) -> jnp.ndarray:
    """Per-client clip factors cᵢ = min(1, med/‖Rᵢ‖) against the weighted
    median client norm — the norm_clip defense: outliers shrink to the
    median scale, inliers pass through untouched (cᵢ = 1 exactly)."""
    n = jnp.sqrt(client_sq_norms(delta_stack))
    med = weighted_quantile(n, jnp.asarray(weights, jnp.float32), 0.5)
    return jnp.minimum(1.0, med / jnp.maximum(n, eps))


def robust_factored_reduce(delta_stack: jnp.ndarray, weights, mode: str, *,
                           trim: float = 0.2, iters: int = 8,
                           eps: float = 1e-8,
                           tol: float = 1e-6) -> jnp.ndarray:
    """Robust weighted reduction over the client axis of a factored stack:
    the drop-in replacement for the plain weighted mean inside
    :func:`factored_lift_average` (weights renormalized internally the same
    way; zero-weight clients vanish from every mode).

    norm_clip      Σ wᵢ cᵢ Rᵢ with median-norm clip factors cᵢ.
    trimmed_mean   coordinate-wise weighted trimmed mean: per coordinate,
                   each sorted client interval of the weight CDF is clipped
                   to the [trim, 1-trim] window (zero-weight clients carry a
                   zero-width interval — excluded for free; trim=0 is
                   exactly the weighted mean).
    geomedian      Weiszfeld iterations toward the weighted geometric median
                   of the per-client factors, seeded at the weighted mean.
                   ``iters`` caps the iteration count; the loop exits early
                   once the iterate moves less than ``tol`` × the seed norm
                   (``tol=0`` always runs the full cap). Zero distances —
                   the iterate landing exactly on a client point, where
                   Weiszfeld's 1/d weight is singular — are floored at
                   ``eps`` so that client's pull stays finite.

    Returns the reduced (·, r) factor in fp32.
    """
    w = _norm_weights(weights)
    s32 = delta_stack.astype(jnp.float32)
    if mode == "none":
        return jnp.einsum("k,k...->...", w, s32)
    if mode == "norm_clip":
        c = median_norm_clip_factors(delta_stack, w)
        return jnp.einsum("k,k...->...", w * c, s32)
    if mode == "trimmed_mean":
        wb = jnp.broadcast_to(w.reshape((-1,) + (1,) * (s32.ndim - 1)),
                              s32.shape)
        order = jnp.argsort(s32, axis=0)
        xs = jnp.take_along_axis(s32, order, 0)
        ws = jnp.take_along_axis(wb, order, 0)
        cum = jnp.cumsum(ws, axis=0)          # total = 1 (w normalized)
        eff = jnp.clip(jnp.minimum(cum, 1.0 - trim)
                       - jnp.maximum(cum - ws, trim), 0.0, None)
        return jnp.sum(eff * xs, 0) / jnp.maximum(jnp.sum(eff, 0), eps)
    if mode == "geomedian":
        y0 = jnp.einsum("k,k...->...", w, s32)
        ref = jnp.sqrt(jnp.sum(y0 * y0)) + eps   # convergence scale

        def _cond(carry):
            _, i, moved = carry
            return (i < iters) & (moved > tol * ref)

        def _body(carry):
            y, i, _ = carry
            d = jnp.sqrt(client_sq_norms(s32 - y[None]))
            inv = w / jnp.maximum(d, eps)      # zero-weight clients drop out
            y_new = jnp.einsum("k,k...->...", inv / jnp.maximum(
                jnp.sum(inv), eps), s32)
            moved = jnp.sqrt(jnp.sum((y_new - y) ** 2))
            return y_new, i + 1, moved

        y, _, _ = jax.lax.while_loop(
            _cond, _body, (y0, jnp.int32(0), jnp.float32(jnp.inf)))
        return y
    raise ValueError(f"robust_agg mode {mode!r} not in {ROBUST_MODES}")


def rebase_factored_stack(stack: jnp.ndarray, basis_stack: jnp.ndarray,
                          side: str) -> jnp.ndarray:
    """Re-express every client's factored coordinates on the REFERENCE
    client's (client 0's) basis via the r×r transfer Grams
    (:func:`projector.reproject` — right: Rᵢ(BᵢᵀB₀), left: (B₀ᵀBᵢ)Rᵢ), so
    coordinate-wise robust statistics are well-defined when per-client bases
    have diverged. The re-basing is a projection: components outside the
    reference subspace are dropped — exactly the components an aligned
    coordinate-wise vote cannot adjudicate. Broadcasts over stacked
    (C, nb, ·, r) scan-block leaves."""
    s32 = stack.astype(jnp.float32)
    b32 = basis_stack.astype(jnp.float32)
    return proj.reproject(s32, b32, b32[0], side)


def robust_factored_lift(delta_stack: jnp.ndarray, basis_stack: jnp.ndarray,
                         side: str, weights, mode: str = "none",
                         hetero: bool = False, trim: float = 0.2,
                         iters: int = 8, tol: float = 1e-6) -> jnp.ndarray:
    """Robust 𝒜 for one factored leaf: reduce the (C, ·, r) client stack with
    ``mode`` and lift once. ``mode='none'`` is EXACTLY
    :func:`factored_lift_average` (the guarded round program's honest-cohort
    bit-identity hinges on this). ``hetero=True`` handles per-client bases
    (the adaptive round-0 / ``refresh_mode='svd'`` diverged-basis case):
    norm_clip contracts per-client (clip factors are basis-independent),
    while the coordinate-wise modes — trimmed_mean/geomedian — first re-base
    every client onto the reference basis via
    :func:`rebase_factored_stack`, making them basis-coherent instead of
    degrading to median-norm clipping."""
    if mode == "none":
        if hetero:
            return factored_lift_average_hetero(delta_stack, basis_stack,
                                                side, weights)
        return factored_lift_average(delta_stack, basis_stack[0], side,
                                     weights)
    if mode == "norm_clip":
        c = median_norm_clip_factors(delta_stack, _norm_weights(weights))
        d = (delta_stack.astype(jnp.float32)
             * c.reshape((-1,) + (1,) * (delta_stack.ndim - 1)))
        if hetero:
            return factored_lift_average_hetero(d, basis_stack, side, weights)
        return factored_lift_average(d, basis_stack[0], side, weights)
    d32 = delta_stack.astype(jnp.float32)
    if hetero:
        d32 = rebase_factored_stack(d32, basis_stack, side)
    red = robust_factored_reduce(d32, weights, mode, trim=trim,
                                 iters=iters, tol=tol)
    return proj.project_back(red, basis_stack[0].astype(jnp.float32), side)


def screen_factored_clients(delta_tree: PyTree, v_tree: Optional[PyTree],
                            scales: jnp.ndarray, weights: jnp.ndarray,
                            zmax: float = 6.0) -> jnp.ndarray:
    """In-round quarantine screen: (C,) bool, True = contribution passes.

    A client fails when any of its factored uplink leaves (accumulators Rᵢ,
    projected moments ṽᵢ, base scale) contain non-finite values, or when its
    overall factored delta norm exceeds ``zmax`` × the weighted median norm
    of the cohort (weights carry the participation mask, so dropped clients
    neither vote for the median nor shift it). A zero median disables the
    outlier test (no scale to screen against). O(C·r·(m+n)) — never lifts.
    """
    finite = jnp.isfinite(jnp.asarray(scales, jnp.float32))
    sq = jnp.zeros_like(jnp.asarray(weights, jnp.float32))
    for x in jax.tree_util.tree_leaves(delta_tree):
        x32 = x.astype(jnp.float32)
        finite &= jnp.all(jnp.isfinite(x32), axis=tuple(range(1, x32.ndim)))
        sq = sq + client_sq_norms(x32)
    if v_tree is not None:
        for x in jax.tree_util.tree_leaves(v_tree,
                                           is_leaf=lambda x: x is None):
            if x is None:
                continue
            x32 = x.astype(jnp.float32)
            finite &= jnp.all(jnp.isfinite(x32),
                              axis=tuple(range(1, x32.ndim)))
    norm = jnp.sqrt(sq)
    med = weighted_quantile(norm, jnp.where(finite, weights, 0.0), 0.5)
    ok_norm = (med <= 0.0) | (norm <= zmax * med)
    return finite & ok_norm


def quarantine_weights(w: jnp.ndarray, keep: jnp.ndarray) -> jnp.ndarray:
    """Fold a quarantine verdict into the round's effective weights: failed
    clients are zeroed and the survivors renormalized. An all-pass verdict
    returns ``w`` UNTOUCHED (no renormalization round-off — the honest
    cohort stays bit-identical to the unguarded round); an all-fail verdict
    degrades to the original weights over fully-sanitized (zeroed) stacks,
    i.e. the round reduces to the decayed base — a skipped round, not NaNs.
    """
    wq = jnp.where(keep, w, 0.0)
    s = jnp.sum(wq)
    return jnp.where(jnp.all(keep), w,
                     jnp.where(s > 0, wq / jnp.maximum(s, 1e-30), w))


def mask_client_rows(tree: PyTree, keep: jnp.ndarray) -> PyTree:
    """Zero the client rows that failed quarantine (None-leaf aware). Zero
    weights alone do NOT remove a corrupted client — 0·NaN = NaN — so every
    weighted reduction must see sanitized stacks. ``jnp.where`` with an
    all-true verdict returns each leaf bitwise unchanged (honest cohorts
    short-circuit exactly)."""
    def one(x):
        if x is None:
            return None
        return jnp.where(keep.reshape((-1,) + (1,) * (x.ndim - 1)), x,
                         jnp.zeros((), x.dtype))
    return jax.tree_util.tree_map(one, tree, is_leaf=lambda x: x is None)


def truncate_to_rank(deltas: PyTree, rank: int) -> PyTree:
    """Post-hoc SVD truncation of dense deltas back to rank r (diagnostic /
    the 'Averaging + SVD' baseline in Appendix F)."""
    def trunc(d):
        if d is None:
            return None
        pair = svd_truncate(d.astype(jnp.float32), rank)
        return (pair.b @ pair.a).astype(d.dtype)

    return jax.tree_util.tree_map(trunc, deltas, is_leaf=lambda x: x is None)
