"""Server aggregation operators 𝒜 (Definition 3.2 + Table 1).

Operate on *stacked* client pytrees: every leaf has a leading client axis K,
so each operator is a single vectorized reduction (and maps 1:1 onto a
weighted ``psum`` over the client mesh axis in the sharded runtime).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import projector as proj
from .lora import LoraPair, is_lora_pair, svd_truncate

PyTree = Any


def _norm_weights(weights: jnp.ndarray) -> jnp.ndarray:
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


def _wavg(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Weighted average over the leading client axis."""
    return jnp.tensordot(w, x.astype(jnp.float32), axes=(0, 0)).astype(x.dtype)


def weighted_average(stacked: PyTree, weights) -> PyTree:
    """Canonical FedAvg: θ̄ = Σ p̃ᵢ θᵢ (Lemma 4.1's convex combination)."""
    w = _norm_weights(weights)
    return jax.tree_util.tree_map(lambda x: _wavg(x, w), stacked)


def factor_average(stacked_adapters: PyTree, weights) -> PyTree:
    """FedIT: average A and B factors separately.

    ΔW̄ = (Σ p̃ᵢ Bᵢ)(Σ p̃ᵢ Aᵢ) — stays rank ≤ r but is a biased estimate of the
    mean lift (the cross terms are dropped), the update-space-mismatch culprit.
    """
    w = _norm_weights(weights)

    def agg(ad):
        if ad is None:
            return None
        return LoraPair(a=_wavg(ad.a, w), b=_wavg(ad.b, w))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def lift_average(stacked_adapters: PyTree, weights, scale: float = 1.0) -> PyTree:
    """FLoRA / FR-LoRA: lift each client adapter to ΔWᵢ = scale·BᵢAᵢ and average
    in the ambient space. Rank can grow to K·r (update-space mismatch, §4.1).

    Returns a pytree of dense deltas (None for non-adapted leaves).
    """
    w = _norm_weights(weights)

    def agg(ad):
        if ad is None:
            return None
        # einsum over client axis: Σ_k w_k B_k A_k, never materializing all K
        # lifts; the ellipsis carries stacked (nb, ·, ·) scan-block leaves.
        return scale * jnp.einsum("k,k...mr,k...rn->...mn", w,
                                  ad.b.astype(jnp.float32),
                                  ad.a.astype(jnp.float32))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def lora_fair_refine(stacked_adapters: PyTree, weights, scale: float = 1.0,
                     ridge: float = 1e-6) -> PyTree:
    """LoRA-Fair: factor averaging followed by a server-side refinement of B̄
    toward the true mean lift:  B̄' = argmin_B ||scale·B Ā − ΔW̄_lift||²_F,
    solved in closed form with a ridge term (batched over stacked
    scan-block leading dims).
    """
    w = _norm_weights(weights)
    swap = lambda x: jnp.swapaxes(x, -1, -2)

    def agg(ad):
        if ad is None:
            return None
        a_bar = _wavg(ad.a, w).astype(jnp.float32)             # (..., r, n)
        mean_lift = jnp.einsum("k,k...mr,k...rn->...mn", w,
                               ad.b.astype(jnp.float32),
                               ad.a.astype(jnp.float32))        # (..., m, n)
        r = a_bar.shape[-2]
        gram = a_bar @ swap(a_bar) + ridge * jnp.eye(r, dtype=jnp.float32)
        b_ref = swap(jnp.linalg.solve(gram, a_bar @ swap(mean_lift))) \
            / max(scale, 1e-12)
        return LoraPair(a=a_bar.astype(ad.a.dtype), b=b_ref.astype(ad.b.dtype))

    return jax.tree_util.tree_map(
        agg, stacked_adapters,
        is_leaf=lambda x: x is None or is_lora_pair(x))


def fr_lora_merge(base_params: PyTree, stacked_adapters: PyTree, weights,
                  scale: float = 1.0) -> PyTree:
    """FR-LoRA: lift-average the client adapters and merge the full-rank delta
    into the base weights (the residual beyond rank r is *kept*, in W0, rather
    than truncated). Fresh zero adapters start the next round.
    """
    deltas = lift_average(stacked_adapters, weights, scale)

    def merge(p, d):
        if d is None:
            return p
        return p + d.astype(p.dtype)

    return jax.tree_util.tree_map(merge, base_params, deltas,
                                  is_leaf=lambda x: x is None)


def dense_delta_average(stacked_deltas: PyTree, weights) -> PyTree:
    """FedAvg on dense target-module deltas (FedAvg-Full / FedGaLore line 11)."""
    return weighted_average(stacked_deltas, weights)


def factored_lift_average(delta_stack: jnp.ndarray, basis: jnp.ndarray,
                          side: str, weights) -> jnp.ndarray:
    """𝒜 for rank-r factored client deltas on a **shared** basis:
    ``Σᵢ wᵢ lift(Rᵢ, B) = lift(Σᵢ wᵢ Rᵢ, B)`` — an O(C·r·dim) reduction in
    projected coordinates plus ONE rank-r lift, instead of the O(C·m·n)
    dense-stack average. delta_stack (C, m, r) right | (C, r, n) left;
    returns the dense (m, n) weighted mean delta (fp32)."""
    w = _norm_weights(weights)
    rbar = jnp.einsum("k,k...->...", w, delta_stack.astype(jnp.float32))
    return proj.project_back(rbar, basis.astype(jnp.float32), side)


def factored_lift_average_hetero(delta_stack: jnp.ndarray,
                                 basis_stack: jnp.ndarray, side: str,
                                 weights) -> jnp.ndarray:
    """𝒜 for factored deltas with **per-client** bases (the adaptive round-0
    data-driven refresh, or ``refresh_mode='svd'``): ``Σᵢ wᵢ lift(Rᵢ, Bᵢ)``
    contracted client-by-client — O(C·m·n·r) FLOPs but only the (m, n) output
    is ever materialized (no (C, m, n) stack). basis_stack (C, dim, r);
    stacked scan blocks (C, nb, ·, r) vmap over nb."""
    if delta_stack.ndim == 4:
        return jax.vmap(
            lambda d, b: factored_lift_average_hetero(d, b, side, weights),
            in_axes=1, out_axes=0)(delta_stack, basis_stack)
    w = _norm_weights(weights)
    d32 = delta_stack.astype(jnp.float32)
    b32 = basis_stack.astype(jnp.float32)
    if side == proj.RIGHT:
        return jnp.einsum("k,kmr,knr->mn", w, d32, b32)
    return jnp.einsum("k,kmr,krn->mn", w, b32, d32)


def truncate_to_rank(deltas: PyTree, rank: int) -> PyTree:
    """Post-hoc SVD truncation of dense deltas back to rank r (diagnostic /
    the 'Averaging + SVD' baseline in Appendix F)."""
    def trunc(d):
        if d is None:
            return None
        pair = svd_truncate(d.astype(jnp.float32), rank)
        return (pair.b @ pair.a).astype(d.dtype)

    return jax.tree_util.tree_map(trunc, deltas, is_leaf=lambda x: x is None)
