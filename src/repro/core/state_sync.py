"""State synchronization protocols 𝒮 (Definition 3.3 + Algorithm 1 line 12).

Inputs are *stacked* per-client projected second moments ṽ (leading client
axis) plus the shared per-round basis R_k reconstructed from the broadcast
seed. Protocols:

  none      — clients reinitialize adaptive states each round (most fed-LoRA).
  avg       — naive weighted averaging of ṽ (the FedOpt-style baseline that
              Appendix F shows is biased by squared drift).
  avg_svd   — naive average followed by rank-r SVD re-projection.
  ajive     — the paper's protocol: lift views V^i = ṽ^i R_kᵀ, extract the
              joint component via AJIVE (joint rank = r), broadcast.

All return the *lifted* (n, n_cols) synchronized state; the caller re-projects
onto each client's next-round basis (InitState, Eq. 5).

Factored fast path: every protocol input has rank ≤ r, so the lift → sync →
re-project round-trip closes over the projected coordinates.
:func:`sync_block_factored` runs the same protocols without ever building a
dense ``(m, n)`` view — weighted averaging commutes with the (linear) lift,
rank-r SVD re-projection of a rank-≤r lift is the identity (making
``avg_svd`` ≡ ``avg`` in factored form), AJIVE runs on the (C·r) score space
(`ajive.ajive_sync_factored`), and the old→new basis change is the r×r
transfer ``projector.reproject``. Requires the shared-basis invariant of the
seeded-broadcast protocol (Appendix D); the dense :func:`sync_block` is the
oracle for heterogeneous bases and parity tests.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .ajive import ajive_sync, ajive_sync_factored, normalize_weights
from . import projector as proj

PyTree = Any


def lift_views(v_stack: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """ṽ (K, m, r) + basis (n, r) -> views (K, m, n) [right side]; left is
    (K, r, n) + (m, r) -> (K, m, n)."""
    if side == proj.RIGHT:
        return jnp.einsum("kmr,nr->kmn", v_stack, basis)
    return jnp.einsum("mr,krn->kmn", basis, v_stack)


def project_state(lifted: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """Re-project a lifted (m, n) state onto a (possibly new) basis."""
    if side == proj.RIGHT:
        return lifted @ basis                  # (m,n)@(n,r) -> (m,r)
    return basis.T @ lifted                    # (r,m)@(m,n) -> (r,n)


def sync_none(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    return None


def sync_avg(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    w = normalize_weights(weights, v_stack.shape[0])
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    return jnp.einsum("k,kmn->mn", w, views)


def sync_avg_svd(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    avg = sync_avg(v_stack, basis, side, weights)
    r = rank if rank is not None else basis.shape[1]
    u, s, vt = jnp.linalg.svd(avg, full_matrices=False)
    return (u[:, :r] * s[:r][None, :]) @ vt[:r]


def sync_ajive(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    """The paper's 𝒮: spectral shared-signal extraction across client views."""
    r = rank if rank is not None else basis.shape[1]
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return ajive_sync(views, rank=r, weights=w)


SYNC_PROTOCOLS = {
    "none": sync_none,
    "avg": sync_avg,
    "avg_svd": sync_avg_svd,
    "ajive": sync_ajive,
}


def sync_lifted_views(protocol: str, views: jnp.ndarray, weights=None,
                      rank: Optional[int] = None) -> jnp.ndarray:
    """Run protocol 𝒮 on *already-lifted* (k, m, n) views — the dense
    reference dispatch shared by the engine and the sharded runtime (used
    when clients lifted with heterogeneous bases, where the factored path
    does not apply)."""
    if protocol == "ajive":
        return ajive_sync(views, rank=rank, weights=weights)
    avg = jnp.einsum("k,kmn->mn", normalize_weights(weights, views.shape[0]),
                     views)
    if protocol == "avg":
        return avg
    if protocol == "avg_svd":
        u, s, vt = jnp.linalg.svd(avg, full_matrices=False)
        return (u[:, :rank] * s[:rank][None, :]) @ vt[:rank]
    raise ValueError(protocol)


def sync_block(protocol: str, v_stack: jnp.ndarray, old_basis: jnp.ndarray,
               new_basis: jnp.ndarray, side: str, weights=None,
               rank: Optional[int] = None) -> Optional[jnp.ndarray]:
    """One adapted block end-to-end: lift with the round-k basis, synchronize,
    re-project onto the round-(k+1) basis. Returns the next-round ṽ init, or
    None for protocol='none' (clients zero-init).

    This is the dense reference path (materializes (k, m, n) views); the
    production round loop uses :func:`sync_block_factored`.
    """
    lifted = SYNC_PROTOCOLS[protocol](v_stack, old_basis, side, weights, rank)
    if lifted is None:
        return None
    return jnp.maximum(project_state(lifted, new_basis, side), 0.0)


def sync_block_synced_factored(protocol: str, v_stack: jnp.ndarray, side: str,
                               weights=None,
                               rank: Optional[int] = None
                               ) -> Optional[jnp.ndarray]:
    """Run protocol 𝒮 in projected coordinates (no lift): returns the synced
    state expressed on the *round-k* basis, or None for 'none'."""
    if protocol == "none":
        return None
    if protocol in ("avg", "avg_svd"):
        # Lift is linear ⇒ averaging commutes with it; the rank-r SVD
        # re-projection in avg_svd is the identity on a rank-≤r lift.
        w = normalize_weights(weights, v_stack.shape[0])
        return jnp.einsum("k,k...->...", w, v_stack.astype(jnp.float32))
    if protocol == "ajive":
        r = rank if rank is not None else (
            v_stack.shape[-1] if side == proj.RIGHT else v_stack.shape[-2])
        return ajive_sync_factored(v_stack, rank=r, weights=weights, side=side)
    raise ValueError(protocol)


def sync_block_factored(protocol: str, v_stack: jnp.ndarray,
                        old_basis: jnp.ndarray, new_basis: jnp.ndarray,
                        side: str, weights=None,
                        rank: Optional[int] = None) -> Optional[jnp.ndarray]:
    """Factored counterpart of :func:`sync_block`: synchronize in projected
    coordinates, then change basis with the r×r transfer — the dense (m, n)
    lift is never built. Assumes the shared-basis invariant (all clients hold
    the same seeded round-k basis)."""
    synced = sync_block_synced_factored(protocol, v_stack, side, weights, rank)
    if synced is None:
        return None
    return jnp.maximum(proj.reproject(synced, old_basis, new_basis, side), 0.0)
