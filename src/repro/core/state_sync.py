"""State synchronization protocols 𝒮 (Definition 3.3 + Algorithm 1 line 12).

Inputs are *stacked* per-client projected second moments ṽ (leading client
axis) plus the shared per-round basis R_k reconstructed from the broadcast
seed. Protocols:

  none      — clients reinitialize adaptive states each round (most fed-LoRA).
  avg       — naive weighted averaging of ṽ (the FedOpt-style baseline that
              Appendix F shows is biased by squared drift).
  avg_svd   — naive average followed by rank-r SVD re-projection.
  ajive     — the paper's protocol: lift views V^i = ṽ^i R_kᵀ, extract the
              joint component via AJIVE (joint rank = r), broadcast.

All return the *lifted* (n, n_cols) synchronized state; the caller re-projects
onto each client's next-round basis (InitState, Eq. 5).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .ajive import ajive_sync
from . import projector as proj

PyTree = Any


def lift_views(v_stack: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """ṽ (K, m, r) + basis (n, r) -> views (K, m, n) [right side]; left is
    (K, r, n) + (m, r) -> (K, m, n)."""
    if side == proj.RIGHT:
        return jnp.einsum("kmr,nr->kmn", v_stack, basis)
    return jnp.einsum("mr,krn->kmn", basis, v_stack)


def project_state(lifted: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """Re-project a lifted (m, n) state onto a (possibly new) basis."""
    if side == proj.RIGHT:
        return lifted @ basis                  # (m,n)@(n,r) -> (m,r)
    return basis.T @ lifted                    # (r,m)@(m,n) -> (r,n)


def sync_none(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    return None


def sync_avg(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    k = v_stack.shape[0]
    w = (jnp.full((k,), 1.0 / k) if weights is None
         else jnp.asarray(weights, jnp.float32) / jnp.sum(weights))
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    return jnp.einsum("k,kmn->mn", w, views)


def sync_avg_svd(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    avg = sync_avg(v_stack, basis, side, weights)
    r = rank if rank is not None else basis.shape[1]
    u, s, vt = jnp.linalg.svd(avg, full_matrices=False)
    return (u[:, :r] * s[:r][None, :]) @ vt[:r]


def sync_ajive(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    """The paper's 𝒮: spectral shared-signal extraction across client views."""
    r = rank if rank is not None else basis.shape[1]
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return ajive_sync(views, rank=r, weights=w)


SYNC_PROTOCOLS = {
    "none": sync_none,
    "avg": sync_avg,
    "avg_svd": sync_avg_svd,
    "ajive": sync_ajive,
}


def sync_block(protocol: str, v_stack: jnp.ndarray, old_basis: jnp.ndarray,
               new_basis: jnp.ndarray, side: str, weights=None,
               rank: Optional[int] = None) -> Optional[jnp.ndarray]:
    """One adapted block end-to-end: lift with the round-k basis, synchronize,
    re-project onto the round-(k+1) basis. Returns the next-round ṽ init, or
    None for protocol='none' (clients zero-init)."""
    lifted = SYNC_PROTOCOLS[protocol](v_stack, old_basis, side, weights, rank)
    if lifted is None:
        return None
    return jnp.maximum(project_state(lifted, new_basis, side), 0.0)
