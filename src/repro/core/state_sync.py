"""State synchronization protocols 𝒮 (Definition 3.3 + Algorithm 1 line 12).

Inputs are *stacked* per-client projected second moments ṽ (leading client
axis) plus the shared per-round basis R_k reconstructed from the broadcast
seed. Protocols:

  none      — clients reinitialize adaptive states each round (most fed-LoRA).
  avg       — naive weighted averaging of ṽ (the FedOpt-style baseline that
              Appendix F shows is biased by squared drift).
  avg_svd   — naive average followed by rank-r SVD re-projection.
  ajive     — the paper's protocol: lift views V^i = ṽ^i R_kᵀ, extract the
              joint component via AJIVE (joint rank = r), broadcast.

All return the *lifted* (n, n_cols) synchronized state; the caller re-projects
onto each client's next-round basis (InitState, Eq. 5).

Factored fast path: every protocol input has rank ≤ r, so the lift → sync →
re-project round-trip closes over the projected coordinates.
:func:`sync_block_factored` runs the same protocols without ever building a
dense ``(m, n)`` view — weighted averaging commutes with the (linear) lift,
rank-r SVD re-projection of a rank-≤r lift is the identity (making
``avg_svd`` ≡ ``avg`` in factored form), AJIVE runs on the (C·r) score space
(`ajive.ajive_sync_factored`), and the old→new basis change is the r×r
transfer ``projector.reproject``. Requires the shared-basis invariant of the
seeded-broadcast protocol (Appendix D).

Heterogeneous bases (the adaptive round 0, or data-driven refresh modes):
the shared-basis cancellation fails, but :func:`sync_block_hetero_factored`
still closes the round-trip over per-client r×r transfer Grams ``Q_iᵀ Q_0``
— averaging picks up the transfer directly, rank-r SVD factors through the
(C·r)×(C·r) Grams of the two skinny lift factors, and AJIVE composes the
basis change into its score Gram (`ajive.ajive_sync_hetero_factored`). No
default configuration executes a dense lift; :func:`sync_block` and the
per-client dense lift remain as parity oracles.

Chunk-streamed rounds (``core.fed`` / ``launch.steps`` with ``client_chunk``)
assemble the full (C, ·, r) ṽ/basis stacks from per-chunk outputs before
calling any protocol here — every 𝒮 input is the complete cohort uplink
(O(C·r·dim), the factored payload, never a dense view), which keeps the
synchronized result independent of the chunk size.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .ajive import (_inv_sqrt_rank_safe, ajive_sync, ajive_sync_factored,
                    ajive_sync_hetero_factored, normalize_weights)
from . import aggregation as agg
from . import projector as proj

PyTree = Any


def lift_views(v_stack: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """ṽ (K, m, r) + basis (n, r) -> views (K, m, n) [right side]; left is
    (K, r, n) + (m, r) -> (K, m, n)."""
    if side == proj.RIGHT:
        return jnp.einsum("kmr,nr->kmn", v_stack, basis)
    return jnp.einsum("mr,krn->kmn", basis, v_stack)


def project_state(lifted: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """Re-project a lifted (m, n) state onto a (possibly new) basis."""
    if side == proj.RIGHT:
        return lifted @ basis                  # (m,n)@(n,r) -> (m,r)
    return basis.T @ lifted                    # (r,m)@(m,n) -> (r,n)


def sync_none(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    return None


def sync_avg(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    w = normalize_weights(weights, v_stack.shape[0])
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    return jnp.einsum("k,kmn->mn", w, views)


def sync_avg_svd(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    avg = sync_avg(v_stack, basis, side, weights)
    r = rank if rank is not None else basis.shape[1]
    u, s, vt = jnp.linalg.svd(avg, full_matrices=False)
    return (u[:, :r] * s[:r][None, :]) @ vt[:r]


def sync_ajive(v_stack, basis, side, weights=None, rank: Optional[int] = None):
    """The paper's 𝒮: spectral shared-signal extraction across client views."""
    r = rank if rank is not None else basis.shape[1]
    views = lift_views(v_stack.astype(jnp.float32), basis, side)
    w = None if weights is None else jnp.asarray(weights, jnp.float32)
    return ajive_sync(views, rank=r, weights=w)


SYNC_PROTOCOLS = {
    "none": sync_none,
    "avg": sync_avg,
    "avg_svd": sync_avg_svd,
    "ajive": sync_ajive,
}


def sync_lifted_views(protocol: str, views: jnp.ndarray, weights=None,
                      rank: Optional[int] = None) -> jnp.ndarray:
    """Run protocol 𝒮 on *already-lifted* (k, m, n) views — the dense
    reference dispatch shared by the engine and the sharded runtime (used
    when clients lifted with heterogeneous bases, where the factored path
    does not apply)."""
    if protocol == "ajive":
        return ajive_sync(views, rank=rank, weights=weights)
    avg = jnp.einsum("k,kmn->mn", normalize_weights(weights, views.shape[0]),
                     views)
    if protocol == "avg":
        return avg
    if protocol == "avg_svd":
        u, s, vt = jnp.linalg.svd(avg, full_matrices=False)
        return (u[:, :rank] * s[:rank][None, :]) @ vt[:rank]
    raise ValueError(protocol)


def sync_block(protocol: str, v_stack: jnp.ndarray, old_basis: jnp.ndarray,
               new_basis: jnp.ndarray, side: str, weights=None,
               rank: Optional[int] = None) -> Optional[jnp.ndarray]:
    """One adapted block end-to-end: lift with the round-k basis, synchronize,
    re-project onto the round-(k+1) basis. Returns the next-round ṽ init, or
    None for protocol='none' (clients zero-init).

    This is the dense reference path (materializes (k, m, n) views); the
    production round loop uses :func:`sync_block_factored`.
    """
    lifted = SYNC_PROTOCOLS[protocol](v_stack, old_basis, side, weights, rank)
    if lifted is None:
        return None
    return jnp.maximum(project_state(lifted, new_basis, side), 0.0)


def sync_block_synced_factored(protocol: str, v_stack: jnp.ndarray, side: str,
                               weights=None,
                               rank: Optional[int] = None,
                               exclude_zero_weights: bool = False,
                               robust: str = "none", trim: float = 0.2,
                               iters: int = 8, tol: float = 1e-6
                               ) -> Optional[jnp.ndarray]:
    """Run protocol 𝒮 in projected coordinates (no lift): returns the synced
    state expressed on the *round-k* basis, or None for 'none'.

    ``exclude_zero_weights`` is the participation-masked round's 𝒮 contract:
    clients carrying zero aggregation weight (dropped / straggling this
    round) are excluded from the AJIVE joint-basis estimate, not just from
    the final weighted mean (averaging protocols exclude them already —
    zero weights vanish from a weighted mean).

    ``robust`` extends the 𝒜-side defense (``FedConfig.robust_agg``) to the
    projected-moment stacks: the protocol's final weighted mean over the
    (C, ·, r) stack is replaced by the matching
    :func:`aggregation.robust_factored_reduce` mode (trimmed-mean /
    geomedian / norm-clip in factored coordinates), so one poisoned moment
    upload cannot drag the synchronized state every honest client inherits.
    ``robust='none'`` is EXACTLY the unguarded reduction — the guarded
    program's honest-cohort bit-identity hinges on this."""
    if protocol == "none":
        return None
    if protocol in ("avg", "avg_svd"):
        # Lift is linear ⇒ averaging commutes with it; the rank-r SVD
        # re-projection in avg_svd is the identity on a rank-≤r lift.
        if robust != "none":
            return agg.robust_factored_reduce(v_stack, weights, robust,
                                              trim=trim, iters=iters, tol=tol)
        w = normalize_weights(weights, v_stack.shape[0])
        return jnp.einsum("k,k...->...", w, v_stack.astype(jnp.float32))
    if protocol == "ajive":
        r = rank if rank is not None else (
            v_stack.shape[-1] if side == proj.RIGHT else v_stack.shape[-2])
        return ajive_sync_factored(v_stack, rank=r, weights=weights, side=side,
                                   exclude_zero_weights=exclude_zero_weights,
                                   robust=robust, trim=trim, iters=iters,
                                   tol=tol)
    raise ValueError(protocol)


# ------------------------------------------- heterogeneous-basis factored --

def transfer_grams(b_stack: jnp.ndarray) -> jnp.ndarray:
    """Per-client r×r basis-change transfers ``T_i = Q_iᵀ Q_0`` onto the
    reference (client-0) basis. b_stack (C, dim, r) -> (C, r, r)."""
    b32 = b_stack.astype(jnp.float32)
    return jnp.einsum("cdr,ds->crs", b32, b32[0])


def _gram_orth(gram: jnp.ndarray):
    """Rank-safe orthonormalization of a factor ``X`` from its Gram ``XᵀX``:
    returns (coeff, rfac) with ``Q = X @ coeff`` orthonormal (numerically-null
    directions zeroed) and ``X = Q @ rfac``."""
    lam, vec = jnp.linalg.eigh(gram)
    lam = jnp.maximum(lam[::-1], 0.0)
    vec = vec[:, ::-1]
    coeff = vec * _inv_sqrt_rank_safe(lam)[None, :]
    rfac = (vec * jnp.sqrt(lam)[None, :]).T
    return coeff, rfac


def _hetero_avg_svd(v32, b32, w, rank, side):
    """Rank-``rank`` SVD of the weighted average of heterogeneously-lifted
    views, projected onto the client-0 basis — via the two skinny factors of
    ``A = Σ wᵢ lift(ṽ^i, Q_i)`` and their (C·r)×(C·r) Grams, never forming
    the dense (m, n) average."""
    c, r = v32.shape[0], b32.shape[-1]
    t_stack = transfer_grams(b32).reshape(c * r, r)        # Ĉᵀ Q_0
    if side == proj.RIGHT:
        # A = Û Ĉᵀ, Û = [wᵢ ṽ^i] (m, C·r), Ĉ = [Q_i] (n, C·r)
        uhat = jnp.moveaxis(w[:, None, None] * v32, 0, 1).reshape(
            v32.shape[1], c * r)
        chat = jnp.moveaxis(b32, 0, 1).reshape(b32.shape[1], c * r)
        cu, ru = _gram_orth(uhat.T @ uhat)
        cc, rc = _gram_orth(chat.T @ chat)
        p, s, wt = jnp.linalg.svd(ru @ rc.T)               # middle (C·r)²
        left = uhat @ (cu @ p[:, :rank])                   # Q_u P_r, (m, rank)
        right = wt[:rank] @ (cc.T @ t_stack)               # W_rᵀ Q_cᵀ Q_0
        return (left * s[:rank][None, :]) @ right          # (m, r)
    # A = Ĉ V̂, Ĉ = [Q_i] (m, C·r), V̂ = [wᵢ ṽ^i] stacked rows (C·r, n)
    chat = jnp.moveaxis(b32, 0, 1).reshape(b32.shape[1], c * r)
    vhat = (w[:, None, None] * v32).reshape(c * r, v32.shape[-1])
    cc, rc = _gram_orth(chat.T @ chat)
    cv, rv = _gram_orth(vhat @ vhat.T)
    p, s, wt = jnp.linalg.svd(rc @ rv.T)
    left = t_stack.T @ (cc @ p[:, :rank])                  # Q_0ᵀ Q_c P_r
    right = (wt[:rank] @ cv.T) @ vhat                      # W_rᵀ Q_vᵀ, (rank, n)
    return (left * s[:rank][None, :]) @ right              # (r, n)


def sync_block_hetero_factored(protocol: str, v_stack: jnp.ndarray,
                               b_stack: jnp.ndarray, side: str, weights=None,
                               rank: Optional[int] = None,
                               exclude_zero_weights: bool = False,
                               robust: str = "none", trim: float = 0.2,
                               iters: int = 8, tol: float = 1e-6
                               ) -> Optional[jnp.ndarray]:
    """Factored 𝒮 for **heterogeneous client bases** (the adaptive round-0
    case): each client lifted with its own basis, so the shared-basis
    cancellation of :func:`sync_block_synced_factored` does not apply — but
    the lift → 𝒮 → re-project-onto-client-0 round-trip still closes over r×r
    transfer Grams ``Q_iᵀ Q_0`` (see :func:`ajive_sync_hetero_factored`),
    eliminating the last dense per-client lift. Returns the synced state in
    projected shape on the client-0 basis (the dense per-client-lift
    :func:`sync_block`-style oracle's output), or None for 'none'.

    ``robust`` mirrors :func:`sync_block_synced_factored`: for the averaging
    protocols the moment stacks are first re-based onto the client-0
    coordinates (:func:`aggregation.rebase_factored_stack` — basis-coherent
    robust statistics under diverged bases) and then robustly reduced.
    Robust avg_svd reduces on the re-based coordinates, where every stack
    row is already rank ≤ r on the reference subspace, so the rank-r SVD
    re-projection is the identity and the mode coincides with robust avg
    (the out-of-subspace residual a robust vote cannot adjudicate is
    dropped). AJIVE's joint output is already expressed on client 0, so its
    final reduction robustifies directly."""
    if protocol == "none":
        return None
    if v_stack.ndim == 4:                      # stacked scan blocks (C,nb,·,r)
        return jax.vmap(
            lambda vs, bs: sync_block_hetero_factored(protocol, vs, bs, side,
                                                      weights, rank,
                                                      exclude_zero_weights,
                                                      robust, trim, iters,
                                                      tol),
            in_axes=1, out_axes=0)(v_stack, b_stack)
    r = b_stack.shape[-1]
    rank = rank if rank is not None else r
    v32 = v_stack.astype(jnp.float32)
    b32 = b_stack.astype(jnp.float32)
    w = normalize_weights(weights, v_stack.shape[0])
    if protocol == "ajive":
        return ajive_sync_hetero_factored(
            v32, b32, rank, weights, side,
            exclude_zero_weights=exclude_zero_weights,
            robust=robust, trim=trim, iters=iters, tol=tol)
    if robust != "none" and protocol in ("avg", "avg_svd"):
        based = agg.rebase_factored_stack(v32, b32, side)
        return agg.robust_factored_reduce(based, weights, robust,
                                          trim=trim, iters=iters, tol=tol)
    if protocol == "avg":
        t = transfer_grams(b32)                            # (C, r, r)
        if side == proj.RIGHT:
            return jnp.einsum("c,cmr,crs->ms", w, v32, t)
        return jnp.einsum("c,crs,crn->sn", w, t, v32)
    if protocol == "avg_svd":
        return _hetero_avg_svd(v32, b32, w, rank, side)
    raise ValueError(protocol)


def map_sync_leaves(leaf_fn, v_leaves, b_leaves, bucketed: bool = True):
    """Apply ``leaf_fn(v_stack, b_stack) -> synced`` over parallel per-leaf
    lists, one **vmapped program per shape bucket**.

    The per-leaf 𝒮 programs of a real model tree are overwhelmingly
    shape-identical (every attention block contributes the same (C, m, r)
    right leaf); running them one-by-one re-emits the same Gram → eigh →
    joint-basis chain per leaf and serializes the tiny solves. Bucketing by
    ``(v.shape, v.dtype, b.shape, b.dtype)`` — mirroring the PR-1 refresh
    bucketing (`galore.bucket_by_shape`) — stacks each bucket and emits the
    chain once under ``jax.vmap``, so the r×r eigendecompositions lower as
    one batched solve (kernel-routed on TPU). On CPU the batched eigh is
    bit-identical to the per-leaf loop, which survives under
    ``bucketed=False`` as the parity oracle.

    ``None`` v-leaves (non-adapted blocks) pass through as ``None``.
    ``leaf_fn`` must not return ``None`` (dispatch protocol='none' before
    calling). Singleton buckets skip the vmap wrapper entirely.
    """
    from .galore import bucket_by_shape
    out = [None] * len(v_leaves)
    if not bucketed:
        for i, (v, b) in enumerate(zip(v_leaves, b_leaves)):
            if v is not None:
                out[i] = leaf_fn(v, b)
        return out
    keys = [None if v is None else
            (tuple(v.shape), str(v.dtype), tuple(b.shape), str(b.dtype))
            for v, b in zip(v_leaves, b_leaves)]
    buckets, _ = bucket_by_shape(keys)
    for _, idxs in buckets:
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = leaf_fn(v_leaves[i], b_leaves[i])
            continue
        vs = jnp.stack([v_leaves[i] for i in idxs])
        bs = jnp.stack([b_leaves[i] for i in idxs])
        res = jax.vmap(leaf_fn)(vs, bs)
        for j, i in enumerate(idxs):
            out[i] = res[j]
    return out


def sync_block_factored(protocol: str, v_stack: jnp.ndarray,
                        old_basis: jnp.ndarray, new_basis: jnp.ndarray,
                        side: str, weights=None,
                        rank: Optional[int] = None) -> Optional[jnp.ndarray]:
    """Factored counterpart of :func:`sync_block`: synchronize in projected
    coordinates, then change basis with the r×r transfer — the dense (m, n)
    lift is never built. Assumes the shared-basis invariant (all clients hold
    the same seeded round-k basis)."""
    synced = sync_block_synced_factored(protocol, v_stack, side, weights, rank)
    if synced is None:
        return None
    return jnp.maximum(proj.reproject(synced, old_basis, new_basis, side), 0.0)
