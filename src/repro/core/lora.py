"""LoRA parameterization and factor algebra (paper §4.1 + baselines).

A LoRA-adapted block is ``W = W0 + (alpha/r) * B A`` with ``A ∈ R^{r×n}``
(Gaussian init) and ``B ∈ R^{m×r}`` (zero init). The federated baselines
differ in which factors train and how they aggregate:

  FedIT      — avg A and B separately:  ΔW̄ = (Σ p̃ᵢ Bᵢ)(Σ p̃ᵢ Aᵢ)   (rank ≤ r)
  FFA-LoRA   — A frozen at A0:          ΔW̄ = (Σ p̃ᵢ Bᵢ) A0          (rank ≤ r)
  LoRA-Fair  — factor avg + server refinement toward the mean lift
  FLoRA      — lift:                    ΔW̄ = Σ p̃ᵢ Bᵢ Aᵢ            (rank ≤ Kr)
  FR-LoRA    — lift + residual carry-over into re-initialized factors

The rank-tail diagnostic (Eq. 10) measures the off-manifold component
``dist_F(ΔW̄, M_{≤r}) = sqrt(Σ_{j>r} σ_j²)`` that drives update-space mismatch.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class LoraPair(NamedTuple):
    a: jnp.ndarray   # (..., r, n)
    b: jnp.ndarray   # (..., m, r)


def lora_init(key: jax.Array, shape, rank: int, dtype=jnp.float32,
              a_std: float = 0.02) -> LoraPair:
    """Adapters for a (m, n) block or a stacked (nb, m, n) scan-block leaf
    (one adapter per layer, leading dims broadcast through the factor
    algebra — ``b @ a`` is a batched matmul)."""
    *lead, m, n = shape
    a = a_std * jax.random.normal(key, (*lead, rank, n), dtype)
    b = jnp.zeros((*lead, m, rank), dtype)
    return LoraPair(a=a, b=b)


def lora_delta(pair: LoraPair, scale: float = 1.0) -> jnp.ndarray:
    return scale * (pair.b @ pair.a)


def is_lora_pair(x) -> bool:
    return isinstance(x, LoraPair)


def tree_lora_init(key: jax.Array, params: PyTree, target_fn, rank: int,
                   dtype=jnp.float32) -> PyTree:
    """LoraPair for each matrix target leaf — plain (m, n) or stacked
    (nb, m, n) scan-block layout — None elsewhere (mirrors the (2, 3)-D
    acceptance of ``fed.split_trainable`` so the LoRA baselines adapt the
    same target modules as the dense/GaLore methods)."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for i, (path, p) in enumerate(leaves):
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in path)
        if p.ndim in (2, 3) and target_fn(pstr, p):
            out.append(lora_init(jax.random.fold_in(key, i), p.shape,
                                 min(rank, min(p.shape[-2:])), dtype))
        else:
            out.append(None)
    return jax.tree_util.tree_unflatten(treedef, out)


def apply_lora(params: PyTree, adapters: PyTree, scale: float = 1.0) -> PyTree:
    """Effective weights W0 + scale·BA (None adapters pass through)."""
    def merge(p, ad):
        if ad is None:
            return p
        return p + lora_delta(ad, scale).astype(p.dtype)
    return jax.tree_util.tree_map(merge, params, adapters, is_leaf=is_lora_pair)


# --------------------------------------------------------------- metrics ----

def rank_tail_energy(delta_w: jnp.ndarray, rank: int) -> jnp.ndarray:
    """Eckart–Young distance to the rank-≤r manifold (Eq. 10); batched over
    any leading dims."""
    s = jnp.linalg.svd(delta_w, compute_uv=False)
    return jnp.sqrt(jnp.sum(s[..., rank:] ** 2, axis=-1))


def effective_rank(delta_w: jnp.ndarray, tol: float = 1e-6) -> jnp.ndarray:
    s = jnp.linalg.svd(delta_w, compute_uv=False)
    return jnp.sum(s > tol * s[..., :1], axis=-1)


def svd_truncate(delta_w: jnp.ndarray, rank: int) -> LoraPair:
    """Re-factorize a dense delta to rank-r LoRA factors (used by FR-LoRA and
    post-hoc SVD baselines); batched over any leading dims."""
    u, s, vt = jnp.linalg.svd(delta_w, full_matrices=False)
    sq = jnp.sqrt(s[..., :rank])
    return LoraPair(a=sq[..., :, None] * vt[..., :rank, :],
                    b=u[..., :, :rank] * sq[..., None, :])
