"""Planet-scale cohorts: population store, fault/adversary injection, stale
buffer, crash-resumable orchestration.

The compiled federated round trains a fixed C-client cohort; a real
federation samples that cohort each round from a large, mostly-offline
population with heterogeneous capacity — and some sampled clients drop out,
deliver their update rounds late, or upload corrupted state. This module
decouples the two worlds:

ParticipationConfig / sample_cohort
    Seeded per-round fault AND adversary injection: which population clients
    the round's C compiled slots hold, which drop (never contribute), which
    straggle (contribute ``delay`` rounds late), and which are corrupted
    this round (NaN shard / sign-flip / norm-scale attack —
    ``corrupt_rate``, realized as uplink multipliers by
    :func:`corruption_multipliers`). The plan for round k is a pure host
    function of ``(config, k)`` — identical whether rounds are driven one
    ``run_round`` at a time or as one ``lax.scan`` sweep, and across
    restarts; corruption draws come strictly AFTER the fault draws, so
    enabling adversaries never perturbs who drops or straggles. Every plan
    keeps ≥ 1 HONEST on-time participant (a round with zero trustworthy
    weight is undefined; ``corrupt_rate >= 1`` raises).

ClientStateStore
    Sticky per-client factored state for the whole virtual population: the
    rank-r accumulator rows ``R_i`` and projected-moment rows ṽ_i each
    client last produced, O(r(m+n)) per client — ~10⁵ cold clients fit in
    host memory, and least-recently-used shards spill to disk through
    ``checkpoint.io`` (whose atomic save + payload validation + non-finite
    rejection make a crash mid-spill recoverable: the shard falls back to
    its last complete spill, or to cold zeros — never to NaN rows).
    ``gather`` assembles a sampled cohort's rows into the round's (C, ·, r)
    stacked layout; ``scatter`` writes the round's donated buffer rows back
    under the population ids.

StalenessBuffer
    FedBuff-style bounded-staleness aggregation: a straggler's factored
    contribution (R_i rows + ṽ_i rows + birth basis + base scale) is masked
    out of its birth round and buffered; at its due round it merges into the
    global weights and the synced moments with a ``staleness_decay**delay``
    weight. ``capacity`` bounds the buffer: pushing onto a full buffer
    evicts (drops) the earliest-due entry. Delay-0 participation bypasses
    the buffer entirely — even at capacity — so ``max_staleness=0`` is
    *exactly* the synchronous round.

PopulationRunner — the round lifecycle is plan → quarantine → aggregate →
snapshot:
    1. **plan**: ``sample_cohort`` fixes the round's participants, faults,
       and adversary assignments (pure in (config, round)).
    2. **quarantine**: the fused round runs with the plan's participation
       mask and corruption multipliers; inside the compiled program the
       engine screens every factored contribution (non-finite + median-norm
       outlier tests) and folds failures into the zero-weight mask path —
       renormalized out of 𝒜, excluded from the AJIVE score Gram in 𝒮,
       stacks sanitized. Corrupted clients are also barred from scattering
       poisoned rows into the store. A drift/loss tripwire can additionally
       roll the federation back to the round-start state and replay with
       host-detected offenders force-quarantined (bounded retries, then
       degrade with a warning).
    3. **aggregate**: robust factored 𝒜 + exclusion-aware 𝒮 produce the new
       global state; due stale updates merged beforehand, stragglers
       buffered after.
    4. **snapshot**: on the configured cadence the FULL federation state —
       server weights, synced moments, client buffers, staleness-buffer
       entries, store rows, history — is written through ``checkpoint.io``'s
       atomic writer (``keep_last`` GC bounds disk); :meth:`PopulationRunner.
       restore` rebuilds a killed run from the latest snapshot with
       loss-curve parity to an uninterrupted run.

Bit-identity guarantees (each asserted in tests): a full-participation mask
short-circuits onto the unmasked compiled program; an all-honest cohort
through the guarded (quarantine/robust) program is bit-identical to the
unguarded round; ``max_staleness=0`` is bit-exactly the synchronous round;
and chunked ≡ unchunked cohort streaming — so every defense and scaling
layer is pay-for-what-you-use.

Drift observatory: :func:`moment_divergence` (weighted dispersion of the
per-client projected moments around the synced v̄ — the quantity 𝒮 is
supposed to keep bounded under partial participation) and
:func:`tree_rel_err` (relative Frobenius error between pytrees, used for
the stale-vs-fresh aggregation error). ``benchmarks/bench_participation.py``
and ``benchmarks/bench_state_mismatch.py`` share these implementations.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from collections import OrderedDict
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import galore as gal
from . import projector as proj
from ..checkpoint import io as ckpt_io

PyTree = Any


# ------------------------------------------------------------ fault plans ---

@dataclasses.dataclass(frozen=True)
class ParticipationConfig:
    """Seeded cohort sampling + fault injection knobs.

    population       virtual population size M; each round samples C of M
                     clients without replacement (0 ⇒ M = C, every client
                     holds a permanent slot — sampling degenerates to the
                     identity and only the fault injection remains).
    dropout_rate     P(a sampled client drops this round) — dropped clients
                     keep their compiled slot but carry zero effective
                     weight and are excluded from the AJIVE joint basis.
    straggler_rate   P(a surviving client straggles): its contribution is
                     masked out of the birth round and lands ``delay``
                     rounds late through the staleness buffer.
    max_staleness    k: straggler delays are uniform on {1..k}. 0 disables
                     straggling entirely (delay-0 ≡ on-time participation,
                     bypassing the buffer — bit-exactly synchronous).
    staleness_decay  β: a delay-d stale update merges with weight β^d.
    stale_scale      server-side learning rate on the stale merge.
    seed             fault-injection seed, independent of the train seed.
    corrupt_rate     P(an on-time client uploads corrupted state this
                     round). Adversary draws come strictly after the fault
                     draws (enabling them never changes who drops or
                     straggles), only on-time clients are corrupted (a
                     dropped adversary contributes nothing; a straggling
                     one would be screened at merge), and every plan keeps
                     ≥ 1 honest on-time participant — ``corrupt_rate >= 1``
                     makes that impossible and raises.
    corrupt_modes    which attacks the adversary mixes, drawn uniformly per
                     corrupted client: 'nan' (non-finite shard), 'sign_flip'
                     (negated update), 'scale' (update × attack_scale).
    attack_scale     multiplier of the 'scale' norm attack.
    """
    population: int = 0
    dropout_rate: float = 0.0
    straggler_rate: float = 0.0
    max_staleness: int = 0
    staleness_decay: float = 0.5
    stale_scale: float = 1.0
    seed: int = 0
    corrupt_rate: float = 0.0
    corrupt_modes: tuple = ("nan", "sign_flip", "scale")
    attack_scale: float = 100.0


CORRUPT_MODES = ("nan", "sign_flip", "scale")


class CohortPlan(NamedTuple):
    """One round's participation plan (host numpy, fully deterministic).

    clients  (C,) int64 population ids occupying the compiled cohort slots
    mask     (C,) bool — True = on-time participant (contributes this round)
    delays   (C,) int64 — 0 on-time, d ∈ {1..k} straggler (lands d rounds
             late), -1 dropped (never contributes)
    corrupt  (C,) int64 adversary assignment — 0 honest, j ≥ 1 the 1-based
             index into ``pcfg.corrupt_modes`` (None on hand-built plans:
             treated as all-honest)
    """
    round_idx: int
    clients: np.ndarray
    mask: np.ndarray
    delays: np.ndarray
    corrupt: Optional[np.ndarray] = None


def sample_cohort(pcfg: ParticipationConfig, cohort: int, round_idx: int,
                  population: Optional[int] = None) -> CohortPlan:
    """The round's cohort + fault plan as a pure function of (config, round).

    Deterministic in ``(pcfg.seed, round_idx)`` only — NOT in call order —
    so per-round drivers and scan-over-rounds drivers (and restarts) see
    identical plans. Draw order is fixed (sample → dropout → straggle →
    delays → corruption) so disabling a downstream knob never perturbs an
    upstream draw: ``max_staleness=0`` yields the same drops as
    ``straggler_rate=0``, and ``corrupt_rate=0`` yields the same
    clients/mask/delays as any positive rate.
    """
    pop = population if population is not None else (pcfg.population or cohort)
    if pop < cohort:
        raise ValueError(f"population {pop} < cohort {cohort}")
    rng = np.random.default_rng([pcfg.seed, round_idx])
    if pop == cohort:
        ids = np.arange(cohort, dtype=np.int64)
    else:
        ids = np.sort(rng.choice(pop, size=cohort,
                                 replace=False)).astype(np.int64)
    drop_u = rng.random(cohort)
    strag_u = rng.random(cohort)
    dropped = drop_u < pcfg.dropout_rate
    straggling = (~dropped) & (strag_u < pcfg.straggler_rate)
    if pcfg.max_staleness <= 0:
        straggling[:] = False          # delay-0 ≡ on-time: no buffering
    delays = np.zeros(cohort, dtype=np.int64)
    delays[dropped] = -1
    if straggling.any():
        delays[straggling] = rng.integers(1, pcfg.max_staleness + 1,
                                          size=int(straggling.sum()))
    if not (delays == 0).any():
        # A round needs ≥ 1 on-time participant: promote one deterministic
        # victim (the first faulted slot) back to on-time.
        delays[0] = 0
    mask = delays == 0
    # Adversary assignment — drawn strictly after the fault plan so the
    # clients/mask/delays above are invariant in corrupt_rate. Only on-time
    # clients are corruptible: a dropped adversary contributes nothing, and
    # corrupting a straggler would merely be screened at its stale merge.
    corrupt = np.zeros(cohort, dtype=np.int64)
    if pcfg.corrupt_rate > 0.0:
        for m in pcfg.corrupt_modes:
            if m not in CORRUPT_MODES:
                raise ValueError(f"corrupt mode {m!r} not in "
                                 f"{CORRUPT_MODES}")
        if not pcfg.corrupt_modes:
            raise ValueError("corrupt_rate > 0 needs >= 1 corrupt mode")
        corrupt_u = rng.random(cohort)
        bad = mask & (corrupt_u < pcfg.corrupt_rate)
        if bad.any():
            corrupt[bad] = rng.integers(1, len(pcfg.corrupt_modes) + 1,
                                        size=int(bad.sum()))
        if not (mask & (corrupt == 0)).any():
            # The honest counterpart of the on-time guarantee: quarantine
            # will (correctly) zero every corrupted contribution, so a
            # fully-adversarial on-time set would leave the round without
            # trustworthy weight. Pardon one deterministic victim — unless
            # the config makes honesty impossible.
            if pcfg.corrupt_rate >= 1.0:
                raise ValueError(
                    "corrupt_rate >= 1 leaves no honest on-time "
                    "participant in any round — quarantine + dropout must "
                    "leave at least one trustworthy client")
            corrupt[int(np.nonzero(mask)[0][0])] = 0
    return CohortPlan(round_idx=int(round_idx), clients=ids, mask=mask,
                      delays=delays, corrupt=corrupt)


def corruption_multipliers(plan: CohortPlan,
                           pcfg: ParticipationConfig) -> Optional[np.ndarray]:
    """Realize a plan's adversary assignments as the (C,) float32 per-client
    uplink multipliers the guarded round injects after the local phase
    (``FedEngine.run_round(attack=)``): 1.0 honest, NaN corrupted shard,
    -1.0 sign flip, ``attack_scale`` norm attack. None when the plan has no
    adversaries (the engine then never leaves the unguarded/un-attacked
    dispatch on its own)."""
    if plan.corrupt is None or not (plan.corrupt != 0).any():
        return None
    value = {"nan": np.float32(np.nan), "sign_flip": np.float32(-1.0),
             "scale": np.float32(pcfg.attack_scale)}
    mult = np.ones(plan.corrupt.shape[0], np.float32)
    for i in np.nonzero(plan.corrupt)[0]:
        mult[i] = value[pcfg.corrupt_modes[int(plan.corrupt[i]) - 1]]
    return mult


def corruption_schedule(pcfg: ParticipationConfig, cohort: int,
                        rounds: int, start_round: int = 0,
                        population: Optional[int] = None) -> list:
    """The seeded K-round attack schedule: one
    :func:`corruption_multipliers` entry per round (None for honest
    rounds), drawn from the same deterministic (seed, round) plans the
    participation layer uses. This is the shared attack operand source for
    engine AND runtime drivers — both sides of an attack-parity grid feed
    identical multipliers into ``run_round(attack=)``, so any divergence is
    the round program's, not the adversary's."""
    return [corruption_multipliers(
                sample_cohort(pcfg, cohort, start_round + k, population),
                pcfg)
            for k in range(int(rounds))]


# ------------------------------------------------------ client-state store --

def _flatten_with_keys(tree: PyTree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in leaves]
    return keys, [leaf for _, leaf in leaves], treedef


class ClientStateStore:
    """Host-side sticky state for a virtual client population.

    Rows are stored in contiguous per-shard numpy arrays (``shard_size``
    clients per shard); cold shards spill to ``directory`` through the
    atomic ``checkpoint.io`` writer and reload on demand, so the resident
    set is ``max_resident_shards`` regardless of population size. A client
    that has never been scattered reads back as zeros (cold).

    ``template`` is a pytree of per-client leaves (no leading client axis);
    gather/scatter speak (len(ids), ·) stacked trees of the same structure —
    the round's donated-buffer layout.
    """

    def __init__(self, n_clients: int, template: PyTree,
                 directory: Optional[str] = None, shard_size: int = 1024,
                 max_resident_shards: Optional[int] = None):
        self.n_clients = int(n_clients)
        self.shard_size = int(shard_size)
        self.directory = directory
        self.n_shards = -(-self.n_clients // self.shard_size)
        if max_resident_shards is None:
            max_resident_shards = 64 if directory else self.n_shards
        if directory is None and max_resident_shards < self.n_shards:
            raise ValueError("spill requires a directory: "
                             f"{self.n_shards} shards > resident cap "
                             f"{max_resident_shards}")
        self.max_resident = max(1, int(max_resident_shards))
        keys, leaves, self._treedef = _flatten_with_keys(template)
        self._keys = keys
        self._specs = [(tuple(np.shape(x)), np.asarray(x).dtype if not
                        hasattr(x, "dtype") else np.dtype(x.dtype))
                       for x in leaves]
        # LRU resident set: shard idx -> list of (rows_in_shard, *leaf) arrays
        self._resident: "OrderedDict[int, list]" = OrderedDict()
        self._dirty: set = set()
        self.last_round = np.full(self.n_clients, -1, dtype=np.int64)
        self.spills = 0
        self.loads = 0

    # -- shard management --
    def _shard_rows(self, shard: int) -> int:
        lo = shard * self.shard_size
        return min(self.shard_size, self.n_clients - lo)

    def _zero_shard(self, shard: int) -> list:
        rows = self._shard_rows(shard)
        return [np.zeros((rows,) + shape, dtype) for shape, dtype
                in self._specs]

    def _shard_template(self, shard: int) -> list:
        return self._zero_shard(shard)

    def _ensure_resident(self, shard: int) -> list:
        if shard in self._resident:
            self._resident.move_to_end(shard)
            return self._resident[shard]
        data = None
        if self.directory is not None:
            try:
                restored = ckpt_io.restore(self.directory, shard,
                                           self._shard_template(shard),
                                           name="clients")
                # np.array (copy): restore hands back device arrays whose
                # numpy views are read-only, and shard rows must be writable
                data = [np.array(x) for x in restored]
                self.loads += 1
            except (FileNotFoundError, ValueError):
                # Never spilled, a spill cut short mid-write, or a payload
                # carrying non-finite rows (restore's rejection): the
                # atomic writer guarantees nothing half-written sits under
                # the final name, so "missing/invalid/poisoned" cleanly
                # means "cold" — NaN rows never round-trip into the store.
                data = None
        if data is None:
            data = self._zero_shard(shard)
        self._resident[shard] = data
        self._evict()
        return data

    def _evict(self):
        while len(self._resident) > self.max_resident:
            shard, data = self._resident.popitem(last=False)
            if shard in self._dirty:
                self._spill(shard, data)

    def _spill(self, shard: int, data: list):
        if self.directory is None:
            raise RuntimeError("eviction without a spill directory")
        ckpt_io.save(self.directory, shard, data, name="clients")
        self._dirty.discard(shard)
        self.spills += 1

    def flush(self):
        """Spill every dirty resident shard (atomic per shard)."""
        if self.directory is None:
            return
        for shard in sorted(self._dirty & set(self._resident)):
            self._spill(shard, self._resident[shard])

    # -- row access --
    def gather(self, ids: np.ndarray) -> PyTree:
        """Rows for ``ids`` as a stacked (len(ids), ·) pytree (zeros for
        cold clients) — the round's client-buffer layout."""
        ids = np.asarray(ids, np.int64)
        outs = [np.empty((len(ids),) + shape, dtype)
                for shape, dtype in self._specs]
        shards = ids // self.shard_size
        for shard in np.unique(shards):
            sel = np.nonzero(shards == shard)[0]
            rows = ids[sel] - shard * self.shard_size
            data = self._ensure_resident(int(shard))
            for o, d in zip(outs, data):
                o[sel] = d[rows]
        return jax.tree_util.tree_unflatten(self._treedef, outs)

    def scatter(self, ids: np.ndarray, rows: PyTree,
                round_idx: Optional[int] = None):
        """Write stacked rows back under population ids (marks shards
        dirty; they spill lazily on eviction or ``flush``)."""
        ids = np.asarray(ids, np.int64)
        _, leaves, _ = _flatten_with_keys(rows)
        if len(leaves) != len(self._specs):
            raise ValueError("scatter tree structure != store template")
        leaves = [np.asarray(x) for x in leaves]
        shards = ids // self.shard_size
        for shard in np.unique(shards):
            sel = np.nonzero(shards == shard)[0]
            rel = ids[sel] - shard * self.shard_size
            data = self._ensure_resident(int(shard))
            for d, leaf in zip(data, leaves):
                d[rel] = leaf[sel]
            self._dirty.add(int(shard))
        if round_idx is not None:
            self.last_round[ids] = int(round_idx)

    def resident_bytes(self) -> int:
        return sum(a.nbytes for data in self._resident.values() for a in data)


# ------------------------------------------------------- staleness buffer ---

class StaleEntry(NamedTuple):
    """One straggler's buffered factored contribution.

    deltas  per-leaf client update: rank-r accumulator rows R_i (factored
            GaLore clients) or dense trainable deltas vs the birth-round
            global (dense/LoRA clients)
    bases   per-leaf (dim, r) birth-round basis (None leaves for dense)
    v_rows  per-leaf projected-moment rows ṽ_i (None for non-sync methods)
    """
    client_id: int
    birth_round: int
    due_round: int
    weight: float          # cohort sample weight at birth
    decay: float           # staleness_decay**delay * stale_scale
    base_scale: float      # (1-ηλ)^T at birth
    deltas: PyTree
    bases: Optional[PyTree]
    v_rows: Optional[PyTree]


class StalenessBuffer:
    """FedBuff-style bounded buffer: entries keyed by due round; by
    construction no entry lives longer than ``max_staleness`` rounds.

    ``capacity`` (None = unbounded) additionally caps the number of buffered
    entries: pushing onto a full buffer first evicts the entry with the
    earliest due round (FIFO among ties — the entry closest to merging,
    i.e. the least information lost relative to its decay weight), DROPS it
    (counted in ``evictions``, returned to the caller for observability),
    and then admits the new entry. Only stragglers ever reach ``push`` —
    delay-0 participation bypasses the buffer entirely, so a full buffer
    never affects on-time clients."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is not None and int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        self.capacity = None if capacity is None else int(capacity)
        self._entries: List[StaleEntry] = []
        self.evictions = 0

    def push(self, entry: StaleEntry) -> Optional[StaleEntry]:
        evicted = None
        if (self.capacity is not None
                and len(self._entries) >= self.capacity):
            idx = min(range(len(self._entries)),
                      key=lambda i: (self._entries[i].due_round, i))
            evicted = self._entries.pop(idx)
            self.evictions += 1
        self._entries.append(entry)
        return evicted

    def pop_due(self, round_idx: int) -> List[StaleEntry]:
        due = [e for e in self._entries if e.due_round <= round_idx]
        self._entries = [e for e in self._entries if e.due_round > round_idx]
        return due

    def __len__(self):
        return len(self._entries)

    @property
    def pending_rounds(self) -> List[int]:
        return sorted({e.due_round for e in self._entries})


# ------------------------------------------------------ drift observatory ---

def moment_divergence(v_rows: PyTree, v_bar: PyTree,
                      weights: Optional[np.ndarray] = None) -> float:
    """Weighted relative dispersion of per-client projected moments around
    the synced v̄: sqrt(Σ_i w_i ‖ṽ_i − v̄‖²_F) / (‖v̄‖_F + ε), summed over
    adapted blocks. This is the drift 𝒮 is meant to absorb — the shared
    metric of the participation bench and ``bench_state_mismatch``."""
    num, den = 0.0, 0.0
    rows = jax.tree_util.tree_leaves(v_rows, is_leaf=lambda x: x is None)
    bars = jax.tree_util.tree_leaves(v_bar, is_leaf=lambda x: x is None)
    w = None
    for r_leaf, b_leaf in zip(rows, bars):
        if r_leaf is None or b_leaf is None:
            continue
        r_np = np.asarray(r_leaf, np.float64)
        b_np = np.asarray(b_leaf, np.float64)
        if w is None:
            w = (np.full(r_np.shape[0], 1.0 / r_np.shape[0])
                 if weights is None else
                 np.asarray(weights, np.float64) /
                 max(float(np.sum(weights)), 1e-30))
        diff = (r_np - b_np[None]).reshape(r_np.shape[0], -1)
        num += float(w @ np.sum(diff * diff, axis=1))
        den += float(np.sum(b_np ** 2))
    return float(np.sqrt(num) / (np.sqrt(den) + 1e-12))


def tree_rel_err(tree_a: PyTree, tree_b: PyTree) -> float:
    """Relative Frobenius error ‖a − b‖_F / (‖b‖_F + ε) across all leaves —
    the stale-vs-fresh aggregation error metric."""
    num, den = 0.0, 0.0
    la = jax.tree_util.tree_leaves(tree_a, is_leaf=lambda x: x is None)
    lb = jax.tree_util.tree_leaves(tree_b, is_leaf=lambda x: x is None)
    for a, b in zip(la, lb):
        if a is None or b is None:
            continue
        a_np = np.asarray(a, np.float64)
        b_np = np.asarray(b, np.float64)
        num += float(np.sum((a_np - b_np) ** 2))
        den += float(np.sum(b_np ** 2))
    return float(np.sqrt(num) / (np.sqrt(den) + 1e-12))


# ------------------------------------------------------------- the runner ---

def _moment_leaf_side(delta_leaf, basis_leaf) -> str:
    """Projected-buffer side convention (matches ``fed._aggregate_factored``):
    right buffers are (..., m, r) with an (..., n, r) basis — trailing dims
    agree; left buffers are (..., r, n) with (..., m, r)."""
    return (proj.RIGHT if delta_leaf.shape[-1] == basis_leaf.shape[-1]
            else proj.LEFT)


class PopulationRunner:
    """Drives ``FedEngine`` rounds against a virtual population.

    Per round: sample the cohort plan → merge due stale updates into the
    global state → gather the cohort's sticky rows → run the masked fused
    round (compiled shapes untouched) → harvest the round's retained client
    buffers → push stragglers into the staleness buffer → scatter rows back
    to the store → record drift metrics.

    ``batches_for(ids, round_idx)`` supplies the cohort's local data with
    leading (C, T, ...) axes (e.g. ``lambda ids, r:
    batcher.round_batches(T, clients=list(ids))``).

    Requires the fused factored round (``fused_round and factored_sync``) —
    the harvest reads the engine's retained post-round client buffers, which
    only the fused path keeps.

    Defense-in-depth layers (all off by default):

    * **Adversary injection** — when the participation config draws
      corrupted clients, their uplink is perturbed *inside* the compiled
      round via the engine's attack operand (``corruption_multipliers``),
      and their rows are excluded from the sticky-row scatter.
    * **Snapshots** — ``snapshot_dir`` + ``snapshot_every=k`` persist the
      full federation state (global, retained client buffers, staleness
      buffer, store round-stamps, history) every k rounds through the
      atomic checkpoint writer, retaining ``snapshot_keep`` snapshots.
    * **Tripwire** — ``drift_tripwire`` / ``loss_tripwire`` thresholds
      arm a host-side guard: when a round's ``moment_divergence`` or
      ``mean_final_loss`` spikes past the threshold (or goes non-finite),
      the runner rolls the federation back to the captured round-start
      state and replays the round with the offending clients quarantined
      (host-side screen of the harvested uplink), for at most
      ``tripwire_retries`` replays before degrading with a warning.
    """

    def __init__(self, engine, batches_for: Callable[[np.ndarray, int], PyTree],
                 cohort: int, pcfg: Optional[ParticipationConfig] = None,
                 store_dir: Optional[str] = None, shard_size: int = 1024,
                 max_resident_shards: Optional[int] = None,
                 buffer_capacity: Optional[int] = None,
                 snapshot_dir: Optional[str] = None, snapshot_every: int = 0,
                 snapshot_keep: int = 3, drift_tripwire: float = 0.0,
                 loss_tripwire: float = 0.0, tripwire_retries: int = 1):
        if not (engine.cfg.fused_round and engine.cfg.factored_sync):
            raise ValueError("PopulationRunner requires the fused factored "
                             "round (it harvests the retained client "
                             "buffers)")
        self.engine = engine
        self.batches_for = batches_for
        self.cohort = int(cohort)
        self.pcfg = pcfg or engine.cfg.participation or ParticipationConfig()
        self.population = self.pcfg.population or self.cohort
        self.store = ClientStateStore(
            self.population, self._row_template(), directory=store_dir,
            shard_size=shard_size, max_resident_shards=max_resident_shards)
        self.buffer = StalenessBuffer(capacity=buffer_capacity)
        self.history: List[Dict[str, float]] = []
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)
        self.snapshot_keep = int(snapshot_keep)
        self.drift_tripwire = float(drift_tripwire)
        self.loss_tripwire = float(loss_tripwire)
        self.tripwire_retries = int(tripwire_retries)
        self._last_harvest: Optional[Dict[str, PyTree]] = None

    # -- templates / layout --
    def _galore_shapes(self):
        eng = self.engine
        st = jax.eval_shape(lambda: eng.tx.init(eng.global_trainable))
        g = gal.galore_state_of(st)
        v_tree = gal.extract_projected_v(g)
        return jax.tree_util.tree_map(
            lambda x: None if x is None else np.zeros(x.shape, np.float32),
            v_tree, is_leaf=lambda x: x is None)

    def _row_template(self) -> PyTree:
        """Per-client sticky row: factored accumulator + projected moments
        (GaLore clients), or the dense trainable delta (LoRA/dense
        clients)."""
        eng = self.engine
        if eng._factored:
            moments = self._galore_shapes()
            return {"delta": moments, "v": moments}
        tmpl = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, np.float32), eng.global_trainable)
        row = {"delta": tmpl}
        if eng.spec.optimizer == "galore_adamw":
            row["v"] = self._galore_shapes()
        return row

    def _base_scale(self) -> float:
        """(1-ηλ)^T — the factored round's decoupled-weight-decay scalar,
        identical across clients under the constant engine lr."""
        c = self.engine.cfg
        return float((1.0 - c.lr * c.weight_decay) ** c.local_steps)

    # -- harvest: slice the engine's retained post-round buffers host-side --
    def _harvest(self) -> Dict[str, PyTree]:
        eng = self.engine
        out: Dict[str, PyTree] = {}
        if eng._factored:
            out["delta"] = jax.tree_util.tree_map(np.asarray,
                                                  eng._client_state)
        else:
            out["trainable"] = jax.tree_util.tree_map(np.asarray,
                                                      eng._client_state)
        if eng.spec.optimizer == "galore_adamw":
            g = gal.galore_state_of(eng._client_opt)
            to_np = lambda t: jax.tree_util.tree_map(
                lambda x: None if x is None else np.asarray(x), t,
                is_leaf=lambda x: x is None)
            out["v"] = to_np(gal.extract_projected_v(g))
            out["bases"] = to_np(gal.extract_bases(g))
        return out

    @staticmethod
    def _rows(tree: Optional[PyTree], i: int) -> Optional[PyTree]:
        if tree is None:
            return None
        return jax.tree_util.tree_map(
            lambda x: None if x is None else x[i], tree,
            is_leaf=lambda x: x is None)

    # -- stale merge --
    def _merge_due(self, round_idx: int) -> Dict[str, float]:
        """Apply due stale contributions to the engine's global state
        (FedBuff server step), BEFORE the round runs.

        Weights: ``W ← W·(1 + Σ_j α_j (s_j − 1)) + Σ_j α_j·lift(R_j, B_j)``
        for factored clients (the decay term applied against the *current*
        base — exact when weight_decay=0, the documented FedBuff-style
        approximation otherwise), or ``W ← W + Σ_j α_j Δ_j`` for dense/LoRA
        deltas, with α_j = weight_j · decay_j.

        Moments: v̄ ← (1−ρ)·v̄ + ρ·(Σ α_j ṽ_j→now / Σα), ρ = Σα/(1+Σα), each
        stale ṽ re-based from its birth basis onto the current basis via the
        r×r transfer, clamped ≥ 0 (second moments).
        """
        due = self.buffer.pop_due(round_idx)
        if not due:
            return {"stale_merged": 0, "stale_weight_err": 0.0,
                    "stale_moment_div": 0.0}
        eng = self.engine
        tmap = jax.tree_util.tree_map
        g_old = eng.global_trainable

        # -- weights: fold each due entry into the global trainable. Every
        # tree here (trainable, factored deltas, bases) shares one treedef —
        # they are all tree_maps over the trainable tree — so structural
        # Nones (frozen leaves) align and tree_map skips them uniformly.
        g_acc = tmap(lambda x: np.asarray(x, np.float64), g_old)
        for e in due:
            alpha = e.weight * e.decay
            if e.bases is not None:
                lifted = tmap(
                    lambda d, b: np.asarray(proj.project_back(
                        jnp.asarray(d, jnp.float32),
                        jnp.asarray(b, jnp.float32),
                        _moment_leaf_side(d, b)), np.float64),
                    e.deltas, e.bases)
                g_acc = tmap(
                    lambda acc, l, a=alpha, s=e.base_scale:
                        acc + a * (s - 1.0) * acc + a * l,
                    g_acc, lifted)
            else:
                g_acc = tmap(
                    lambda acc, d, a=alpha:
                        acc + a * np.asarray(d, np.float64),
                    g_acc, e.deltas)
        g_new = tmap(lambda acc, x: jnp.asarray(acc.astype(np.float32),
                                                x.dtype), g_acc, g_old)
        weight_err = tree_rel_err(g_new, g_old)
        eng.global_trainable = g_new

        # -- moments: reproject each stale ṽ birth→current basis, decay-merge.
        stale_div = 0.0
        v_entries = [(e, e.weight * e.decay) for e in due
                     if e.v_rows is not None]
        if eng.synced_v is not None and v_entries:
            cur_bases = gal.extract_bases(
                gal.galore_state_of(eng._client_opt))
            cur0 = tmap(lambda b: np.asarray(b[0]), cur_bases)
            a_sum = sum(a for _, a in v_entries)
            rho = a_sum / (1.0 + a_sum)
            moved_list = []
            acc = None
            for e, alpha in v_entries:
                moved = tmap(
                    lambda v, b, c: np.asarray(proj.reproject(
                        jnp.asarray(v, jnp.float32),
                        jnp.asarray(b, jnp.float32),
                        jnp.asarray(c, jnp.float32),
                        _moment_leaf_side(v, b)), np.float64),
                    e.v_rows, e.bases, cur0)
                moved_list.append(moved)
                acc = (tmap(lambda m, a=alpha: a * m, moved) if acc is None
                       else tmap(lambda s, m, a=alpha: s + a * m, acc, moved))
            v_bar_old = tmap(lambda v: np.asarray(v, np.float64),
                             eng.synced_v)
            eng.synced_v = tmap(
                lambda vb, s: jnp.asarray(np.maximum(
                    (1.0 - rho) * vb + rho * (s / a_sum),
                    0.0).astype(np.float32)),
                v_bar_old, acc)
            stale_div = moment_divergence(
                tmap(lambda *ms: np.stack(ms), *moved_list), v_bar_old,
                weights=np.asarray([a for _, a in v_entries]))
        return {"stale_merged": len(due), "stale_weight_err": weight_err,
                "stale_moment_div": stale_div}

    # -- one population round --
    def run_round(self, weights: Optional[np.ndarray] = None
                  ) -> Dict[str, Any]:
        eng = self.engine
        plan = sample_cohort(self.pcfg, self.cohort, eng.round_idx,
                             self.population)
        tripwire = self.drift_tripwire > 0.0 or self.loss_tripwire > 0.0
        guard = self._capture(plan) if tripwire else None
        record = self._execute_round(plan, weights)

        replays = 0
        quarantined = np.zeros(self.cohort, bool)
        while tripwire and self._tripped(record):
            offenders = (self._offending_clients()
                         & plan.mask & ~quarantined)
            new_q = quarantined | offenders
            still_live = (plan.mask & ~new_q).any()
            if (replays >= self.tripwire_retries or not offenders.any()
                    or not still_live):
                warnings.warn(
                    "tripwire: round %d still exceeds thresholds after %d "
                    "replay(s) (drift=%.3g loss=%.3g); degrading — keeping "
                    "the tripped round's result"
                    % (record["round"], replays,
                       record["moment_divergence"],
                       record["mean_final_loss"]))
                break
            quarantined = new_q
            self._rollback(guard)
            # Quarantined clients drop out entirely: masked, no delay slot,
            # and their corruption code cleared so the attack operand does
            # not re-inject NaN into their (now zero-weight) rows.
            replay_plan = plan._replace(
                mask=plan.mask & ~quarantined,
                delays=np.where(quarantined, -1, plan.delays),
                corrupt=(None if plan.corrupt is None else
                         np.where(quarantined, 0, plan.corrupt)))
            record = self._execute_round(replay_plan, weights)
            replays += 1
        if tripwire:
            extra = {"tripwire_replays": replays,
                     "tripwire_quarantined": int(quarantined.sum())}
            self.history[-1].update(extra)
            record.update(extra)

        if (self.snapshot_dir is not None and self.snapshot_every > 0
                and eng.round_idx % self.snapshot_every == 0):
            self.snapshot()
        return record

    def _execute_round(self, plan: CohortPlan,
                       weights: Optional[np.ndarray]) -> Dict[str, Any]:
        eng = self.engine
        t = eng.round_idx
        stale_metrics = self._merge_due(t)
        gathered = self.store.gather(plan.clients)   # sticky rows (obs/warm)
        batches = self.batches_for(plan.clients, t)
        prev_global = None
        if not eng._factored:
            # Dense/LoRA clients report stale deltas against their BIRTH
            # round's global (the model they trained from) — capture it
            # before the round aggregates (global_trainable is not donated,
            # so this is a live reference, not a copy race).
            prev_global = jax.tree_util.tree_map(
                lambda x: np.asarray(x, np.float32), eng.global_trainable)
        attack = corruption_multipliers(plan, self.pcfg)
        metrics = eng.run_round(batches, weights=weights, mask=plan.mask,
                                attack=attack)

        harvest = self._harvest()
        self._last_harvest = harvest
        scale = self._base_scale()
        w_norm = np.asarray(eng._normalize_weights(weights, self.cohort))

        # Stragglers: buffer their factored contribution for the due round.
        # (Corruption is drawn on-time-only, so every straggler is honest.)
        evict0 = self.buffer.evictions
        for i in np.nonzero(plan.delays > 0)[0]:
            delay = int(plan.delays[i])
            if eng._factored:
                deltas = self._rows(harvest["delta"], i)
                bases = self._rows(harvest["bases"], i)
            else:
                tr_i = self._rows(harvest["trainable"], i)
                deltas = jax.tree_util.tree_map(
                    lambda a, b: np.asarray(a, np.float32) - b,
                    tr_i, prev_global)
                bases = None
            self.buffer.push(StaleEntry(
                client_id=int(plan.clients[i]), birth_round=t,
                due_round=t + delay, weight=float(w_norm[i]),
                decay=float(self.pcfg.staleness_decay ** delay
                            * self.pcfg.stale_scale),
                base_scale=scale, deltas=deltas, bases=bases,
                v_rows=self._rows(harvest.get("v"), i)))

        # Scatter: participants + stragglers persist their new sticky rows;
        # dropped clients keep their previous (possibly cold) rows, and so
        # do corrupted clients — their harvested rows carry the attacked (or
        # quarantine-zeroed) uplink, which must not poison the store.
        live = plan.delays >= 0
        if plan.corrupt is not None:
            live = live & (plan.corrupt == 0)
        if live.any():
            rows: Dict[str, PyTree] = {}
            if eng._factored:
                rows["delta"] = jax.tree_util.tree_map(
                    lambda x: x[live], harvest["delta"])
                rows["v"] = jax.tree_util.tree_map(
                    lambda x: None if x is None else x[live], harvest["v"],
                    is_leaf=lambda x: x is None)
            else:
                rows["delta"] = jax.tree_util.tree_map(
                    lambda a, b: np.asarray(a, np.float32)[live] - b[None],
                    harvest["trainable"], prev_global)
                if "v" in harvest:
                    rows["v"] = jax.tree_util.tree_map(
                        lambda x: None if x is None else x[live],
                        harvest["v"], is_leaf=lambda x: x is None)
            self.store.scatter(plan.clients[live], rows, round_idx=t)

        # Drift observatory: dispersion of on-time clients' end-of-round
        # moments around the freshly synced v̄.
        drift = 0.0
        if eng.synced_v is not None and "v" in harvest:
            on = plan.mask
            drift = moment_divergence(
                jax.tree_util.tree_map(
                    lambda x: None if x is None else x[on], harvest["v"],
                    is_leaf=lambda x: x is None),
                eng.synced_v, weights=w_norm[on])

        record = {
            "round": int(t),
            "participants": int(plan.mask.sum()),
            "dropped": int((plan.delays < 0).sum()),
            "straggling": int((plan.delays > 0).sum()),
            "buffered": len(self.buffer),
            "moment_divergence": drift,
            "mean_final_loss": float(np.asarray(
                metrics["local_loss"])[plan.mask, -1].mean()),
            "corrupted": (0 if plan.corrupt is None
                          else int((plan.corrupt != 0).sum())),
            "stale_evicted": self.buffer.evictions - evict0,
            **stale_metrics,
        }
        self.history.append(record)
        record = dict(record)
        record["plan"] = plan
        record["gathered"] = gathered
        record["local_loss"] = metrics["local_loss"]
        return record

    # -- tripwire: capture / detect / rollback / screen --
    def _capture(self, plan: CohortPlan) -> Dict[str, Any]:
        """Round-start state for rollback. JAX arrays are immutable and the
        referenced engine buffers (global/frozen/synced) are never donated,
        so references suffice; host-side state is copied."""
        eng = self.engine
        cap = {"global": eng.global_trainable, "synced": eng.synced_v,
               "round_idx": eng.round_idx,
               "entries": list(self.buffer._entries),
               "evictions": self.buffer.evictions,
               "history_len": len(self.history),
               "clients": plan.clients.copy(),
               "rows": self.store.gather(plan.clients),
               "last_round": self.store.last_round.copy()}
        if eng._frozen_mutates():
            cap["frozen"] = eng.frozen
        return cap

    def _rollback(self, cap: Dict[str, Any]) -> None:
        eng = self.engine
        eng.global_trainable = cap["global"]
        eng.synced_v = cap["synced"]
        if "frozen" in cap:
            eng.frozen = cap["frozen"]
        eng.round_idx = cap["round_idx"]
        self.buffer._entries = list(cap["entries"])
        self.buffer.evictions = cap["evictions"]
        del self.history[cap["history_len"]:]
        self.store.scatter(cap["clients"], cap["rows"])
        self.store.last_round = cap["last_round"].copy()

    def _tripped(self, record: Dict[str, Any]) -> bool:
        loss = record["mean_final_loss"]
        drift = record["moment_divergence"]
        if not (np.isfinite(loss) and np.isfinite(drift)):
            return True
        if self.loss_tripwire > 0.0 and loss > self.loss_tripwire:
            return True
        return self.drift_tripwire > 0.0 and drift > self.drift_tripwire

    def _offending_clients(self) -> np.ndarray:
        """Host-side screen of the last harvested uplink, mirroring the
        in-round quarantine in float64: a client offends when any of its
        retained buffers are non-finite, or when its factored norm exceeds
        ``quarantine_zmax`` × the cohort median norm."""
        h = self._last_harvest
        if h is None:
            return np.zeros(self.cohort, bool)
        finite = np.ones(self.cohort, bool)
        sq = np.zeros(self.cohort)
        delta_tree = h["delta"] if "delta" in h else h["trainable"]
        for tree in (delta_tree, h.get("v")):
            if tree is None:
                continue
            for x in jax.tree_util.tree_leaves(
                    tree, is_leaf=lambda x: x is None):
                if x is None:
                    continue
                x2 = np.asarray(x, np.float64).reshape(self.cohort, -1)
                ok = np.isfinite(x2)
                finite &= ok.all(axis=1)
                x2 = np.where(ok, x2, 0.0)
                sq += (x2 * x2).sum(axis=1)
        norm = np.sqrt(sq)
        out = ~finite
        med = np.median(norm[finite]) if finite.any() else 0.0
        if med > 0.0:
            out |= norm > self.engine.cfg.quarantine_zmax * med
        return out

    # -- snapshots: crash-resumable federation state --
    def _entry_template(self) -> Dict[str, Optional[PyTree]]:
        """Per-entry restore template matching ``StaleEntry`` array trees."""
        eng = self.engine
        if eng._factored:
            moments = self._galore_shapes()
            st = jax.eval_shape(lambda: eng.tx.init(eng.global_trainable))
            b_tree = gal.extract_bases(gal.galore_state_of(st))
            bases = jax.tree_util.tree_map(
                lambda x: None if x is None else np.zeros(x.shape,
                                                          np.float32),
                b_tree, is_leaf=lambda x: x is None)
            return {"deltas": moments, "bases": bases, "v_rows": moments}
        deltas = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, np.float32), eng.global_trainable)
        row = {"deltas": deltas, "bases": None, "v_rows": None}
        if eng.spec.optimizer == "galore_adamw":
            row["v_rows"] = self._galore_shapes()
        return row

    def snapshot(self, step: Optional[int] = None) -> int:
        """Persist the full federation state atomically.

        Payload (npz, via :mod:`repro.checkpoint.io`): global trainable,
        retained per-client buffers (non-finite entries sanitized to 0 —
        they are rebuilt from the global at round start and must not trip
        the restore-side corruption check), staleness-buffer entry arrays,
        the store's round stamps, and synced_v/frozen when live. Scalar
        metadata (round index, history, entry bookkeeping) goes to a
        sibling ``fed_<step>.meta.json`` written with the same
        tmp+rename discipline. Retains ``snapshot_keep`` snapshots.
        """
        if self.snapshot_dir is None:
            raise ValueError("snapshot_dir is not configured")
        eng = self.engine
        step = int(eng.round_idx if step is None else step)
        self.store.flush()
        eng._ensure_client_buffers(self.cohort)
        clean = lambda t: jax.tree_util.tree_map(
            lambda x: None if x is None else np.nan_to_num(
                np.asarray(x), nan=0.0, posinf=0.0, neginf=0.0),
            t, is_leaf=lambda x: x is None)
        payload: Dict[str, Any] = {
            "global": eng.global_trainable,
            "client_state": clean(eng._client_state),
            "client_opt": clean(eng._client_opt),
            "last_round": self.store.last_round,
            "entries": [{"deltas": clean(e.deltas),
                         "bases": clean(e.bases),
                         "v_rows": clean(e.v_rows)}
                        for e in self.buffer._entries]}
        if eng.synced_v is not None:
            payload["synced_v"] = eng.synced_v
        if eng._frozen_mutates():
            payload["frozen"] = eng.frozen
        ckpt_io.save(self.snapshot_dir, step, payload, name="fed",
                     keep_last=self.snapshot_keep)
        meta = {"round_idx": int(eng.round_idx),
                "history": self.history,
                "has_synced_v": eng.synced_v is not None,
                "has_frozen": bool(eng._frozen_mutates()),
                "buffer_evictions": int(self.buffer.evictions),
                "entries": [{"client_id": int(e.client_id),
                             "birth_round": int(e.birth_round),
                             "due_round": int(e.due_round),
                             "weight": float(e.weight),
                             "decay": float(e.decay),
                             "base_scale": float(e.base_scale),
                             "has_bases": e.bases is not None,
                             "has_v": e.v_rows is not None}
                            for e in self.buffer._entries]}
        mpath = os.path.join(self.snapshot_dir,
                             "fed_%08d.meta.json" % step)
        tmp = mpath + ".tmp"
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, mpath)
        return step

    def restore(self, step: Optional[int] = None) -> int:
        """Rebuild the federation from a snapshot (latest when ``step`` is
        None) — the crash-resume path: construct a fresh runner with the
        same config, then ``restore()``. The checkpoint reader rejects
        non-finite payloads, so a poisoned snapshot fails loudly here
        instead of silently resuming corrupted state."""
        if self.snapshot_dir is None:
            raise ValueError("snapshot_dir is not configured")
        if step is None:
            step = ckpt_io.latest_step(self.snapshot_dir, name="fed")
            if step is None:
                raise FileNotFoundError(
                    "no federation snapshot found in %r" % self.snapshot_dir)
        step = int(step)
        mpath = os.path.join(self.snapshot_dir,
                             "fed_%08d.meta.json" % step)
        with open(mpath) as f:
            meta = json.load(f)
        eng = self.engine
        eng._ensure_client_buffers(self.cohort)
        base_entry = self._entry_template()
        entry_templates = []
        for info in meta["entries"]:
            t = dict(base_entry)
            if not info["has_bases"]:
                t["bases"] = None
            if not info["has_v"]:
                t["v_rows"] = None
            entry_templates.append(t)
        template: Dict[str, Any] = {
            "global": eng.global_trainable,
            "client_state": eng._client_state,
            "client_opt": eng._client_opt,
            # int32 template: round stamps fit comfortably and jnp would
            # truncate int64 anyway under the default x64-off config.
            "last_round": self.store.last_round.astype(np.int32),
            "entries": entry_templates}
        if meta["has_synced_v"]:
            template["synced_v"] = (eng.synced_v if eng.synced_v is not None
                                    else eng._zero_synced_template())
        if meta["has_frozen"]:
            template["frozen"] = eng.frozen
        data = ckpt_io.restore(self.snapshot_dir, step, template, name="fed")
        eng.global_trainable = data["global"]
        eng._client_state = data["client_state"]
        eng._client_opt = data["client_opt"]
        eng.synced_v = data["synced_v"] if meta["has_synced_v"] else None
        if meta["has_frozen"]:
            eng.frozen = data["frozen"]
        eng.round_idx = int(meta["round_idx"])
        self.history = list(meta["history"])
        self.store.last_round = np.asarray(data["last_round"], np.int64)
        entries = []
        for info, trees in zip(meta["entries"], data["entries"]):
            entries.append(StaleEntry(
                client_id=int(info["client_id"]),
                birth_round=int(info["birth_round"]),
                due_round=int(info["due_round"]),
                weight=float(info["weight"]), decay=float(info["decay"]),
                base_scale=float(info["base_scale"]),
                deltas=trees["deltas"],
                bases=trees["bases"] if info["has_bases"] else None,
                v_rows=trees["v_rows"] if info["has_v"] else None))
        self.buffer._entries = entries
        self.buffer.evictions = int(meta.get("buffer_evictions", 0))
        self._last_harvest = None
        return step

    def run_rounds(self, k_rounds: int,
                   weights: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """K sequential population rounds (stale merges mutate the carry on
        the host between rounds, so the scanned driver cannot absorb them;
        dropout-only configs can use ``FedEngine.run_rounds(masks=...)``
        directly)."""
        out = None
        for _ in range(int(k_rounds)):
            out = self.run_round(weights=weights)
        self.store.flush()
        return {"history": self.history[-int(k_rounds):],
                "last": out}
