"""FedGaLore core — the paper's contribution.

Subspace alignment: GaLore-style gradient-subspace client optimization
(`galore`, `projector`). State alignment: drift-robust synchronization of
projected second moments via AJIVE (`ajive`, `state_sync`). Baseline federated
LoRA methods and the 𝒯/𝒜/𝒮 round decomposition live in `fed`, `lora`,
`aggregation`.
"""
from . import aggregation, ajive, fed, galore, lora, projector, state_sync
from .fed import METHODS, FedConfig, FedEngine, FedMethodSpec
from .galore import GaloreConfig, GaloreState, galore_adamw, scale_by_galore

__all__ = [
    "aggregation", "ajive", "fed", "galore", "lora", "projector",
    "state_sync", "METHODS", "FedConfig", "FedEngine", "FedMethodSpec",
    "GaloreConfig", "GaloreState", "galore_adamw", "scale_by_galore",
]
