"""Rank-r gradient projectors for GaLore-style subspace optimization.

Implements the paper's Appendix A.1 conventions:

* ``proj_type=std`` side rule — for a block ``W ∈ R^{m×n}``: if ``m >= n`` use a
  RIGHT basis ``B ∈ R^{n×r}`` (orthonormal columns; the paper's ``P = Bᵀ``) and
  project ``g̃ = g B ∈ R^{m×r}``; if ``m < n`` use a LEFT basis ``B ∈ R^{m×r}``
  and ``g̃ = Bᵀ g ∈ R^{r×n}``.
* Data-driven bases: exact SVD or randomized SVD (RSVD — two tall GEMMs + a
  small SVD; MXU-friendly, the TPU-native choice).
* Seeded random orthonormal bases: fully determined by an integer seed, so in
  the random-adaptive phase the server broadcasts only ``s_k`` (Appendix D).
* Low-rank change-of-basis reprojection ``X ← X (B_oldᵀ B_new)`` used when the
  projector refreshes, which never materialises a dense ``m×n`` buffer.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

RIGHT = "right"
LEFT = "left"


def proj_side(shape) -> str:
    """GaLore ``proj_type=std``: right basis iff m >= n (square ⇒ right).

    Shapes may carry leading batch dims (stacked scan blocks) — only the
    trailing two matter.
    """
    if len(shape) < 2:
        raise ValueError(f"projector requires a ≥2-D block, got {shape}")
    m, n = shape[-2:]
    return RIGHT if m >= n else LEFT


def basis_dim(shape) -> int:
    """The ambient dimension the basis lives in (n for right, m for left)."""
    m, n = shape[-2:]
    return n if proj_side(shape) == RIGHT else m


def project(g: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """g (..., m, n), basis (..., dim, r) -> (..., m, r) or (..., r, n)."""
    if side == RIGHT:
        return jnp.einsum("...mn,...nr->...mr", g, basis)
    return jnp.einsum("...mr,...mn->...rn", basis, g)


def project_back(u: jnp.ndarray, basis: jnp.ndarray, side: str) -> jnp.ndarray:
    """Projected update back to ambient shape."""
    if side == RIGHT:
        return jnp.einsum("...mr,...nr->...mn", u, basis)
    return jnp.einsum("...mr,...rn->...mn", basis, u)


def reproject(buf: jnp.ndarray, old_basis: jnp.ndarray, new_basis: jnp.ndarray,
              side: str) -> jnp.ndarray:
    """Change-of-basis for projected optimizer buffers (Appendix A.1).

    Right: buf (m,r) ← buf @ (B_oldᵀ B_new);  Left: buf (r,n) ← (B_newᵀ B_old) buf.
    The r×r transfer matrix keeps everything low-rank. Leading batch dims
    (stacked scan blocks) broadcast through.
    """
    transfer = jnp.einsum("...dr,...ds->...rs", old_basis, new_basis)  # (r,r)
    if side == RIGHT:
        return jnp.einsum("...mr,...rs->...ms", buf, transfer)
    return jnp.einsum("...rs,...rn->...sn", transfer, buf)


# ---------------------------------------------------------------- bases ----

def svd_basis(g: jnp.ndarray, rank: int, side: str) -> jnp.ndarray:
    """Exact top-r singular basis of the gradient (GaLore's SVD refresh)."""
    g32 = g.astype(jnp.float32)
    u, _, vt = jnp.linalg.svd(g32, full_matrices=False)
    if side == RIGHT:
        return vt[:rank].T          # (n,r) right singular vectors
    return u[:, :rank]              # (m,r) left singular vectors


def rsvd_basis(g: jnp.ndarray, rank: int, side: str, key: jax.Array,
               oversample: int = 8, power_iters: int = 1) -> jnp.ndarray:
    """Randomized SVD basis — two tall GEMMs + a small QR/SVD (TPU-friendly)."""
    g32 = g.astype(jnp.float32)
    m, n = g32.shape
    k = min(rank + oversample, min(m, n))
    if side == LEFT:
        g32 = g32.T                 # reduce to the right-basis problem on gᵀ
        m, n = n, m
    # Right basis of g32 == left basis of g32ᵀ: sketch the row space.
    omega = jax.random.normal(key, (m, k), jnp.float32)
    y = g32.T @ omega               # (n,k)
    for _ in range(power_iters):
        y = g32.T @ (g32 @ y)
    q, _ = jnp.linalg.qr(y)         # (n,k) orthonormal
    b = g32 @ q                     # (m,k)
    _, _, vt = jnp.linalg.svd(b, full_matrices=False)   # (k,k)
    basis = q @ vt[:rank].T         # (n,r)
    return basis


def random_basis(seed, dim: int, rank: int) -> jnp.ndarray:
    """Seeded random orthonormal basis (dim,r): QR of a Gaussian sketch.

    Deterministic in ``seed`` — this is what makes the server-broadcast-a-seed
    protocol possible (only the integer travels, never the basis).
    """
    key = jax.random.PRNGKey(seed) if jnp.ndim(seed) == 0 and not isinstance(
        seed, jax.Array) else (seed if isinstance(seed, jax.Array) and seed.shape == (2,)
                               else jax.random.PRNGKey(seed))
    gauss = jax.random.normal(key, (dim, rank), jnp.float32)
    q, r = jnp.linalg.qr(gauss)
    # Fix signs for full determinism across backends.
    signs = jnp.sign(jnp.diagonal(r))
    signs = jnp.where(signs == 0, 1.0, signs)
    return q * signs[None, :]


def seeded_block_key(seed: jnp.ndarray, refresh_idx: jnp.ndarray,
                     block_id: int) -> jax.Array:
    """Per-(round seed, refresh, block) key so blocks decorrelate but every
    client reconstructs the identical basis from the broadcast seed."""
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    key = jax.random.fold_in(key, jnp.asarray(refresh_idx, jnp.uint32))
    return jax.random.fold_in(key, block_id)


# ------------------------------------------- stacked (scan-block) variants --

def svd_basis_nd(g: jnp.ndarray, rank: int, side: str) -> jnp.ndarray:
    """svd_basis vmapped over leading stacked-block dims."""
    if g.ndim == 2:
        return svd_basis(g, rank, side)
    return jax.vmap(lambda gg: svd_basis_nd(gg, rank, side))(g)


def rsvd_basis_nd(g: jnp.ndarray, rank: int, side: str, keys: jax.Array,
                  oversample: int = 8) -> jnp.ndarray:
    """rsvd_basis vmapped over a leading stacked-block dim; ``keys`` must have
    one PRNG key per block row."""
    if g.ndim == 2:
        return rsvd_basis(g, rank, side, keys, oversample)
    return jax.vmap(lambda gg, kk: rsvd_basis_nd(gg, rank, side, kk,
                                                 oversample))(g, keys)


def random_basis_nd(keys: jax.Array, dim: int, rank: int) -> jnp.ndarray:
    """Seeded random bases: keys (..., 2) -> (..., dim, rank)."""
    if keys.ndim == 1:
        return random_basis(keys, dim, rank)
    return jax.vmap(lambda kk: random_basis_nd(kk, dim, rank))(keys)


def stacked_keys(base_key: jax.Array, n: int) -> jax.Array:
    """Per-layer keys derived from a shared base key (deterministic)."""
    return jax.vmap(lambda i: jax.random.fold_in(base_key, i))(jnp.arange(n))


class ProjectorSchedule(NamedTuple):
    """SVD->random schedule (Appendix D): data-driven bases for the first
    ``adaptive_steps`` refreshes, seeded random thereafter."""
    refresh_every: int            # tau
    adaptive_steps: int           # S: number of data-driven refreshes
    rank: int
    oversample: int = 8
    use_exact_svd: bool = False   # exact SVD vs RSVD in the adaptive phase

    def is_adaptive(self, refresh_idx) -> jnp.ndarray:
        return jnp.asarray(refresh_idx) < self.adaptive_steps
