"""Federated fine-tuning engine — 𝒯 / 𝒜 / 𝒮 composition (paper §3, Alg. 1).

This is the *reference* engine used by tests and the paper-table benchmarks:
clients are vectorized with ``jax.vmap`` over a leading client axis (the same
mapping the production runtime realizes as a mesh axis), local steps run under
``jax.lax.scan``, and each method is a (trainable-kind, optimizer,
aggregation, state-sync) 4-tuple per Table 1:

  ============  =========  ===========  ==============  =======
  method        trainable  optimizer 𝒯  aggregation 𝒜   sync 𝒮
  ============  =========  ===========  ==============  =======
  fedavg_full   dense      AdamW        dense avg       none
  fedit         LoRA(A,B)  Adam         factor avg      none
  ffa_lora      LoRA(B)    SGD          factor avg      none
  lora_fair     LoRA(A,B)  SGD          factor avg+ref  none
  flora         LoRA(A,B)  AdamW        lift ΔW, merge  none
  fr_lora       LoRA(A,B)  AdamW        lift ΔW, merge
                                        + rank-r refac  none
  fedgalore-    dense      GaLoreAdamW  dense avg       none
  fedgalore     dense      GaLoreAdamW  dense avg       AJIVE(ṽ)
  ============  =========  ===========  ==============  =======
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import aggregation as agg
from . import galore as gal
from . import lora as lora_lib
from . import projector as proj
from . import state_sync as sync_lib
from .. import optim as optim_lib
from ..optim.base import apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedMethodSpec:
    name: str
    trainable: str          # 'dense' | 'lora' | 'lora_b' | 'galore'
    optimizer: str          # 'sgd' | 'sgdm' | 'adam' | 'adamw' | 'galore_adamw'
    aggregation: str        # 'dense_avg'|'factor_avg'|'fair'|'lift_merge'|'lift_refac'
    state_sync: str         # 'none' | 'avg' | 'avg_svd' | 'ajive'


METHODS: Dict[str, FedMethodSpec] = {
    "fedavg_full": FedMethodSpec("fedavg_full", "dense", "adamw", "dense_avg", "none"),
    "fedit": FedMethodSpec("fedit", "lora", "adam", "factor_avg", "none"),
    "ffa_lora": FedMethodSpec("ffa_lora", "lora_b", "sgd", "factor_avg", "none"),
    "lora_fair": FedMethodSpec("lora_fair", "lora", "sgd", "fair", "none"),
    "flora": FedMethodSpec("flora", "lora", "adamw", "lift_merge", "none"),
    "fr_lora": FedMethodSpec("fr_lora", "lora", "adamw", "lift_refac", "none"),
    "fedgalore": FedMethodSpec("fedgalore", "galore", "galore_adamw", "dense_avg", "ajive"),
    "fedgalore_minus": FedMethodSpec("fedgalore_minus", "galore", "galore_adamw",
                                     "dense_avg", "none"),
    # extra ablations beyond the paper's table
    "fedgalore_avg": FedMethodSpec("fedgalore_avg", "galore", "galore_adamw",
                                   "dense_avg", "avg"),
    "fedgalore_avg_svd": FedMethodSpec("fedgalore_avg_svd", "galore", "galore_adamw",
                                       "dense_avg", "avg_svd"),
}


@dataclasses.dataclass(frozen=True)
class FedConfig:
    method: str = "fedgalore"
    rank: int = 8
    lora_scale: float = 2.0          # alpha / r
    lr: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0   # Assumption 3.8 (bounded G)
    local_steps: int = 8               # T
    rounds: int = 10                   # K
    adaptive_refreshes: int = 2        # S (SVD->random schedule)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    seed: int = 0
    reset_opt_each_round: bool = True  # 𝒮 'none' => reinit each round
    # Fast paths (see galore / state_sync module docstrings). factored_sync
    # synchronizes in projected coordinates under the shared-basis invariant
    # of the seeded-broadcast protocol; False restores the dense per-client
    # lift (the oracle, and the only correct path for heterogeneous bases).
    fused: bool = True
    use_pallas: Optional[bool] = None
    factored_sync: bool = True


# ------------------------------------------------------------ trainables ----

def split_trainable(params: PyTree, target_fn) -> tuple:
    """dense/galore trainable: the target leaves themselves; the rest frozen."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    train, frozen = [], []
    for path, p in leaves:
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in path)
        if p.ndim == 2 and target_fn(pstr, p):
            train.append(p)
            frozen.append(None)
        else:
            train.append(None)
            frozen.append(p)
    return (jax.tree_util.tree_unflatten(treedef, train),
            jax.tree_util.tree_unflatten(treedef, frozen))


def merge_dense(frozen: PyTree, trainable: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda f, t: t if f is None else f, frozen, trainable,
        is_leaf=lambda x: x is None)


def merge_lora(base: PyTree, adapters: PyTree, scale: float,
               freeze_a: bool = False) -> PyTree:
    def merge(p, ad):
        if ad is None:
            return p
        a = jax.lax.stop_gradient(ad.a) if freeze_a else ad.a
        return p + (scale * (ad.b @ a)).astype(p.dtype)
    return jax.tree_util.tree_map(merge, base, adapters,
                                  is_leaf=lora_lib.is_lora_pair)


# -------------------------------------------------------------- the engine --

class FedEngine:
    """Reference federated simulation. ``loss_fn(params, batch) -> scalar``."""

    def __init__(self, cfg: FedConfig, loss_fn: Callable, params: PyTree,
                 target_fn: Callable = None, eval_fn: Callable = None):
        self.cfg = cfg
        self.spec = METHODS[cfg.method]
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.target_fn = target_fn or (lambda p, x: True)
        self.base_params = params
        key = jax.random.PRNGKey(cfg.seed)

        if self.spec.trainable in ("dense", "galore"):
            self.global_trainable, self.frozen = split_trainable(params, self.target_fn)
        else:
            self.global_trainable = lora_lib.tree_lora_init(
                key, params, self.target_fn, cfg.rank)
            self.frozen = params   # LoRA: base stays whole, delta is additive

        self.galore_cfg = gal.GaloreConfig(
            rank=cfg.rank, refresh_every=10 ** 9,   # engine refreshes manually
            adaptive_steps=cfg.adaptive_refreshes, b1=cfg.b1, b2=cfg.b2,
            eps=cfg.eps, refresh_mode="auto", fused=cfg.fused,
            use_pallas=cfg.use_pallas)
        self.tx = self._make_tx()
        self._local_train = jax.jit(jax.vmap(self._local_train_one,
                                             in_axes=(0, 0, 0)))
        self.round_idx = 0
        self.synced_v = None   # lifted+projected ṽ init from 𝒮

    # ----------------------------------------------------------- optimizer --
    def _make_tx(self):
        c = self.cfg
        o = self.spec.optimizer
        if o == "sgd":
            return optim_lib.sgd(c.lr, clip_norm=c.clip_norm)
        if o == "sgdm":
            return optim_lib.sgd(c.lr, momentum=0.9, clip_norm=c.clip_norm)
        if o == "adam":
            return optim_lib.adam(c.lr, c.b1, c.b2, c.eps, clip_norm=c.clip_norm)
        if o == "adamw":
            return optim_lib.adamw(c.lr, c.b1, c.b2, c.eps, c.weight_decay,
                                   clip_norm=c.clip_norm)
        if o == "galore_adamw":
            return gal.galore_adamw(self.galore_cfg, c.lr, c.weight_decay,
                                    seed=c.seed, clip_norm=c.clip_norm)
        raise ValueError(o)

    # -------------------------------------------------------------- 𝒯 -------
    def _trainable_loss(self, trainable, batch):
        if self.spec.trainable in ("dense", "galore"):
            params = merge_dense(self.frozen, trainable)
        else:
            params = merge_lora(self.frozen, trainable, self.cfg.lora_scale,
                                freeze_a=(self.spec.trainable == "lora_b"))
        return self.loss_fn(params, batch)

    def _local_train_one(self, trainable, opt_state, batches):
        """T local steps on one client (lax.scan) — Definition 3.1."""
        def step(carry, batch):
            tr, st = carry
            loss, grads = jax.value_and_grad(self._trainable_loss)(tr, batch)
            updates, st = self.tx.update(grads, st, tr)
            tr = apply_updates(tr, updates)
            return (tr, st), loss
        (trainable, opt_state), losses = jax.lax.scan(
            step, (trainable, opt_state), batches)
        return trainable, opt_state, losses

    def _init_client_opt_states(self, n_clients: int):
        """Round-start InitState (Eq. 5): fresh states, then install synced ṽ
        and refresh the projector for the new round."""
        def init_one(i):
            st = self.tx.init(self.global_trainable)
            if self.spec.optimizer == "galore_adamw":
                g = gal.galore_state_of(st)
                g = gal.with_seed(g, self.cfg.seed + self.round_idx)  # s_k
                g = g._replace(count=jnp.asarray(
                    self.round_idx * self.cfg.local_steps, jnp.int32))
                if self.synced_v is not None:
                    g = gal.with_projected_v(g, self.synced_v)
                g = gal.manual_refresh(self.galore_cfg, g, self.round_idx)
                st = gal.replace_galore_state(st, g)
            return st
        states = [init_one(i) for i in range(n_clients)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)

    # ------------------------------------------------------------ a round ---
    def run_round(self, client_batches: PyTree, weights=None):
        """client_batches: pytree with leading axes (K clients, T steps, ...).

        Returns dict of metrics. Mutates engine global state.
        """
        k_clients = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        w = (jnp.full((k_clients,), 1.0 / k_clients) if weights is None
             else jnp.asarray(weights, jnp.float32) / jnp.sum(jnp.asarray(weights)))

        stacked_trainable = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (k_clients,) + x.shape),
            self.global_trainable)
        opt_states = self._init_client_opt_states(k_clients)

        out_trainable, out_opt, losses = self._local_train(
            stacked_trainable, opt_states, client_batches)

        self._aggregate(out_trainable, w)
        self._sync_states(out_opt, w)
        self.round_idx += 1
        return {"local_loss": losses,                      # (K, T)
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    # -------------------------------------------------------------- 𝒜 -------
    def _aggregate(self, stacked, w):
        s = self.spec.aggregation
        c = self.cfg
        if s == "dense_avg":
            self.global_trainable = agg.dense_delta_average(stacked, w)
        elif s == "factor_avg":
            self.global_trainable = agg.factor_average(stacked, w)
        elif s == "fair":
            self.global_trainable = agg.lora_fair_refine(stacked, w, c.lora_scale)
        elif s in ("lift_merge", "lift_refac"):
            deltas = agg.lift_average(stacked, w, c.lora_scale)
            if s == "lift_merge":
                # FLoRA: the full-rank average reaches every client via the
                # merged base; adapters restart from zero.
                self.frozen = jax.tree_util.tree_map(
                    lambda p, d: p if d is None else p + d.astype(p.dtype),
                    self.frozen, deltas, is_leaf=lambda x: x is None)
                self.global_trainable = self._fresh_adapters()
            else:
                # FR-LoRA: rank-r refactorization carries what fits in the
                # adapters; the residual merges into the base (kept, not lost).
                new_ad, resid = [], []
                dl, treedef = jax.tree_util.tree_flatten(
                    deltas, is_leaf=lambda x: x is None)
                for d in dl:
                    if d is None:
                        new_ad.append(None)
                        resid.append(None)
                    else:
                        pair = lora_lib.svd_truncate(d / max(c.lora_scale, 1e-12),
                                                     c.rank)
                        new_ad.append(pair)
                        resid.append(d - c.lora_scale * (pair.b @ pair.a))
                self.global_trainable = jax.tree_util.tree_unflatten(treedef, new_ad)
                resid = jax.tree_util.tree_unflatten(treedef, resid)
                self.frozen = jax.tree_util.tree_map(
                    lambda p, r: p if r is None else p + r.astype(p.dtype),
                    self.frozen, resid, is_leaf=lambda x: x is None)
        else:
            raise ValueError(s)

    def _fresh_adapters(self):
        key = jax.random.PRNGKey(self.cfg.seed + 1000 + self.round_idx)
        return lora_lib.tree_lora_init(key, self.base_params, self.target_fn,
                                       self.cfg.rank)

    # -------------------------------------------------------------- 𝒮 -------
    def _bases_shared(self) -> bool:
        """Whether every client ended the round on the identical basis.

        The only in-step refresh the engine permits fires at count == 0
        (round 0, refresh_every is effectively ∞); with adaptive refreshes
        enabled that refresh is data-driven from each client's *own* gradient,
        so round-0 bases are client-specific and 𝒮 must take the dense
        per-client lift. From round 1 on, every refresh is the seeded-random
        broadcast (manual_refresh with grads=None) — bases are bit-identical
        across clients and the factored path applies.
        """
        round0_adaptive = (self.round_idx == 0
                           and self.galore_cfg.adaptive_steps > 0
                           and self.galore_cfg.refresh_mode != "random")
        return not round0_adaptive

    def _sync_states(self, stacked_opt_states, w):
        if self.spec.state_sync == "none" or self.spec.optimizer != "galore_adamw":
            self.synced_v = None
            return
        g_stack = gal.galore_state_of(stacked_opt_states)
        v_stack_tree = gal.extract_projected_v(g_stack)     # leaves (K, ., r)
        basis_tree = gal.extract_bases(g_stack)             # leaves (K, dim, r)

        vs, treedef = jax.tree_util.tree_flatten(v_stack_tree,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(basis_tree, is_leaf=lambda x: x is None)
        synced = []
        for v_stack, b_stack in zip(vs, bs):
            if v_stack is None:
                synced.append(None)
                continue
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT

            if self.cfg.factored_sync and self._bases_shared():
                # Shared-basis invariant (the seeded-broadcast protocol keeps
                # every client on the identical round-k basis): synchronize
                # directly on the projected ṽ — no (K, m, n) lift. The result
                # stays on the round-k basis; manual_refresh applies the
                # next-round transfer at InitState.
                synced.append(sync_lib.sync_block_synced_factored(
                    self.spec.state_sync, v_stack, side, w, rank))
                continue

            def sync_one(v_cl, b_cl):
                # v_cl (K, m, r)|(K, r, n); b_cl (K, dim, r). Lift each
                # client's ṽ with its *own* basis (identical across clients
                # in the seeded-random phase), synchronize, re-project onto
                # the shared (client-0) end-of-round basis.
                if side == proj.RIGHT:
                    views = jnp.einsum("kmr,knr->kmn",
                                       v_cl.astype(jnp.float32),
                                       b_cl.astype(jnp.float32))
                else:
                    views = jnp.einsum("kmr,krn->kmn",
                                       b_cl.astype(jnp.float32),
                                       v_cl.astype(jnp.float32))
                lifted = sync_lib.sync_lifted_views(self.spec.state_sync,
                                                    views, w, rank)
                return sync_lib.project_state(lifted, b_cl[0], side)

            if v_stack.ndim == 4:        # stacked scan blocks (K, nb, ., r)
                synced.append(jax.vmap(sync_one, in_axes=(1, 1))(v_stack,
                                                                 b_stack))
            else:
                synced.append(sync_one(v_stack, b_stack))
        self.synced_v = jax.tree_util.tree_unflatten(treedef, synced)

    # ------------------------------------------------------------- helpers --
    def global_params(self) -> PyTree:
        if self.spec.trainable in ("dense", "galore"):
            return merge_dense(self.frozen, self.global_trainable)
        return merge_lora(self.frozen, self.global_trainable, self.cfg.lora_scale)

    def evaluate(self, batch) -> float:
        if self.eval_fn is None:
            return float(self.loss_fn(self.global_params(), batch))
        return float(self.eval_fn(self.global_params(), batch))
