"""Federated fine-tuning engine — 𝒯 / 𝒜 / 𝒮 composition (paper §3, Alg. 1).

This is the *reference* engine used by tests and the paper-table benchmarks:
clients are vectorized with ``jax.vmap`` over a leading client axis (the same
mapping the production runtime realizes as a mesh axis), local steps run under
``jax.lax.scan``, and each method is a (trainable-kind, optimizer,
aggregation, state-sync) 4-tuple per Table 1:

  ============  =========  ===========  ==============  =======
  method        trainable  optimizer 𝒯  aggregation 𝒜   sync 𝒮
  ============  =========  ===========  ==============  =======
  fedavg_full   dense      AdamW        dense avg       none
  fedit         LoRA(A,B)  Adam         factor avg      none
  ffa_lora      LoRA(B)    SGD          factor avg      none
  lora_fair     LoRA(A,B)  SGD          factor avg+ref  none
  flora         LoRA(A,B)  AdamW        lift ΔW, merge  none
  fr_lora       LoRA(A,B)  AdamW        lift ΔW, merge
                                        + rank-r refac  none
  fedgalore-    dense      GaLoreAdamW  dense avg       none
  fedgalore     dense      GaLoreAdamW  dense avg       AJIVE(ṽ)
  ============  =========  ===========  ==============  =======

Execution model
---------------
The default round is **whole-round fused**: InitState (Eq. 5 — fresh moments,
installed synced ṽ, bucketed projector refresh), T local steps, aggregation 𝒜
and state sync 𝒮 lower as ONE jitted program per round, with the persistent
client buffers donated back in every call so XLA reuses their memory for the
round's outputs. For the GaLore methods those buffers are **rank-r factored**:
within a round every local update lives in the shared rank-r subspace, so a
client carries only the (m, r)/(r, n) accumulator ``R_i`` around the broadcast
global base — the local step reads ``W_i = base_scale·W + lift(R_i)``
transiently, decoupled weight decay rides the scalar ``base_scale =
(1-ηλ)^t``, and 𝒜 collapses to ``base_scale·W + Σ wᵢ lift(Rᵢ)`` (O(C·r(m+n))
state and reduction instead of O(C·m·n); see ``galore.factored_adamw_step``).
On top of that the round **streams the cohort in chunks**: with
``FedConfig.client_chunk=B`` the fused program scans over C/B client chunks,
so the dense forward/backward working set scales with B while the factored
per-client results accumulate at O(C·r(m+n)) — cohort size is decoupled from
peak memory (C≈512 on a laptop-class host). 𝒮 never leaves projected
coordinates: shared-basis rounds run the factored protocols, and the adaptive
round-0 diverged-basis case runs the heterogeneous-basis factored sync (r×r
transfer Grams — no dense ``(C, m, n)`` lift anywhere).
:meth:`FedEngine.run_rounds` additionally drives K rounds as a single
``lax.scan`` dispatch for benchmark sweeps. ``FedConfig.factored_clients=
False`` keeps the fused round on dense per-client weight stacks;
``fused_round=False`` (or ``factored_sync=False``) restores the eager
stage-by-stage reference round — the dense-buffer parity oracle.

Memory model of the default factored round: **lift-free end to end**
(``FedConfig.lift_free``). The local step never reads a dense per-leaf
weight: target leaves enter the loss as ``models.layers.LowRankDelta`` nodes
whose delta-aware matmul computes ``base_scale·(x@W) + split-matmul(R_i)``
(O(t·r·(m+n)) on top of the base GEMM), and the custom VJP returns the
cotangent for ``R_i`` already in rank-r coordinates — so the factored round
executes **zero** O(m·n·r) lift GEMMs and **zero** dense m×n gradient
cotangents for GaLore target leaves. Global-norm clipping stays exact via
the VJP's dense-norm probes. The transient-lift read (``lift_free=False`` —
materialize ``base_scale·W + lift(R_i)`` per leaf per step, dense AD, then
re-project) survives as the parity oracle, and is still what the adaptive
round 0 runs (a ``lax.cond``): its data-driven RSVD refresh needs the dense
per-client gradient that the lift-free path never builds."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import aggregation as agg
from . import galore as gal
from . import lora as lora_lib
from . import projector as proj
from . import state_sync as sync_lib
from .population import ParticipationConfig
from .. import optim as optim_lib
from ..optim.base import apply_updates

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedMethodSpec:
    name: str
    trainable: str          # 'dense' | 'lora' | 'lora_b' | 'galore'
    optimizer: str          # 'sgd' | 'sgdm' | 'adam' | 'adamw' | 'galore_adamw'
    aggregation: str        # 'dense_avg'|'factor_avg'|'fair'|'lift_merge'|'lift_refac'
    state_sync: str         # 'none' | 'avg' | 'avg_svd' | 'ajive'


METHODS: Dict[str, FedMethodSpec] = {
    "fedavg_full": FedMethodSpec("fedavg_full", "dense", "adamw", "dense_avg", "none"),
    "fedit": FedMethodSpec("fedit", "lora", "adam", "factor_avg", "none"),
    "ffa_lora": FedMethodSpec("ffa_lora", "lora_b", "sgd", "factor_avg", "none"),
    "lora_fair": FedMethodSpec("lora_fair", "lora", "sgd", "fair", "none"),
    "flora": FedMethodSpec("flora", "lora", "adamw", "lift_merge", "none"),
    "fr_lora": FedMethodSpec("fr_lora", "lora", "adamw", "lift_refac", "none"),
    "fedgalore": FedMethodSpec("fedgalore", "galore", "galore_adamw", "dense_avg", "ajive"),
    "fedgalore_minus": FedMethodSpec("fedgalore_minus", "galore", "galore_adamw",
                                     "dense_avg", "none"),
    # extra ablations beyond the paper's table
    "fedgalore_avg": FedMethodSpec("fedgalore_avg", "galore", "galore_adamw",
                                   "dense_avg", "avg"),
    "fedgalore_avg_svd": FedMethodSpec("fedgalore_avg_svd", "galore", "galore_adamw",
                                       "dense_avg", "avg_svd"),
}


@dataclasses.dataclass(frozen=True)
class FedConfig:
    method: str = "fedgalore"
    rank: int = 8
    lora_scale: float = 2.0          # alpha / r
    lr: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0   # Assumption 3.8 (bounded G)
    local_steps: int = 8               # T
    rounds: int = 10                   # K
    adaptive_refreshes: int = 2        # S (SVD->random schedule)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    seed: int = 0
    reset_opt_each_round: bool = True  # 𝒮 'none' => reinit each round
    # Fast paths (see galore / state_sync module docstrings). factored_sync
    # synchronizes in projected coordinates — shared-basis rounds via the
    # seeded-broadcast invariant, the adaptive round 0 via the heterogeneous-
    # basis r×r transfer Grams; False restores the dense per-client lift
    # (the parity oracle). fused_round compiles InitState + T local steps +
    # 𝒜 + 𝒮 as one buffer-donated program per round; False runs the eager
    # stage-by-stage reference round (requires factored_sync=False to also
    # exercise the dense 𝒮 oracle).
    fused: bool = True
    use_pallas: Optional[bool] = None
    factored_sync: bool = True
    fused_round: bool = True
    # Client memory model of the fused round (module docstring). With
    # factored_clients (GaLore methods only) clients persist rank-r
    # accumulators instead of dense weight copies; False keeps the dense
    # stacked round (the in-fused-path oracle). client_chunk=B streams the
    # cohort through the round in C/B chunks (B must divide C; None = one
    # chunk), bounding the dense transient working set by B clients.
    factored_clients: bool = True
    client_chunk: Optional[int] = None
    # Lift-free factored local steps (module docstring): the delta-aware
    # forward + projected-cotangent backward replace the per-leaf transient
    # lift and the dense gradient. Effective when the factored client model
    # is active (all trainable leaves are target blocks); the adaptive
    # round 0 stays on the transient-lift read via a lax.cond (its RSVD
    # refresh needs dense gradients). False keeps PR 4's transient-lift
    # read everywhere — the lift-free parity oracle.
    lift_free: bool = True
    # Planet-scale participation (core.population module docstring): seeded
    # per-round cohort sampling out of a large virtual client population,
    # plus per-client dropout and straggler-delay fault injection. The
    # compiled round keeps its fixed (C, ·, r) shapes — dropped/straggling
    # clients are masked via :meth:`FedEngine.run_round`'s ``mask`` argument
    # (zero effective weight + AJIVE score exclusion), and straggler updates
    # land k rounds late through ``population.StalenessBuffer`` with
    # ``staleness_decay**delay`` weights. None disables the layer: every
    # round is the always-on full-cohort round (bit-identical to the
    # pre-participation engine). Orchestrated by
    # ``population.PopulationRunner``; the engine itself only consumes the
    # per-round masks.
    participation: Optional[ParticipationConfig] = None
    # Defense-in-depth (core.aggregation robust section): the guarded round
    # program screens/aggregates against corrupted client uploads, entirely
    # in factored coordinates. quarantine=True turns on the in-round screen
    # (non-finite reduction + median-norm outlier test at quarantine_zmax ×
    # the weighted median client norm); failures fold into the exclude-zero
    # mask path — zero renormalized weight in 𝒜, excluded from the AJIVE
    # score Gram in 𝒮, stacks sanitized so 0·NaN never reaches a reduction.
    # robust_agg replaces the weighted mean over factored client deltas in
    # 𝒜: 'norm_clip' (median-of-norms clipping), 'trimmed_mean'
    # (coordinate-wise weighted trim by robust_trim per tail), 'geomedian'
    # (Weiszfeld iterations, capped at robust_iters and converged early at
    # relative tolerance robust_tol); heterogeneous-basis rounds re-base
    # every client's factored stack onto the reference client's basis via
    # the r×r transfer Grams, so the coordinate-wise modes stay
    # well-defined when bases diverge. The same robust mode guards 𝒮: the
    # projected-moment stacks feeding state_sync/ajive are robustly
    # reduced (and quarantined clients' score columns excluded from the
    # joint-basis Gram) before spectral extraction. The guarded program is
    # compiled SEPARATELY — with both knobs at their defaults and no
    # injected attack, rounds run the pre-PR unguarded program, and an
    # all-honest cohort through the guarded program is bit-identical to
    # it (all-pass short-circuit; asserted in tests).
    robust_agg: str = "none"
    quarantine: bool = False
    quarantine_zmax: float = 6.0
    robust_trim: float = 0.2
    robust_iters: int = 8
    robust_tol: float = 1e-6
    # 𝒮 execution shape (state_sync / ajive module docstrings). bucketed_sync
    # groups shape-identical leaves into one vmapped sync program per bucket
    # (batched r×r eigh, kernel-routed on TPU); False keeps the per-leaf loop
    # as the parity oracle. pipeline_sync makes the scan-over-rounds drivers
    # one-round-deep software pipelines: round k's 𝒮 is deferred into round
    # k+1's body (where it only gates the first optimizer-moment read, so it
    # overlaps the gradient work of the next local phase) with an epilogue
    # sync after the scan — numerically the SAME program as the sequential
    # schedule (each round still consumes exactly round k-1's synced
    # moments), re-associated for overlap; False keeps the strictly
    # sequential scan body as the timing/parity oracle. Single-round
    # :meth:`FedEngine.run_round` dispatches are always sequential.
    bucketed_sync: bool = True
    pipeline_sync: bool = True


# ------------------------------------------------------------ trainables ----

def split_trainable(params: PyTree, target_fn) -> tuple:
    """dense/galore trainable: the target matrix leaves themselves (2-D, or
    3-D stacked scan blocks — one projector per layer); the rest frozen."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    train, frozen = [], []
    for path, p in leaves:
        pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in path)
        if p.ndim in (2, 3) and target_fn(pstr, p):
            train.append(p)
            frozen.append(None)
        else:
            train.append(None)
            frozen.append(p)
    return (jax.tree_util.tree_unflatten(treedef, train),
            jax.tree_util.tree_unflatten(treedef, frozen))


def merge_dense(frozen: PyTree, trainable: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda f, t: t if f is None else f, frozen, trainable,
        is_leaf=lambda x: x is None)


def merge_lora(base: PyTree, adapters: PyTree, scale: float,
               freeze_a: bool = False) -> PyTree:
    def merge(p, ad):
        if ad is None:
            return p
        a = jax.lax.stop_gradient(ad.a) if freeze_a else ad.a
        return p + (scale * (ad.b @ a)).astype(p.dtype)
    return jax.tree_util.tree_map(merge, base, adapters,
                                  is_leaf=lora_lib.is_lora_pair)


# -------------------------------------------------------------- the engine --

class FedEngine:
    """Reference federated simulation. ``loss_fn(params, batch) -> scalar``."""

    def __init__(self, cfg: FedConfig, loss_fn: Callable, params: PyTree,
                 target_fn: Callable = None, eval_fn: Callable = None):
        self.cfg = cfg
        self.spec = METHODS[cfg.method]
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.target_fn = target_fn or (lambda p, x: True)
        self.base_params = params
        key = jax.random.PRNGKey(cfg.seed)

        if self.spec.trainable in ("dense", "galore"):
            self.global_trainable, self.frozen = split_trainable(params, self.target_fn)
        else:
            self.global_trainable = lora_lib.tree_lora_init(
                key, params, self.target_fn, cfg.rank)
            self.frozen = params   # LoRA: base stays whole, delta is additive
        if not jax.tree_util.tree_leaves(self.global_trainable):
            raise ValueError(
                f"target_fn selected no trainable leaves for method "
                f"'{cfg.method}' — nothing to train or aggregate")

        self.galore_cfg = gal.GaloreConfig(
            rank=cfg.rank, refresh_every=10 ** 9,   # engine refreshes manually
            adaptive_steps=cfg.adaptive_refreshes, b1=cfg.b1, b2=cfg.b2,
            eps=cfg.eps, refresh_mode="auto", fused=cfg.fused,
            use_pallas=cfg.use_pallas)
        self.tx = self._make_tx()
        # Client axes for the optimizer state: moments/bases are per-client
        # (axis 0); the GaLore step counter and round seed stay UNBATCHED —
        # they are identical across clients by construction, and keeping them
        # scalar keeps the in-step `count % τ` refresh a real `lax.cond`
        # under vmap (a batched predicate would lower to a select that
        # computes the RSVD branch every local step).
        self._opt_axes = self._client_opt_axes()
        self._local_train = jax.jit(jax.vmap(
            self._local_train_one, in_axes=(0, self._opt_axes, 0, None),
            out_axes=(0, self._opt_axes, 0)))
        self.round_idx = 0
        self.synced_v = None   # lifted+projected ṽ init from 𝒮
        # Factored-delta clients (module docstring): GaLore methods whose
        # trainable is entirely target blocks carry rank-r accumulators
        # instead of dense per-client weight copies in the fused round.
        self._factored = False
        if cfg.factored_clients and self.spec.optimizer == "galore_adamw":
            st_shape = jax.eval_shape(
                lambda: self.tx.init(self.global_trainable))
            self._factored = gal.all_blocks_projected(
                gal.galore_state_of(st_shape))
        # Lift-free delta-context local steps: default on whenever the
        # factored client model is (all blocks projected); lift_free=False
        # keeps the transient-lift read as the parity oracle.
        self._lift_free = bool(cfg.lift_free) and self._factored
        # Whole-round fused program state: the persistent client buffers —
        # factored (C, ·, r) accumulators or dense (C, m, n) stacks — are
        # donated back into every round call (their memory is reused for
        # the round's outputs), and the jitted round / scan-over-rounds
        # drivers are built lazily on first use.
        self._client_state = None
        self._client_opt = None
        self._round_jit = None
        self._rounds_scan_jit = None
        # Participation-masked variants: same round math on renormalized
        # masked weights, with zero-weight clients additionally excluded
        # from the AJIVE joint-basis estimate. Kept as SEPARATE compiled
        # programs so the unmasked round stays byte-for-byte the program it
        # was before the participation layer existed (full-participation
        # masks short-circuit onto it — bit-identical by construction).
        self._round_masked_jit = None
        self._rounds_scan_masked_jit = None
        # Guarded variants (quarantine / robust_agg / injected attacks):
        # again separate compiled programs, so the default round is
        # byte-for-byte the pre-defense program and honest cohorts through
        # the guard short-circuit onto the same math bit-identically.
        if cfg.robust_agg not in agg.ROBUST_MODES:
            raise ValueError(f"robust_agg={cfg.robust_agg!r} not in "
                             f"{agg.ROBUST_MODES}")
        self._guard_cfg = bool(cfg.quarantine) or cfg.robust_agg != "none"
        if self._guard_cfg and not self._factored:
            raise ValueError(
                "quarantine/robust_agg need the factored client model "
                "(GaLore methods with factored_clients=True) — the screen "
                "and the robust reductions run on rank-r factored stacks")
        self._round_guard_jit = None
        self._rounds_scan_guard_jit = None
        # Lazy zero (dim, r) basis-shape donor for the pipelined scans'
        # slim pending sync (values never read).
        self._basis_template_tree = None

    # ----------------------------------------------------------- optimizer --
    def _make_tx(self):
        c = self.cfg
        o = self.spec.optimizer
        if o == "sgd":
            return optim_lib.sgd(c.lr, clip_norm=c.clip_norm)
        if o == "sgdm":
            return optim_lib.sgd(c.lr, momentum=0.9, clip_norm=c.clip_norm)
        if o == "adam":
            return optim_lib.adam(c.lr, c.b1, c.b2, c.eps, clip_norm=c.clip_norm)
        if o == "adamw":
            return optim_lib.adamw(c.lr, c.b1, c.b2, c.eps, c.weight_decay,
                                   clip_norm=c.clip_norm)
        if o == "galore_adamw":
            return gal.galore_adamw(self.galore_cfg, c.lr, c.weight_decay,
                                    seed=c.seed, clip_norm=c.clip_norm)
        raise ValueError(o)

    # -------------------------------------------------------------- 𝒯 -------
    def _trainable_loss(self, trainable, batch, frozen):
        if self.spec.trainable in ("dense", "galore"):
            params = merge_dense(frozen, trainable)
        else:
            params = merge_lora(frozen, trainable, self.cfg.lora_scale,
                                freeze_a=(self.spec.trainable == "lora_b"))
        return self.loss_fn(params, batch)

    def _local_train_one(self, trainable, opt_state, batches, frozen):
        """T local steps on one client (lax.scan) — Definition 3.1."""
        def step(carry, batch):
            tr, st = carry
            loss, grads = jax.value_and_grad(self._trainable_loss)(
                tr, batch, frozen)
            updates, st = self.tx.update(grads, st, tr)
            tr = apply_updates(tr, updates)
            return (tr, st), loss
        (trainable, opt_state), losses = jax.lax.scan(
            step, (trainable, opt_state), batches)
        return trainable, opt_state, losses

    def _init_state0(self, round_idx, synced_v, global_trainable):
        """One client's round-start InitState (Eq. 5): fresh moments, install
        the synced ṽ, refresh the projector for the new round (seeded
        broadcast — identical for every client, so the caller broadcasts the
        result along the client axis). jit/scan-safe in ``round_idx``."""
        st = self.tx.init(global_trainable)
        if self.spec.optimizer == "galore_adamw":
            g = gal.galore_state_of(st)
            g = gal.with_seed(g, self.cfg.seed + round_idx)       # s_k
            g = g._replace(count=jnp.asarray(
                round_idx * self.cfg.local_steps, jnp.int32))
            if synced_v is not None:
                g = gal.with_projected_v(g, synced_v)
            g = gal.manual_refresh(self.galore_cfg, g, round_idx)
            st = gal.replace_galore_state(st, g)
        return st

    def _client_opt_axes(self):
        """vmap axes tree for the optimizer state: 0 everywhere except the
        GaLore counter/seed, which stay scalar (see __init__)."""
        st = jax.eval_shape(lambda: self.tx.init(self.global_trainable))
        return gal.client_opt_axes(st)

    def _stack_opt_state(self, st, n_clients: int):
        """Broadcast one InitState along the client axis, honoring the
        unbatched-count/seed layout of :meth:`_client_opt_axes`."""
        return gal.stack_opt_state(st, n_clients)

    def _init_client_opt_states(self, n_clients: int):
        """Round-start InitState for all clients. States are identical by
        construction (the round-boundary refresh is the seeded broadcast), so
        one state is built — with the bucketed ``manual_refresh``, one vmapped
        refresh per shape bucket — and broadcast along the client axis."""
        st = self._init_state0(self.round_idx, self.synced_v,
                               self.global_trainable)
        return self._stack_opt_state(st, n_clients)

    # ------------------------------------------------------------ a round ---
    def _normalize_weights(self, weights, k_clients):
        return sync_lib.normalize_weights(weights, k_clients)

    def _masked_weights(self, weights, mask, k_clients):
        """Effective weights of a participation-masked round: the base
        weights with dropped clients zeroed, renormalized over the
        participants — eagerly, so the masked round is exactly the original
        round reweighted onto the participating subset."""
        w = np.asarray(self._normalize_weights(weights, k_clients))
        wm = np.where(np.asarray(mask, bool), w, 0.0)
        s = float(wm.sum())
        if s <= 0.0:
            raise ValueError("participation mask drops every client in the "
                             "cohort — a round needs >= 1 on-time participant")
        return jnp.asarray(wm / s, jnp.float32)

    @staticmethod
    def _canon_mask(mask, k_clients):
        """None | all-true masks collapse to None: full participation runs
        the pre-participation program on the pre-participation inputs
        (bit-identity is by construction, not by numerics)."""
        if mask is None:
            return None
        m = np.asarray(mask, bool).reshape(-1)
        if m.shape != (k_clients,):
            raise ValueError(f"mask shape {m.shape} != cohort ({k_clients},)")
        return None if m.all() else m

    @staticmethod
    def _canon_attack(attack, k_clients):
        """None | all-ones attack vectors collapse to None: an adversary-free
        round never forces the guarded program on its own (a quarantine /
        robust_agg config still does)."""
        if attack is None:
            return None
        a = np.asarray(attack, np.float32).reshape(-1)
        if a.shape != (k_clients,):
            raise ValueError(f"attack shape {a.shape} != cohort "
                             f"({k_clients},)")
        return None if np.all(a == 1.0) else a

    def run_round(self, client_batches: PyTree, weights=None, mask=None,
                  attack=None):
        """client_batches: pytree with leading axes (K clients, T steps, ...).

        Returns dict of metrics. Mutates engine global state. Default: the
        whole-round fused program (one dispatch, donated client buffers);
        ``fused_round=False`` or ``factored_sync=False`` runs the eager
        stage-by-stage reference round.

        ``mask`` (optional bool (K,)) marks this round's on-time
        participants: masked-out clients still occupy their compiled cohort
        slot (shapes never change) but carry zero effective weight in 𝒜 and
        are excluded from the AJIVE joint basis in 𝒮. A full-participation
        mask short-circuits onto the unmasked program — bit-identical to
        calling without a mask. The eager reference round applies the
        weight masking only (no score exclusion — it predates the
        participation layer and stays the unmasked oracle).

        ``attack`` (optional float (K,)) injects per-client uplink
        corruption INSIDE the compiled round: each client's factored
        contribution (accumulator, projected moments) is multiplied by its
        entry after the local phase (NaN = corrupted shard, -1 = sign flip,
        s = norm scale attack; see ``population.corruption_multipliers``).
        An all-ones vector short-circuits to no attack. Any attack — or a
        ``quarantine``/``robust_agg`` config — selects the guarded program:
        screen (if quarantine) → sanitize + renormalize → robust 𝒜 →
        exclusion-aware 𝒮. An honest cohort through the guarded program is
        bit-identical to the unguarded one.
        """
        k_clients = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        mask = self._canon_mask(mask, k_clients)
        attack = self._canon_attack(attack, k_clients)
        guarded = self._guard_cfg or attack is not None
        if not (self.cfg.fused_round and self.cfg.factored_sync):
            if guarded:
                raise ValueError(
                    "quarantine/robust_agg/attack injection require the "
                    "fused factored round (fused_round + factored_sync)")
            w = (self._normalize_weights(weights, k_clients) if mask is None
                 else self._masked_weights(weights, mask, k_clients))
            return self._run_round_eager(client_batches, w, k_clients)

        extra = ()
        if guarded:
            w = (self._normalize_weights(weights, k_clients) if mask is None
                 else self._masked_weights(weights, mask, k_clients))
            round_fn = self._round_guard_jitted()
            a = (np.ones((k_clients,), np.float32) if attack is None
                 else attack)
            extra = (jnp.asarray(a, jnp.float32),)
        elif mask is None:
            w = self._normalize_weights(weights, k_clients)
            round_fn = self._round_jitted()
        else:
            w = self._masked_weights(weights, mask, k_clients)
            round_fn = self._round_masked_jitted()
        self._ensure_client_buffers(k_clients)
        out = round_fn(
            self._client_state, self._client_opt, self.global_trainable,
            self.frozen, self.synced_v,
            jnp.asarray(self.round_idx, jnp.int32), client_batches, w,
            *extra)
        if self._frozen_mutates():
            (self._client_state, self._client_opt, self.global_trainable,
             self.frozen, self.synced_v, losses) = out
        else:
            (self._client_state, self._client_opt, self.global_trainable,
             self.synced_v, losses) = out
        self.round_idx += 1
        return {"local_loss": losses,                      # (K, T)
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    def run_rounds(self, round_batches: PyTree, weights=None, masks=None):
        """K rounds as ONE dispatch: ``lax.scan`` over the fused round.

        round_batches: pytree with leading (K rounds, C clients, T steps, ...)
        axes. Returns dict with ``local_loss`` of shape (K, C, T). Mutates
        engine global state exactly as K successive :meth:`run_round` calls
        (modulo the eager round-0 dense-𝒮 oracle, replaced by the
        heterogeneous-basis factored sync).

        ``masks`` (optional bool (K rounds, C)) applies a per-round
        participation mask: the per-round effective weights are renormalized
        eagerly (pure host function of the masks — reproducible between this
        scan driver and K :meth:`run_round` calls) and ride the scan as xs.
        All-true masks short-circuit onto the unmasked scan program.
        Staleness is NOT expressible inside the scan (stale merges mutate
        the carry between rounds on the host) — ``population.
        PopulationRunner`` falls back to sequential rounds when a staleness
        buffer is active.
        """
        leading = jax.tree_util.tree_leaves(round_batches)[0].shape
        k_rounds, k_clients = leading[0], leading[1]
        if masks is not None:
            masks = np.asarray(masks, bool)
            if masks.shape != (int(k_rounds), int(k_clients)):
                raise ValueError(f"masks shape {masks.shape} != "
                                 f"({k_rounds}, {k_clients})")
            if masks.all():
                masks = None
        if not (self.cfg.fused_round and self.cfg.factored_sync):
            # Honor the eager/oracle configuration: K sequential reference
            # rounds (keeps dense-𝒮 oracle comparisons driven through
            # run_rounds honest instead of silently going factored).
            losses = jnp.stack([
                self.run_round(
                    jax.tree_util.tree_map(lambda x, r=r: x[r],
                                           round_batches),
                    weights,
                    None if masks is None else masks[r])["local_loss"]
                for r in range(int(k_rounds))])
            return {"local_loss": losses,
                    "mean_final_loss": float(jnp.mean(losses[-1, :, -1]))}
        # Attack injection is not expressible inside the scan driver (a
        # per-round attack would ride the xs, but corruption plans come from
        # PopulationRunner, which drives sequential rounds anyway) — the
        # guarded scan exists so a quarantine/robust_agg config still gets
        # the one-dispatch sweep, guarding every round with a unit attack.
        if masks is None and not self._guard_cfg:
            w = self._normalize_weights(weights, k_clients)
            scan_fn = self._rounds_scan_jitted()
        else:
            # Per-round effective weights as scan xs; exclusion-aware 𝒮.
            if masks is None:
                w_one = self._normalize_weights(weights, k_clients)
                w = jnp.tile(w_one[None], (int(k_rounds), 1))
            else:
                w = jnp.stack([self._masked_weights(weights, m, k_clients)
                               for m in masks])
            scan_fn = (self._rounds_scan_guard_jitted() if self._guard_cfg
                       else self._rounds_scan_masked_jitted())

        synced_v = self.synced_v
        if synced_v is None and self._method_syncs():
            # Uniform scan carry: a zero synced ṽ is bit-identical to "no
            # synced state" (fresh moments are zero and the install clamps
            # at zero), so round 0 inside the scan matches run_round.
            synced_v = self._zero_synced_template()
        carry, losses = scan_fn(
            self.global_trainable, self.frozen, synced_v,
            jnp.asarray(self.round_idx, jnp.int32), round_batches, w)
        if self._frozen_mutates():
            self.global_trainable, self.frozen, new_synced, _ = carry
        else:
            self.global_trainable, new_synced, _ = carry
        if self._method_syncs():
            self.synced_v = new_synced
        self.round_idx += int(k_rounds)
        return {"local_loss": losses,                      # (K, C, T)
                "mean_final_loss": float(jnp.mean(losses[-1, :, -1]))}

    def _build_rounds_scan(self, exclude_zero: bool, guard: bool = False,
                           pipelined: bool = False):
        """jit a scan-over-rounds driver. Unmasked: one weight vector closed
        into every round (scan-invariant). Masked (``exclude_zero``): one
        effective weight vector per round rides the xs, and 𝒮 excludes
        zero-weight clients from the joint-basis estimate. ``guard`` runs
        every round through the quarantine/robust-𝒜 program (unit attack —
        per-round injected attacks don't ride the scan).

        ``pipelined`` (``FedConfig.pipeline_sync`` with a syncing method) is
        the one-round-deep software pipeline: every round *defers* its 𝒮
        install by returning the slim pending payload ``(tree, w_eff)``
        (:meth:`_slim_payload` — protocol-aware: the weighted-mean
        protocols reduce in-body and carry the small synced tree, ajive
        carries the per-client projected-moment stacks its joint basis
        needs), which the next round's body drains at its
        top (:meth:`_sync_pending`); a post-scan epilogue drains the last
        round. Round k+1 still consumes exactly round k's synced moments —
        the schedule is numerically the sequential program, re-associated
        so the deferred eigh chain only gates the *first optimizer-moment
        read* of the next local phase (the gradient work before it is
        independent and free to overlap). The carry stays slim: the
        per-client basis stacks never ride the scan boundary — when the
        call's first round may hold heterogeneous bases (adaptive round 0),
        that one round runs its transfer-Gram 𝒮 inline inside its own body
        and parks the small synced tree in a carried slot instead. Both
        schedules run as one uniform scan of the same length (splitting
        rounds across scans of different lengths changes XLA's loop
        compilation and costs bit-parity with the oracle). The sequential
        body survives under ``pipeline_sync=False`` as the timing/parity
        oracle."""
        frozen_mutates = self._frozen_mutates()
        # Robust-𝒮 rides the guarded program only: the deferred 𝒮 drains
        # (and the hetero0 inline sync) must reduce the projected-moment
        # stacks with the same robust mode the in-body rounds use, so the
        # pipelined guarded scan stays numerically the sequential guarded
        # program. Unguarded scans keep robust="none" — bit-identity with
        # the pre-robust program.
        robust = self.cfg.robust_agg if guard else "none"
        if pipelined:
            # Build the slim-sync basis template eagerly: materialized under
            # an active trace it would cache tracers (omnistaging) instead
            # of the concrete scan-invariant constant.
            self._basis_template()

        def scan_rounds(global_tr, frozen, synced_v, round_idx, batches, w):
            # frozen rides in the carry only for the lift aggregations
            # that rewrite it; otherwise it is scan-invariant (closed
            # over by the body — no per-iteration copy).
            k_rounds = jax.tree_util.tree_leaves(batches)[0].shape[0]
            xs = (batches, w) if exclude_zero else batches

            def run_round(g_tr, fz, sv, ridx, round_b, w_r, skip):
                kw = {}
                if guard:
                    kc = jax.tree_util.tree_leaves(round_b)[0].shape[0]
                    kw["attack"] = jnp.ones((kc,), jnp.float32)
                _, _, g_tr, fz, out_sv, losses = self._round_core(
                    g_tr, fz, sv, ridx, round_b, w_r,
                    exclude_zero=exclude_zero, skip_sync=skip, **kw)
                return g_tr, fz, out_sv, losses

            def seq_body(carry, x):
                round_b, w_r = x if exclude_zero else (x, w)
                if frozen_mutates:
                    g_tr, fz, sv, ridx = carry
                else:
                    (g_tr, sv, ridx), fz = carry, frozen
                g_tr, fz, sv, losses = run_round(
                    g_tr, fz, sv, ridx, round_b, w_r, skip=False)
                new_carry = ((g_tr, fz, sv, ridx + 1) if frozen_mutates
                             else (g_tr, sv, ridx + 1))
                return new_carry, losses

            carry0 = ((global_tr, frozen, synced_v, round_idx)
                      if frozen_mutates
                      else (global_tr, synced_v, round_idx))
            if not pipelined:
                return jax.lax.scan(seq_body, carry0, xs)

            # One uniform scan for the pipelined schedule too: the bodies
            # differ from seq_body only around 𝒮, so the local phases
            # compile in-loop exactly as the sequential oracle's do
            # (splitting rounds across scans of different lengths changes
            # XLA's loop compilation and costs bit-parity).
            # hetero0: the call's first round may hold heterogeneous bases
            # (adaptive refresh) AND the payload defers per-client stacks
            # whose drain is shared-basis-only — that round must sync
            # inline into a carried slot. The weighted-mean protocols'
            # payload is the fully synced tree (round-0 cond included), so
            # they never need the slot.
            hetero0 = (self.galore_cfg.adaptive_steps > 0
                       and self.galore_cfg.refresh_mode != "random"
                       and not self._slim_reduces_in_body())
            k_clients = jax.tree_util.tree_leaves(batches)[0].shape[1]

            def pipe_body(carry, x):
                round_b, w_r = x if exclude_zero else (x, w)
                if frozen_mutates:
                    if hetero0:
                        g_tr, fz, pend, sv0, ridx = carry
                    else:
                        g_tr, fz, pend, ridx = carry
                else:
                    fz = frozen
                    if hetero0:
                        g_tr, pend, sv0, ridx = carry
                    else:
                        g_tr, pend, ridx = carry
                pv, pw = pend

                def drain(_):
                    # Drain the previous round's slim pending payload here,
                    # at the top of this round's body, so its eigh chain
                    # sits adjacent to this round's independent gradient
                    # work. The first round of the call adopts the entry
                    # synced_v (outer cond); under hetero0 the second round
                    # adopts the first's inline sv0 instead (its bases may
                    # have diverged — the slim shared drain doesn't apply).
                    if not hetero0:
                        return self._sync_pending(pv, pw, exclude_zero,
                                                  robust=robust)
                    return jax.lax.cond(
                        ridx == round_idx + 1, lambda _: sv0,
                        lambda _: self._sync_pending(pv, pw, exclude_zero,
                                                     robust=robust),
                        operand=None)

                sv = jax.lax.cond(ridx == round_idx, lambda _: synced_v,
                                  drain, operand=None)
                kw = {}
                if guard:
                    kc = jax.tree_util.tree_leaves(round_b)[0].shape[0]
                    kw["attack"] = jnp.ones((kc,), jnp.float32)
                _, out_opt, g_tr, fz, pend_new, losses = self._round_core(
                    g_tr, fz, sv, ridx, round_b, w_r,
                    exclude_zero=exclude_zero, skip_sync=True, **kw)
                if hetero0:
                    def inline0(_):
                        # Possibly-heterogeneous first round of the call:
                        # run its transfer-Gram-capable 𝒮 inline (post-guard
                        # effective weights ride pend_new) — the per-client
                        # basis stacks never enter the carry.
                        v_t, b_t = self._sync_uplink(out_opt)
                        return self._sync_states_from_uplink(
                            v_t, b_t, pend_new[1], ridx, exclude_zero,
                            robust=robust)
                    sv0 = jax.lax.cond(ridx == round_idx, inline0,
                                       lambda _: sv0, operand=None)
                    new_carry = ((g_tr, fz, pend_new, sv0, ridx + 1)
                                 if frozen_mutates
                                 else (g_tr, pend_new, sv0, ridx + 1))
                else:
                    new_carry = ((g_tr, fz, pend_new, ridx + 1)
                                 if frozen_mutates
                                 else (g_tr, pend_new, ridx + 1))
                return new_carry, losses

            pend_0 = self._zero_slim_template(k_clients)
            if hetero0:
                slots = (pend_0, self._zero_synced_template())
            else:
                slots = (pend_0,)
            carry0 = ((global_tr, frozen) + slots + (round_idx,)
                      if frozen_mutates
                      else (global_tr,) + slots + (round_idx,))
            carry, losses = jax.lax.scan(pipe_body, carry0, xs)
            if frozen_mutates:
                g_tr, fz = carry[0], carry[1]
                rest = carry[2:]
            else:
                g_tr, fz = carry[0], frozen
                rest = carry[1:]
            pend, ridx = rest[0], rest[-1]
            # Epilogue: drain the last round's pending payload so the
            # returned carry matches the sequential schedule
            # state-for-state. A single-round hetero0 call never deferred
            # past its inline sv0.
            if hetero0 and k_rounds == 1:
                sv = rest[1]
            else:
                pv, pw = pend
                sv = self._sync_pending(pv, pw, exclude_zero, robust=robust)
            carry = ((g_tr, fz, sv, ridx) if frozen_mutates
                     else (g_tr, sv, ridx))
            return carry, losses
        return jax.jit(scan_rounds)

    def _zero_slim_template(self, k_clients: int):
        """Zero-filled slim pending payload ``(tree, w)`` for ``k_clients``
        — the pipelined scan's initial pending slot (shape donor only; the
        first iteration adopts the entry synced_v instead of draining it).
        The tree matches :meth:`_slim_payload`: reduced (no client axis)
        for the weighted-mean protocols, (C, ·, r) stacks for ajive."""
        w0 = jnp.zeros((k_clients,), jnp.float32)
        if self._slim_reduces_in_body():
            return (self._zero_synced_template(), w0)
        st = jax.eval_shape(lambda: self.tx.init(self.global_trainable))
        v = gal.extract_projected_v(gal.galore_state_of(st))
        return (jax.tree_util.tree_map(
                    lambda x: None if x is None else jnp.zeros(
                        (k_clients,) + x.shape, x.dtype),
                    v, is_leaf=lambda x: x is None),
                w0)

    def _pipeline_rounds(self) -> bool:
        """Pipelined scan drivers apply when the method syncs at all and the
        config keeps the (default) pipelined schedule."""
        return self.cfg.pipeline_sync and self._method_syncs()

    def _rounds_scan_jitted(self):
        if self._rounds_scan_jit is None:
            self._rounds_scan_jit = self._build_rounds_scan(
                exclude_zero=False, pipelined=self._pipeline_rounds())
        return self._rounds_scan_jit

    def _rounds_scan_masked_jitted(self):
        if self._rounds_scan_masked_jit is None:
            self._rounds_scan_masked_jit = self._build_rounds_scan(
                exclude_zero=True, pipelined=self._pipeline_rounds())
        return self._rounds_scan_masked_jit

    def _rounds_scan_guard_jitted(self):
        if self._rounds_scan_guard_jit is None:
            self._rounds_scan_guard_jit = self._build_rounds_scan(
                exclude_zero=True, guard=True,
                pipelined=self._pipeline_rounds())
        return self._rounds_scan_guard_jit

    # ------------------------------------------------- fused round program --
    def _method_syncs(self) -> bool:
        return (self.spec.state_sync != "none"
                and self.spec.optimizer == "galore_adamw")

    def _zero_synced_template(self):
        st = jax.eval_shape(lambda: self.tx.init(self.global_trainable))
        v_tree = gal.extract_projected_v(gal.galore_state_of(st))
        return jax.tree_util.tree_map(
            lambda x: None if x is None else jnp.zeros(x.shape, x.dtype),
            v_tree, is_leaf=lambda x: x is None)

    def _ensure_client_buffers(self, k_clients: int):
        """Allocate the persistent client buffers once; every fused round
        donates them back and adopts the round's outputs. Factored clients
        persist the rank-r (C, ·, r) accumulator stacks (O(C·r(m+n)) bytes);
        the dense (C, m, n) weight stacks survive only under
        ``factored_clients=False``."""
        have = (self._client_state is not None
                and jax.tree_util.tree_leaves(
                    self._client_state)[0].shape[0] == k_clients)
        if have:
            return
        # Shapes only — no device work: the buffer values are never read
        # (InitState rebuilds them inside the round program).
        st = jax.eval_shape(lambda: self._stack_opt_state(
            self._init_state0(0, None, self.global_trainable), k_clients))
        zeros = lambda s: jnp.zeros(s.shape, s.dtype)
        if self._factored:
            # The stacked moments already carry the (C, ·, r) accumulator
            # shapes — the factored client buffer mirrors them.
            self._client_state = gal.zero_client_deltas(
                gal.galore_state_of(st))
        else:
            self._client_state = jax.tree_util.tree_map(
                lambda x: jnp.zeros((k_clients,) + x.shape, x.dtype),
                self.global_trainable)
        self._client_opt = jax.tree_util.tree_map(zeros, st)

    def client_buffer_bytes(self) -> int:
        """Bytes held by the persistent per-client round buffers (the cohort
        memory the factored representation shrinks) — the bench metric."""
        total = 0
        for tree in (self._client_state, self._client_opt):
            if tree is not None:
                total += sum(x.nbytes
                             for x in jax.tree_util.tree_leaves(tree))
        return total

    def _chunk_size(self, k_clients: int) -> int:
        b = self.cfg.client_chunk or k_clients
        if k_clients % b:
            raise ValueError(f"client_chunk={b} must divide the cohort size "
                             f"{k_clients}")
        return b

    def _local_train_factored_one(self, deltas, opt_state, batches, frozen,
                                  global_trainable):
        """T factored local steps on one client (lax.scan): the client never
        holds a persistent dense weight copy — every step reads
        ``base_scale·W_global + lift(R_i)`` transiently and updates only the
        rank-r accumulator (galore.factored_adamw_step)."""
        c = self.cfg

        def step(carry, batch):
            dl, scale, st = carry
            tr = gal.lift_client_trainable(global_trainable, dl,
                                           gal.galore_state_of(st), scale)
            loss, grads = jax.value_and_grad(self._trainable_loss)(
                tr, batch, frozen)
            dl, scale, st = gal.factored_adamw_step(
                self.galore_cfg, grads, st, dl, scale, lr=c.lr,
                weight_decay=c.weight_decay, clip_norm=c.clip_norm)
            return (dl, scale, st), loss

        (deltas, scale, opt_state), losses = jax.lax.scan(
            step, (deltas, jnp.ones([], jnp.float32), opt_state), batches)
        return deltas, opt_state, losses, scale

    def _local_train_liftfree_one(self, deltas, opt_state, batches, frozen,
                                  global_trainable):
        """T lift-free local steps on one client (lax.scan): target leaves
        enter the loss as LowRankDelta nodes — the forward is the split-
        matmul delta read, the backward returns the R_i cotangent already in
        rank-r coordinates plus exact dense-norm probes for clipping, and
        the step consumes them with the projection GEMM skipped
        (galore.factored_adamw_step on a LiftFreeGrads bundle). The in-step
        refresh is hoisted before the forward (galore.maybe_refresh_instep)
        so cotangents arrive on the refreshed basis — seeded-random only,
        which is why the adaptive round 0 runs the transient oracle
        instead."""
        c = self.cfg

        def step(carry, batch):
            dl, scale, st = carry
            g0 = gal.maybe_refresh_instep(self.galore_cfg,
                                          gal.galore_state_of(st))
            st = gal.replace_galore_state(st, g0)
            loss, grads = gal.liftfree_value_and_grad(
                lambda tr: self._trainable_loss(tr, batch, frozen),
                global_trainable, dl, g0, scale)
            dl, scale, st = gal.factored_adamw_step(
                self.galore_cfg, grads, st, dl, scale, lr=c.lr,
                weight_decay=c.weight_decay, clip_norm=c.clip_norm)
            return (dl, scale, st), loss

        (deltas, scale, opt_state), losses = jax.lax.scan(
            step, (deltas, jnp.ones([], jnp.float32), opt_state), batches)
        return deltas, opt_state, losses, scale

    def _round0_adaptive(self) -> bool:
        """Whether round 0's in-step refresh is data-driven (RSVD of each
        client's own dense gradient) — the one case the lift-free read
        cannot serve and the transient-lift oracle handles via lax.cond."""
        return (self.galore_cfg.adaptive_steps > 0
                and self.galore_cfg.refresh_mode != "random")

    def _aggregate_factored(self, global_trainable, out_deltas, out_opt,
                            base_scales, w, round_idx, robust: str = "none"):
        """𝒜 for factored clients: ``(Σᵢ wᵢ sᵢ)·W + Σᵢ wᵢ lift(Rᵢ, Bᵢ)`` per
        target leaf (``sᵢ`` the per-client decayed base scales — identical
        under a constant lr, per-client under a schedule). Shared-basis
        rounds reduce in projected coordinates and lift once; the adaptive
        round-0 diverged-basis case contracts the per-client lifts
        client-by-client (a ``lax.cond``, mirroring
        :meth:`_sync_states_pure`) — no (C, m, n) stack either way.
        ``robust`` swaps the weighted mean over the factored stacks for a
        robust reduction (``aggregation.robust_factored_lift``; 'none' is
        exactly the plain path)."""
        bases = gal.extract_bases(gal.galore_state_of(out_opt))
        round0_hetero = (self.galore_cfg.adaptive_steps > 0
                         and self.galore_cfg.refresh_mode != "random")
        sbar = jnp.einsum("c,c->", w, base_scales.astype(jnp.float32))

        def one(w0, d_stack, b_stack):
            side = (proj.RIGHT if d_stack.shape[-1] == b_stack.shape[-1]
                    else proj.LEFT)

            def shared(_):
                return agg.robust_factored_lift(
                    d_stack, b_stack, side, w, robust, hetero=False,
                    trim=self.cfg.robust_trim, iters=self.cfg.robust_iters,
                    tol=self.cfg.robust_tol)

            def hetero(_):
                return agg.robust_factored_lift(
                    d_stack, b_stack, side, w, robust, hetero=True,
                    trim=self.cfg.robust_trim, iters=self.cfg.robust_iters,
                    tol=self.cfg.robust_tol)

            if round0_hetero:
                lifted = jax.lax.cond(round_idx == 0, hetero, shared,
                                      operand=None)
            else:
                lifted = shared(None)
            return (sbar * w0.astype(jnp.float32) + lifted).astype(w0.dtype)

        return jax.tree_util.tree_map(one, global_trainable, out_deltas,
                                      bases)

    def _apply_guard(self, out_d, out_opt, scales, w, attack):
        """The in-round defense gate, between the local phase and 𝒜/𝒮.

        1. Adversary injection: each client's uplink — factored accumulators
           AND projected moments — is multiplied by its ``attack`` entry
           (1.0 for honest clients: bitwise no-op).
        2. Quarantine screen (``cfg.quarantine``): non-finite + median-norm
           outlier test over the factored contributions
           (``aggregation.screen_factored_clients``). Failing clients are
           folded into the exclude-zero mask path — weights zeroed and
           renormalized over the survivors, stacks/scales sanitized so
           0·NaN never reaches a weighted reduction, moments zeroed out of
           the AJIVE score Gram. An all-pass verdict leaves every operand
           bitwise untouched (the honest short-circuit).

        Returns (out_d, out_opt, scales, w, quarantined_count).
        """
        tmap = jax.tree_util.tree_map
        ab = lambda x: attack.astype(jnp.float32).reshape(
            (-1,) + (1,) * (x.ndim - 1))
        out_d = tmap(lambda x: (x.astype(jnp.float32) * ab(x)).astype(
            x.dtype), out_d)
        g = gal.galore_state_of(out_opt)
        v_tree = tmap(
            lambda x: None if x is None
            else (x.astype(jnp.float32) * ab(x)).astype(x.dtype),
            gal.extract_projected_v(g), is_leaf=lambda x: x is None)
        n_quar = jnp.zeros([], jnp.int32)
        if self.cfg.quarantine:
            keep = agg.screen_factored_clients(
                out_d, v_tree, scales, w, zmax=self.cfg.quarantine_zmax)
            out_d = agg.mask_client_rows(out_d, keep)
            v_tree = agg.mask_client_rows(v_tree, keep)
            scales = jnp.where(keep, scales, 1.0)   # enters the sbar einsum
            w = agg.quarantine_weights(w, keep)
            n_quar = jnp.sum((~keep).astype(jnp.int32))
        out_opt = gal.replace_galore_state(out_opt,
                                           gal.with_projected_v(g, v_tree))
        return out_d, out_opt, scales, w, n_quar

    def _round_core(self, global_trainable, frozen, synced_v, round_idx,
                    client_batches, w, exclude_zero: bool = False,
                    attack=None, skip_sync: bool = False):
        """The whole federated round as a pure function: InitState → T local
        steps (vmapped clients, streamed over cohort chunks) → 𝒜 → factored
        𝒮. Shared by the per-round jitted program and the scan-over-rounds
        driver. ``exclude_zero`` is the participation-masked variant: w is a
        masked+renormalized weight vector and 𝒮 drops zero-weight clients
        from the AJIVE joint basis (𝒜 needs no flag — zero weights already
        vanish from every weighted reduction).

        Chunk streaming: the cohort is reshaped (C, …) → (C/B, B, …) and a
        ``lax.scan`` runs the B-client vmapped local phase per chunk, so the
        dense forward/backward working set is bounded by B clients while the
        per-client results — factored accumulators, projected moments,
        losses — stack to the full (C, …) cohort (each client's computation
        is independent, so chunked ≡ unchunked client-for-client). 𝒜 and 𝒮
        then run once on the full factored stacks, keeping them bit-identical
        across chunk sizes.

        ``attack`` (guarded variant only) is the (C,) per-client corruption
        multiplier injected after the local phase; its presence also arms
        the quarantine screen and robust 𝒜/𝒮 per the config
        (:meth:`_apply_guard`; the same ``robust_agg`` mode guards the
        projected-moment reductions inside 𝒮).

        ``skip_sync`` is the pipelined-scan building block: instead of
        installing 𝒮's result here, the ``new_synced`` slot returns the
        round's *slim* pending payload ``(tree, w_eff)`` (see
        :meth:`_slim_payload` — the reduced synced tree for the
        weighted-mean protocols, the projected-moment stacks for ajive,
        plus the post-guard effective weights) for the caller to drain at
        the top of the next round's body (or in the post-scan epilogue)
        via :meth:`_sync_pending`. The slim payload is shared-basis-only
        (no per-client basis stacks ride the scan carry); the possibly
        heterogeneous adaptive round 0 is handled by the pipelined caller
        syncing that round inline from the full uplink. Same math,
        re-associated across the round boundary."""
        if attack is not None and not self._factored:
            raise ValueError("the guarded round requires factored clients")
        k_clients = jax.tree_util.tree_leaves(client_batches)[0].shape[0]
        b = self._chunk_size(k_clients)
        n_chunks = k_clients // b
        st0 = self._init_state0(round_idx, synced_v, global_trainable)
        opt0 = self._stack_opt_state(st0, b)

        def stream(local_fn, batches):
            """Run the B-client vmapped local phase over the cohort: directly
            for a single chunk, as a lax.scan over C/B chunks otherwise, and
            reassemble the full (C, …) stacks either way."""
            if n_chunks == 1:
                return local_fn(batches)
            cb = jax.tree_util.tree_map(
                lambda x: x.reshape((n_chunks, b) + x.shape[1:]), batches)
            _, out = jax.lax.scan(
                lambda carry, batch_c: (carry, local_fn(batch_c)), None, cb)
            unchunk = lambda x: x.reshape((k_clients,) + x.shape[2:])
            out_x, opt_s, loss_s = out[0], out[1], out[2]
            merged = (jax.tree_util.tree_map(unchunk, out_x),
                      gal.unchunk_opt_state(opt_s, k_clients),
                      unchunk(loss_s))
            if len(out) == 4:                     # factored: (C,) base scales
                merged += (out[3].reshape((k_clients,)),)
            return merged

        if self._factored:
            deltas0 = self._stack_deltas0(st0, b)

            def vmapped(fn):
                return jax.vmap(fn, in_axes=(0, self._opt_axes, 0, None,
                                             None),
                                out_axes=(0, self._opt_axes, 0, 0))

            def transient_fn(batch_c):
                return vmapped(self._local_train_factored_one)(
                    deltas0, opt0, batch_c, frozen, global_trainable)

            def liftfree_fn(batch_c):
                return vmapped(self._local_train_liftfree_one)(
                    deltas0, opt0, batch_c, frozen, global_trainable)

            if not self._lift_free:
                local_fn = transient_fn
            elif self._round0_adaptive():
                # Round 0's data-driven refresh needs dense gradients; every
                # later round runs lift-free. Same output pytree both ways.
                def local_fn(batch_c):
                    return jax.lax.cond(round_idx == 0, transient_fn,
                                        liftfree_fn, batch_c)
            else:
                local_fn = liftfree_fn

            out_d, out_opt, losses, scales = stream(local_fn, client_batches)
            robust = "none"
            if attack is not None:
                out_d, out_opt, scales, w, _ = self._apply_guard(
                    out_d, out_opt, scales, w, attack)
                robust = self.cfg.robust_agg
            new_global = self._aggregate_factored(
                global_trainable, out_d, out_opt, scales, w, round_idx,
                robust=robust)
            if skip_sync:
                new_synced = (self._slim_payload(out_opt, w, round_idx,
                                                 exclude_zero,
                                                 robust=robust), w)
            else:
                new_synced = self._sync_states_pure(out_opt, w, round_idx,
                                                    exclude_zero,
                                                    robust=robust)
            return out_d, out_opt, new_global, frozen, new_synced, losses

        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (b,) + x.shape), global_trainable)

        def local_fn(batch_c):
            return jax.vmap(
                self._local_train_one, in_axes=(0, self._opt_axes, 0, None),
                out_axes=(0, self._opt_axes, 0))(
                stacked, opt0, batch_c, frozen)

        out_tr, out_opt, losses = stream(local_fn, client_batches)
        new_global, new_frozen = self._aggregate_pure(out_tr, w, frozen,
                                                      round_idx)
        if skip_sync:
            new_synced = (self._slim_payload(out_opt, w, round_idx,
                                             exclude_zero), w)
        else:
            new_synced = self._sync_states_pure(out_opt, w, round_idx,
                                                exclude_zero)
        return out_tr, out_opt, new_global, new_frozen, new_synced, losses

    def _stack_deltas0(self, st0, n: int):
        """Zero round-start factored accumulators for n clients."""
        d0 = gal.zero_client_deltas(gal.galore_state_of(st0))
        return jax.tree_util.tree_map(
            lambda x: jnp.zeros((n,) + x.shape, x.dtype), d0)

    def _frozen_mutates(self) -> bool:
        """Only the lift aggregations (FLoRA / FR-LoRA) write the frozen
        base; every other method's frozen is round-invariant, so the fused
        programs take it as a plain input and never emit it as an output
        (an undonated output would memcpy the whole base every round)."""
        return self.spec.aggregation in ("lift_merge", "lift_refac")

    def _build_round_jit(self, exclude_zero: bool, guard: bool = False):
        frozen_mutates = self._frozen_mutates()

        if guard:
            def round_fn(client_tr, client_opt, global_trainable, frozen,
                         synced_v, round_idx, client_batches, w, attack):
                del client_tr, client_opt
                out = self._round_core(global_trainable, frozen, synced_v,
                                       round_idx, client_batches, w,
                                       exclude_zero=True, attack=attack)
                if frozen_mutates:
                    return out
                out_tr, out_opt, new_global, _, new_synced, losses = out
                return out_tr, out_opt, new_global, new_synced, losses
            return jax.jit(round_fn, donate_argnums=(0, 1))

        def round_fn(client_tr, client_opt, global_trainable, frozen,
                     synced_v, round_idx, client_batches, w):
            # client_tr/client_opt are donated carries: their values are
            # never read (InitState rebuilds both), only their buffers
            # are reused for this round's stacked outputs.
            del client_tr, client_opt
            out = self._round_core(global_trainable, frozen, synced_v,
                                   round_idx, client_batches, w,
                                   exclude_zero=exclude_zero)
            if frozen_mutates:
                return out
            out_tr, out_opt, new_global, _, new_synced, losses = out
            return out_tr, out_opt, new_global, new_synced, losses
        return jax.jit(round_fn, donate_argnums=(0, 1))

    def _round_jitted(self):
        if self._round_jit is None:
            self._round_jit = self._build_round_jit(exclude_zero=False)
        return self._round_jit

    def _round_masked_jitted(self):
        """The participation-masked round program: identical math on the
        masked+renormalized weights, plus AJIVE score exclusion in 𝒮.
        Compiled separately so the unmasked program never changes."""
        if self._round_masked_jit is None:
            self._round_masked_jit = self._build_round_jit(exclude_zero=True)
        return self._round_masked_jit

    def _round_guard_jitted(self):
        """The guarded round program: attack injection → quarantine screen →
        robust 𝒜 → exclusion-aware 𝒮, always exclude-zero (quarantined
        clients fold into the same mask path as dropped ones). Compiled
        separately; honest cohorts through it are bit-identical to the
        unguarded program (all-pass short-circuit — asserted in tests)."""
        if self._round_guard_jit is None:
            self._round_guard_jit = self._build_round_jit(
                exclude_zero=True, guard=True)
        return self._round_guard_jit

    def _run_round_eager(self, client_batches, w, k_clients):
        """Stage-by-stage reference round (the parity oracle): separately
        dispatched InitState, jitted local training, eager 𝒜 and 𝒮."""
        stacked_trainable = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (k_clients,) + x.shape),
            self.global_trainable)
        opt_states = self._init_client_opt_states(k_clients)

        out_trainable, out_opt, losses = self._local_train(
            stacked_trainable, opt_states, client_batches, self.frozen)

        self.global_trainable, self.frozen = self._aggregate_pure(
            out_trainable, w, self.frozen, self.round_idx)
        self.synced_v = self._sync_states_eager(out_opt, w)
        self.round_idx += 1
        return {"local_loss": losses,                      # (K, T)
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    # -------------------------------------------------------------- 𝒜 -------
    def _aggregate_pure(self, stacked, w, frozen, round_idx):
        """Aggregation 𝒜 as a pure function of the client-stacked trainables:
        returns (new_global_trainable, new_frozen)."""
        s = self.spec.aggregation
        c = self.cfg
        if s == "dense_avg":
            return agg.dense_delta_average(stacked, w), frozen
        if s == "factor_avg":
            return agg.factor_average(stacked, w), frozen
        if s == "fair":
            return agg.lora_fair_refine(stacked, w, c.lora_scale), frozen
        if s in ("lift_merge", "lift_refac"):
            deltas = agg.lift_average(stacked, w, c.lora_scale)
            if s == "lift_merge":
                # FLoRA: the full-rank average reaches every client via the
                # merged base; adapters restart from zero.
                frozen = jax.tree_util.tree_map(
                    lambda p, d: p if d is None else p + d.astype(p.dtype),
                    frozen, deltas, is_leaf=lambda x: x is None)
                return self._fresh_adapters(round_idx), frozen
            # FR-LoRA: rank-r refactorization carries what fits in the
            # adapters; the residual merges into the base (kept, not lost).
            new_ad, resid = [], []
            dl, treedef = jax.tree_util.tree_flatten(
                deltas, is_leaf=lambda x: x is None)
            for d in dl:
                if d is None:
                    new_ad.append(None)
                    resid.append(None)
                else:
                    pair = lora_lib.svd_truncate(d / max(c.lora_scale, 1e-12),
                                                 c.rank)
                    new_ad.append(pair)
                    resid.append(d - c.lora_scale * (pair.b @ pair.a))
            trainable = jax.tree_util.tree_unflatten(treedef, new_ad)
            resid = jax.tree_util.tree_unflatten(treedef, resid)
            frozen = jax.tree_util.tree_map(
                lambda p, r: p if r is None else p + r.astype(p.dtype),
                frozen, resid, is_leaf=lambda x: x is None)
            return trainable, frozen
        raise ValueError(s)

    def _fresh_adapters(self, round_idx):
        key = jax.random.PRNGKey(self.cfg.seed + 1000 + round_idx)
        return lora_lib.tree_lora_init(key, self.base_params, self.target_fn,
                                       self.cfg.rank)

    # -------------------------------------------------------------- 𝒮 -------
    def _bases_shared(self) -> bool:
        """Whether every client ended the round on the identical basis.

        The only in-step refresh the engine permits fires at count == 0
        (round 0, refresh_every is effectively ∞); with adaptive refreshes
        enabled that refresh is data-driven from each client's *own* gradient,
        so round-0 bases are client-specific and 𝒮 must account for the
        per-client basis (heterogeneous factored sync; dense per-client lift
        in the eager oracle). From round 1 on, every refresh is the seeded-
        random broadcast (manual_refresh with grads=None) — bases are
        bit-identical across clients and the shared factored path applies.
        """
        round0_adaptive = (self.round_idx == 0
                           and self.galore_cfg.adaptive_steps > 0
                           and self.galore_cfg.refresh_mode != "random")
        return not round0_adaptive

    def _sync_uplink(self, stacked_opt_states):
        """The 𝒮 input payload of a round: (projected-ṽ tree, basis tree)
        extracted from the client-stacked optimizer states — O(C·r·dim),
        the factored uplink, never the full optimizer state."""
        g_stack = gal.galore_state_of(stacked_opt_states)
        return (gal.extract_projected_v(g_stack),    # leaves (K, ., r)
                gal.extract_bases(g_stack))          # leaves (K, dim, r)

    def _slim_uplink(self, stacked_opt_states):
        """The shared-basis 𝒮 input payload — the projected-ṽ tree alone.
        This is what a pipelined scan carries between rounds: past the
        (possibly heterogeneous) adaptive round 0 every client holds the
        identical seeded basis, so the per-client basis stacks contribute
        nothing to 𝒮 and carrying them through the scan boundary is pure
        copy traffic. Shapes ride via :meth:`_basis_template`."""
        return gal.extract_projected_v(gal.galore_state_of(stacked_opt_states))

    def _slim_reduces_in_body(self) -> bool:
        """Whether the pipelined payload is the already-reduced synced tree.

        For the shared-basis weighted-mean protocols — 'avg', and 'avg_svd',
        whose rank-r re-projection is the identity on rank-≤r lifts — the
        whole 𝒮 is one fused ``einsum('k,k...->...')``: there is no
        spectral tail worth deferring, and carrying the (C, ·, r)
        per-client stacks across the scan boundary just to average them
        later is pure carry traffic (≈1 ms/round at C=512). So those
        protocols sync fully in-body (including the adaptive round-0
        hetero cond, exactly as the sequential body does): the pending
        slot holds the same small synced tree the sequential carry does,
        and the drain is a passthrough — only the install is
        re-associated across the round boundary. Only 'ajive' — whose
        joint-basis estimate needs the full per-client score stacks —
        defers the slim uplink."""
        return self.spec.state_sync in ("avg", "avg_svd")

    def _slim_payload(self, stacked_opt_states, w, round_idx,
                      exclude_zero: bool, robust: str = "none"):
        """The ``skip_sync`` pending payload for one round: the fully
        synced tree for the weighted-mean protocols (via the normal
        :meth:`_sync_states_pure` — its internal round-0 cond covers the
        heterogeneous adaptive case, so the pipelined body does exactly
        the sequential body's sync work and only the *install* crosses
        the round boundary), the per-client projected-ṽ stacks for ajive
        (see :meth:`_slim_reduces_in_body`)."""
        if self._slim_reduces_in_body():
            return self._sync_states_pure(stacked_opt_states, w, round_idx,
                                          exclude_zero, robust=robust)
        return self._slim_uplink(stacked_opt_states)

    def _basis_template(self):
        """Zero-filled single-client basis tree (leaves ``(dim, r)``) —
        the shape/rank donor for :meth:`_sync_pending`. Scan-invariant
        (closed over, never carried); values are never read."""
        if self._basis_template_tree is None:
            st = jax.eval_shape(lambda: self.tx.init(self.global_trainable))
            b = gal.extract_bases(gal.galore_state_of(st))
            self._basis_template_tree = jax.tree_util.tree_map(
                lambda x: None if x is None else jnp.zeros(x.shape, x.dtype),
                b, is_leaf=lambda x: x is None)
        return self._basis_template_tree

    def _sync_pending(self, v_tree, w, exclude_zero: bool = False,
                      robust: str = "none"):
        """Drain one slim pending payload (see :meth:`_slim_payload`):
        passthrough for the weighted-mean protocols (fully synced
        in-body, any round), shared-basis factored 𝒮 on the carried
        projected-moment stacks for ajive — where it is only valid for
        rounds ≥ 1 of a scan: the adaptive round 0 (diverged bases)
        syncs inline in its own body into the carried slot."""
        if self._slim_reduces_in_body():
            return v_tree
        return self._sync_states_from_uplink(
            v_tree, self._basis_template(), w, None, exclude_zero,
            shared_only=True, robust=robust)

    def _sync_blocks(self, v_stack_tree, basis_tree, block_fn,
                     bucketed: bool = False):
        """Map ``block_fn(v_stack, b_stack, side, rank)`` over the adapted
        blocks; ``bucketed`` groups shape-identical leaves into one vmapped
        program per bucket (`state_sync.map_sync_leaves`)."""
        vs, treedef = jax.tree_util.tree_flatten(v_stack_tree,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(basis_tree, is_leaf=lambda x: x is None)

        def leaf_fn(v_stack, b_stack):
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT
            return block_fn(v_stack, b_stack, side, rank)

        synced = sync_lib.map_sync_leaves(leaf_fn, vs, bs, bucketed=bucketed)
        return jax.tree_util.tree_unflatten(treedef, synced)

    def _sync_states_pure(self, stacked_opt_states, w, round_idx,
                          exclude_zero: bool = False, robust: str = "none"):
        """Factored 𝒮 for the fused round: shared-basis rounds synchronize on
        the projected ṽ directly (no lift); the adaptive round-0 diverged-
        basis case runs the heterogeneous-basis factored sync (r×r transfer
        Grams) — the dense (K, m, n) per-client lift never executes. The
        round-0 branch is a ``lax.cond`` so one compiled program serves the
        whole scanned sweep. ``exclude_zero`` (the participation-masked
        round) drops zero-weight clients from the AJIVE joint basis."""
        if not self._method_syncs():
            return None
        v_tree, b_tree = self._sync_uplink(stacked_opt_states)
        return self._sync_states_from_uplink(v_tree, b_tree, w, round_idx,
                                             exclude_zero, robust=robust)

    def _sync_states_from_uplink(self, v_stack_tree, basis_tree, w, round_idx,
                                 exclude_zero: bool = False,
                                 shared_only: bool = False,
                                 robust: str = "none"):
        """𝒮 on an extracted uplink payload (see :meth:`_sync_uplink`) —
        shared with the pipelined scan drivers, which sync the *previous*
        round's carried payload at the top of the next round's body.
        ``shared_only`` statically drops the adaptive round-0 hetero branch
        (callers guarantee round ≥ 1); ``basis_tree`` then only donates
        per-leaf rank/side shapes and may be a single-client template.
        ``robust`` (guarded rounds) swaps the weighted-mean reductions over
        the projected-moment stacks inside the sync protocols for the
        robust estimator (``'none'`` is exactly the plain path — bitwise)."""
        protocol = self.spec.state_sync
        round0_hetero_possible = (not shared_only
                                  and self.galore_cfg.adaptive_steps > 0
                                  and self.galore_cfg.refresh_mode != "random")

        def sync_block(v_stack, b_stack, side, rank):
            def shared(_):
                # Shared-basis invariant (the seeded-broadcast protocol keeps
                # every client on the identical round-k basis): synchronize
                # directly on the projected ṽ — no (K, m, n) lift. The result
                # stays on the round-k basis; manual_refresh applies the
                # next-round transfer at InitState.
                return sync_lib.sync_block_synced_factored(
                    protocol, v_stack, side, w, rank,
                    exclude_zero_weights=exclude_zero, robust=robust,
                    trim=self.cfg.robust_trim, iters=self.cfg.robust_iters,
                    tol=self.cfg.robust_tol)

            def hetero(_):
                return sync_lib.sync_block_hetero_factored(
                    protocol, v_stack, b_stack, side, w, rank,
                    exclude_zero_weights=exclude_zero, robust=robust,
                    trim=self.cfg.robust_trim, iters=self.cfg.robust_iters,
                    tol=self.cfg.robust_tol)

            if not round0_hetero_possible:
                return shared(None)
            return jax.lax.cond(round_idx == 0, hetero, shared, operand=None)

        return self._sync_blocks(v_stack_tree, basis_tree, sync_block,
                                 bucketed=self.cfg.bucketed_sync)

    def _sync_states_eager(self, stacked_opt_states, w):
        """Reference 𝒮 for the eager round: the factored shared-basis path
        when it applies, otherwise (adaptive round 0, or factored_sync=False)
        the dense per-client lift — the retained parity oracle for the
        heterogeneous factored sync."""
        if not self._method_syncs():
            return None
        protocol = self.spec.state_sync
        use_factored = self.cfg.factored_sync and self._bases_shared()

        def sync_block(v_stack, b_stack, side, rank):
            if use_factored:
                return sync_lib.sync_block_synced_factored(
                    protocol, v_stack, side, w, rank)

            def sync_one(v_cl, b_cl):
                # v_cl (K, m, r)|(K, r, n); b_cl (K, dim, r). Lift each
                # client's ṽ with its *own* basis (identical across clients
                # in the seeded-random phase), synchronize, re-project onto
                # the shared (client-0) end-of-round basis.
                if side == proj.RIGHT:
                    views = jnp.einsum("kmr,knr->kmn",
                                       v_cl.astype(jnp.float32),
                                       b_cl.astype(jnp.float32))
                else:
                    views = jnp.einsum("kmr,krn->kmn",
                                       b_cl.astype(jnp.float32),
                                       v_cl.astype(jnp.float32))
                lifted = sync_lib.sync_lifted_views(protocol, views, w, rank)
                return sync_lib.project_state(lifted, b_cl[0], side)

            if v_stack.ndim == 4:        # stacked scan blocks (K, nb, ., r)
                return jax.vmap(sync_one, in_axes=(1, 1))(v_stack, b_stack)
            return sync_one(v_stack, b_stack)

        v_tree, b_tree = self._sync_uplink(stacked_opt_states)
        return self._sync_blocks(v_tree, b_tree, sync_block)

    # ------------------------------------------------------------- helpers --
    def global_params(self) -> PyTree:
        if self.spec.trainable in ("dense", "galore"):
            return merge_dense(self.frozen, self.global_trainable)
        return merge_lora(self.frozen, self.global_trainable, self.cfg.lora_scale)

    def evaluate(self, batch) -> float:
        if self.eval_fn is None:
            return float(self.loss_fn(self.global_params(), batch))
        return float(self.eval_fn(self.global_params(), batch))
