"""AJIVE — Angle-based Joint and Individual Variation Explained.

Implements Algorithm 5 (Appendix E) in pure jnp, following the mvlearn logic:

  Phase 1  per-view economy SVD at an initial signal rank; singular-value
           threshold at the r/r+1 midpoint.
  Phase 2  joint SVD of the concatenated score (U) matrices; joint rank either
           fixed (paper production choice: k = r) or estimated from the
           Wedin + random-direction bounds via seeded resampling.
  Phase 3  per-view decomposition  X = J + I + E  with
           J = U_joint U_jointᵀ X (joint), I = thresholded SVD of the residual
           (individual), E = the rest (noise).

The federated server applies this to the lifted second-moment views
``V^{i} = ṽ_T^{i} R_kᵀ`` and broadcasts the shared component (§5 "Why AJIVE").
All SVDs are economy-size and MXU-lowerable; resampling uses explicit keys so
the estimator is deterministic and jit-safe with static ranks.

Factored fast path
------------------
Every federated input has rank ≤ r by construction (ṽ is (·, r) and the
shared basis is orthonormal), so the dense pipeline above — per-view SVDs of
``(m, n)`` lifted views and an ``(n, n)`` joint projector — does O(n²)-to-
O(n³) work to recover structure that lives entirely in a ``(C·r)``-dimensional
score space. :func:`ajive_sync_factored` runs Phases 1–3 directly on the
*projected* moments: per-view SVDs via a batched r×r Gram eigh (kernel-
routed, see :func:`_topk_eig_desc_stack`), the joint basis via the
statically-dispatched :func:`_joint_basis` (exact small Gram or sketched
Rayleigh–Ritz, depending on which of d and C·k is small), and the joint
projector applied as two skinny GEMMs. It never materializes a dense view
and returns the synchronized state in projected shape. The dense
:func:`ajive_sync` is retained as the parity oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from ..kernels import ops as kernel_ops


class AjiveResult(NamedTuple):
    joint: jnp.ndarray        # (k_views, n, m) per-view joint components J^(i)
    individual: jnp.ndarray   # (k_views, n, m) per-view individual I^(i)
    noise: jnp.ndarray        # (k_views, n, m) E^(i)
    joint_basis: jnp.ndarray  # (n, r_joint) shared column basis U_joint
    joint_mean: jnp.ndarray   # (n, m) weighted mean of joint components
    sv_joint: jnp.ndarray     # singular values of the stacked score matrix


def _center(x):
    return x - jnp.mean(x, axis=0, keepdims=True)


def _rank_truncate(x, rank: int):
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    return u[:, :rank], s[:rank], vt[:rank], s


def wedin_bound(x, u, s, vt, key, n_samples: int = 20) -> jnp.ndarray:
    """Resampled Wedin-style perturbation bound for one view (Phase 2 aid).

    Estimates how far the signal scores may rotate under the residual noise:
    samples random unit directions, measures ||Eᵀ u||/s_min style statistics.
    Returns a squared-singular-value cutoff contribution in [0, 1]-scale.
    """
    resid = x - (u * s[None, :]) @ vt
    n, m = x.shape
    k = u.shape[1]
    keys = jax.random.split(key, n_samples)

    def one(kk):
        kv, ku = jax.random.split(kk)
        dv = jax.random.normal(kv, (m,))
        dv = dv / (jnp.linalg.norm(dv) + 1e-12)
        du = jax.random.normal(ku, (n,))
        du = du / (jnp.linalg.norm(du) + 1e-12)
        return jnp.maximum(jnp.linalg.norm(resid @ dv),
                           jnp.linalg.norm(resid.T @ du))

    est = jnp.percentile(jax.vmap(one)(keys), 95)
    sin_theta = jnp.minimum(est / (s[-1] + 1e-12), 1.0)
    return sin_theta


def random_direction_bound(shapes: Sequence[tuple], ranks: Sequence[int],
                           key, n_samples: int = 20) -> jnp.ndarray:
    """Null distribution of the top squared singular value of stacked random
    orthonormal score matrices (Phase 2 'random bound')."""
    def one(kk):
        total = 0
        tops = []
        subkeys = jax.random.split(kk, len(shapes))
        mats = []
        for (n, _), r, sk in zip(shapes, ranks, subkeys):
            g = jax.random.normal(sk, (n, r))
            q, _ = jnp.linalg.qr(g)
            mats.append(q)
        m = jnp.concatenate(mats, axis=1)
        s = jnp.linalg.svd(m, compute_uv=False)
        return s[0] ** 2

    keys = jax.random.split(key, n_samples)
    vals = jax.vmap(one)(keys)
    return jnp.percentile(vals, 95)


def ajive(views: jnp.ndarray, signal_ranks, joint_rank: Optional[int] = None,
          individual_ranks=None, center: bool = True,
          key: Optional[jax.Array] = None,
          return_rank_diag: bool = False):
    """Run AJIVE on ``views`` of shape (k_views, n, m).

    ``signal_ranks``: int or per-view list — Phase 1 initial signal rank.
    ``joint_rank``: fixed joint rank (paper: k = r). If None, estimated from
    the Wedin/random bounds (requires ``key``); the estimate is returned as a
    *mask* applied to a max-rank basis so shapes stay static under jit.
    """
    k_views, n, m = views.shape
    if isinstance(signal_ranks, int):
        signal_ranks = [signal_ranks] * k_views
    if center:
        views = jax.vmap(_center)(views)

    # ---- Phase 1: per-view signal extraction -------------------------------
    scores, thresholds, svds = [], [], []
    for i in range(k_views):
        r = signal_ranks[i]
        u, s, vt, s_full = _rank_truncate(views[i], r)
        scores.append(u)
        # SV threshold: midpoint between r-th and (r+1)-th singular value.
        nxt = s_full[r] if r < s_full.shape[0] else jnp.zeros([])
        thresholds.append(0.5 * (s_full[r - 1] + nxt))
        svds.append((u, s, vt))

    # ---- Phase 2: score-space segmentation ----------------------------------
    stacked = jnp.concatenate(scores, axis=1)        # (n, sum r_i)
    u_joint_full, d_joint, _ = jnp.linalg.svd(stacked, full_matrices=False)

    if joint_rank is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        kw, kr = jax.random.split(key)
        # Wedin: aggregate per-view sin-theta into a squared-SV cutoff.
        sin_thetas = []
        wkeys = jax.random.split(kw, k_views)
        for i in range(k_views):
            u, s, vt = svds[i]
            sin_thetas.append(wedin_bound(views[i], u, s, vt, wkeys[i]))
        wedin_cut = sum(1.0 - jnp.minimum(st, 1.0) ** 2 for st in sin_thetas)
        wedin_cut = k_views - wedin_cut + 1e-6  # cutoff on squared SVs
        rand_cut = random_direction_bound([(n, m)] * k_views, signal_ranks, kr)
        cutoff = jnp.maximum(wedin_cut, rand_cut)
        rank_mask = (d_joint ** 2 > cutoff)
        max_joint = min(min(signal_ranks), u_joint_full.shape[1])
        mask = rank_mask[:max_joint].astype(views.dtype)
        u_joint = u_joint_full[:, :max_joint] * mask[None, :]
        est_rank = jnp.sum(rank_mask[:max_joint])
    else:
        u_joint = u_joint_full[:, :joint_rank]
        est_rank = jnp.asarray(joint_rank)

    # ---- Phase 3: final decomposition ---------------------------------------
    proj = u_joint @ u_joint.T                       # (n, n) joint projector
    joints, individuals, noises = [], [], []
    for i in range(k_views):
        x = views[i]
        j = proj @ x
        resid = x - j
        r_ind = (individual_ranks[i] if individual_ranks is not None
                 else signal_ranks[i])
        ui, si, vti, si_full = _rank_truncate(resid, r_ind)
        # Keep only components above the Phase-1 view threshold.
        keep = (si > thresholds[i]).astype(x.dtype)
        ind = (ui * (si * keep)[None, :]) @ vti
        joints.append(j)
        individuals.append(ind)
        noises.append(x - j - ind)

    joint = jnp.stack(joints)
    result = AjiveResult(joint=joint,
                         individual=jnp.stack(individuals),
                         noise=jnp.stack(noises),
                         joint_basis=u_joint,
                         joint_mean=jnp.mean(joint, axis=0),
                         sv_joint=d_joint)
    if return_rank_diag:
        return result, est_rank
    return result


def normalize_weights(weights: Optional[jnp.ndarray], k: int) -> jnp.ndarray:
    """Client weights as a normalized fp32 simplex point (None = uniform)."""
    if weights is None:
        return jnp.full((k,), 1.0 / k, jnp.float32)
    w = jnp.asarray(weights, jnp.float32)
    return w / jnp.sum(w)


def ajive_sync(views: jnp.ndarray, rank: int,
               weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Server-side second-moment synchronization (Algorithm 1, line 12).

    views: (k_views, n, m) lifted second-moment matrices V^{i} = ṽ^{i} R_kᵀ.
    Returns the drift-robust shared estimate v̄ (n, m): the weighted mean of
    the per-view joint components, with joint rank = ``rank`` (paper sets the
    AJIVE joint rank to the client projector rank r).
    """
    res = ajive(views, signal_ranks=rank, joint_rank=rank, center=False)
    if weights is None:
        return res.joint_mean
    w = weights / jnp.sum(weights)
    return jnp.einsum("k,knm->nm", w, res.joint)


# ------------------------------------------------------ factored fast path --

def _topk_eig_desc(sym: jnp.ndarray, k: int):
    """Top-k eigenpairs of a small symmetric PSD matrix, descending."""
    lam, vec = jnp.linalg.eigh(sym)
    lam = jnp.maximum(lam[::-1], 0.0)
    vec = vec[:, ::-1]
    return lam[:k], vec[:, :k]


def _topk_eig_desc_stack(sym: jnp.ndarray, k: int,
                         mask: Optional[jnp.ndarray] = None):
    """Top-k eigenpairs of a (..., n, n) symmetric PSD stack, descending.

    One batched solve for the whole stack — kernel-routed through
    :func:`repro.kernels.ops.batched_small_eigh` (Pallas parallel-Jacobi on
    TPU for n ≤ 64; LAPACK on CPU, bit-identical to the per-matrix path).

    ``mask`` (batch-shaped bool) excludes stack entries from the solve: a
    masked slice is replaced by the identity and its eigenvalues zeroed, so
    the solver never touches its payload (a masked client's Gram may be
    non-finite — Jacobi rotations and LAPACK both propagate NaN across the
    whole slice) and downstream rank-floors drop its directions. An
    all-true mask is bitwise the unmasked solve.
    """
    lam, vec = kernel_ops.batched_small_eigh(sym, mask=mask)
    lam = jnp.maximum(lam[..., ::-1], 0.0)
    vec = vec[..., ::-1]
    return lam[..., :k], vec[..., :k]


def _inv_sqrt_rank_safe(lam: jnp.ndarray, rel_tol: float = 1e-10):
    """1/√λ per eigendirection, with numerically-null directions
    (λ ≤ rel_tol·λ_max) mapped to 0 instead of noise-amplified — a
    rank-revealing floor so rank-deficient inputs degrade gracefully rather
    than injecting amplified round-off into the score space."""
    lam_max = lam[..., :1]                         # sorted descending
    keep = lam > rel_tol * lam_max
    return jnp.where(keep, 1.0 / jnp.sqrt(jnp.where(keep, lam, 1.0)), 0.0)


def _factored_joint_scores(scores: jnp.ndarray, joint_rank: int):
    """Phase 2 on the stacked score matrix S (d, C·k) via its (C·k)×(C·k)
    Gram: u_joint = S W Λ^{-1/2}. Avoids the O(d·(Ck)²)-with-large-constant
    dense SVD and never touches the ambient dimension."""
    gram = scores.T @ scores                       # (C·k, C·k)
    lam, w = _topk_eig_desc(gram, joint_rank)
    return scores @ (w * _inv_sqrt_rank_safe(lam)[None, :])


_EXACT_JOINT_DIM = 64      # largest Gram solved exactly in the joint basis
_SKETCH_SEED = 0x5CE7C4    # fixed key: the sketch is deterministic by design


def _keep_mask_cols(lam: jnp.ndarray, vec: jnp.ndarray,
                    rel_tol: float = 1e-10):
    """Zero eigenvector columns of numerically-null directions
    (λ ≤ rel_tol·λ_max, λ sorted descending) — the rank-revealing floor of
    :func:`_inv_sqrt_rank_safe`, replicated for routes whose eigenvectors
    are orthonormal even in the null space."""
    keep = lam > rel_tol * lam[..., :1]
    return vec * keep[..., None, :].astype(vec.dtype)


def _joint_basis_sketch(scores: jnp.ndarray, k: int, oversample: int = 8,
                        iters: int = 2):
    """Sketched Rayleigh–Ritz top-k basis of S Sᵀ, S = [S_1 … S_C] (d, C·k₁)
    held as per-client stacks (C, d, k₁). Randomized subspace iteration with
    a fixed key: y ← S Sᵀ y via two skinny einsums per pass (the stacked
    matrix is never materialized), column-normalized between passes, then a
    QR range basis and an s×s Ritz eigenproblem. O(iters·d·C·k₁·s) total —
    at C = 512, r = 4 this replaces a 2048² Gram + eigh (~2 s) with ~50 ms,
    with projector error at fp32 round-off on graded spectra."""
    d = scores.shape[1]
    s = min(d, max(16, k + oversample))
    y = jax.random.normal(jax.random.PRNGKey(_SKETCH_SEED), (d, s),
                          jnp.float32)
    for _ in range(iters):
        z = jnp.einsum("cdk,ds->cks", scores, y)       # Sᵀ y, per client
        y = jnp.einsum("cdk,cks->ds", scores, z)       # S (Sᵀ y)
        y = y / (jnp.linalg.norm(y, axis=0, keepdims=True) + 1e-30)
    q, _ = jnp.linalg.qr(y)                            # (d, s) range basis
    b = jnp.einsum("cdk,ds->cks", scores, q)           # Sᵀ q
    m = jnp.einsum("cks,ckt->st", b, b)                # qᵀ S Sᵀ q
    lam, vec = _topk_eig_desc(m, k)
    return q @ _keep_mask_cols(lam, vec)


def _joint_basis(scores: jnp.ndarray, k: int):
    """Phase-2 joint basis from per-client score stacks (C, d, k₁).

    Three statically-dispatched routes, all spanning the top-k eigenspace of
    the stacked score matrix S = [S_1 … S_C] (d, C·k₁). Every Phase-3
    consumer uses the basis only through the projector U Uᵀ, so route choice
    changes nothing beyond round-off (and arbitrary directions inside
    degenerate eigenvalue clusters, where no implementation is canonical):

    * ``d ≤ 64`` — exact d×d left Gram ``Σ_c S_c S_cᵀ``; covers every
      left-side shared leaf (d = r there).
    * ``C·k₁ ≤ 64`` — exact right Gram ``SᵀS`` via
      :func:`_factored_joint_scores`; bit-identical to the pre-batching
      small-cohort path.
    * otherwise — :func:`_joint_basis_sketch`. The (C·k₁)² Gram + eigh this
      avoids was the dominant 𝒮 cost from C = 64 up (7.7 ms of each 9.3 ms
      leaf sync at C = 64, r = 4).

    All routes apply the rank-revealing floor (λ ≤ rel_tol·λ_max ⇒ zeroed
    basis column): the right-Gram route gets it from ``Λ^{-1/2}``, the
    eigh/Ritz routes replicate it via :func:`_keep_mask_cols`.
    """
    c_views, d, k1 = scores.shape
    if d <= _EXACT_JOINT_DIM:
        gram = jnp.einsum("cdk,cek->de", scores, scores)
        lam, vec = _topk_eig_desc(gram, k)
        return _keep_mask_cols(lam, vec)
    if c_views * k1 <= _EXACT_JOINT_DIM:
        stacked = jnp.moveaxis(scores, 0, 1).reshape(d, c_views * k1)
        return _factored_joint_scores(stacked, k)
    return _joint_basis_sketch(scores, k)


def _participation_mask(weights: Optional[jnp.ndarray],
                        exclude_zero_weights: bool) -> Optional[jnp.ndarray]:
    """Per-client {0,1} mask derived from zero aggregation weights.

    Zero weights remove a client from the final weighted joint estimate, but
    Phases 1–2 are *unweighted*: a dropped client's scores would still shape
    the joint basis. With ``exclude_zero_weights`` the mask zeroes the
    dropped clients' score columns before the joint-basis Gram, so zeroed
    columns contribute zero eigenvalues and the joint basis is built from
    participants only (the participation-masked round's 𝒮 semantics).

    The exclusion is ``jnp.where``-based, not multiplicative: ``0 · NaN``
    is NaN, so a multiplicative mask would let a quarantined client's
    non-finite scores poison the joint basis anyway. ``jnp.where`` with an
    all-true mask returns the scores bitwise unchanged (the honest-cohort
    bit-identity short-circuit)."""
    if not exclude_zero_weights or weights is None:
        return None
    return jnp.asarray(weights, jnp.float32) > 0


def _mask_score_cols(scores: jnp.ndarray,
                     mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Apply the participation mask to (C, ·, k) score stacks (NaN-proof —
    see :func:`_participation_mask`)."""
    if mask is None:
        return scores
    return jnp.where(mask[:, None, None], scores, jnp.zeros((), scores.dtype))


def ajive_sync_factored(v_stack: jnp.ndarray, rank: int,
                        weights: Optional[jnp.ndarray] = None,
                        side: str = "right",
                        exclude_zero_weights: bool = False,
                        robust: str = "none", trim: float = 0.2,
                        iters: int = 8, tol: float = 1e-6) -> jnp.ndarray:
    """Server-side second-moment sync on *projected* moments (Alg. 1 l.12).

    The lifted view of client i is ``V^i = ṽ^i Bᵀ`` (right blocks) or
    ``B ṽ^i`` (left blocks) with one shared orthonormal basis B — rank ≤ r.
    Left-multiplying by B never changes column-space geometry, so AJIVE's
    three phases close over the coefficient space:

      Phase 1  per-view orthonormal scores from the r×r Gram ``ṽᵀṽ``
               (right: scores ``ṽ W Λ^{-1/2}`` ∈ R^{m×r}; left: scores are
               the r×r eigenvectors themselves — B cancels).
      Phase 2  joint basis from the (C·r)×(C·r) Gram of the stacked scores.
      Phase 3  per-view joint component ``J̃^i = U U^T ṽ^i`` — two skinny
               GEMMs; the ambient (m, n) view and the (n, n) projector are
               never formed.

    v_stack: (C, m, r) right | (C, r, n) left — the uplink payload as-is.
    Returns the weighted joint estimate **in projected shape** ((m, r) or
    (r, n)); lifting it with B reproduces dense ``ajive_sync`` output (for a
    shared basis), and re-basing onto next round's basis is the r×r transfer
    ``projector.reproject``. Stacked scan blocks (C, nb, ·, r) vmap over nb.

    Parity with the dense oracle is defined for **full-rank** ṽ. Rank-
    deficient views have no well-defined Phase-1 score directions in either
    implementation; here the numerically-null eigendirections are zeroed
    (rank-revealing floor) where the dense SVD would return arbitrary noise
    directions — graceful degradation, but not bit-parity.

    ``exclude_zero_weights`` additionally masks the Phase-1 score columns of
    zero-weight clients (see :func:`_participation_mask`): the joint basis
    is then estimated from participating clients only — the semantics of
    the participation-masked round, where a dropped client's local state
    must not influence the server filter at all. The mask also routes the
    Phase-1 Gram eigendecomposition through the masked batched-eigh path
    (:func:`_topk_eig_desc_stack`), so excluded clients' Grams are never
    solved.

    ``robust`` replaces the final weighted joint mean with the matching
    :func:`aggregation.robust_factored_reduce` mode over the per-client
    joint components (all expressed on the shared basis — coordinate-wise
    statistics are well-defined). ``robust='none'`` is bitwise the plain
    weighted mean.
    """
    if v_stack.ndim == 4:                          # stacked scan blocks
        return jax.vmap(
            lambda vs: ajive_sync_factored(vs, rank, weights, side,
                                           exclude_zero_weights, robust,
                                           trim, iters, tol),
            in_axes=1, out_axes=0)(v_stack)

    a = v_stack.astype(jnp.float32)                # (C, m, r) | (C, r, n)
    c_views = a.shape[0]
    r = a.shape[-1] if side == "right" else a.shape[-2]
    k = min(rank, r)
    mask = _participation_mask(weights, exclude_zero_weights)

    if side == "right":
        # Phase 1: per-view economy SVD via the r×r Gram of ṽ^i.
        gram = jnp.einsum("cmr,cms->crs", a, a)            # (C, r, r)
        lam, wv = _topk_eig_desc_stack(gram, k, mask=mask)
        scores = jnp.einsum("cmr,crk->cmk", a, wv)         # ṽ W
        scores = scores * _inv_sqrt_rank_safe(lam)[:, None, :]
        scores = _mask_score_cols(scores, mask)
        u_joint = _joint_basis(scores, k)                  # (m, k)
        joint = jnp.einsum("mj,cjr->cmr", u_joint,
                           jnp.einsum("mj,cmr->cjr", u_joint, a))
    else:
        # Left blocks: lifted scores are B·(eigvecs of ṽṽᵀ); the shared
        # orthonormal B cancels from every Gram, so Phases 1–3 run wholly in
        # the r-dimensional coefficient space.
        gram = jnp.einsum("crn,csn->crs", a, a)            # (C, r, r)
        _, wv = _topk_eig_desc_stack(gram, k, mask=mask)
        wv = _mask_score_cols(wv, mask)
        q = _joint_basis(wv, k)                            # (r, k)
        joint = jnp.einsum("rj,cjn->crn", q,
                           jnp.einsum("rj,crn->cjn", q, a))

    w_final = normalize_weights(weights, c_views)
    if robust != "none":
        from . import aggregation as agg
        return agg.robust_factored_reduce(joint, w_final, robust, trim=trim,
                                          iters=iters, tol=tol)
    return jnp.einsum("c,c...->...", w_final, joint)


def ajive_sync_hetero_factored(v_stack: jnp.ndarray, b_stack: jnp.ndarray,
                               rank: int,
                               weights: Optional[jnp.ndarray] = None,
                               side: str = "right",
                               exclude_zero_weights: bool = False,
                               robust: str = "none", trim: float = 0.2,
                               iters: int = 8, tol: float = 1e-6
                               ) -> jnp.ndarray:
    """Factored AJIVE 𝒮 for **heterogeneous client bases** (adaptive round 0).

    Client i lifted its ṽ with its *own* orthonormal basis ``Q_i``; the dense
    oracle builds every ``(m, n)`` view ``V^i = ṽ^i Q_iᵀ`` (right) /
    ``Q_i ṽ^i`` (left), runs AJIVE, and re-projects the weighted joint
    component onto the reference (client-0) basis ``Q_0``. All of that closes
    over r×r transfer algebra:

      right  Phase-1/2 are basis-free (``V^i V^iᵀ = ṽ^i ṽ^iᵀ`` since
             ``Q_iᵀ Q_i = I``) — identical to the shared-basis path; the
             per-client basis change enters only in Phase 3, where the r×r
             transfer ``T_i = Q_iᵀ Q_0`` composes into the projected joint:
             ``J^i Q_0 = (U Uᵀ ṽ^i) T_i``.
      left   Phase-1 scores lift as ``Q_i u^i`` (skinny, O(dim·r)); the
             basis change ``Q_iᵀ Q_j`` is thereby composed into the Phase-2
             score Gram, and Phase 3 is ``Q_0ᵀ J^i = (Q_0ᵀ U)(Uᵀ Q_i) ṽ^i``
             — r×k algebra throughout.

    v_stack (C, m, r) right | (C, r, n) left; b_stack (C, dim, r) per-client
    end-of-round bases. Returns the weighted joint estimate in projected
    shape, expressed on the client-0 basis (matching the dense per-client
    lift oracle to fp32 precision on full-rank inputs). No ``(C, m, n)``
    view, ``(n, n)`` projector, or dense broadcast is ever formed. Stacked
    scan blocks (C, nb, ·, r) vmap over nb. ``exclude_zero_weights`` masks
    zero-weight clients' score columns out of the joint-basis estimate (see
    :func:`ajive_sync_factored`). ``robust`` robustifies the final weighted
    joint mean exactly as in :func:`ajive_sync_factored` — the per-client
    joint components are already re-expressed on the client-0 basis by the
    transfer composition, so coordinate-wise modes are basis-coherent here
    with no extra re-basing step.
    """
    if v_stack.ndim == 4:                          # stacked scan blocks
        return jax.vmap(
            lambda vs, bs: ajive_sync_hetero_factored(vs, bs, rank, weights,
                                                      side,
                                                      exclude_zero_weights,
                                                      robust, trim, iters,
                                                      tol),
            in_axes=1, out_axes=0)(v_stack, b_stack)

    a = v_stack.astype(jnp.float32)                # (C, m, r) | (C, r, n)
    b = b_stack.astype(jnp.float32)                # (C, dim, r)
    c_views = a.shape[0]
    r = a.shape[-1] if side == "right" else a.shape[-2]
    k = min(rank, r)
    mask = _participation_mask(weights, exclude_zero_weights)

    if side == "right":
        gram = jnp.einsum("cmr,cms->crs", a, a)            # (C, r, r)
        lam, wv = _topk_eig_desc_stack(gram, k, mask=mask)
        scores = jnp.einsum("cmr,crk->cmk", a, wv)
        scores = scores * _inv_sqrt_rank_safe(lam)[:, None, :]
        scores = _mask_score_cols(scores, mask)
        u_joint = _joint_basis(scores, k)                  # (m, k)
        joint = jnp.einsum("mj,cjr->cmr", u_joint,
                           jnp.einsum("mj,cmr->cjr", u_joint, a))
        transfer = jnp.einsum("cdr,ds->crs", b, b[0])      # T_i = Q_iᵀ Q_0
        joint = jnp.einsum("cmr,crs->cms", joint, transfer)
    else:
        gram = jnp.einsum("crn,csn->crs", a, a)            # (C, r, r)
        _, wv = _topk_eig_desc_stack(gram, k, mask=mask)
        scores = jnp.einsum("cdr,crk->cdk", b, wv)         # Q_i u^i, skinny
        scores = _mask_score_cols(scores, mask)
        u_joint = _joint_basis(scores, k)                  # (dim, k)
        t0 = jnp.einsum("dr,dk->rk", b[0], u_joint)        # Q_0ᵀ U
        ti = jnp.einsum("cdr,dk->crk", b, u_joint)         # Q_iᵀ U
        joint = jnp.einsum("rk,csk,csn->crn", t0, ti, a)

    w_final = normalize_weights(weights, c_views)
    if robust != "none":
        from . import aggregation as agg
        return agg.robust_factored_reduce(joint, w_final, robust, trim=trim,
                                          iters=iters, tol=tol)
    return jnp.einsum("c,c...->...", w_final, joint)
