"""AJIVE — Angle-based Joint and Individual Variation Explained.

Implements Algorithm 5 (Appendix E) in pure jnp, following the mvlearn logic:

  Phase 1  per-view economy SVD at an initial signal rank; singular-value
           threshold at the r/r+1 midpoint.
  Phase 2  joint SVD of the concatenated score (U) matrices; joint rank either
           fixed (paper production choice: k = r) or estimated from the
           Wedin + random-direction bounds via seeded resampling.
  Phase 3  per-view decomposition  X = J + I + E  with
           J = U_joint U_jointᵀ X (joint), I = thresholded SVD of the residual
           (individual), E = the rest (noise).

The federated server applies this to the lifted second-moment views
``V^{i} = ṽ_T^{i} R_kᵀ`` and broadcasts the shared component (§5 "Why AJIVE").
All SVDs are economy-size and MXU-lowerable; resampling uses explicit keys so
the estimator is deterministic and jit-safe with static ranks.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp


class AjiveResult(NamedTuple):
    joint: jnp.ndarray        # (k_views, n, m) per-view joint components J^(i)
    individual: jnp.ndarray   # (k_views, n, m) per-view individual I^(i)
    noise: jnp.ndarray        # (k_views, n, m) E^(i)
    joint_basis: jnp.ndarray  # (n, r_joint) shared column basis U_joint
    joint_mean: jnp.ndarray   # (n, m) weighted mean of joint components
    sv_joint: jnp.ndarray     # singular values of the stacked score matrix


def _center(x):
    return x - jnp.mean(x, axis=0, keepdims=True)


def _rank_truncate(x, rank: int):
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    return u[:, :rank], s[:rank], vt[:rank], s


def wedin_bound(x, u, s, vt, key, n_samples: int = 20) -> jnp.ndarray:
    """Resampled Wedin-style perturbation bound for one view (Phase 2 aid).

    Estimates how far the signal scores may rotate under the residual noise:
    samples random unit directions, measures ||Eᵀ u||/s_min style statistics.
    Returns a squared-singular-value cutoff contribution in [0, 1]-scale.
    """
    resid = x - (u * s[None, :]) @ vt
    n, m = x.shape
    k = u.shape[1]
    keys = jax.random.split(key, n_samples)

    def one(kk):
        kv, ku = jax.random.split(kk)
        dv = jax.random.normal(kv, (m,))
        dv = dv / (jnp.linalg.norm(dv) + 1e-12)
        du = jax.random.normal(ku, (n,))
        du = du / (jnp.linalg.norm(du) + 1e-12)
        return jnp.maximum(jnp.linalg.norm(resid @ dv),
                           jnp.linalg.norm(resid.T @ du))

    est = jnp.percentile(jax.vmap(one)(keys), 95)
    sin_theta = jnp.minimum(est / (s[-1] + 1e-12), 1.0)
    return sin_theta


def random_direction_bound(shapes: Sequence[tuple], ranks: Sequence[int],
                           key, n_samples: int = 20) -> jnp.ndarray:
    """Null distribution of the top squared singular value of stacked random
    orthonormal score matrices (Phase 2 'random bound')."""
    def one(kk):
        total = 0
        tops = []
        subkeys = jax.random.split(kk, len(shapes))
        mats = []
        for (n, _), r, sk in zip(shapes, ranks, subkeys):
            g = jax.random.normal(sk, (n, r))
            q, _ = jnp.linalg.qr(g)
            mats.append(q)
        m = jnp.concatenate(mats, axis=1)
        s = jnp.linalg.svd(m, compute_uv=False)
        return s[0] ** 2

    keys = jax.random.split(key, n_samples)
    vals = jax.vmap(one)(keys)
    return jnp.percentile(vals, 95)


def ajive(views: jnp.ndarray, signal_ranks, joint_rank: Optional[int] = None,
          individual_ranks=None, center: bool = True,
          key: Optional[jax.Array] = None,
          return_rank_diag: bool = False):
    """Run AJIVE on ``views`` of shape (k_views, n, m).

    ``signal_ranks``: int or per-view list — Phase 1 initial signal rank.
    ``joint_rank``: fixed joint rank (paper: k = r). If None, estimated from
    the Wedin/random bounds (requires ``key``); the estimate is returned as a
    *mask* applied to a max-rank basis so shapes stay static under jit.
    """
    k_views, n, m = views.shape
    if isinstance(signal_ranks, int):
        signal_ranks = [signal_ranks] * k_views
    if center:
        views = jax.vmap(_center)(views)

    # ---- Phase 1: per-view signal extraction -------------------------------
    scores, thresholds, svds = [], [], []
    for i in range(k_views):
        r = signal_ranks[i]
        u, s, vt, s_full = _rank_truncate(views[i], r)
        scores.append(u)
        # SV threshold: midpoint between r-th and (r+1)-th singular value.
        nxt = s_full[r] if r < s_full.shape[0] else jnp.zeros([])
        thresholds.append(0.5 * (s_full[r - 1] + nxt))
        svds.append((u, s, vt))

    # ---- Phase 2: score-space segmentation ----------------------------------
    stacked = jnp.concatenate(scores, axis=1)        # (n, sum r_i)
    u_joint_full, d_joint, _ = jnp.linalg.svd(stacked, full_matrices=False)

    if joint_rank is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        kw, kr = jax.random.split(key)
        # Wedin: aggregate per-view sin-theta into a squared-SV cutoff.
        sin_thetas = []
        wkeys = jax.random.split(kw, k_views)
        for i in range(k_views):
            u, s, vt = svds[i]
            sin_thetas.append(wedin_bound(views[i], u, s, vt, wkeys[i]))
        wedin_cut = sum(1.0 - jnp.minimum(st, 1.0) ** 2 for st in sin_thetas)
        wedin_cut = k_views - wedin_cut + 1e-6  # cutoff on squared SVs
        rand_cut = random_direction_bound([(n, m)] * k_views, signal_ranks, kr)
        cutoff = jnp.maximum(wedin_cut, rand_cut)
        rank_mask = (d_joint ** 2 > cutoff)
        max_joint = min(min(signal_ranks), u_joint_full.shape[1])
        mask = rank_mask[:max_joint].astype(views.dtype)
        u_joint = u_joint_full[:, :max_joint] * mask[None, :]
        est_rank = jnp.sum(rank_mask[:max_joint])
    else:
        u_joint = u_joint_full[:, :joint_rank]
        est_rank = jnp.asarray(joint_rank)

    # ---- Phase 3: final decomposition ---------------------------------------
    proj = u_joint @ u_joint.T                       # (n, n) joint projector
    joints, individuals, noises = [], [], []
    for i in range(k_views):
        x = views[i]
        j = proj @ x
        resid = x - j
        r_ind = (individual_ranks[i] if individual_ranks is not None
                 else signal_ranks[i])
        ui, si, vti, si_full = _rank_truncate(resid, r_ind)
        # Keep only components above the Phase-1 view threshold.
        keep = (si > thresholds[i]).astype(x.dtype)
        ind = (ui * (si * keep)[None, :]) @ vti
        joints.append(j)
        individuals.append(ind)
        noises.append(x - j - ind)

    joint = jnp.stack(joints)
    result = AjiveResult(joint=joint,
                         individual=jnp.stack(individuals),
                         noise=jnp.stack(noises),
                         joint_basis=u_joint,
                         joint_mean=jnp.mean(joint, axis=0),
                         sv_joint=d_joint)
    if return_rank_diag:
        return result, est_rank
    return result


def ajive_sync(views: jnp.ndarray, rank: int,
               weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Server-side second-moment synchronization (Algorithm 1, line 12).

    views: (k_views, n, m) lifted second-moment matrices V^{i} = ṽ^{i} R_kᵀ.
    Returns the drift-robust shared estimate v̄ (n, m): the weighted mean of
    the per-view joint components, with joint rank = ``rank`` (paper sets the
    AJIVE joint rank to the client projector rank r).
    """
    res = ajive(views, signal_ranks=rank, joint_rank=rank, center=False)
    if weights is None:
        return res.joint_mean
    w = weights / jnp.sum(weights)
    return jnp.einsum("k,knm->nm", w, res.joint)
