"""GaLoreAdamW — gradient-subspace AdamW (paper §5 + Appendix A.1).

For each *target block* ``W ∈ R^{m×n}`` the optimizer keeps a rank-r basis and
AdamW moments in the projected shape (``(m,r)`` right / ``(r,n)`` left), never
materializing dense ``m×n`` states:

    g̃  = project(g, B)                      # MXU GEMM
    m̃  = β₁ m̃ + (1-β₁) g̃
    ṽ  = β₂ ṽ + (1-β₂) g̃²
    ũ  = m̂ / (√v̂ + ε)                       # bias-corrected
    u  = project_back(ũ, B)                 # MXU GEMM
    W ← W - η u - η λ W                      # ambient-space AdamW step

The projector refreshes every ``τ`` steps: data-driven (RSVD/SVD of the current
gradient) for the first ``S`` refreshes, then **seeded random orthonormal** —
the basis is a pure function of ``(s_k, refresh_idx, block_id)`` so the server
only ever broadcasts the integer seed (Appendix D). On refresh the buffers are
re-expressed with the r×r transfer ``B_oldᵀ B_new`` (Appendix A.1).

Non-target leaves (biases, norms) fall back to dense AdamW moments.

Execution paths
---------------
The default ``update`` is the **fused, shape-bucketed** path: target blocks
with identical (shape, rank) form one bucket whose basis/moment state is
stacked and whose trace-heavy machinery — the projector refresh (QR / RSVD /
refresh-mode cond) and, on TPU, the fused optimizer kernel — is emitted once
per bucket (vmapped over the stacked leading dim), so trace size and compile
time stop scaling linearly with leaf count. On TPU the per-bucket step lowers
to the fused Pallas kernel (``kernels.galore_adamw.galore_precond_step``) —
one VMEM-resident pass with no dense HBM round-trips between optimizer
stages. On CPU/GPU-jnp the cheap GEMM+Adam chain stays per leaf (reading each
dense gradient exactly once beats a stack/unstack round-trip) and XLA fuses
the projected-space elementwise chain. ``GaloreConfig.fused=False`` selects
the original per-leaf reference loop, retained as the parity oracle;
``GaloreConfig.use_pallas`` forces the kernel on/off (None = auto: TPU only —
on CPU the kernel still runs, in interpret mode, when forced on, which is what
the parity tests use).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import projector as proj
from ..kernels import ops as kops
from ..optim.base import GradientTransformation

PyTree = Any


class GaloreBlockState(NamedTuple):
    basis: jnp.ndarray   # (dim, r) fp32, orthonormal columns
    m: jnp.ndarray       # projected first moment, fp32
    v: jnp.ndarray       # projected second moment, fp32 (elementwise)


class DenseMoments(NamedTuple):
    m: jnp.ndarray
    v: jnp.ndarray


class GaloreState(NamedTuple):
    count: jnp.ndarray   # int32 step counter
    seed: jnp.ndarray    # uint32 round seed s_k (server-broadcast)
    blocks: PyTree       # per-leaf GaloreBlockState | DenseMoments


def default_target_fn(path: str, leaf: jnp.ndarray) -> bool:
    """Target = any matrix leaf (attention/MLP projections). 3-D leaves are
    stacked scan blocks: one independent projector per layer (leading dim)."""
    return leaf.ndim in (2, 3)


@dataclasses.dataclass(frozen=True)
class GaloreConfig:
    rank: int = 8
    refresh_every: int = 200          # tau
    adaptive_steps: int = 2           # S data-driven refreshes, then random
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    oversample: int = 8
    use_exact_svd: bool = False
    # 'auto': lax.cond picks RSVD vs random by refresh index (both lowered)
    # 'random': only the seeded-random branch is compiled (production dry-run)
    # 'svd': only the data-driven branch (warmup-phase step function)
    refresh_mode: str = "auto"
    bias_correction: bool = True
    # Fused/bucketed execution (see module docstring). fused=False restores
    # the per-leaf reference loop (the parity oracle). use_pallas: None = auto
    # (TPU backend only); True forces the kernel (interpret mode off-TPU).
    fused: bool = True
    use_pallas: Optional[bool] = None
    pallas_block_rows: int = 128


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _block_rank(cfg: GaloreConfig, shape) -> int:
    return min(cfg.rank, min(shape[-2:]))


def _proj_shape(shape, rank: int, side: str):
    """Projected buffer shape, preserving leading stacked dims."""
    lead = tuple(shape[:-2])
    m, n = shape[-2:]
    return lead + ((m, rank) if side == proj.RIGHT else (rank, n))


def _block_keys(seed, refresh_idx, block_id, lead_shape):
    """One key for a 2-D block; per-layer keys for stacked (nb, m, n) blocks."""
    key = proj.seeded_block_key(seed, refresh_idx, block_id)
    if not lead_shape:
        return key
    return proj.stacked_keys(key, lead_shape[0])


def galore_init(cfg: GaloreConfig, params: PyTree,
                target_fn: Callable = default_target_fn,
                seed: int = 0) -> GaloreState:
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    block_states = []
    for block_id, (path, p) in enumerate(leaves):
        if target_fn(_path_str(path), p) and p.ndim >= 2:
            side = proj.proj_side(p.shape)
            r = _block_rank(cfg, p.shape)
            dim = proj.basis_dim(p.shape)
            keys = _block_keys(jnp.uint32(seed), jnp.uint32(0), block_id,
                               p.shape[:-2])
            basis = proj.random_basis_nd(keys, dim, r)
            pshape = _proj_shape(p.shape, r, side)
            block_states.append(GaloreBlockState(
                basis=basis,
                m=jnp.zeros(pshape, jnp.float32),
                v=jnp.zeros(pshape, jnp.float32)))
        else:
            block_states.append(DenseMoments(
                m=jnp.zeros(p.shape, jnp.float32),
                v=jnp.zeros(p.shape, jnp.float32)))
    return GaloreState(count=jnp.zeros([], jnp.int32),
                       seed=jnp.asarray(seed, jnp.uint32),
                       blocks=jax.tree_util.tree_unflatten(treedef, block_states))


def _refresh_basis(cfg: GaloreConfig, g32, old: GaloreBlockState,
                   refresh_idx, seed, block_id, side, rank):
    dim = proj.basis_dim(g32.shape)
    keys = _block_keys(seed, refresh_idx, block_id, g32.shape[:-2])

    def random_branch(_):
        return proj.random_basis_nd(keys, dim, rank)

    def data_branch(_):
        if cfg.use_exact_svd:
            return proj.svd_basis_nd(g32, rank, side)
        return proj.rsvd_basis_nd(g32, rank, side, keys, cfg.oversample)

    if cfg.refresh_mode == "random":
        new_basis = random_branch(None)
    elif cfg.refresh_mode == "svd":
        new_basis = data_branch(None)
    else:
        new_basis = jax.lax.cond(refresh_idx < cfg.adaptive_steps,
                                 data_branch, random_branch, operand=None)
    m = proj.reproject(old.m, old.basis, new_basis, side)
    # ṽ is an elementwise second moment; the change-of-basis transfer is the
    # paper's Appendix A.1 rule — clamp to keep the sqrt well-defined.
    v = jnp.maximum(proj.reproject(old.v, old.basis, new_basis, side), 0.0)
    return GaloreBlockState(basis=new_basis, m=m, v=v)


def _projected_adam(cfg: GaloreConfig, gt, m, v, count):
    """The shared projected-space Adam chain: moment EMAs + (optionally
    bias-corrected) update direction. Single source of truth for both the
    per-leaf reference loop and the bucketed fused path."""
    m = cfg.b1 * m + (1 - cfg.b1) * gt
    v = cfg.b2 * v + (1 - cfg.b2) * gt * gt
    if cfg.bias_correction:
        c = count.astype(jnp.float32)
        c1 = 1 - cfg.b1 ** c
        c2 = 1 - cfg.b2 ** c
    else:
        c1 = c2 = 1.0
    ut = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
    return m, v, ut


def _block_update(cfg: GaloreConfig, g, st: GaloreBlockState, count,
                  refresh_idx, do_refresh, seed, block_id):
    side = proj.proj_side(g.shape)
    rank = st.basis.shape[-1]
    g32 = g.astype(jnp.float32)

    st = jax.lax.cond(
        do_refresh,
        lambda s: _refresh_basis(cfg, g32, s, refresh_idx, seed, block_id,
                                 side, rank),
        lambda s: s, st)

    gt = proj.project(g32, st.basis, side)
    m, v, ut = _projected_adam(cfg, gt, st.m, st.v, count)
    u = proj.project_back(ut, st.basis, side)
    return u, GaloreBlockState(basis=st.basis, m=m, v=v)


def _dense_update(cfg: GaloreConfig, g, st: DenseMoments, count):
    m, v, u = _projected_adam(cfg, g.astype(jnp.float32), st.m, st.v, count)
    return u, DenseMoments(m=m, v=v)


def _resolve_use_pallas(cfg: GaloreConfig) -> bool:
    if cfg.use_pallas is not None:
        return cfg.use_pallas
    return jax.default_backend() == "tpu"


def _bucketed_update(cfg: GaloreConfig, use_pallas: bool, g_leaves,
                     blk_leaves, count, refresh_idx, do_refresh, seed):
    """Shape-bucketed batched GaLore step (the fused default).

    Target blocks with identical (shape, rank) share one stacked state bucket:
    the refresh (QR/RSVD + mode cond — the dominant trace cost) is emitted
    once per bucket, vmapped, and the Pallas kernel path consumes the whole
    bucket in one batched call. Per-block seeded keys fold in the *original*
    leaf index, so every basis is bit-identical to the per-leaf reference loop
    (the server-broadcast-a-seed protocol is unaffected by bucketing).
    """
    n_leaves = len(blk_leaves)
    updates = [None] * n_leaves
    new_blocks = [None] * n_leaves

    buckets: dict = {}
    for i, (g, st) in enumerate(zip(g_leaves, blk_leaves)):
        if isinstance(st, GaloreBlockState):
            buckets.setdefault((tuple(g.shape), int(st.basis.shape[-1])),
                               []).append(i)
        else:
            updates[i], new_blocks[i] = _dense_update(cfg, g, st, count)

    for (shape, rank), idxs in sorted(buckets.items()):
        side = proj.proj_side(shape)
        lead = shape[:-2]
        dim = proj.basis_dim(shape)

        def stacked_g(idxs=idxs):
            # Materialized only where the batched form pays for the copy:
            # inside the (rare) data-driven refresh branch and the Pallas
            # kernel call. The jnp hot path reads the leaves directly.
            return jnp.stack([g_leaves[i] for i in idxs]).astype(jnp.float32)

        basis = jnp.stack([blk_leaves[i].basis for i in idxs])
        m = jnp.stack([blk_leaves[i].m for i in idxs])
        v = jnp.stack([blk_leaves[i].v for i in idxs])
        block_ids = jnp.asarray(idxs, jnp.uint32)

        def bucket_keys(block_ids=block_ids, lead=lead):
            keys = jax.vmap(lambda bid: proj.seeded_block_key(
                seed, refresh_idx, bid))(block_ids)
            if lead:
                keys = jax.vmap(
                    lambda kk: proj.stacked_keys(kk, lead[0]))(keys)
            return keys

        def random_branch(_, dim=dim, rank=rank, bucket_keys=bucket_keys):
            return proj.random_basis_nd(bucket_keys(), dim, rank)

        def data_branch(_, stacked_g=stacked_g, rank=rank, side=side,
                        bucket_keys=bucket_keys):
            if cfg.use_exact_svd:
                return proj.svd_basis_nd(stacked_g(), rank, side)
            return proj.rsvd_basis_nd(stacked_g(), rank, side, bucket_keys(),
                                      cfg.oversample)

        def refresh(args, side=side, random_branch=random_branch,
                    data_branch=data_branch):
            b_old, m_old, v_old = args
            if cfg.refresh_mode == "random":
                b_new = random_branch(None)
            elif cfg.refresh_mode == "svd":
                b_new = data_branch(None)
            else:
                b_new = jax.lax.cond(refresh_idx < cfg.adaptive_steps,
                                     data_branch, random_branch, operand=None)
            m_new = proj.reproject(m_old, b_old, b_new, side)
            v_new = jnp.maximum(proj.reproject(v_old, b_old, b_new, side), 0.0)
            return b_new, m_new, v_new

        basis, m, v = jax.lax.cond(do_refresh, refresh, lambda a: a,
                                   (basis, m, v))

        if use_pallas:
            # One fused VMEM-resident pass per bucket (vmapped over the
            # bucket's leading dim -> an extra grid dimension, not a loop).
            # Stacking the gradients costs one extra read/write of g, which
            # the kernel's saved inter-stage HBM round-trips repay.
            u, m, v = kops.galore_precond_step(
                stacked_g(), basis, m, v, count.astype(jnp.float32),
                side=side, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                block_rows=cfg.pallas_block_rows,
                bias_correction=cfg.bias_correction)
            for j, i in enumerate(idxs):
                updates[i] = u[j]
                new_blocks[i] = GaloreBlockState(basis=basis[j], m=m[j],
                                                 v=v[j])
            continue

        # jnp hot path: the trace-heavy refresh above is shared per bucket;
        # the cheap GEMM+Adam chain stays per leaf so the dense gradient is
        # read exactly once (no O(leaf·m·n) stack/unstack round-trip — XLA
        # fuses the projected-space elementwise chain between the two GEMMs).
        for j, i in enumerate(idxs):
            gt = proj.project(g_leaves[i].astype(jnp.float32), basis[j], side)
            mj, vj, ut = _projected_adam(cfg, gt, m[j], v[j], count)
            updates[i] = proj.project_back(ut, basis[j], side)
            new_blocks[i] = GaloreBlockState(basis=basis[j], m=mj, v=vj)

    return updates, new_blocks


def scale_by_galore(cfg: GaloreConfig,
                    target_fn: Callable = default_target_fn,
                    seed: int = 0) -> GradientTransformation:
    """GaLore preconditioning as a GradientTransformation (chain with weight
    decay + lr like AdamW). ``cfg.fused`` selects the bucketed/fused default
    path; ``fused=False`` runs the per-leaf reference loop (parity oracle)."""

    def init(params):
        return galore_init(cfg, params, target_fn, seed)

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        refresh_idx = state.count // cfg.refresh_every
        do_refresh = (state.count % cfg.refresh_every) == 0

        leaves = jax.tree_util.tree_flatten_with_path(grads)[0]
        treedef = jax.tree_util.tree_structure(grads)
        blk_leaves = jax.tree_util.tree_leaves(
            state.blocks, is_leaf=lambda x: isinstance(x, (GaloreBlockState,
                                                           DenseMoments)))
        if cfg.fused:
            updates, new_blocks = _bucketed_update(
                cfg, _resolve_use_pallas(cfg), [g for _, g in leaves],
                blk_leaves, count, refresh_idx, do_refresh, state.seed)
        else:
            updates, new_blocks = [], []
            for block_id, ((path, g), st) in enumerate(zip(leaves,
                                                           blk_leaves)):
                if isinstance(st, GaloreBlockState):
                    u, nst = _block_update(cfg, g, st, count, refresh_idx,
                                           do_refresh, state.seed, block_id)
                else:
                    u, nst = _dense_update(cfg, g, st, count)
                updates.append(u)
                new_blocks.append(nst)
        return (jax.tree_util.tree_unflatten(treedef, updates),
                GaloreState(count=count, seed=state.seed,
                            blocks=jax.tree_util.tree_unflatten(treedef, new_blocks)))

    return GradientTransformation(init, update)


def galore_adamw(cfg: GaloreConfig, learning_rate, weight_decay: float = 0.01,
                 target_fn: Callable = default_target_fn, seed: int = 0,
                 clip_norm: Optional[float] = None) -> GradientTransformation:
    from ..optim.base import chain, clip_by_global_norm, scale_by_learning_rate
    from ..optim.adamw import add_decayed_weights
    txs = []
    if clip_norm is not None:
        txs.append(clip_by_global_norm(clip_norm))
    txs += [scale_by_galore(cfg, target_fn, seed),
            add_decayed_weights(weight_decay),
            scale_by_learning_rate(learning_rate)]
    return chain(*txs)


def _bucketed_manual_refresh(cfg: GaloreConfig, blk_leaves, grads_leaves,
                             refresh_idx, seed):
    """Shape-bucketed round-boundary refresh: blocks with identical
    (basis shape, moment shape) share one stacked bucket whose key
    derivation, basis draw (QR / RSVD / SVD), and r×r moment transfer are
    emitted once and vmapped — O(buckets) ops instead of O(leaves). Per-block
    keys fold the *original* leaf index so every basis is bit-identical to
    the per-leaf reference loop (the broadcast-a-seed protocol is unaffected).
    """
    out = [None] * len(blk_leaves)
    buckets: dict = {}
    for i, st in enumerate(blk_leaves):
        if isinstance(st, GaloreBlockState):
            buckets.setdefault((tuple(st.basis.shape), tuple(st.m.shape)),
                               []).append(i)
        else:
            out[i] = st

    for (bshape, mshape), idxs in sorted(buckets.items()):
        rank = bshape[-1]
        dim = bshape[-2]
        lead = bshape[:-2]
        side = proj.RIGHT if mshape[-1] == rank else proj.LEFT
        basis = jnp.stack([blk_leaves[i].basis for i in idxs])
        m = jnp.stack([blk_leaves[i].m for i in idxs])
        v = jnp.stack([blk_leaves[i].v for i in idxs])
        block_ids = jnp.asarray(idxs, jnp.uint32)
        keys = jax.vmap(lambda bid: proj.seeded_block_key(
            seed, refresh_idx, bid))(block_ids)
        if lead:
            keys = jax.vmap(lambda kk: proj.stacked_keys(kk, lead[0]))(keys)
        if grads_leaves is not None:
            g32 = jnp.stack([grads_leaves[i] for i in idxs]).astype(
                jnp.float32)
            if cfg.use_exact_svd:
                new_basis = proj.svd_basis_nd(g32, rank, side)
            else:
                new_basis = proj.rsvd_basis_nd(g32, rank, side, keys,
                                               cfg.oversample)
        else:
            new_basis = proj.random_basis_nd(keys, dim, rank)
        m_new = proj.reproject(m, basis, new_basis, side)
        v_new = jnp.maximum(proj.reproject(v, basis, new_basis, side), 0.0)
        for j, i in enumerate(idxs):
            out[i] = GaloreBlockState(basis=new_basis[j], m=m_new[j],
                                      v=v_new[j])
    return out


def manual_refresh(cfg: GaloreConfig, state: GaloreState, refresh_idx,
                   grads: Optional[PyTree] = None) -> GaloreState:
    """Refresh every block basis *now* (round-boundary refresh used by the
    federated engine; the in-step ``count % τ`` path is used by the compiled
    production train step).

    Data-driven (RSVD/SVD of ``grads``) when ``grads`` is given and
    ``refresh_idx < adaptive_steps``; seeded-random otherwise. With
    ``grads=None`` (the engine's seeded-broadcast round boundary) the refresh
    index may be a traced value, so the refresh is jit/scan-safe and the
    fused round program can run it with a scanned round counter. The default
    ``cfg.fused`` execution is shape-bucketed (one vmapped key-derivation +
    QR + transfer per bucket); ``fused=False`` keeps the per-leaf reference
    loop as the parity oracle.
    """
    grads_leaves = None
    if grads is not None:
        # Data-driven refreshes need a *concrete* refresh index (the round
        # number) — the adaptive-vs-random decision is made at trace time.
        refresh_idx_int = int(refresh_idx)
        adaptive = (cfg.refresh_mode != "random"
                    and refresh_idx_int < cfg.adaptive_steps)
        if adaptive:
            grads_leaves = jax.tree_util.tree_leaves(grads)
    refresh_idx = jnp.asarray(refresh_idx, jnp.uint32)

    blk_leaves, treedef = jax.tree_util.tree_flatten(
        state.blocks, is_leaf=lambda x: isinstance(x, (GaloreBlockState,
                                                       DenseMoments)))
    if cfg.fused:
        out = _bucketed_manual_refresh(cfg, blk_leaves, grads_leaves,
                                       refresh_idx, state.seed)
        return GaloreState(count=state.count, seed=state.seed,
                           blocks=jax.tree_util.tree_unflatten(treedef, out))

    out = []
    for block_id, st in enumerate(blk_leaves):
        if not isinstance(st, GaloreBlockState):
            out.append(st)
            continue
        rank = st.basis.shape[-1]
        # Projected buffers are (rows, r) for right-side blocks and (r, cols)
        # for left-side blocks (Appendix A.1 shape summary).
        side = proj.RIGHT if st.m.shape[-1] == rank else proj.LEFT
        keys = _block_keys(state.seed, refresh_idx, block_id,
                           st.basis.shape[:-2])
        if grads_leaves is not None:
            g32 = grads_leaves[block_id].astype(jnp.float32)
            if cfg.use_exact_svd:
                new_basis = proj.svd_basis_nd(g32, rank, side)
            else:
                new_basis = proj.rsvd_basis_nd(g32, rank, side, keys,
                                               cfg.oversample)
        else:
            new_basis = proj.random_basis_nd(keys, st.basis.shape[-2], rank)
        m = proj.reproject(st.m, st.basis, new_basis, side)
        v = jnp.maximum(proj.reproject(st.v, st.basis, new_basis, side), 0.0)
        out.append(GaloreBlockState(basis=new_basis, m=m, v=v))
    return GaloreState(count=state.count, seed=state.seed,
                       blocks=jax.tree_util.tree_unflatten(treedef, out))


# ------------------------------------------------- fed-layer state access ---

def galore_state_of(opt_state) -> GaloreState:
    """Find the GaloreState inside a chained optimizer state."""
    if isinstance(opt_state, GaloreState):
        return opt_state
    for s in opt_state:
        if isinstance(s, GaloreState):
            return s
    raise ValueError("no GaloreState in optimizer state")


def replace_galore_state(opt_state, new: GaloreState):
    if isinstance(opt_state, GaloreState):
        return new
    return tuple(new if isinstance(s, GaloreState) else s for s in opt_state)


def extract_projected_v(state: GaloreState) -> PyTree:
    """The per-block projected second moments ṽ — the client uplink payload."""
    def pick(st):
        return st.v if isinstance(st, GaloreBlockState) else None
    return jax.tree_util.tree_map(
        pick, state.blocks,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))


def extract_bases(state: GaloreState) -> PyTree:
    def pick(st):
        return st.basis if isinstance(st, GaloreBlockState) else None
    return jax.tree_util.tree_map(
        pick, state.blocks,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))


def with_projected_v(state: GaloreState, new_v: PyTree) -> GaloreState:
    """Install server-synchronized ṽ (next-round initialization, Alg. 1 l.13)."""
    def put(st, nv):
        if isinstance(st, GaloreBlockState) and nv is not None:
            return GaloreBlockState(basis=st.basis, m=st.m,
                                    v=jnp.maximum(nv.astype(jnp.float32), 0.0))
        return st
    blocks = jax.tree_util.tree_map(
        put, state.blocks, new_v,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))
    return GaloreState(count=state.count, seed=state.seed, blocks=blocks)


def with_seed(state: GaloreState, seed) -> GaloreState:
    return GaloreState(count=state.count,
                       seed=jnp.asarray(seed, jnp.uint32), blocks=state.blocks)
