"""GaLoreAdamW — gradient-subspace AdamW (paper §5 + Appendix A.1).

For each *target block* ``W ∈ R^{m×n}`` the optimizer keeps a rank-r basis and
AdamW moments in the projected shape (``(m,r)`` right / ``(r,n)`` left), never
materializing dense ``m×n`` states:

    g̃  = project(g, B)                      # MXU GEMM
    m̃  = β₁ m̃ + (1-β₁) g̃
    ṽ  = β₂ ṽ + (1-β₂) g̃²
    ũ  = m̂ / (√v̂ + ε)                       # bias-corrected
    u  = project_back(ũ, B)                 # MXU GEMM
    W ← W - η u - η λ W                      # ambient-space AdamW step

The projector refreshes every ``τ`` steps: data-driven (RSVD/SVD of the current
gradient) for the first ``S`` refreshes, then **seeded random orthonormal** —
the basis is a pure function of ``(s_k, refresh_idx, block_id)`` so the server
only ever broadcasts the integer seed (Appendix D). On refresh the buffers are
re-expressed with the r×r transfer ``B_oldᵀ B_new`` (Appendix A.1).

Non-target leaves (biases, norms) fall back to dense AdamW moments.

Execution paths
---------------
The default ``update`` is the **fused, shape-bucketed** path: target blocks
with identical (shape, rank) form one bucket whose basis/moment state is
stacked and whose trace-heavy machinery — the projector refresh (QR / RSVD /
refresh-mode cond) and, on TPU, the fused optimizer kernel — is emitted once
per bucket (vmapped over the stacked leading dim), so trace size and compile
time stop scaling linearly with leaf count. On TPU the per-bucket step lowers
to the fused Pallas kernel (``kernels.galore_adamw.galore_precond_step``) —
one VMEM-resident pass with no dense HBM round-trips between optimizer
stages. On CPU/GPU-jnp the cheap GEMM+Adam chain stays per leaf (reading each
dense gradient exactly once beats a stack/unstack round-trip) and XLA fuses
the projected-space elementwise chain. ``GaloreConfig.fused=False`` selects
the original per-leaf reference loop, retained as the parity oracle;
``GaloreConfig.use_pallas`` forces the kernel on/off (None = auto: TPU only —
on CPU the kernel still runs, in interpret mode, when forced on, which is what
the parity tests use).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import projector as proj
from ..kernels import ops as kops
from ..optim.base import GradientTransformation

PyTree = Any


class GaloreBlockState(NamedTuple):
    basis: jnp.ndarray   # (dim, r) fp32, orthonormal columns
    m: jnp.ndarray       # projected first moment, fp32
    v: jnp.ndarray       # projected second moment, fp32 (elementwise)


class DenseMoments(NamedTuple):
    m: jnp.ndarray
    v: jnp.ndarray


class GaloreState(NamedTuple):
    count: jnp.ndarray   # int32 step counter
    seed: jnp.ndarray    # uint32 round seed s_k (server-broadcast)
    blocks: PyTree       # per-leaf GaloreBlockState | DenseMoments


def default_target_fn(path: str, leaf: jnp.ndarray) -> bool:
    """Target = any matrix leaf (attention/MLP projections). 3-D leaves are
    stacked scan blocks: one independent projector per layer (leading dim)."""
    return leaf.ndim in (2, 3)


@dataclasses.dataclass(frozen=True)
class GaloreConfig:
    rank: int = 8
    refresh_every: int = 200          # tau
    adaptive_steps: int = 2           # S data-driven refreshes, then random
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    oversample: int = 8
    use_exact_svd: bool = False
    # 'auto': lax.cond picks RSVD vs random by refresh index (both lowered)
    # 'random': only the seeded-random branch is compiled (production dry-run)
    # 'svd': only the data-driven branch (warmup-phase step function)
    refresh_mode: str = "auto"
    bias_correction: bool = True
    # Fused/bucketed execution (see module docstring). fused=False restores
    # the per-leaf reference loop (the parity oracle). use_pallas: None = auto
    # (TPU backend only); True forces the kernel (interpret mode off-TPU).
    fused: bool = True
    use_pallas: Optional[bool] = None
    pallas_block_rows: int = 128


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _block_rank(cfg: GaloreConfig, shape) -> int:
    return min(cfg.rank, min(shape[-2:]))


def _proj_shape(shape, rank: int, side: str):
    """Projected buffer shape, preserving leading stacked dims."""
    lead = tuple(shape[:-2])
    m, n = shape[-2:]
    return lead + ((m, rank) if side == proj.RIGHT else (rank, n))


def _block_keys(seed, refresh_idx, block_id, lead_shape):
    """One key for a 2-D block; per-layer keys for stacked (nb, m, n) blocks."""
    key = proj.seeded_block_key(seed, refresh_idx, block_id)
    if not lead_shape:
        return key
    return proj.stacked_keys(key, lead_shape[0])


def galore_init(cfg: GaloreConfig, params: PyTree,
                target_fn: Callable = default_target_fn,
                seed: int = 0) -> GaloreState:
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    block_states = []
    for block_id, (path, p) in enumerate(leaves):
        if target_fn(_path_str(path), p) and p.ndim >= 2:
            side = proj.proj_side(p.shape)
            r = _block_rank(cfg, p.shape)
            dim = proj.basis_dim(p.shape)
            keys = _block_keys(jnp.uint32(seed), jnp.uint32(0), block_id,
                               p.shape[:-2])
            basis = proj.random_basis_nd(keys, dim, r)
            pshape = _proj_shape(p.shape, r, side)
            block_states.append(GaloreBlockState(
                basis=basis,
                m=jnp.zeros(pshape, jnp.float32),
                v=jnp.zeros(pshape, jnp.float32)))
        else:
            block_states.append(DenseMoments(
                m=jnp.zeros(p.shape, jnp.float32),
                v=jnp.zeros(p.shape, jnp.float32)))
    return GaloreState(count=jnp.zeros([], jnp.int32),
                       seed=jnp.asarray(seed, jnp.uint32),
                       blocks=jax.tree_util.tree_unflatten(treedef, block_states))


def _refresh_basis(cfg: GaloreConfig, g32, old: GaloreBlockState,
                   refresh_idx, seed, block_id, side, rank):
    dim = proj.basis_dim(g32.shape)
    keys = _block_keys(seed, refresh_idx, block_id, g32.shape[:-2])

    def random_branch(_):
        return proj.random_basis_nd(keys, dim, rank)

    def data_branch(_):
        if cfg.use_exact_svd:
            return proj.svd_basis_nd(g32, rank, side)
        return proj.rsvd_basis_nd(g32, rank, side, keys, cfg.oversample)

    if cfg.refresh_mode == "random":
        new_basis = random_branch(None)
    elif cfg.refresh_mode == "svd":
        new_basis = data_branch(None)
    else:
        new_basis = jax.lax.cond(refresh_idx < cfg.adaptive_steps,
                                 data_branch, random_branch, operand=None)
    m = proj.reproject(old.m, old.basis, new_basis, side)
    # ṽ is an elementwise second moment; the change-of-basis transfer is the
    # paper's Appendix A.1 rule — clamp to keep the sqrt well-defined.
    v = jnp.maximum(proj.reproject(old.v, old.basis, new_basis, side), 0.0)
    return GaloreBlockState(basis=new_basis, m=m, v=v)


def _projected_adam(cfg: GaloreConfig, gt, m, v, count):
    """The shared projected-space Adam chain: moment EMAs + (optionally
    bias-corrected) update direction. Single source of truth for both the
    per-leaf reference loop and the bucketed fused path."""
    m = cfg.b1 * m + (1 - cfg.b1) * gt
    v = cfg.b2 * v + (1 - cfg.b2) * gt * gt
    if cfg.bias_correction:
        c = count.astype(jnp.float32)
        c1 = 1 - cfg.b1 ** c
        c2 = 1 - cfg.b2 ** c
    else:
        c1 = c2 = 1.0
    ut = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
    return m, v, ut


def _block_update(cfg: GaloreConfig, g, st: GaloreBlockState, count,
                  refresh_idx, do_refresh, seed, block_id,
                  project_back: bool = True):
    side = proj.proj_side(g.shape)
    rank = st.basis.shape[-1]
    g32 = g.astype(jnp.float32)

    st = jax.lax.cond(
        do_refresh,
        lambda s: _refresh_basis(cfg, g32, s, refresh_idx, seed, block_id,
                                 side, rank),
        lambda s: s, st)

    gt = proj.project(g32, st.basis, side)
    m, v, ut = _projected_adam(cfg, gt, st.m, st.v, count)
    u = proj.project_back(ut, st.basis, side) if project_back else ut
    return u, GaloreBlockState(basis=st.basis, m=m, v=v)


def _dense_update(cfg: GaloreConfig, g, st: DenseMoments, count):
    m, v, u = _projected_adam(cfg, g.astype(jnp.float32), st.m, st.v, count)
    return u, DenseMoments(m=m, v=v)


def _resolve_use_pallas(cfg: GaloreConfig) -> bool:
    if cfg.use_pallas is not None:
        return cfg.use_pallas
    return jax.default_backend() == "tpu"


def _bucketed_update(cfg: GaloreConfig, use_pallas: bool, g_leaves,
                     blk_leaves, count, refresh_idx, do_refresh, seed,
                     project_back: bool = True):
    """Shape-bucketed batched GaLore step (the fused default).

    Target blocks with identical (shape, rank) share one stacked state bucket:
    the refresh (QR/RSVD + mode cond — the dominant trace cost) is emitted
    once per bucket, vmapped, and the Pallas kernel path consumes the whole
    bucket in one batched call. Per-block seeded keys fold in the *original*
    leaf index, so every basis is bit-identical to the per-leaf reference loop
    (the server-broadcast-a-seed protocol is unaffected by bucketing).
    ``project_back=False`` keeps the update in projected coordinates (ũ,
    shaped like the moments) — the factored-delta client path, where the
    ambient lift is deferred to the weight read.
    """
    n_leaves = len(blk_leaves)
    updates = [None] * n_leaves
    new_blocks = [None] * n_leaves

    buckets: dict = {}
    for i, (g, st) in enumerate(zip(g_leaves, blk_leaves)):
        if isinstance(st, GaloreBlockState):
            buckets.setdefault((tuple(g.shape), int(st.basis.shape[-1])),
                               []).append(i)
        else:
            updates[i], new_blocks[i] = _dense_update(cfg, g, st, count)

    for (shape, rank), idxs in sorted(buckets.items()):
        side = proj.proj_side(shape)
        lead = shape[:-2]
        dim = proj.basis_dim(shape)

        def stacked_g(idxs=idxs):
            # Materialized only where the batched form pays for the copy:
            # inside the (rare) data-driven refresh branch and the Pallas
            # kernel call. The jnp hot path reads the leaves directly.
            return jnp.stack([g_leaves[i] for i in idxs]).astype(jnp.float32)

        basis = jnp.stack([blk_leaves[i].basis for i in idxs])
        m = jnp.stack([blk_leaves[i].m for i in idxs])
        v = jnp.stack([blk_leaves[i].v for i in idxs])
        block_ids = jnp.asarray(idxs, jnp.uint32)

        def bucket_keys(block_ids=block_ids, lead=lead):
            keys = jax.vmap(lambda bid: proj.seeded_block_key(
                seed, refresh_idx, bid))(block_ids)
            if lead:
                keys = jax.vmap(
                    lambda kk: proj.stacked_keys(kk, lead[0]))(keys)
            return keys

        def random_branch(_, dim=dim, rank=rank, bucket_keys=bucket_keys):
            return proj.random_basis_nd(bucket_keys(), dim, rank)

        def data_branch(_, stacked_g=stacked_g, rank=rank, side=side,
                        bucket_keys=bucket_keys):
            if cfg.use_exact_svd:
                return proj.svd_basis_nd(stacked_g(), rank, side)
            return proj.rsvd_basis_nd(stacked_g(), rank, side, bucket_keys(),
                                      cfg.oversample)

        def refresh(args, side=side, random_branch=random_branch,
                    data_branch=data_branch):
            b_old, m_old, v_old = args
            if cfg.refresh_mode == "random":
                b_new = random_branch(None)
            elif cfg.refresh_mode == "svd":
                b_new = data_branch(None)
            else:
                b_new = jax.lax.cond(refresh_idx < cfg.adaptive_steps,
                                     data_branch, random_branch, operand=None)
            m_new = proj.reproject(m_old, b_old, b_new, side)
            v_new = jnp.maximum(proj.reproject(v_old, b_old, b_new, side), 0.0)
            return b_new, m_new, v_new

        basis, m, v = jax.lax.cond(do_refresh, refresh, lambda a: a,
                                   (basis, m, v))

        if use_pallas:
            # One fused VMEM-resident pass per bucket (vmapped over the
            # bucket's leading dim -> an extra grid dimension, not a loop).
            # Stacking the gradients costs one extra read/write of g, which
            # the kernel's saved inter-stage HBM round-trips repay. With
            # project_back=False the kernel skips the final lift GEMM and
            # emits ũ in the moment shape.
            u, m, v = kops.galore_precond_step(
                stacked_g(), basis, m, v, count.astype(jnp.float32),
                side=side, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
                block_rows=cfg.pallas_block_rows,
                bias_correction=cfg.bias_correction,
                project_back=project_back)
            for j, i in enumerate(idxs):
                updates[i] = u[j]
                new_blocks[i] = GaloreBlockState(basis=basis[j], m=m[j],
                                                 v=v[j])
            continue

        # jnp hot path: the trace-heavy refresh above is shared per bucket;
        # the cheap GEMM+Adam chain stays per leaf so the dense gradient is
        # read exactly once (no O(leaf·m·n) stack/unstack round-trip — XLA
        # fuses the projected-space elementwise chain between the two GEMMs).
        for j, i in enumerate(idxs):
            gt = proj.project(g_leaves[i].astype(jnp.float32), basis[j], side)
            mj, vj, ut = _projected_adam(cfg, gt, m[j], v[j], count)
            updates[i] = (proj.project_back(ut, basis[j], side)
                          if project_back else ut)
            new_blocks[i] = GaloreBlockState(basis=basis[j], m=mj, v=vj)

    return updates, new_blocks


def galore_transform_update(cfg: GaloreConfig, grads, state: GaloreState,
                            project_back: bool = True,
                            projected: bool = False):
    """One GaLore preconditioning step as a pure function (the
    ``scale_by_galore`` update body): in-step ``count % τ`` refresh, projected
    Adam moments, update direction. With the default ``project_back=True``
    target-block updates are lifted back to ambient shape (the dense chain
    API). ``project_back=False`` returns them as the *projected* ũ (shaped
    like the moments) — the factored-delta client path, which keeps the whole
    local step in rank-r coordinates and defers the lift to the weight read.
    Non-target (``DenseMoments``) leaves are plain Adam either way.

    ``projected=True`` is the **lift-free** consumption mode: the incoming
    gradients are *already* in rank-r coordinates (the projected-cotangent
    VJP of the delta-aware forward), so the ``Pᵀg`` projection GEMM is
    skipped and the step is pure projected-space Adam. The caller owns the
    refresh (hoisted :func:`maybe_refresh_instep` before the forward, so the
    cotangents arrive on the refreshed basis); every leaf must be a target
    block (:func:`all_blocks_projected`)."""
    count = state.count + 1
    refresh_idx = state.count // cfg.refresh_every
    do_refresh = (state.count % cfg.refresh_every) == 0

    leaves = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree_util.tree_structure(grads)
    blk_leaves = jax.tree_util.tree_leaves(
        state.blocks, is_leaf=lambda x: isinstance(x, (GaloreBlockState,
                                                       DenseMoments)))
    if projected:
        updates, new_blocks = [], []
        for (path, g), st in zip(leaves, blk_leaves):
            if not isinstance(st, GaloreBlockState):
                raise ValueError(
                    "projected-gradient GaLore step requires every leaf to "
                    f"be a target block; {_path_str(path)} is dense")
            side = _moment_side(st)
            m, v, ut = _projected_adam(cfg, g.astype(jnp.float32), st.m,
                                       st.v, count)
            updates.append(proj.project_back(ut, st.basis, side)
                           if project_back else ut)
            new_blocks.append(GaloreBlockState(basis=st.basis, m=m, v=v))
        return (jax.tree_util.tree_unflatten(treedef, updates),
                GaloreState(count=count, seed=state.seed,
                            blocks=jax.tree_util.tree_unflatten(treedef,
                                                                new_blocks)))
    if cfg.fused:
        updates, new_blocks = _bucketed_update(
            cfg, _resolve_use_pallas(cfg), [g for _, g in leaves],
            blk_leaves, count, refresh_idx, do_refresh, state.seed,
            project_back=project_back)
    else:
        updates, new_blocks = [], []
        for block_id, ((path, g), st) in enumerate(zip(leaves,
                                                       blk_leaves)):
            if isinstance(st, GaloreBlockState):
                u, nst = _block_update(cfg, g, st, count, refresh_idx,
                                       do_refresh, state.seed, block_id,
                                       project_back=project_back)
            else:
                u, nst = _dense_update(cfg, g, st, count)
            updates.append(u)
            new_blocks.append(nst)
    return (jax.tree_util.tree_unflatten(treedef, updates),
            GaloreState(count=count, seed=state.seed,
                        blocks=jax.tree_util.tree_unflatten(treedef,
                                                            new_blocks)))


def scale_by_galore(cfg: GaloreConfig,
                    target_fn: Callable = default_target_fn,
                    seed: int = 0) -> GradientTransformation:
    """GaLore preconditioning as a GradientTransformation (chain with weight
    decay + lr like AdamW). ``cfg.fused`` selects the bucketed/fused default
    path; ``fused=False`` runs the per-leaf reference loop (parity oracle)."""

    def init(params):
        return galore_init(cfg, params, target_fn, seed)

    def update(grads, state, params=None):
        del params
        return galore_transform_update(cfg, grads, state, project_back=True)

    return GradientTransformation(init, update)


def galore_adamw(cfg: GaloreConfig, learning_rate, weight_decay: float = 0.01,
                 target_fn: Callable = default_target_fn, seed: int = 0,
                 clip_norm: Optional[float] = None) -> GradientTransformation:
    from ..optim.base import chain, clip_by_global_norm, scale_by_learning_rate
    from ..optim.adamw import add_decayed_weights
    txs = []
    if clip_norm is not None:
        txs.append(clip_by_global_norm(clip_norm))
    txs += [scale_by_galore(cfg, target_fn, seed),
            add_decayed_weights(weight_decay),
            scale_by_learning_rate(learning_rate)]
    return chain(*txs)


def bucket_by_shape(keys):
    """Group leaf indices by an identical-shape key: ``keys[i]`` is a
    hashable layout descriptor for leaf i (or None to leave it unbucketed).
    Returns ``(buckets, passthrough)`` — a deterministically-ordered list of
    ``(key, [indices])`` plus the unbucketed indices. Leaves sharing a key
    can be stacked and run as one vmapped program (the refresh and 𝒮 bucket
    layout contract: one compiled program per distinct shape, O(buckets)
    ops instead of O(leaves))."""
    groups: dict = {}
    passthrough = []
    for i, key in enumerate(keys):
        if key is None:
            passthrough.append(i)
        else:
            groups.setdefault(key, []).append(i)
    return sorted(groups.items()), passthrough


def _bucketed_manual_refresh(cfg: GaloreConfig, blk_leaves, grads_leaves,
                             refresh_idx, seed):
    """Shape-bucketed round-boundary refresh: blocks with identical
    (basis shape, moment shape) share one stacked bucket whose key
    derivation, basis draw (QR / RSVD / SVD), and r×r moment transfer are
    emitted once and vmapped — O(buckets) ops instead of O(leaves). Per-block
    keys fold the *original* leaf index so every basis is bit-identical to
    the per-leaf reference loop (the broadcast-a-seed protocol is unaffected).
    """
    out = [None] * len(blk_leaves)
    buckets, passthrough = bucket_by_shape(
        [(tuple(st.basis.shape), tuple(st.m.shape))
         if isinstance(st, GaloreBlockState) else None for st in blk_leaves])
    for i in passthrough:
        out[i] = blk_leaves[i]

    for (bshape, mshape), idxs in buckets:
        rank = bshape[-1]
        dim = bshape[-2]
        lead = bshape[:-2]
        side = proj.RIGHT if mshape[-1] == rank else proj.LEFT
        basis = jnp.stack([blk_leaves[i].basis for i in idxs])
        m = jnp.stack([blk_leaves[i].m for i in idxs])
        v = jnp.stack([blk_leaves[i].v for i in idxs])
        block_ids = jnp.asarray(idxs, jnp.uint32)
        keys = jax.vmap(lambda bid: proj.seeded_block_key(
            seed, refresh_idx, bid))(block_ids)
        if lead:
            keys = jax.vmap(lambda kk: proj.stacked_keys(kk, lead[0]))(keys)
        if grads_leaves is not None:
            g32 = jnp.stack([grads_leaves[i] for i in idxs]).astype(
                jnp.float32)
            if cfg.use_exact_svd:
                new_basis = proj.svd_basis_nd(g32, rank, side)
            else:
                new_basis = proj.rsvd_basis_nd(g32, rank, side, keys,
                                               cfg.oversample)
        else:
            new_basis = proj.random_basis_nd(keys, dim, rank)
        m_new = proj.reproject(m, basis, new_basis, side)
        v_new = jnp.maximum(proj.reproject(v, basis, new_basis, side), 0.0)
        for j, i in enumerate(idxs):
            out[i] = GaloreBlockState(basis=new_basis[j], m=m_new[j],
                                      v=v_new[j])
    return out


def manual_refresh(cfg: GaloreConfig, state: GaloreState, refresh_idx,
                   grads: Optional[PyTree] = None) -> GaloreState:
    """Refresh every block basis *now* (round-boundary refresh used by the
    federated engine; the in-step ``count % τ`` path is used by the compiled
    production train step).

    Data-driven (RSVD/SVD of ``grads``) when ``grads`` is given and
    ``refresh_idx < adaptive_steps``; seeded-random otherwise. With
    ``grads=None`` (the engine's seeded-broadcast round boundary) the refresh
    index may be a traced value, so the refresh is jit/scan-safe and the
    fused round program can run it with a scanned round counter. The default
    ``cfg.fused`` execution is shape-bucketed (one vmapped key-derivation +
    QR + transfer per bucket); ``fused=False`` keeps the per-leaf reference
    loop as the parity oracle.
    """
    grads_leaves = None
    if grads is not None:
        # Data-driven refreshes need a *concrete* refresh index (the round
        # number) — the adaptive-vs-random decision is made at trace time.
        refresh_idx_int = int(refresh_idx)
        adaptive = (cfg.refresh_mode != "random"
                    and refresh_idx_int < cfg.adaptive_steps)
        if adaptive:
            grads_leaves = jax.tree_util.tree_leaves(grads)
    refresh_idx = jnp.asarray(refresh_idx, jnp.uint32)

    blk_leaves, treedef = jax.tree_util.tree_flatten(
        state.blocks, is_leaf=lambda x: isinstance(x, (GaloreBlockState,
                                                       DenseMoments)))
    if cfg.fused:
        out = _bucketed_manual_refresh(cfg, blk_leaves, grads_leaves,
                                       refresh_idx, state.seed)
        return GaloreState(count=state.count, seed=state.seed,
                           blocks=jax.tree_util.tree_unflatten(treedef, out))

    out = []
    for block_id, st in enumerate(blk_leaves):
        if not isinstance(st, GaloreBlockState):
            out.append(st)
            continue
        rank = st.basis.shape[-1]
        # Projected buffers are (rows, r) for right-side blocks and (r, cols)
        # for left-side blocks (Appendix A.1 shape summary).
        side = proj.RIGHT if st.m.shape[-1] == rank else proj.LEFT
        keys = _block_keys(state.seed, refresh_idx, block_id,
                           st.basis.shape[:-2])
        if grads_leaves is not None:
            g32 = grads_leaves[block_id].astype(jnp.float32)
            if cfg.use_exact_svd:
                new_basis = proj.svd_basis_nd(g32, rank, side)
            else:
                new_basis = proj.rsvd_basis_nd(g32, rank, side, keys,
                                               cfg.oversample)
        else:
            new_basis = proj.random_basis_nd(keys, st.basis.shape[-2], rank)
        m = proj.reproject(st.m, st.basis, new_basis, side)
        v = jnp.maximum(proj.reproject(st.v, st.basis, new_basis, side), 0.0)
        out.append(GaloreBlockState(basis=new_basis, m=m, v=v))
    return GaloreState(count=state.count, seed=state.seed,
                       blocks=jax.tree_util.tree_unflatten(treedef, out))


def maybe_refresh_instep(cfg: GaloreConfig, state: GaloreState) -> GaloreState:
    """Hoisted in-step refresh for the lift-free local step.

    Fires on the dense path's exact predicate (``count % τ == 0``,
    ``refresh_idx = count // τ``) but *before* the step's forward instead of
    inside the optimizer update — so the delta-aware forward reads (and the
    projected cotangent therefore arrives on) the refreshed basis, which is
    precisely the basis the dense path would project its basis-independent
    dense gradient onto. Equivalent by construction wherever the factored
    client model is valid (refreshes land only where R_i ≡ 0).

    Seeded-random refreshes only (:func:`manual_refresh` with ``grads=None``)
    — callers must not enter the lift-free path when a data-driven refresh
    could fire (``refresh_mode='svd'`` or an in-window adaptive refresh),
    since those need the dense gradient this path never materializes."""
    do = (state.count % cfg.refresh_every) == 0
    idx = state.count // cfg.refresh_every
    return jax.lax.cond(do, lambda s: manual_refresh(cfg, s, idx),
                        lambda s: s, state)


# --------------------------------------------- factored-delta client state --
#
# Within a federated round every GaLoreAdamW local update lives in the shared
# rank-r subspace (the projector refreshes only at local step 0, where the
# round-start delta is identically zero), so a client never needs a dense
# per-client weight copy: its whole trainable state is the factored
# accumulator R_i (shaped like the projected moments) around the broadcast
# global base,
#
#     W_i(t) = base_scale(t) · W_global + lift(R_i(t), B_i),
#     base_scale(t) = (1 - η λ)^t,
#
# with decoupled weight decay absorbed into the scalar ``base_scale`` so the
# delta stays *exactly* rank-r (the dense AdamW recurrence
# W ← (1-ηλ)W - η·lift(ũ) splits leaf-wise into base_scale and R_i because
# the lift is linear). O(r(m+n)) persistent state per client per block
# instead of O(m·n); aggregation closes over ``base_scale·W + Σ wᵢ lift(Rᵢ)``.


def _moment_side(st: GaloreBlockState) -> str:
    """Projected buffers are (rows, r) right / (r, cols) left (Appendix A.1)."""
    return proj.RIGHT if st.m.shape[-1] == st.basis.shape[-1] else proj.LEFT


def all_blocks_projected(state: GaloreState) -> bool:
    """Whether every trainable leaf is a GaLore target block — the
    precondition for the factored-delta client representation (a
    ``DenseMoments`` leaf takes full-rank Adam updates that no rank-r
    accumulator can carry)."""
    leaves = jax.tree_util.tree_leaves(
        state.blocks, is_leaf=lambda x: isinstance(x, (GaloreBlockState,
                                                       DenseMoments)))
    return all(isinstance(s, GaloreBlockState) for s in leaves)


def zero_client_deltas(state: GaloreState) -> PyTree:
    """Round-start factored accumulators R_i = 0, shaped like the projected
    moments (works on concrete states and ``eval_shape`` pytrees alike)."""
    def one(st):
        return jnp.zeros(st.m.shape, jnp.float32)
    return jax.tree_util.tree_map(
        one, state.blocks,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))


def lift_client_trainable(base: PyTree, deltas: PyTree, state: GaloreState,
                          base_scale) -> PyTree:
    """The transient dense weight read ``base_scale·W + lift(R_i, B_i)`` per
    target leaf — the only place a client's dense weights ever materialize
    (inside the local step's forward/backward; never as persistent state)."""
    def one(w0, d, st):
        lifted = proj.project_back(d, st.basis.astype(jnp.float32),
                                   _moment_side(st))
        return (base_scale * w0.astype(jnp.float32) + lifted).astype(w0.dtype)
    return jax.tree_util.tree_map(one, base, deltas, state.blocks)


class LiftFreeGrads(NamedTuple):
    """Lift-free gradient bundle: per-leaf *projected* cotangents (moment
    shape — the delta-aware VJP emits them in rank-r coordinates) plus the
    exact squared dense-gradient norm probes that stand in for the dense
    leaves in global-norm clipping."""
    proj: PyTree    # g̃ per target leaf, shaped like the projected moments
    nsq: PyTree     # ‖dense g‖² per leaf (scalar, or (nb,) for stacked)


def liftfree_params(base: PyTree, deltas: PyTree, nsq: PyTree,
                    state: GaloreState, base_scale) -> PyTree:
    """Build the delta-context trainable tree: each target leaf becomes a
    :class:`models.layers.LowRankDelta` carrying (base W, basis, R̃, norm
    probe, base_scale) — the loss consumes it through ``layers.dense`` /
    ``@`` and neither the lifted weight nor a dense cotangent ever exists.
    ``base_scale`` is broadcast per-layer for stacked (nb, m, n) leaves so
    the node slices cleanly under the model's scan over layers."""
    from ..models.layers import LowRankDelta

    def one(w0, d, ns, st):
        lead = w0.shape[:-2]
        return LowRankDelta(
            w=w0, basis=st.basis.astype(jnp.float32),
            rt=d.astype(jnp.float32), nsq=ns,
            scale=jnp.broadcast_to(jnp.asarray(base_scale, jnp.float32),
                                   lead))
    return jax.tree_util.tree_map(one, base, deltas, nsq, state.blocks)


def liftfree_nsq0(deltas: PyTree) -> PyTree:
    """Zero norm probes, one scalar per target leaf (per layer for stacked
    leaves): the differentiated inputs whose cotangents come back as
    ‖dense g‖² from the delta-aware VJP."""
    return jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape[:-2], jnp.float32), deltas)


def liftfree_value_and_grad(loss_of_params, base: PyTree, deltas: PyTree,
                            state: GaloreState, base_scale):
    """``(loss, LiftFreeGrads)`` for one lift-free local step: differentiate
    the loss wrt the rank-r accumulators (cotangents arrive projected) and
    the norm probes (cotangents arrive as exact dense-grad squared norms).
    The base weights, bases, and scale are closed-over constants — AD never
    touches them, so no dense m×n cotangent exists in the program."""
    def wrapped(dl, ns):
        return loss_of_params(liftfree_params(base, dl, ns, state,
                                              base_scale))
    loss, (gt, nsq) = jax.value_and_grad(wrapped, argnums=(0, 1))(
        deltas, liftfree_nsq0(deltas))
    return loss, LiftFreeGrads(proj=gt, nsq=nsq)


def factored_adamw_step(cfg: GaloreConfig, grads, opt_state, deltas,
                        base_scale, *, lr, weight_decay: float = 0.0,
                        clip_norm: Optional[float] = None):
    """One GaLoreAdamW local step in factored-delta coordinates.

    Mirrors the :func:`galore_adamw` chain (global-norm clip →
    ``scale_by_galore`` → decoupled weight decay → lr) with the ambient lift
    eliminated: the preconditioner emits the *projected* ũ
    (``galore_transform_update(project_back=False)``) and the AdamW weight
    recurrence is applied leaf-wise to the factored state,

        R_i ← R_i − η(ũ + λ R_i),   base_scale ← base_scale − η λ base_scale.

    Requires every trainable leaf to be a target block
    (:func:`all_blocks_projected`) and the basis to be fixed whenever any
    R_i ≠ 0 — i.e. projector refreshes may only fire at local step 0, where
    the round-start accumulators are identically zero (``refresh_every %
    local_steps == 0`` in the runtime; the engine refreshes only at round
    boundaries). Returns ``(new_deltas, new_base_scale, new_opt_state)`` with
    the optimizer state structurally identical to the dense chain's (the 𝒮 /
    install / stacking machinery is representation-agnostic). With a schedule
    ``lr`` the step size reads the chain's ``ScaleByLrState`` count, which is
    batched per client — callers must treat ``base_scale`` as per-client
    (vmap out axis 0); the aggregation consumes it as ``Σ wᵢ sᵢ``.

    ``grads`` may be the dense per-leaf gradients (the transient-lift read)
    or a :class:`LiftFreeGrads` bundle (the lift-free read): projected
    cotangents consumed with the ``Pᵀg`` projection skipped, and global-norm
    clipping driven by the exact dense-norm probes — same arithmetic as
    ``clip_by_global_norm`` on gradients that never materialized."""
    from ..optim.base import ClipState, ScaleByLrState, global_norm
    if isinstance(opt_state, GaloreState):
        states = [opt_state]
    else:
        states = list(opt_state)
    new_states = list(states)
    lift_free = isinstance(grads, LiftFreeGrads)
    if lift_free:
        grads, nsq = grads.proj, grads.nsq
    if clip_norm is not None:
        # Same arithmetic as optim.base.clip_by_global_norm on the dense
        # gradients (the factored path changes the state, not the math).
        if lift_free:
            gnorm = jnp.sqrt(sum(jnp.sum(x)
                                 for x in jax.tree_util.tree_leaves(nsq)))
        else:
            gnorm = global_norm(grads)
        cscale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
        grads = jax.tree_util.tree_map(lambda g: g * cscale, grads)
    gi = next(i for i, s in enumerate(states) if isinstance(s, GaloreState))
    ut, new_states[gi] = galore_transform_update(cfg, grads, states[gi],
                                                 project_back=False,
                                                 projected=lift_free)
    step_lr = None
    for i, s in enumerate(states):
        if isinstance(s, ScaleByLrState):
            step_lr = lr(s.count) if callable(lr) else lr
            new_states[i] = ScaleByLrState(count=s.count + 1)
    if step_lr is None:
        if callable(lr):
            raise ValueError("a schedule lr needs the chain's ScaleByLrState "
                             "to supply the step count")
        step_lr = lr
    new_deltas = jax.tree_util.tree_map(
        lambda d, u: d - step_lr * (u + weight_decay * d), deltas, ut)
    new_scale = base_scale - step_lr * weight_decay * base_scale
    if isinstance(opt_state, GaloreState):
        return new_deltas, new_scale, new_states[0]
    return new_deltas, new_scale, tuple(new_states)


# ----------------------------------------------- client-axis state layout ---
#
# Stacked client optimizer states keep the per-client moments/bases batched
# along axis 0 but ride the GaLore step counter and round seed UNBATCHED:
# they are identical across clients by construction, and a scalar count keeps
# the in-step `count % τ` refresh a real `lax.cond` under the client vmap
# (a batched predicate lowers to a select that computes the RSVD branch every
# local step). These helpers are the single source of truth for that layout,
# shared by the engine, the sharded runtime, and the dry-run.


def map_opt_layout(opt_state, batched: Callable, scalar: Callable = lambda x: x):
    """Map ``batched`` over the per-client leaves of a (possibly chained)
    optimizer state and ``scalar`` over the unbatched GaLore count/seed."""
    def per_state(s):
        if isinstance(s, GaloreState):
            return GaloreState(count=scalar(s.count), seed=scalar(s.seed),
                               blocks=jax.tree_util.tree_map(batched,
                                                             s.blocks))
        return jax.tree_util.tree_map(batched, s)

    if isinstance(opt_state, GaloreState):
        return per_state(opt_state)
    return tuple(per_state(s) for s in opt_state)


def client_opt_axes(opt_state):
    """The vmap in/out axes tree for a client-stacked optimizer state:
    0 everywhere except the GaLore count/seed, which stay scalar."""
    return map_opt_layout(opt_state, batched=lambda _: 0,
                          scalar=lambda _: None)


def stack_opt_state(opt_state, n_clients: int, copy: bool = False):
    """Broadcast one optimizer state along the client axis in the
    unbatched-count/seed layout. ``copy=True`` materializes real per-client
    buffers (for eagerly-held state that will be donated)."""
    def bcast(x):
        out = jnp.broadcast_to(x, (n_clients,) + x.shape)
        return out.copy() if copy else out
    return map_opt_layout(opt_state, batched=bcast)


def chunk_opt_state(opt_state, n_chunks: int, chunk: int):
    """Reshape a client-stacked state (C, …) into chunk-streamed (n_chunks,
    B, …) form for a ``lax.scan`` over cohort chunks. The unbatched
    count/seed are broadcast along the chunk axis (every chunk starts the
    round from the same scalar state) so they can ride the scan xs."""
    return map_opt_layout(
        opt_state,
        batched=lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]),
        scalar=lambda x: jnp.broadcast_to(x, (n_chunks,) + x.shape))


def unchunk_opt_state(opt_state, n_clients: int):
    """Inverse of :func:`chunk_opt_state` on scan-stacked chunk outputs:
    merge (n_chunks, B, …) back to (C, …); collapse the chunk-replicated
    scalars (identical across chunks — each chunk advances the same
    round-start counter by the same T steps)."""
    return map_opt_layout(
        opt_state,
        batched=lambda x: x.reshape((n_clients,) + x.shape[2:]),
        scalar=lambda x: x[0])


# ------------------------------------------------- fed-layer state access ---

def galore_state_of(opt_state) -> GaloreState:
    """Find the GaloreState inside a chained optimizer state."""
    if isinstance(opt_state, GaloreState):
        return opt_state
    for s in opt_state:
        if isinstance(s, GaloreState):
            return s
    raise ValueError("no GaloreState in optimizer state")


def replace_galore_state(opt_state, new: GaloreState):
    if isinstance(opt_state, GaloreState):
        return new
    return tuple(new if isinstance(s, GaloreState) else s for s in opt_state)


def extract_projected_v(state: GaloreState) -> PyTree:
    """The per-block projected second moments ṽ — the client uplink payload."""
    def pick(st):
        return st.v if isinstance(st, GaloreBlockState) else None
    return jax.tree_util.tree_map(
        pick, state.blocks,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))


def extract_bases(state: GaloreState) -> PyTree:
    def pick(st):
        return st.basis if isinstance(st, GaloreBlockState) else None
    return jax.tree_util.tree_map(
        pick, state.blocks,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))


def with_projected_v(state: GaloreState, new_v: PyTree) -> GaloreState:
    """Install server-synchronized ṽ (next-round initialization, Alg. 1 l.13)."""
    def put(st, nv):
        if isinstance(st, GaloreBlockState) and nv is not None:
            return GaloreBlockState(basis=st.basis, m=st.m,
                                    v=jnp.maximum(nv.astype(jnp.float32), 0.0))
        return st
    blocks = jax.tree_util.tree_map(
        put, state.blocks, new_v,
        is_leaf=lambda x: isinstance(x, (GaloreBlockState, DenseMoments)))
    return GaloreState(count=state.count, seed=state.seed, blocks=blocks)


def with_seed(state: GaloreState, seed) -> GaloreState:
    return GaloreState(count=state.count,
                       seed=jnp.asarray(seed, jnp.uint32), blocks=state.blocks)
