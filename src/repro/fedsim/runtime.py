"""Sharded federated runtime: the paper's round as an SPMD program.

Clients live on the (pod, data) mesh axes; each client's trainable copy is
tensor-parallel over the model axis; the frozen base is FSDP-sharded
(identical across clients). One `round_step` call runs the **whole round**
inside the mesh: T local GaLoreAdamW steps per client (lax.scan), factored
aggregation over the client axes, and the server-side state filter 𝒮
(Algorithm 1, line 12) — factored sync of the projected second moments,
broadcast-free O(dim·r) install, seed bump. The round program never drops
out of the mesh onto the host, and the jitted call donates the stacked
client buffers (global trainable + per-client optimizer states), so each
round's outputs reuse the previous round's memory.

Client memory model: with the default ``factored_clients=True`` a client's
round state is the rank-r factored accumulator ``R_i`` around the shared
global base, and with the default ``lift_free=True`` the local step is
**lift-free**: target leaves flow into the model as delta-context nodes
(``models.layers.LowRankDelta``) whose split-matmul apply and projected-
cotangent VJP replace both the per-leaf ``base_scale·W + lift(R_i)``
transient and the dense m×n gradient (``lift_free=False`` keeps the
transient-lift read as the parity oracle; ``refresh_mode='svd'`` forces it —
data-driven refreshes need dense gradients). Decoupled weight decay rides
the scalar ``base_scale`` and 𝒜 collapses to ``base_scale·W + Σ wᵢ
lift(Rᵢ)``, so no dense ``(C, m, n)`` per-client weight stack exists
anywhere in the round program; per-client persistent state is O(r(m+n)) per
block (the projected moments + basis). ``client_chunk=B``
additionally streams the cohort through the round in C/B sequential chunks,
bounding the dense forward/backward working set by B clients and decoupling
cohort size from peak memory (C≈512 rounds on a single host). The stacked
optimizer states ride the GaLore count/seed unbatched (``galore.
stack_opt_state``), keeping the in-step refresh predicate scalar under the
client vmap. ``factored_clients=False`` restores the dense per-client weight
stacks (the parity oracle, and the required fallback when
``refresh_every % local_steps != 0`` would let a mid-round refresh strand a
non-zero accumulator on a stale basis).

The server sync runs **factored** in every default configuration: the
uplinked ṽ are synchronized directly in projected coordinates
(`state_sync.sync_block_synced_factored` on the shared seeded basis;
`state_sync.sync_block_hetero_factored` via r×r transfer Grams when
data-driven refreshes diverge the bases, e.g. ``refresh_mode='svd'``) — no
``(C, m, n)`` lifted view, ``(n, n)`` joint projector, or dense per-client
broadcast is ever materialized. ``factored_sync=False`` restores the dense
lift (the parity oracle), and ``fused_round=False`` restores the legacy
jit-𝒯𝒜 + host-𝒮 round (the eager reference for benchmarks).

:meth:`ShardedFederation.run_rounds` drives K rounds as a single
``lax.scan`` dispatch for benchmark sweeps.

This is the production counterpart of core.fed.FedEngine (which vmaps
clients on a single host).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..launch import steps as steps_lib

PyTree = Any


class ShardedFederation:
    def __init__(self, cfg: ArchConfig, spec: steps_lib.TrainSpec, mesh,
                 n_clients: int, state_sync: str = "ajive", seed: int = 0,
                 factored_sync: bool = True, fused_round: bool = True,
                 factored_clients: bool = True,
                 client_chunk: Optional[int] = None,
                 lift_free: Optional[bool] = None):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self.n_clients = n_clients
        self.state_sync = state_sync
        self.factored_sync = factored_sync
        self.fused_round = fused_round
        self.round_idx = 0

        if client_chunk is not None:
            # Chunks sequentialize the client dim, but each chunk's vmap
            # still maps clients onto the mesh — B must cover the client
            # axes or SPMD lowering fails with an opaque sharding error.
            client_devices = 1
            for a in spec.client_axes:
                if a in mesh.shape:
                    client_devices *= mesh.shape[a]
            if client_chunk % client_devices:
                raise ValueError(
                    f"client_chunk={client_chunk} must be a multiple of the "
                    f"client mesh axes size {client_devices} "
                    f"(axes {spec.client_axes})")

        key = jax.random.PRNGKey(seed)
        self.global_trainable, self.frozen, opt_state = \
            steps_lib.init_train_state(key, cfg, spec)
        # Per-client moments/bases batched on axis 0; GaLore count/seed
        # unbatched (identical across clients — scalar keeps the in-step
        # refresh a real cond under the client vmap).
        self.opt_states = gal.stack_opt_state(opt_state, n_clients,
                                              copy=True)
        # Fused default: 𝒮 + install + seed bump lower inside the round
        # program; the stacked buffers are donated so round k+1's outputs
        # reuse round k's memory. state_sync=None lowers the legacy 𝒯𝒜-only
        # program used by the eager reference path.
        self._round_core = steps_lib.make_fed_round_step(
            cfg, spec, n_clients,
            state_sync=(state_sync if fused_round else None),
            factored_sync=factored_sync,
            factored_clients=factored_clients, client_chunk=client_chunk,
            lift_free=lift_free)
        self._round = jax.jit(self._round_core,
                              donate_argnums=(0, 2) if fused_round else ())
        self._rounds_scan = None

    def run_round(self, batches: PyTree, weights: Optional[jnp.ndarray] = None):
        """batches: pytree with leading (C, T, b, ...) axes."""
        w = (jnp.full((self.n_clients,), 1.0 / self.n_clients)
             if weights is None else weights)
        with self.mesh:
            new_global, out_states, losses, v_upload = self._round(
                self.global_trainable, self.frozen, self.opt_states,
                batches, w)
        self.global_trainable = new_global
        if self.fused_round:
            # 𝒮 already ran in-mesh; the returned states are next-round-ready.
            self.opt_states = out_states
        else:
            self.opt_states = self._sync_and_reinit(out_states, v_upload, w)
        self.round_idx += 1
        return {"losses": losses,
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    def run_rounds(self, batches: PyTree,
                   weights: Optional[jnp.ndarray] = None):
        """K rounds as ONE dispatch: ``lax.scan`` over the in-mesh round.

        batches: pytree with leading (K rounds, C, T, b, ...) axes. Requires
        the fused round (𝒮 must lower inside the scanned program).
        """
        if not self.fused_round:
            raise ValueError("run_rounds requires fused_round=True: the "
                             "legacy round program returns unsynced states "
                             "and would silently skip 𝒮 inside the scan")
        leading = jax.tree_util.tree_leaves(batches)[0].shape
        k_rounds = leading[0]
        w = (jnp.full((self.n_clients,), 1.0 / self.n_clients)
             if weights is None else weights)
        if self._rounds_scan is None:
            def scan_rounds(global_trainable, frozen, opt_states, bat, w):
                def body(carry, round_b):
                    g_tr, states = carry
                    g_tr, states, losses, _ = self._round_core(
                        g_tr, frozen, states, round_b, w)
                    return (g_tr, states), losses
                return jax.lax.scan(body, (global_trainable, opt_states),
                                    bat)
            self._rounds_scan = jax.jit(scan_rounds, donate_argnums=(0, 2))
        with self.mesh:
            (self.global_trainable, self.opt_states), losses = \
                self._rounds_scan(self.global_trainable, self.frozen,
                                  self.opt_states, batches, w)
        self.round_idx += int(k_rounds)
        return {"losses": losses,                          # (K, C, T)
                "mean_final_loss": float(jnp.mean(losses[-1, :, -1]))}

    # ---------------------------------------------- 𝒮 (eager reference) -----
    def _sync_and_reinit(self, out_states, v_upload, w):
        """Host-side 𝒮 of the legacy round: the same server filter as the
        in-mesh tail of the fused round (`steps.sync_client_states`), run
        eagerly between jit boundaries — the reference the fused round is
        benchmarked against."""
        del v_upload    # sync_client_states re-extracts from the states
        return steps_lib.sync_client_states(
            out_states, w, self.n_clients, self.state_sync,
            factored=self.factored_sync, bases_shared=self._bases_shared())

    def _bases_shared(self) -> bool:
        """The shared-basis factored sync requires every client on the
        identical basis. With the production ``refresh_mode='random'`` (or
        'auto' with zero adaptive steps, which never takes the data branch)
        every in-step refresh is seeded-random from the broadcast seed —
        shared by construction. 'svd' refreshes from each client's own
        gradient, so bases diverge and the sync takes the heterogeneous
        factored path (dense per-client lift only with
        ``factored_sync=False``)."""
        return self.spec.refresh_mode != "svd"
