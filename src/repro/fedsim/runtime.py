"""Sharded federated runtime: the paper's round as an SPMD program.

Clients live on the (pod, data) mesh axes; each client's trainable copy is
tensor-parallel over the model axis; the frozen base is FSDP-sharded
(identical across clients). One `round_step` call runs T local GaLoreAdamW
steps per client (lax.scan), FedAvg-aggregates via an all-reduce over the
client axes, and returns the uploaded projected second moments ṽ. The
server-side state filter (Algorithm 1, line 12) then runs per adapted block
and the synchronized state is installed for the next round.

The server sync runs **factored** by default: the uplinked ṽ are synchronized
directly in projected coordinates (`state_sync.sync_block_synced_factored`),
so the round loop never materializes a dense ``(C, m, n)`` lifted view, an
``(n, n)`` joint projector, or a dense per-client broadcast — the installed
state is the O(dim·r) projected buffer. ``factored_sync=False`` restores the
dense lift (the parity oracle).

This is the production counterpart of core.fed.FedEngine (which vmaps
clients on a single host).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..core import projector as proj
from ..core import state_sync as sync_lib
from ..launch import steps as steps_lib

PyTree = Any


class ShardedFederation:
    def __init__(self, cfg: ArchConfig, spec: steps_lib.TrainSpec, mesh,
                 n_clients: int, state_sync: str = "ajive", seed: int = 0,
                 factored_sync: bool = True):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self.n_clients = n_clients
        self.state_sync = state_sync
        self.factored_sync = factored_sync
        self.round_idx = 0

        key = jax.random.PRNGKey(seed)
        self.global_trainable, self.frozen, opt_state = \
            steps_lib.init_train_state(key, cfg, spec)
        self.opt_states = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape).copy(),
            opt_state)
        self._round = jax.jit(
            steps_lib.make_fed_round_step(cfg, spec, n_clients))

    def run_round(self, batches: PyTree, weights: Optional[jnp.ndarray] = None):
        """batches: pytree with leading (C, T, b, ...) axes."""
        w = (jnp.full((self.n_clients,), 1.0 / self.n_clients)
             if weights is None else weights)
        with self.mesh:
            new_global, out_states, losses, v_upload = self._round(
                self.global_trainable, self.frozen, self.opt_states,
                batches, w)
        self.global_trainable = new_global
        self.opt_states = self._sync_and_reinit(out_states, v_upload, w)
        self.round_idx += 1
        return {"losses": losses,
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    # ------------------------------------------------------------- 𝒮 --------
    def _sync_and_reinit(self, out_states, v_upload, w):
        g_stack = gal.galore_state_of(out_states)
        if self.state_sync != "none":
            synced = self._ajive_blocks(g_stack, v_upload, w)
            g_new = gal.with_projected_v(
                jax.tree_util.tree_map(lambda x: x, g_stack), synced)
        else:
            g_new = g_stack
        g_new = gal.GaloreState(
            count=g_new.count, seed=g_new.seed + 1, blocks=g_new.blocks)
        return gal.replace_galore_state(out_states, g_new)

    def _ajive_blocks(self, g_stack, v_upload, w):
        bases = gal.extract_bases(g_stack)
        vs, treedef = jax.tree_util.tree_flatten(v_upload,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(bases, is_leaf=lambda x: x is None)
        out = []
        for v_stack, b_stack in zip(vs, bs):
            if v_stack is None:
                out.append(None)
                continue
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT

            if self.factored_sync and self._bases_shared():
                # Factored 𝒮: sync the (C, ., r) uplink directly; the shared
                # seeded basis cancels, so no (C, m, n) lift and no (n, n)
                # projector. Result is the O(dim·r) projected state.
                synced = jnp.maximum(sync_lib.sync_block_synced_factored(
                    self.state_sync, v_stack, side, w, rank), 0.0)
            else:
                synced = self._dense_sync_block(v_stack, b_stack, w, rank,
                                                side)
            # every client slot shares the synced projected state (a
            # broadcast view of the O(dim·r) buffer, not a dense tensor)
            out.append(jnp.broadcast_to(
                synced[None], (self.n_clients,) + synced.shape))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _bases_shared(self) -> bool:
        """The factored sync requires every client on the identical basis.
        With the production ``refresh_mode='random'`` (or 'auto' with zero
        adaptive steps, which never takes the data branch) every in-step
        refresh is seeded-random from the broadcast seed — shared by
        construction. 'svd' refreshes from each client's own gradient, so
        bases diverge and the sync must take the per-client dense lift."""
        return self.spec.refresh_mode != "svd"

    def _dense_sync_block(self, v_stack, b_stack, w, rank, side):
        """Dense reference 𝒮 (parity oracle): lift each client's ṽ with its
        *own* end-of-round basis (correct under diverged bases), run the
        configured protocol on the lifted views, re-project onto the
        client-0 basis."""
        def sync_one(v_cl, b_cl):
            # v_cl (C, m, r) | (C, r, n); b_cl (C, dim, r)
            v32 = v_cl.astype(jnp.float32)
            b32 = b_cl.astype(jnp.float32)
            if side == proj.RIGHT:
                views = jnp.einsum("kmr,knr->kmn", v32, b32)
            else:
                views = jnp.einsum("kmr,krn->kmn", b32, v32)
            lifted = sync_lib.sync_lifted_views(self.state_sync, views, w,
                                                rank)
            return jnp.maximum(
                sync_lib.project_state(lifted, b_cl[0], side), 0.0)

        if v_stack.ndim == 4:         # stacked scan blocks: (C, nb, ., r)
            return jax.vmap(sync_one, in_axes=(1, 1))(v_stack, b_stack)
        return sync_one(v_stack, b_stack)
