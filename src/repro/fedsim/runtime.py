"""Sharded federated runtime: the paper's round as an SPMD program.

Clients live on the (pod, data) mesh axes; each client's trainable copy is
tensor-parallel over the model axis; the frozen base is FSDP-sharded
(identical across clients). One `round_step` call runs T local GaLoreAdamW
steps per client (lax.scan), FedAvg-aggregates via an all-reduce over the
client axes, and returns the uploaded projected second moments ṽ. The
server-side AJIVE filter (Algorithm 1, line 12) then runs per adapted block
and the synchronized state is installed for the next round.

This is the production counterpart of core.fed.FedEngine (which vmaps
clients on a single host).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..core import projector as proj
from ..core.ajive import ajive_sync
from ..launch import steps as steps_lib

PyTree = Any


class ShardedFederation:
    def __init__(self, cfg: ArchConfig, spec: steps_lib.TrainSpec, mesh,
                 n_clients: int, state_sync: str = "ajive", seed: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self.n_clients = n_clients
        self.state_sync = state_sync
        self.round_idx = 0

        key = jax.random.PRNGKey(seed)
        self.global_trainable, self.frozen, opt_state = \
            steps_lib.init_train_state(key, cfg, spec)
        self.opt_states = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape).copy(),
            opt_state)
        self._round = jax.jit(
            steps_lib.make_fed_round_step(cfg, spec, n_clients))

    def run_round(self, batches: PyTree, weights: Optional[jnp.ndarray] = None):
        """batches: pytree with leading (C, T, b, ...) axes."""
        w = (jnp.full((self.n_clients,), 1.0 / self.n_clients)
             if weights is None else weights)
        with self.mesh:
            new_global, out_states, losses, v_upload = self._round(
                self.global_trainable, self.frozen, self.opt_states,
                batches, w)
        self.global_trainable = new_global
        self.opt_states = self._sync_and_reinit(out_states, v_upload, w)
        self.round_idx += 1
        return {"losses": losses,
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    # ------------------------------------------------------------- 𝒮 --------
    def _sync_and_reinit(self, out_states, v_upload, w):
        g_stack = gal.galore_state_of(out_states)
        if self.state_sync != "none":
            synced = self._ajive_blocks(g_stack, v_upload, w)
            g_new = gal.with_projected_v(
                jax.tree_util.tree_map(lambda x: x, g_stack), synced)
        else:
            g_new = g_stack
        g_new = gal.GaloreState(
            count=g_new.count, seed=g_new.seed + 1, blocks=g_new.blocks)
        return gal.replace_galore_state(out_states, g_new)

    def _ajive_blocks(self, g_stack, v_upload, w):
        bases = gal.extract_bases(g_stack)
        vs, treedef = jax.tree_util.tree_flatten(v_upload,
                                                 is_leaf=lambda x: x is None)
        bs = jax.tree_util.tree_leaves(bases, is_leaf=lambda x: x is None)
        out = []
        for v_stack, b_stack in zip(vs, bs):
            if v_stack is None:
                out.append(None)
                continue
            rank = b_stack.shape[-1]
            side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT
            basis0 = jax.tree_util.tree_map(lambda x: x[0], b_stack)

            def sync_one(v_cl, basis):
                # v_cl (C, m, r) | (C, r, n); basis (dim, r) shared (seeded)
                if side == proj.RIGHT:
                    views = jnp.einsum("kmr,nr->kmn", v_cl, basis)
                else:
                    views = jnp.einsum("mr,krn->kmn", basis, v_cl)
                lifted = ajive_sync(views.astype(jnp.float32), rank=rank,
                                    weights=w)
                if side == proj.RIGHT:
                    return jnp.maximum(lifted @ basis, 0.0)
                return jnp.maximum(basis.T @ lifted, 0.0)

            if v_stack.ndim == 4:     # stacked scan blocks: (C, nb, ., r)
                synced = jax.vmap(sync_one, in_axes=(1, 0))(
                    v_stack, basis0)
            else:
                synced = sync_one(v_stack, basis0)
            # broadcast the synchronized state to every client slot
            out.append(jnp.broadcast_to(
                synced[None], (self.n_clients,) + synced.shape))
        return jax.tree_util.tree_unflatten(treedef, out)
