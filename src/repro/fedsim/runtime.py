"""Sharded federated runtime: the paper's round as an SPMD program.

Clients live on the (pod, data) mesh axes; each client's trainable copy is
tensor-parallel over the model axis; the frozen base is FSDP-sharded
(identical across clients). One `round_step` call runs the **whole round**
inside the mesh: T local GaLoreAdamW steps per client (lax.scan), factored
aggregation over the client axes, and the server-side state filter 𝒮
(Algorithm 1, line 12) — factored sync of the projected second moments,
broadcast-free O(dim·r) install, seed bump. The round program never drops
out of the mesh onto the host, and the jitted call donates the stacked
client buffers (global trainable + per-client optimizer states), so each
round's outputs reuse the previous round's memory.

Client memory model: with the default ``factored_clients=True`` a client's
round state is the rank-r factored accumulator ``R_i`` around the shared
global base, and with the default ``lift_free=True`` the local step is
**lift-free**: target leaves flow into the model as delta-context nodes
(``models.layers.LowRankDelta``) whose split-matmul apply and projected-
cotangent VJP replace both the per-leaf ``base_scale·W + lift(R_i)``
transient and the dense m×n gradient (``lift_free=False`` keeps the
transient-lift read as the parity oracle; ``refresh_mode='svd'`` forces it —
data-driven refreshes need dense gradients). Decoupled weight decay rides
the scalar ``base_scale`` and 𝒜 collapses to ``base_scale·W + Σ wᵢ
lift(Rᵢ)``, so no dense ``(C, m, n)`` per-client weight stack exists
anywhere in the round program; per-client persistent state is O(r(m+n)) per
block (the projected moments + basis). ``client_chunk=B``
additionally streams the cohort through the round in C/B sequential chunks,
bounding the dense forward/backward working set by B clients and decoupling
cohort size from peak memory (C≈512 rounds on a single host). The stacked
optimizer states ride the GaLore count/seed unbatched (``galore.
stack_opt_state``), keeping the in-step refresh predicate scalar under the
client vmap. ``factored_clients=False`` restores the dense per-client weight
stacks (the parity oracle, and the required fallback when
``refresh_every % local_steps != 0`` would let a mid-round refresh strand a
non-zero accumulator on a stale basis).

The server sync runs **factored** in every default configuration: the
uplinked ṽ are synchronized directly in projected coordinates
(`state_sync.sync_block_synced_factored` on the shared seeded basis;
`state_sync.sync_block_hetero_factored` via r×r transfer Grams when
data-driven refreshes diverge the bases, e.g. ``refresh_mode='svd'``) — no
``(C, m, n)`` lifted view, ``(n, n)`` joint projector, or dense per-client
broadcast is ever materialized. ``factored_sync=False`` restores the dense
lift (the parity oracle), and ``fused_round=False`` restores the legacy
jit-𝒯𝒜 + host-𝒮 round (the eager reference for benchmarks).

:meth:`ShardedFederation.run_rounds` drives K rounds as a single
``lax.scan`` dispatch for benchmark sweeps. With the default
``pipeline_sync=True`` (and a method that syncs) the scan runs the
**one-round-deep pipelined schedule**: the body defers round k's 𝒮 to the
top of round k+1's iteration (a raw ``state_sync=None`` round core returns
the unsynced states, which ride the carry), and a post-scan drain runs the
final round's 𝒮 so the returned states match the sequential schedule
state-for-state. This is a pure re-association of the same round math —
round k+1's first local update still consumes round-k *synced* moments, and
the parity suite pins pipelined ≡ sequential bit-tight — but it lets XLA
overlap the r×r sync chain with round k+1's independent gradient work
instead of serializing 𝒮 between rounds. ``pipeline_sync=False`` keeps the
strictly sequential scan as the oracle. Quarantined scans pipeline too: the
raw round core returns its post-screen effective weights
(``return_weights``), which ride the scan carry so the deferred 𝒮 reduces
over exactly the clients the quarantine kept.

This is the production counterpart of core.fed.FedEngine (which vmaps
clients on a single host).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core import galore as gal
from ..core import population as pop_lib
from ..launch import steps as steps_lib

PyTree = Any


class ShardedFederation:
    """``participation`` (a ``core.population.ParticipationConfig``) enables
    the planet-scale participation layer: :meth:`sample_round_mask` draws the
    seeded per-round fault plan, and :meth:`run_round` / :meth:`run_rounds`
    accept per-round participation masks. Masked rounds run a SEPARATELY
    compiled program — same round math on mask-zeroed weights (the
    in-program normalization renormalizes over the participants) plus AJIVE
    joint-basis exclusion of the masked-out clients — so the unmasked
    program stays byte-for-byte what it was before the participation layer,
    and an all-true mask short-circuits onto it (bit-identical by
    construction)."""

    def __init__(self, cfg: ArchConfig, spec: steps_lib.TrainSpec, mesh,
                 n_clients: int, state_sync: str = "ajive", seed: int = 0,
                 factored_sync: bool = True, fused_round: bool = True,
                 factored_clients: bool = True,
                 client_chunk: Optional[int] = None,
                 lift_free: Optional[bool] = None,
                 participation: Optional[
                     pop_lib.ParticipationConfig] = None,
                 robust_agg: str = "none", quarantine: bool = False,
                 quarantine_zmax: float = 6.0, robust_trim: float = 0.2,
                 robust_iters: int = 8, robust_tol: float = 1e-6,
                 bucketed_sync: bool = True,
                 pipeline_sync: bool = True):
        self.cfg = cfg
        self.spec = spec
        self.mesh = mesh
        self.n_clients = n_clients
        self.state_sync = state_sync
        self.factored_sync = factored_sync
        self.fused_round = fused_round
        self.participation = participation
        self.bucketed_sync = bucketed_sync
        self.pipeline_sync = pipeline_sync
        self.quarantine = quarantine
        self.round_idx = 0

        if client_chunk is not None:
            # Chunks sequentialize the client dim, but each chunk's vmap
            # still maps clients onto the mesh — B must cover the client
            # axes or SPMD lowering fails with an opaque sharding error.
            client_devices = 1
            for a in spec.client_axes:
                if a in mesh.shape:
                    client_devices *= mesh.shape[a]
            if client_chunk % client_devices:
                raise ValueError(
                    f"client_chunk={client_chunk} must be a multiple of the "
                    f"client mesh axes size {client_devices} "
                    f"(axes {spec.client_axes})")

        key = jax.random.PRNGKey(seed)
        self.global_trainable, self.frozen, opt_state = \
            steps_lib.init_train_state(key, cfg, spec)
        # Per-client moments/bases batched on axis 0; GaLore count/seed
        # unbatched (identical across clients — scalar keeps the in-step
        # refresh a real cond under the client vmap).
        self.opt_states = gal.stack_opt_state(opt_state, n_clients,
                                              copy=True)
        # Fused default: 𝒮 + install + seed bump lower inside the round
        # program; the stacked buffers are donated so round k+1's outputs
        # reuse round k's memory. state_sync=None lowers the legacy 𝒯𝒜-only
        # program used by the eager reference path.
        # Defense knobs lower INSIDE the round program (steps.
        # make_fed_round_step): quarantine screens the factored uplink and
        # folds failures into the zero-weight mask path; robust_agg swaps
        # the weighted means of 𝒜 AND 𝒮 for robust factored reductions
        # (heterogeneous bases re-based onto client 0 via transfer Grams).
        # Defaults lower the pre-defense program unchanged. The engine-
        # parity (C,) attack-injection operand rides run_round(attack=) —
        # the guarded (exclusion-aware) program applies it to each client's
        # uplink before the screen.
        self._step_kwargs = dict(
            factored_sync=factored_sync, factored_clients=factored_clients,
            client_chunk=client_chunk, lift_free=lift_free,
            robust_agg=robust_agg, quarantine=quarantine,
            quarantine_zmax=quarantine_zmax, robust_trim=robust_trim,
            robust_iters=robust_iters, robust_tol=robust_tol,
            bucketed_sync=bucketed_sync)
        self._robust_sync_kwargs = dict(
            robust_agg=robust_agg, robust_trim=robust_trim,
            robust_iters=robust_iters, robust_tol=robust_tol)
        self._round_core = steps_lib.make_fed_round_step(
            cfg, spec, n_clients,
            state_sync=(state_sync if fused_round else None),
            **self._step_kwargs)
        self._round = jax.jit(self._round_core,
                              donate_argnums=(0, 2) if fused_round else ())
        self._rounds_scan = None
        # Participation-masked variants (built lazily — a federation that
        # never sees a partial mask never compiles them).
        self._round_masked_core = None
        self._round_masked = None
        self._rounds_scan_masked = None
        # Raw (state_sync=None) round core for the pipelined scans: the
        # body defers 𝒮 into the next iteration, so the scanned round must
        # return unsynced states (built lazily).
        self._round_core_raw = None

    # -------------------------------------------------- participation -------
    def sample_round_mask(self, round_idx: Optional[int] = None) -> np.ndarray:
        """The seeded on-time participation mask for ``round_idx`` (default:
        the next round) under this federation's ``participation`` config — a
        pure host function of (config, round), reproducible across per-round
        and scanned drivers and across restarts."""
        if self.participation is None:
            return np.ones(self.n_clients, bool)
        r = self.round_idx if round_idx is None else int(round_idx)
        return pop_lib.sample_cohort(self.participation, self.n_clients, r,
                                     self.n_clients).mask

    def _canon_mask(self, mask):
        if mask is None:
            return None
        m = np.asarray(mask, bool).reshape(-1)
        if m.shape != (self.n_clients,):
            raise ValueError(f"mask shape {m.shape} != cohort "
                             f"({self.n_clients},)")
        if not m.any():
            raise ValueError("participation mask drops every client — a "
                             "round needs >= 1 on-time participant")
        return None if m.all() else m

    def _canon_attack(self, attack):
        """Canonicalize a (C,) per-client corruption-multiplier operand.
        An all-ones vector IS the honest round — short-circuit to None so
        the unmasked program runs, bit-identical to no attack at all. (A
        NaN entry never compares equal to 1, so corrupted vectors always
        reach the guarded program.)"""
        if attack is None:
            return None
        a = np.asarray(attack, np.float32).reshape(-1)
        if a.shape != (self.n_clients,):
            raise ValueError(f"attack shape {a.shape} != cohort "
                             f"({self.n_clients},)")
        return None if bool(np.all(a == 1.0)) else jnp.asarray(a)

    def _masked_round(self):
        if self._round_masked is None:
            self._round_masked_core = steps_lib.make_fed_round_step(
                self.cfg, self.spec, self.n_clients,
                state_sync=(self.state_sync if self.fused_round else None),
                exclude_zero_weights=True, **self._step_kwargs)
            self._round_masked = jax.jit(
                self._round_masked_core,
                donate_argnums=(0, 2) if self.fused_round else ())
        return self._round_masked

    def _base_weights(self, weights):
        return (jnp.full((self.n_clients,), 1.0 / self.n_clients)
                if weights is None else weights)

    def run_round(self, batches: PyTree,
                  weights: Optional[jnp.ndarray] = None, mask=None,
                  attack=None):
        """batches: pytree with leading (C, T, b, ...) axes.

        ``mask`` (optional bool (C,)) marks the round's on-time
        participants: masked-out clients keep their compiled slot but get
        zero effective weight (the in-program normalization renormalizes
        over the participants) and are excluded from the AJIVE joint basis.
        An all-true mask short-circuits onto the unmasked program —
        bit-identical to calling without a mask.

        ``attack`` (optional (C,) float) is the engine-parity per-client
        corruption multiplier (``core.fed.FedEngine.run_round(attack=)``):
        each client's factored uplink — accumulators and projected moments —
        is multiplied by its entry after the local phase, before the
        quarantine screen, inside the SPMD round program. Attacked rounds
        run the exclusion-aware guarded program (zero-weight clients leave
        the AJIVE joint basis — an exact no-op on all-positive weights,
        matching the engine's guarded jit); an all-ones attack
        short-circuits onto the honest program, bit-identical to no attack.
        Requires the fused factored round."""
        mask = self._canon_mask(mask)
        attack = self._canon_attack(attack)
        if attack is not None and not self.fused_round:
            raise ValueError("attack injection requires fused_round=True "
                             "(the legacy host-𝒮 round syncs with pre-"
                             "quarantine weights)")
        w = self._base_weights(weights)
        if mask is None and attack is None:
            round_fn = self._round
        else:
            round_fn = self._masked_round()
            if mask is not None:
                w = w * jnp.asarray(mask, w.dtype)
        extra = () if attack is None else (attack,)
        with self.mesh:
            new_global, out_states, losses, v_upload = round_fn(
                self.global_trainable, self.frozen, self.opt_states,
                batches, w, *extra)
        self.global_trainable = new_global
        if self.fused_round:
            # 𝒮 already ran in-mesh; the returned states are next-round-ready.
            self.opt_states = out_states
        else:
            # Unmasked: raw w, exactly the pre-participation call. Masked:
            # renormalize over participants (mirrors the in-program 𝒜
            # normalization) and exclude the zero-weight clients from 𝒮.
            w_sync = w if mask is None else w / jnp.sum(w)
            self.opt_states = self._sync_and_reinit(
                out_states, v_upload, w_sync, exclude_zero=mask is not None)
        self.round_idx += 1
        return {"losses": losses,
                "mean_final_loss": float(jnp.mean(losses[:, -1]))}

    def run_rounds(self, batches: PyTree,
                   weights: Optional[jnp.ndarray] = None, masks=None):
        """K rounds as ONE dispatch: ``lax.scan`` over the in-mesh round.

        batches: pytree with leading (K rounds, C, T, b, ...) axes. Requires
        the fused round (𝒮 must lower inside the scanned program).

        ``masks`` (optional bool (K, C)) applies per-round participation
        masks: the per-round mask-zeroed weights ride the scan as xs and the
        scanned body is the exclusion-aware masked round. All-true masks
        short-circuit onto the unmasked scan program.

        When :meth:`_pipeline_rounds` holds, the scan is the one-round-deep
        pipelined schedule (see the module docstring): each body syncs the
        *previous* round's states before its local phase and a post-scan
        drain syncs the last round, so results are state-for-state identical
        to the sequential scan while 𝒮 overlaps the next round's gradient
        work.
        """
        if not self.fused_round:
            raise ValueError("run_rounds requires fused_round=True: the "
                             "legacy round program returns unsynced states "
                             "and would silently skip 𝒮 inside the scan")
        leading = jax.tree_util.tree_leaves(batches)[0].shape
        k_rounds = leading[0]
        w = self._base_weights(weights)
        if masks is not None:
            masks = np.asarray(masks, bool)
            if masks.shape != (int(k_rounds), int(self.n_clients)):
                raise ValueError(f"masks shape {masks.shape} != "
                                 f"({k_rounds}, {self.n_clients})")
            if not masks.any(axis=1).all():
                raise ValueError("a round's participation mask drops every "
                                 "client")
            if masks.all():
                masks = None
        pipelined = self._pipeline_rounds()
        if masks is None:
            if self._rounds_scan is None:
                if pipelined:
                    self._raw_round()    # builds _round_core_raw
                    quar = self.quarantine

                    def scan_rounds(global_trainable, frozen, opt_states,
                                    bat, w):
                        sync = self._make_scan_sync(quar)
                        if quar:
                            # Quarantined rounds rewrite the effective
                            # weights inside the round; the raw core
                            # returns them (return_weights) and they ride
                            # the carry so the deferred 𝒮 reduces over the
                            # survivors only — this is what lets the
                            # quarantined scan pipeline one round deep
                            # like the honest path.
                            def body(carry, round_b):
                                g_tr, states, first, w_prev = carry
                                states = jax.lax.cond(
                                    first, lambda s: s,
                                    lambda s: sync(s, w_prev), states)
                                g_tr, states, losses, _, w_eff = \
                                    self._round_core_raw(
                                        g_tr, frozen, states, round_b, w)
                                return (g_tr, states, jnp.zeros((), bool),
                                        w_eff), losses
                            (g_tr, states, _, w_last), losses = jax.lax.scan(
                                body, (global_trainable, opt_states,
                                       jnp.ones((), bool), w), bat)
                            return (g_tr, sync(states, w_last)), losses

                        def body(carry, round_b):
                            g_tr, states, first = carry
                            states = jax.lax.cond(
                                first, lambda s: s, lambda s: sync(s, w),
                                states)
                            g_tr, states, losses, _ = self._round_core_raw(
                                g_tr, frozen, states, round_b, w)
                            return (g_tr, states,
                                    jnp.zeros((), bool)), losses
                        (g_tr, states, _), losses = jax.lax.scan(
                            body, (global_trainable, opt_states,
                                   jnp.ones((), bool)), bat)
                        # Pipeline drain: the last round's 𝒮 never ran in a
                        # body — run it here so the returned states match
                        # the sequential schedule state-for-state.
                        return (g_tr, sync(states, w)), losses
                else:
                    def scan_rounds(global_trainable, frozen, opt_states,
                                    bat, w):
                        def body(carry, round_b):
                            g_tr, states = carry
                            g_tr, states, losses, _ = self._round_core(
                                g_tr, frozen, states, round_b, w)
                            return (g_tr, states), losses
                        return jax.lax.scan(
                            body, (global_trainable, opt_states), bat)
                self._rounds_scan = jax.jit(scan_rounds,
                                            donate_argnums=(0, 2))
            scan_fn, w_arg = self._rounds_scan, w
        else:
            self._masked_round()     # builds _round_masked_core
            if self._rounds_scan_masked is None:
                if pipelined:
                    self._raw_round()    # builds _round_core_raw
                    quar = self.quarantine

                    def scan_rounds_masked(global_trainable, frozen,
                                           opt_states, bat, w_rounds):
                        sync = self._make_scan_sync(True)

                        def body(carry, xs):
                            round_b, w_r = xs
                            g_tr, states, first, w_prev = carry
                            # 𝒮 of the *previous* round uses that round's
                            # mask-zeroed (and, under quarantine, post-
                            # screen effective) weights, carried alongside
                            # the unsynced states.
                            states = jax.lax.cond(
                                first, lambda s: s, lambda s: sync(s, w_prev),
                                states)
                            if quar:
                                g_tr, states, losses, _, w_eff = \
                                    self._round_core_raw(
                                        g_tr, frozen, states, round_b, w_r)
                            else:
                                g_tr, states, losses, _ = \
                                    self._round_core_raw(
                                        g_tr, frozen, states, round_b, w_r)
                                w_eff = w_r
                            return (g_tr, states, jnp.zeros((), bool),
                                    w_eff), losses
                        (g_tr, states, _, w_last), losses = jax.lax.scan(
                            body, (global_trainable, opt_states,
                                   jnp.ones((), bool), w_rounds[0]),
                            (bat, w_rounds))
                        return (g_tr, sync(states, w_last)), losses
                else:
                    def scan_rounds_masked(global_trainable, frozen,
                                           opt_states, bat, w_rounds):
                        def body(carry, xs):
                            round_b, w_r = xs
                            g_tr, states = carry
                            g_tr, states, losses, _ = self._round_masked_core(
                                g_tr, frozen, states, round_b, w_r)
                            return (g_tr, states), losses
                        return jax.lax.scan(
                            body, (global_trainable, opt_states),
                            (bat, w_rounds))
                self._rounds_scan_masked = jax.jit(scan_rounds_masked,
                                                   donate_argnums=(0, 2))
            scan_fn = self._rounds_scan_masked
            w_arg = jnp.asarray(np.asarray(w)[None] * masks, w.dtype)
        with self.mesh:
            (self.global_trainable, self.opt_states), losses = \
                scan_fn(self.global_trainable, self.frozen,
                        self.opt_states, batches, w_arg)
        self.round_idx += int(k_rounds)
        return {"losses": losses,                          # (K, C, T)
                "mean_final_loss": float(jnp.mean(losses[-1, :, -1]))}

    # ------------------------------------------------ pipelined rounds ------
    def _pipeline_rounds(self) -> bool:
        """Whether :meth:`run_rounds` scans the one-round-deep pipelined
        schedule. Requires a fused round whose method actually syncs.
        Quarantined scans pipeline too: the raw round core returns the
        post-screen effective weights (``return_weights``), which ride the
        scan carry so the deferred 𝒮 reproduces the post-quarantine
        weighting exactly."""
        return (self.pipeline_sync and self.fused_round
                and self.state_sync != "none")

    def _raw_round(self):
        """Raw (state_sync=None) round core for the pipelined scans: the
        body defers 𝒮 to the top of the next iteration, so the scanned
        round must return unsynced states. One core serves masked and
        unmasked scans — ``exclude_zero_weights`` only alters the in-round
        sync tail, which the raw core never runs (the deferred
        `_make_scan_sync` carries the exclusion instead). Under quarantine
        the core also returns the round's post-screen effective weights
        for the deferred 𝒮 to consume."""
        if self._round_core_raw is None:
            self._round_core_raw = steps_lib.make_fed_round_step(
                self.cfg, self.spec, self.n_clients, state_sync=None,
                return_weights=self.quarantine, **self._step_kwargs)

    def _make_scan_sync(self, exclude_zero: bool):
        """The deferred 𝒮 + install + seed bump used by the pipelined scan
        bodies and the post-scan drain — exactly the fused round's sync tail
        (`steps.sync_client_states`), applied one round late. Weight
        normalization is internal to the sync protocols, so passing the raw
        (mask-zeroed, or post-quarantine effective) round weights is
        equivalent to the in-round normalized weights."""
        def sync(states, w):
            return steps_lib.sync_client_states(
                states, w, self.n_clients, self.state_sync,
                factored=self.factored_sync,
                bases_shared=self._bases_shared(),
                exclude_zero_weights=exclude_zero,
                bucketed=self.bucketed_sync, **self._robust_sync_kwargs)
        return sync

    # ---------------------------------------------- 𝒮 (eager reference) -----
    def _sync_and_reinit(self, out_states, v_upload, w, exclude_zero=False):
        """Host-side 𝒮 of the legacy round: the same server filter as the
        in-mesh tail of the fused round (`steps.sync_client_states`), run
        eagerly between jit boundaries — the reference the fused round is
        benchmarked against."""
        del v_upload    # sync_client_states re-extracts from the states
        return steps_lib.sync_client_states(
            out_states, w, self.n_clients, self.state_sync,
            factored=self.factored_sync, bases_shared=self._bases_shared(),
            exclude_zero_weights=exclude_zero,
            bucketed=self.bucketed_sync, **self._robust_sync_kwargs)

    def _bases_shared(self) -> bool:
        """The shared-basis factored sync requires every client on the
        identical basis. With the production ``refresh_mode='random'`` (or
        'auto' with zero adaptive steps, which never takes the data branch)
        every in-step refresh is seeded-random from the broadcast seed —
        shared by construction. 'svd' refreshes from each client's own
        gradient, so bases diverge and the sync takes the heterogeneous
        factored path (dense per-client lift only with
        ``factored_sync=False``)."""
        return self.spec.refresh_mode != "svd"
