from .runtime import ShardedFederation

__all__ = ["ShardedFederation"]
