"""jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` mode (the kernel
body executes as traced Python — correctness only); on a real TPU backend
they compile to Mosaic. ``interpret`` is auto-detected from the backend.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .galore_adamw import galore_adamw_step as _galore
from .galore_adamw import galore_precond_step as _galore_precond
from .lowrank_linear import lowrank_linear as _lowrank
from .rwkv6_scan import rwkv6_scan as _rwkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=_interpret())


def galore_adamw_step(w, g, basis, m, v, count, **kw):
    kw.setdefault("interpret", _interpret())
    return _galore(w, g, basis, m, v, count, **kw)


def galore_precond_step(g, basis, m, v, count, **kw):
    kw.setdefault("interpret", _interpret())
    return _galore_precond(g, basis, m, v, count, **kw)


def lowrank_linear(x, w, basis, rt, scale, **kw):
    kw.setdefault("interpret", _interpret())
    return _lowrank(x, w, basis, rt, scale, **kw)


def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk=128):
    return _rwkv6(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())
