"""jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run in ``interpret=True`` mode (the kernel
body executes as traced Python — correctness only); on a real TPU backend
they compile to Mosaic. ``interpret`` is auto-detected from the backend.
"""
from __future__ import annotations

import jax

import jax.numpy as jnp

from .batched_eigh import MAX_JACOBI_DIM
from .batched_eigh import jacobi_eigh as _jacobi_eigh
from .flash_attention import flash_attention as _flash
from .galore_adamw import galore_adamw_step as _galore
from .galore_adamw import galore_precond_step as _galore_precond
from .lowrank_linear import lowrank_linear as _lowrank
from .lowrank_linear import lowrank_linear_batched as _lowrank_batched
from .rwkv6_scan import rwkv6_scan as _rwkv6


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128):
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  block_q=block_q, block_k=block_k, interpret=_interpret())


def galore_adamw_step(w, g, basis, m, v, count, **kw):
    kw.setdefault("interpret", _interpret())
    return _galore(w, g, basis, m, v, count, **kw)


def galore_precond_step(g, basis, m, v, count, **kw):
    kw.setdefault("interpret", _interpret())
    return _galore_precond(g, basis, m, v, count, **kw)


def lowrank_linear(x, w, basis, rt, scale, **kw):
    kw.setdefault("interpret", _interpret())
    return _lowrank(x, w, basis, rt, scale, **kw)


def lowrank_linear_batched(x, w, bases, rts, scales, ids, **kw):
    kw.setdefault("interpret", _interpret())
    return _lowrank_batched(x, w, bases, rts, scales, ids, **kw)


def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk=128):
    return _rwkv6(r, k, v, w, u, s0, chunk=chunk, interpret=_interpret())


def batched_small_eigh(a, *, mask=None, force=None, sweeps=12, block_b=8):
    """Eigendecomposition of a batched symmetric stack ``(..., n, n)``.

    Returns ``(lam, vec)`` ascending, matching ``jnp.linalg.eigh``. Routing:
    on TPU with n ≤ 64 the batched parallel-Jacobi Pallas kernel keeps the
    whole stack VMEM-resident (XLA's QDWH ``eigh`` is built for one large
    matrix, not (B, r, r) stacks); on CPU LAPACK's per-matrix ``syevd`` is
    already optimal, so the jnp path is the default — bit-identical to the
    pre-kernel behavior. ``force`` pins a path for parity tests:
    ``"jacobi"`` (interpret-mode on CPU) or ``"lapack"``.

    ``mask`` (bool, shaped like the batch dims ``a.shape[:-2]``) is the
    quarantine/participation bucket path: masked entries are solved as the
    identity (their payload never reaches the solver — both Jacobi rotations
    and LAPACK propagate a single NaN across the whole slice) and their
    eigenvalues are returned as exact zeros, so rank-revealing floors
    downstream drop the directions. The select is elementwise, so an
    all-true mask is bitwise identical to ``mask=None``.
    """
    n = a.shape[-1]
    if mask is not None:
        sel = jnp.asarray(mask, bool)[..., None, None]
        a = jnp.where(sel, a, jnp.eye(n, dtype=a.dtype))
    use_jacobi = (force == "jacobi" or
                  (force is None and not _interpret() and n <= MAX_JACOBI_DIM))
    if force == "lapack":
        use_jacobi = False
    if use_jacobi:
        lam, vec = _jacobi_eigh(a, sweeps=sweeps, block_b=block_b,
                                interpret=_interpret())
    else:
        lam, vec = jnp.linalg.eigh(a)
    if mask is not None:
        lam = jnp.where(jnp.asarray(mask, bool)[..., None], lam,
                        jnp.zeros((), lam.dtype))
    return lam, vec
