"""Fused lift-free low-rank linear apply — the factored client weight read.

A factored client's effective weight is ``W_eff = scale·W + lift(R̃, B)``
(rank-r delta ``R̃`` around the broadcast base ``W``). Materializing
``W_eff`` costs an O(m·n·r) lift GEMM plus an O(m·n) transient buffer per
target leaf per local step. This kernel computes the *apply* instead,

  right-projected block (m ≥ n; basis B (n, r), delta R̃ (m, r)):
      y = scale·(x @ W) + (x @ R̃) @ Bᵀ
  left-projected block (m < n; basis B (m, r), delta R̃ (r, n)):
      y = scale·(x @ W) + (x @ B) @ R̃

as split matmuls — O(t·r·(m+n)) extra work on top of the unavoidable base
GEMM, with the dense ``m×n`` lifted weight never existing. One VMEM-resident
pass per row tile of ``x``: the base GEMM, both split GEMMs, and the scaled
add all happen before the tile's output leaves VMEM.

Grid handling mirrors ``galore_adamw.py``: the tile count is
``ceil(t / block)`` (``pl.cdiv``) with the trailing partial tile masked by
Pallas block clipping — no divisibility requirement on the token dim.

The kernel is the *forward* of the lift-free delta read; its backward (the
projected-cotangent VJP — grad wrt R̃ arrives already in rank-r coordinates)
lives in ``models.layers.lowrank_apply``, which consumes this kernel via
``ops.lowrank_linear`` on TPU.

``lowrank_linear_batched`` is the *serving* variant of the same apply: one
decode batch where every row carries its own adapter — the S-LoRA/Punica
shape. The base GEMM is shared across the batch; each grid program gathers
its row's ``(basis_g, R̃_g, scale_g)`` blocks by the scalar-prefetched
``(B,)`` adapter-id operand (the id indexes the BlockSpec ``index_map``, so
only the selected adapter's factors are ever DMA'd — the ``(G, ·, r)``
tables stay put no matter how many fine-tunes are resident). Ragged
per-adapter ranks are handled upstream by zero-padding factors to the
table's r_max: zero basis/R̃ columns contribute exactly zero delta.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RIGHT = "right"
LEFT = "left"


def infer_side(w_shape, basis_shape, rt_shape) -> str:
    """Recover the projection side from buffer shapes (Appendix A.1 layout:
    right ⇒ basis (n, r), delta (m, r); left ⇒ basis (m, r), delta (r, n))."""
    mm, nn = w_shape[-2:]
    dim, r = basis_shape[-2:]
    if dim == nn and rt_shape[-2:] == (mm, r):
        return RIGHT
    if dim == mm and rt_shape[-2:] == (r, nn):
        return LEFT
    raise ValueError(f"inconsistent lowrank shapes: w {w_shape}, "
                     f"basis {basis_shape}, rt {rt_shape}")


def _kernel(scale_ref, x_ref, w_ref, basis_ref, rt_ref, y_out, *, side):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    basis = basis_ref[...].astype(jnp.float32)
    rt = rt_ref[...].astype(jnp.float32)
    if side == RIGHT:
        # (bt, m) @ (m, r) @ (r, n)
        delta = jnp.dot(jnp.dot(x, rt, preferred_element_type=jnp.float32),
                        basis.T, preferred_element_type=jnp.float32)
    else:
        # (bt, m) @ (m, r) @ (r, n)
        delta = jnp.dot(jnp.dot(x, basis, preferred_element_type=jnp.float32),
                        rt, preferred_element_type=jnp.float32)
    y_out[...] = (scale_ref[0, 0] * base + delta).astype(y_out.dtype)


@functools.partial(jax.jit, static_argnames=("side", "block_rows",
                                             "interpret"))
def lowrank_linear(x, w, basis, rt, scale, *, side=None, block_rows=128,
                   interpret=False):
    """Fused ``y = scale·(x @ w) + split-matmul(x, basis, rt)`` for one block.

    x (..., t, m); w (m, n); right side: basis (n, r), rt (m, r); left side:
    basis (m, r), rt (r, n). ``scale`` is the scalar base multiplier
    (``base_scale = (1-ηλ)^t``). Returns y (..., t, n) in the base-GEMM
    result dtype; fp32 accumulation throughout.
    """
    side = side or infer_side(w.shape, basis.shape, rt.shape)
    lead = x.shape[:-1]
    mm, nn = w.shape
    x2 = x.reshape((-1, mm))
    t = x2.shape[0]
    bt = min(block_rows, t)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    r = basis.shape[-1]
    bshape = (nn, r) if side == RIGHT else (mm, r)
    rshape = (mm, r) if side == RIGHT else (r, nn)
    y = pl.pallas_call(
        functools.partial(_kernel, side=side),
        grid=(pl.cdiv(t, bt),),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),   # scale (SMEM-like)
                  pl.BlockSpec((bt, mm), lambda i: (i, 0)),
                  pl.BlockSpec((mm, nn), lambda i: (0, 0)),
                  pl.BlockSpec(bshape, lambda i: (0, 0)),
                  pl.BlockSpec(rshape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bt, nn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, nn), out_dtype),
        interpret=interpret,
    )(jnp.full((1, 1), scale, jnp.float32), x2, w, basis, rt)
    return y.reshape(lead + (nn,))


# ------------------------------------------- batched heterogeneous adapters --

def _batched_kernel(ids_ref, x_ref, w_ref, basis_ref, rt_ref, scale_ref,
                    y_out, *, side):
    """One grid program = one sequence's row tile. The adapter-dependent
    operands (basis/rt/scale) arrive already gathered: their BlockSpec
    index_maps consumed the scalar-prefetched ids, so block 0 here IS
    adapter ``ids[b]``'s block."""
    del ids_ref
    x = x_ref[0].astype(jnp.float32)              # (bt, m)
    w = w_ref[...].astype(jnp.float32)
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    basis = basis_ref[0].astype(jnp.float32)
    rt = rt_ref[0].astype(jnp.float32)
    if side == RIGHT:
        delta = jnp.dot(jnp.dot(x, rt, preferred_element_type=jnp.float32),
                        basis.T, preferred_element_type=jnp.float32)
    else:
        delta = jnp.dot(jnp.dot(x, basis, preferred_element_type=jnp.float32),
                        rt, preferred_element_type=jnp.float32)
    y_out[0] = (scale_ref[0] * base + delta).astype(y_out.dtype)


@functools.partial(jax.jit, static_argnames=("side", "block_t", "interpret"))
def lowrank_linear_batched(x, w, bases, rts, scales, ids, *, side=None,
                           block_t=128, interpret=False):
    """Per-row heterogeneous-adapter apply for one shared base block.

    x (B, t, m) or (B, m); w (m, n) shared base; bases (G, n, r) right /
    (G, m, r) left; rts (G, m, r) right / (G, r, n) left; scales (G,)
    per-adapter base multipliers; ids (B,) int32 adapter index per row.
    Returns ``y[b] = scales[ids[b]]·(x[b] @ w) + split-matmul(x[b],
    bases[ids[b]], rts[ids[b]])`` — one compiled program regardless of G,
    duplicate ids welcome. The token dim tiles by ``block_t`` (ceil-div
    grid, trailing partial tile masked by Pallas block clipping).
    """
    squeeze_t = x.ndim == 2
    if squeeze_t:
        x = x[:, None, :]
    b, t, mm = x.shape
    nn = w.shape[-1]
    side = side or infer_side(w.shape, bases.shape[1:], rts.shape[1:])
    r = bases.shape[-1]
    bshape = (1, nn, r) if side == RIGHT else (1, mm, r)
    rshape = (1, mm, r) if side == RIGHT else (1, r, nn)
    bt = min(block_t, t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, pl.cdiv(t, bt)),
        in_specs=[
            pl.BlockSpec((1, bt, mm), lambda i, j, ids: (i, j, 0)),
            pl.BlockSpec((mm, nn), lambda i, j, ids: (0, 0)),
            pl.BlockSpec(bshape, lambda i, j, ids: (ids[i], 0, 0)),
            pl.BlockSpec(rshape, lambda i, j, ids: (ids[i], 0, 0)),
            pl.BlockSpec((1,), lambda i, j, ids: (ids[i],)),
        ],
        out_specs=pl.BlockSpec((1, bt, nn), lambda i, j, ids: (i, j, 0)),
    )
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    y = pl.pallas_call(
        functools.partial(_batched_kernel, side=side),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, nn), out_dtype),
        interpret=interpret,
    )(jnp.asarray(ids, jnp.int32), x, w, bases,
      rts, jnp.asarray(scales, jnp.float32))
    return y[:, 0, :] if squeeze_t else y
