"""Fused lift-free low-rank linear apply — the factored client weight read.

A factored client's effective weight is ``W_eff = scale·W + lift(R̃, B)``
(rank-r delta ``R̃`` around the broadcast base ``W``). Materializing
``W_eff`` costs an O(m·n·r) lift GEMM plus an O(m·n) transient buffer per
target leaf per local step. This kernel computes the *apply* instead,

  right-projected block (m ≥ n; basis B (n, r), delta R̃ (m, r)):
      y = scale·(x @ W) + (x @ R̃) @ Bᵀ
  left-projected block (m < n; basis B (m, r), delta R̃ (r, n)):
      y = scale·(x @ W) + (x @ B) @ R̃

as split matmuls — O(t·r·(m+n)) extra work on top of the unavoidable base
GEMM, with the dense ``m×n`` lifted weight never existing. One VMEM-resident
pass per row tile of ``x``: the base GEMM, both split GEMMs, and the scaled
add all happen before the tile's output leaves VMEM.

Grid handling mirrors ``galore_adamw.py``: the tile count is
``ceil(t / block)`` (``pl.cdiv``) with the trailing partial tile masked by
Pallas block clipping — no divisibility requirement on the token dim.

The kernel is the *forward* of the lift-free delta read; its backward (the
projected-cotangent VJP — grad wrt R̃ arrives already in rank-r coordinates)
lives in ``models.layers.lowrank_apply``, which consumes this kernel via
``ops.lowrank_linear`` on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RIGHT = "right"
LEFT = "left"


def infer_side(w_shape, basis_shape, rt_shape) -> str:
    """Recover the projection side from buffer shapes (Appendix A.1 layout:
    right ⇒ basis (n, r), delta (m, r); left ⇒ basis (m, r), delta (r, n))."""
    mm, nn = w_shape[-2:]
    dim, r = basis_shape[-2:]
    if dim == nn and rt_shape[-2:] == (mm, r):
        return RIGHT
    if dim == mm and rt_shape[-2:] == (r, nn):
        return LEFT
    raise ValueError(f"inconsistent lowrank shapes: w {w_shape}, "
                     f"basis {basis_shape}, rt {rt_shape}")


def _kernel(scale_ref, x_ref, w_ref, basis_ref, rt_ref, y_out, *, side):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    basis = basis_ref[...].astype(jnp.float32)
    rt = rt_ref[...].astype(jnp.float32)
    if side == RIGHT:
        # (bt, m) @ (m, r) @ (r, n)
        delta = jnp.dot(jnp.dot(x, rt, preferred_element_type=jnp.float32),
                        basis.T, preferred_element_type=jnp.float32)
    else:
        # (bt, m) @ (m, r) @ (r, n)
        delta = jnp.dot(jnp.dot(x, basis, preferred_element_type=jnp.float32),
                        rt, preferred_element_type=jnp.float32)
    y_out[...] = (scale_ref[0, 0] * base + delta).astype(y_out.dtype)


@functools.partial(jax.jit, static_argnames=("side", "block_rows",
                                             "interpret"))
def lowrank_linear(x, w, basis, rt, scale, *, side=None, block_rows=128,
                   interpret=False):
    """Fused ``y = scale·(x @ w) + split-matmul(x, basis, rt)`` for one block.

    x (..., t, m); w (m, n); right side: basis (n, r), rt (m, r); left side:
    basis (m, r), rt (r, n). ``scale`` is the scalar base multiplier
    (``base_scale = (1-ηλ)^t``). Returns y (..., t, n) in the base-GEMM
    result dtype; fp32 accumulation throughout.
    """
    side = side or infer_side(w.shape, basis.shape, rt.shape)
    lead = x.shape[:-1]
    mm, nn = w.shape
    x2 = x.reshape((-1, mm))
    t = x2.shape[0]
    bt = min(block_rows, t)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    r = basis.shape[-1]
    bshape = (nn, r) if side == RIGHT else (mm, r)
    rshape = (mm, r) if side == RIGHT else (r, nn)
    y = pl.pallas_call(
        functools.partial(_kernel, side=side),
        grid=(pl.cdiv(t, bt),),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),   # scale (SMEM-like)
                  pl.BlockSpec((bt, mm), lambda i: (i, 0)),
                  pl.BlockSpec((mm, nn), lambda i: (0, 0)),
                  pl.BlockSpec(bshape, lambda i: (0, 0)),
                  pl.BlockSpec(rshape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bt, nn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, nn), out_dtype),
        interpret=interpret,
    )(jnp.full((1, 1), scale, jnp.float32), x2, w, basis, rt)
    return y.reshape(lead + (nn,))
