"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each reference is the mathematically-plain implementation with fp32
accumulation — the kernels must match these on CPU (interpret=True) across
the shape/dtype sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def galore_adamw_ref(w, g, basis, m, v, *, count, b1=0.9, b2=0.999, eps=1e-8,
                     lr=1e-3, weight_decay=0.0):
    """Fused right-projection GaLoreAdamW step for one block.

    w (M, N) params; g (M, N) dense gradient; basis (N, r); m, v (M, r)
    projected fp32 moments; count = post-increment step (for bias correction).
    Returns (new_w, new_m, new_v).
    """
    g32 = g.astype(jnp.float32)
    gt = g32 @ basis.astype(jnp.float32)                  # (M, r)
    m_new = b1 * m + (1 - b1) * gt
    v_new = b2 * v + (1 - b2) * gt * gt
    c = jnp.asarray(count, jnp.float32)
    c1 = 1 - b1 ** c
    c2 = 1 - b2 ** c
    ut = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)      # (M, r)
    u = ut @ basis.astype(jnp.float32).T                  # (M, N)
    w32 = w.astype(jnp.float32)
    w_new = w32 - lr * u - lr * weight_decay * w32
    return w_new.astype(w.dtype), m_new, v_new


def lowrank_linear_ref(x, w, basis, rt, scale, *, side):
    """Lift-free low-rank linear apply for one factored block.

    x (..., t, m); w (m, n); right: basis (n, r), rt (m, r) —
    ``y = scale·(x@w) + (x@rt)@basisᵀ``; left: basis (m, r), rt (r, n) —
    ``y = scale·(x@w) + (x@basis)@rt``. fp32 accumulation; result in the
    base-GEMM dtype. Mathematically ``x @ (scale·w + lift(rt, basis))``
    with the dense lifted weight never materialized.
    """
    x32 = x.astype(jnp.float32)
    base = scale * (x32 @ w.astype(jnp.float32))
    b32 = basis.astype(jnp.float32)
    r32 = rt.astype(jnp.float32)
    if side == "right":
        delta = (x32 @ r32) @ b32.T
    else:
        delta = (x32 @ b32) @ r32
    return (base + delta).astype(jnp.result_type(x.dtype, w.dtype))


def lowrank_linear_batched_ref(x, w, bases, rts, scales, ids, *, side):
    """Per-row heterogeneous-adapter apply (the serving batch shape).

    x (B, t, m) or (B, m); w (m, n) shared base; bases/rts/scales are
    (G, ·, ·)/(G,) adapter tables; ids (B,) selects each row's adapter:
    ``y[b] = scales[ids[b]]·(x[b]@w) + split-matmul(x[b], bases[ids[b]],
    rts[ids[b]])``. Plain gather + einsum with fp32 accumulation — the
    allclose target for the scalar-prefetch Pallas kernel.
    """
    squeeze_t = x.ndim == 2
    x3 = (x[:, None, :] if squeeze_t else x).astype(jnp.float32)
    ids = jnp.asarray(ids, jnp.int32)
    s = jnp.asarray(scales, jnp.float32)[ids][:, None, None]
    base = s * (x3 @ w.astype(jnp.float32))
    bg = bases.astype(jnp.float32)[ids]
    rg = rts.astype(jnp.float32)[ids]
    if side == "right":
        delta = jnp.einsum("btr,bnr->btn", jnp.einsum("btm,bmr->btr", x3, rg),
                           bg)
    else:
        delta = jnp.einsum("btr,brn->btn", jnp.einsum("btm,bmr->btr", x3, bg),
                           rg)
    y = (base + delta).astype(jnp.result_type(x.dtype, w.dtype))
    return y[:, 0, :] if squeeze_t else y


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q (B, Lq, H, D), k/v (B, Lk, Hkv, D), GQA by head grouping."""
    b, lq, h, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    groups = h // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d)
    qg = q.reshape(b, lq, hkv, groups, d).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(lq)[:, None] + (lk - lq)
        kpos = jnp.arange(lk)[None, :]
        mask = kpos <= qpos
        if window:
            mask &= (qpos - kpos) < window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, lq, h, d).astype(q.dtype)


def rwkv6_scan_ref(r, k, v, w, u, s0=None):
    """RWKV6 WKV recurrence. r,k,v,w (B, L, H, D); u (H, D); s0 (B, H, D, D).

        y_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

    Returns (y (B, L, H, D), s_final).
    """
    b, l, h, d = r.shape
    s = (jnp.zeros((b, h, d, d), jnp.float32) if s0 is None
         else s0.astype(jnp.float32))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t.astype(jnp.float32),
                        v_t.astype(jnp.float32))
        y = jnp.einsum("bhk,bhkv->bhv", r_t.astype(jnp.float32),
                       s + u[None, :, :, None] * kv)
        s = w_t.astype(jnp.float32)[..., None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s
