"""Fused GaLoreAdamW Pallas TPU kernels.

On GPU, GaLore is three GEMMs + elementwise ops with HBM round-trips between
them (project -> Adam update -> project-back -> weight update). These kernels
fuse the whole optimizer step for one weight block into a single VMEM-
resident pass, tiled over the block's long axis:

  right-projected block (basis B (N, r), moments (M, r)), per row-tile (bm, N):
    g̃  = g_i @ B            (MXU;  B stays resident across the grid)
    m̃  = β₁ m̃ + (1-β₁) g̃     (VPU)
    ṽ  = β₂ ṽ + (1-β₂) g̃²    (VPU)
    ũ  = m̂ / (√v̂ + ε)        (VPU, bias-corrected)
    u  = ũ @ Bᵀ              (MXU)
    w_i ← w_i − η u − η λ w_i

  left-projected block (basis B (M, r), moments (r, N)) is the transpose
  problem: the grid tiles *columns* (M, bn) and the two GEMMs become
  g̃ = Bᵀ g_j and u = B ũ, with B resident.

HBM traffic: read w, g once; write w once; m̃/ṽ are O(long_dim·r) — the dense
(M, N) gradient never round-trips between optimizer stages.

Grid handling: the tile count is ``ceil(dim / block)`` (``pl.cdiv``) — the
trailing partial tile is masked by Pallas block clipping (out-of-range reads
are padded, out-of-range writes dropped; every output element depends only on
its own row/column tile, so padding never contaminates valid lanes). There is
no divisibility requirement on M or N.

Two entry points:

* :func:`galore_adamw_step` — the full fused step ``(w, m, v) -> (w', m', v')``
  including the ambient AdamW weight update (lr + decoupled weight decay).
* :func:`galore_precond_step` — the preconditioning-only variant
  ``(g, m, v) -> (u, m', v')`` returning the ambient update direction; this is
  what ``core.galore.scale_by_galore`` wires into its chained-transformation
  hot path (weight decay / lr are applied by the rest of the chain).

Both accept stacked 3-D blocks ``(nb, M, N)`` (per-layer bases/moments with a
leading layer dim) by vmapping the 2-D kernel — under ``jax.vmap`` the batch
dim becomes an extra grid dimension, not a Python loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

RIGHT = "right"
LEFT = "left"


def _adam_update(gt, m_ref, v_ref, count_ref, b1, b2, eps, bias_correction):
    """Shared Adam moment update + (optionally bias-corrected) direction."""
    m = b1 * m_ref[...] + (1.0 - b1) * gt
    v = b2 * v_ref[...] + (1.0 - b2) * gt * gt
    if bias_correction:
        c = count_ref[0, 0]
        c1 = 1.0 - b1 ** c
        c2 = 1.0 - b2 ** c
    else:
        c1 = c2 = 1.0
    ut = (m / c1) / (jnp.sqrt(v / c2) + eps)
    return m, v, ut


def _project(g, basis, side):
    if side == RIGHT:
        return jnp.dot(g, basis, preferred_element_type=jnp.float32)
    return jnp.dot(basis.T, g, preferred_element_type=jnp.float32)


def _project_back(ut, basis, side):
    if side == RIGHT:
        return jnp.dot(ut, basis.T, preferred_element_type=jnp.float32)
    return jnp.dot(basis, ut, preferred_element_type=jnp.float32)


def _step_kernel(count_ref, w_ref, g_ref, basis_ref, m_ref, v_ref,
                 w_out, m_out, v_out, *, side, b1, b2, eps, lr, weight_decay,
                 bias_correction):
    g = g_ref[...].astype(jnp.float32)
    basis = basis_ref[...].astype(jnp.float32)
    gt = _project(g, basis, side)
    m, v, ut = _adam_update(gt, m_ref, v_ref, count_ref, b1, b2, eps,
                            bias_correction)
    u = _project_back(ut, basis, side)
    w = w_ref[...].astype(jnp.float32)
    w_out[...] = (w - lr * u - lr * weight_decay * w).astype(w_out.dtype)
    m_out[...] = m
    v_out[...] = v


def _precond_kernel(count_ref, g_ref, basis_ref, m_ref, v_ref,
                    u_out, m_out, v_out, *, side, b1, b2, eps,
                    bias_correction, project_back=True):
    g = g_ref[...].astype(jnp.float32)
    basis = basis_ref[...].astype(jnp.float32)
    gt = _project(g, basis, side)
    m, v, ut = _adam_update(gt, m_ref, v_ref, count_ref, b1, b2, eps,
                            bias_correction)
    u_out[...] = _project_back(ut, basis, side) if project_back else ut
    m_out[...] = m
    v_out[...] = v


def infer_side(w_shape, basis_shape, m_shape) -> str:
    """Recover the projection side from buffer shapes (Appendix A.1 layout:
    right ⇒ basis (N, r), moments (M, r); left ⇒ basis (M, r), moments (r, N)).
    Square blocks with r == M are genuinely ambiguous and default to right —
    the ``proj_type=std`` convention."""
    mm, nn = w_shape[-2:]
    dim, r = basis_shape[-2:]
    if dim == nn and m_shape[-2:] == (mm, r):
        return RIGHT
    if dim == mm and m_shape[-2:] == (r, nn):
        return LEFT
    raise ValueError(f"inconsistent galore shapes: w {w_shape}, "
                     f"basis {basis_shape}, m {m_shape}")


def _block_specs(side, mm, nn, r, block):
    """Grid + BlockSpecs for one 2-D block. ``block`` tiles rows (right) or
    columns (left); the grid is ceil-div so non-divisible dims get a masked
    tail tile instead of an assertion."""
    if side == RIGHT:
        bm = min(block, mm)
        grid = (pl.cdiv(mm, bm),)
        wg = pl.BlockSpec((bm, nn), lambda i: (i, 0))
        basis = pl.BlockSpec((nn, r), lambda i: (0, 0))
        mv = pl.BlockSpec((bm, r), lambda i: (i, 0))
    else:
        bn = min(block, nn)
        grid = (pl.cdiv(nn, bn),)
        wg = pl.BlockSpec((mm, bn), lambda j: (0, j))
        basis = pl.BlockSpec((mm, r), lambda j: (0, 0))
        mv = pl.BlockSpec((r, bn), lambda j: (0, j))
    return grid, wg, basis, mv


@functools.partial(jax.jit, static_argnames=("side", "b1", "b2", "eps", "lr",
                                             "weight_decay", "block_rows",
                                             "interpret", "bias_correction"))
def galore_adamw_step(w, g, basis, m, v, count, *, side=None, b1=0.9, b2=0.999,
                      eps=1e-8, lr=1e-3, weight_decay=0.0,
                      block_rows=128, interpret=False, bias_correction=True):
    """One fused GaLoreAdamW step for a projected block.

    Right side: w, g (M, N); basis (N, r); m, v (M, r) fp32.
    Left side:  w, g (M, N); basis (M, r); m, v (r, N) fp32.
    Stacked 3-D blocks carry a leading layer dim on every buffer.
    count = post-increment step (bias correction). Returns (w', m', v').
    """
    side = side or infer_side(w.shape, basis.shape, m.shape)
    if w.ndim > 2:
        fn = functools.partial(galore_adamw_step, side=side, b1=b1, b2=b2,
                               eps=eps, lr=lr, weight_decay=weight_decay,
                               block_rows=block_rows, interpret=interpret,
                               bias_correction=bias_correction)
        return jax.vmap(lambda ww, gg, bb, mm_, vv: fn(ww, gg, bb, mm_, vv,
                                                       count))(w, g, basis, m, v)

    mm, nn = w.shape
    r = basis.shape[-1]
    grid, wg_spec, basis_spec, mv_spec = _block_specs(side, mm, nn, r,
                                                      block_rows)
    count_arr = jnp.full((1, 1), count, jnp.float32)
    kernel = functools.partial(_step_kernel, side=side, b1=b1, b2=b2, eps=eps,
                               lr=lr, weight_decay=weight_decay,
                               bias_correction=bias_correction)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),  # count (SMEM-like)
                  wg_spec, wg_spec, basis_spec, mv_spec, mv_spec],
        out_specs=[wg_spec, mv_spec, mv_spec],
        out_shape=[jax.ShapeDtypeStruct(w.shape, w.dtype),
                   jax.ShapeDtypeStruct(m.shape, jnp.float32),
                   jax.ShapeDtypeStruct(v.shape, jnp.float32)],
        interpret=interpret,
    )(count_arr, w, g, basis, m, v)


@functools.partial(jax.jit, static_argnames=("side", "b1", "b2", "eps",
                                             "block_rows", "interpret",
                                             "bias_correction",
                                             "project_back"))
def galore_precond_step(g, basis, m, v, count, *, side=None, b1=0.9, b2=0.999,
                        eps=1e-8, block_rows=128, interpret=False,
                        bias_correction=True, project_back=True):
    """Fused project → Adam → project-back, returning the ambient update
    direction u (fp32) instead of applying it — the ``scale_by_galore`` hot
    path (lr / weight decay live elsewhere in the optimizer chain).

    Shapes as :func:`galore_adamw_step`; returns (u (M, N) fp32, m', v').
    ``project_back=False`` skips the final lift GEMM and returns the
    *projected* ũ in the moment shape ((M, r) right / (r, N) left) — the
    factored-delta client path, whose rank-r accumulator consumes ũ directly
    and never round-trips the dense (M, N) update through HBM.
    """
    side = side or infer_side(g.shape, basis.shape, m.shape)
    if g.ndim > 2:
        fn = functools.partial(galore_precond_step, side=side, b1=b1, b2=b2,
                               eps=eps, block_rows=block_rows,
                               interpret=interpret,
                               bias_correction=bias_correction,
                               project_back=project_back)
        return jax.vmap(lambda gg, bb, mm_, vv: fn(gg, bb, mm_, vv,
                                                   count))(g, basis, m, v)

    mm, nn = g.shape[-2:]
    r = basis.shape[-1]
    grid, wg_spec, basis_spec, mv_spec = _block_specs(side, mm, nn, r,
                                                      block_rows)
    count_arr = jnp.full((1, 1), count, jnp.float32)
    kernel = functools.partial(_precond_kernel, side=side, b1=b1, b2=b2,
                               eps=eps, bias_correction=bias_correction,
                               project_back=project_back)
    u_spec = wg_spec if project_back else mv_spec
    u_shape = g.shape if project_back else m.shape
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  wg_spec, basis_spec, mv_spec, mv_spec],
        out_specs=[u_spec, mv_spec, mv_spec],
        out_shape=[jax.ShapeDtypeStruct(u_shape, jnp.float32),
                   jax.ShapeDtypeStruct(m.shape, jnp.float32),
                   jax.ShapeDtypeStruct(v.shape, jnp.float32)],
        interpret=interpret,
    )(count_arr, g, basis, m, v)
