"""Fused GaLoreAdamW Pallas TPU kernel.

On GPU, GaLore is three GEMMs + elementwise ops with HBM round-trips between
them (project -> Adam update -> project-back -> weight update). This kernel
fuses the whole optimizer step for one weight block into a single VMEM-
resident pass, tiled over rows of the block:

  per row-tile i (bm × N):
    g̃  = g_i @ B            (MXU;  B (N, r) stays resident across the grid)
    m̃  = β₁ m̃ + (1-β₁) g̃     (VPU)
    ṽ  = β₂ ṽ + (1-β₂) g̃²    (VPU)
    ũ  = m̂ / (√v̂ + ε)        (VPU, bias-corrected)
    u  = ũ @ Bᵀ              (MXU)
    w_i ← w_i − η u − η λ w_i

HBM traffic: read w, g once; write w once; m̃/ṽ are O(M·r) — the dense (M, N)
gradient never round-trips between optimizer stages. Tile sizes are MXU/VPU
aligned (bm multiple of 8, N and r padded to 128 by the caller when needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _galore_kernel(count_ref, w_ref, g_ref, basis_ref, m_ref, v_ref,
                   w_out, m_out, v_out, *, b1, b2, eps, lr, weight_decay):
    g = g_ref[...].astype(jnp.float32)            # (bm, N)
    basis = basis_ref[...].astype(jnp.float32)    # (N, r)
    gt = jnp.dot(g, basis, preferred_element_type=jnp.float32)   # (bm, r)

    m = b1 * m_ref[...] + (1.0 - b1) * gt
    v = b2 * v_ref[...] + (1.0 - b2) * gt * gt

    c = count_ref[0, 0]
    c1 = 1.0 - b1 ** c
    c2 = 1.0 - b2 ** c
    ut = (m / c1) / (jnp.sqrt(v / c2) + eps)      # (bm, r)

    u = jnp.dot(ut, basis.T, preferred_element_type=jnp.float32)  # (bm, N)
    w = w_ref[...].astype(jnp.float32)
    w_out[...] = (w - lr * u - lr * weight_decay * w).astype(w_out.dtype)
    m_out[...] = m
    v_out[...] = v


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "lr",
                                             "weight_decay", "block_rows",
                                             "interpret"))
def galore_adamw_step(w, g, basis, m, v, count, *, b1=0.9, b2=0.999,
                      eps=1e-8, lr=1e-3, weight_decay=0.0,
                      block_rows=128, interpret=False):
    """One fused step for a right-projected block.

    w, g (M, N); basis (N, r); m, v (M, r) fp32; count scalar (post-increment
    step for bias correction). Returns (w_new, m_new, v_new).
    """
    mm, nn = w.shape
    r = basis.shape[1]
    bm = min(block_rows, mm)
    assert mm % bm == 0, f"M={mm} must divide block_rows={bm}"
    grid = (mm // bm,)

    count_arr = jnp.full((1, 1), count, jnp.float32)
    kernel = functools.partial(_galore_kernel, b1=b1, b2=b2, eps=eps, lr=lr,
                               weight_decay=weight_decay)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),        # count (SMEM-like)
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),      # w tile
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),      # g tile
            pl.BlockSpec((nn, r), lambda i: (0, 0)),       # basis (resident)
            pl.BlockSpec((bm, r), lambda i: (i, 0)),       # m tile
            pl.BlockSpec((bm, r), lambda i: (i, 0)),       # v tile
        ],
        out_specs=[
            pl.BlockSpec((bm, nn), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
            pl.BlockSpec((bm, r), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), w.dtype),
            jax.ShapeDtypeStruct((mm, r), jnp.float32),
            jax.ShapeDtypeStruct((mm, r), jnp.float32),
        ],
        interpret=interpret,
    )(count_arr, w, g, basis, m, v)
