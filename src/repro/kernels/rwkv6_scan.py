"""Chunked RWKV6 WKV recurrence as a Pallas TPU kernel.

The WKV state S (D×D per head) lives in VMEM scratch and persists across the
sequential chunk dimension of the grid (TPU grids execute in order), so HBM
traffic per chunk is just the r/k/v/w tiles + y output — the state never
round-trips. Grid: (B, H, L/chunk); within a chunk a fori_loop applies the
per-token recurrence

    y_t = r_t · (S + diag(u) k_t v_tᵀ);   S ← diag(w_t) S + k_t v_tᵀ

with rank-1 outer products on the VPU (D = 64 lanes: register-friendly).
This is the TPU-native replacement for RWKV's custom CUDA kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sfin_ref, s_scratch, *, chunk):
    ci = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scratch[...] = s0_ref[...].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)                 # (D,)

    def body(t, s):
        r_t = r_ref[t, :].astype(jnp.float32)          # (D,)
        k_t = k_ref[t, :].astype(jnp.float32)
        v_t = v_ref[t, :].astype(jnp.float32)
        w_t = w_ref[t, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]               # (D, D) rank-1
        y = jnp.sum(r_t[:, None] * (s + u[:, None] * kv), axis=0)
        y_ref[t, :] = y.astype(y_ref.dtype)
        return w_t[:, None] * s + kv

    s = jax.lax.fori_loop(0, chunk, body, s_scratch[...])
    s_scratch[...] = s

    @pl.when(ci == n_chunks - 1)
    def _final():
        sfin_ref[...] = s


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, chunk=128, interpret=False):
    """r,k,v,w (B, L, H, D); u (H, D); s0 (B, H, D, D) fp32 or None.

    Returns (y (B, L, H, D), s_final (B, H, D, D) fp32).
    """
    b, l, h, d = r.shape
    chunk = min(chunk, l)
    assert l % chunk == 0
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    # (B, L, H, D) -> (B, H, L, D)
    rt, kt, vt, wt = (x.transpose(0, 2, 1, 3) for x in (r, k, v, w))

    kernel = functools.partial(_rwkv_kernel, chunk=chunk)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(b, h, l // chunk),
        in_specs=[
            pl.BlockSpec((None, None, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, d), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((None, None, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, d), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((None, None, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, l, d), r.dtype),
            jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, s0)
    return y.transpose(0, 2, 1, 3), sfin
