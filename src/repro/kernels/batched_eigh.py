"""Batched small-matrix symmetric eigendecomposition — Pallas Jacobi kernel.

The factored 𝒮 path is built out of *stacks* of tiny symmetric PSD
eigenproblems: the per-view r×r score Grams of Phase 1, the d×d left Grams
of the joint-basis extraction, and the s×s Rayleigh–Ritz reductions of the
sketched joint path (``ajive``). On CPU these lower to LAPACK ``syevd`` per
matrix — fine. On TPU, XLA's ``eigh`` is a QDWH iteration designed for one
*large* matrix; a (B, n, n) stack of n ≤ 64 problems wants the opposite
shape: one resident program that sweeps every matrix in the batch in
lock-step. That is this kernel.

Algorithm: cyclic Jacobi with a **parallel (round-robin) ordering** — each
step applies n//2 disjoint Givens rotations simultaneously, so a full sweep
is ``n_steps = n-1`` (n even; odd n rides a phantom column) steps instead of
n(n-1)/2 serial rotations. A rotation step is expressed entirely in
MXU-friendly matrix algebra (no scatters, no dynamic row updates):

    J = I + P diag(c-1) Pᵀ + Q diag(c-1) Qᵀ + P diag(s) Qᵀ - Q diag(s) Pᵀ
    A ← Jᵀ A J,   V ← V J

where P/Q are the step's static one-hot pair embeddings (n, n_pairs) and
(c, s) come from the standard symmetric-Schur 2×2 solve on the current
(app, aqq, apq) diagonals. Zero off-diagonals are pinned to θ = 0 so
converged (and phantom) pairs are exact no-ops instead of π/2 swaps.

Convergence: cyclic Jacobi is globally convergent and asymptotically
quadratic; ``sweeps`` is a fixed compile-time count (default 12 — machine
precision for n ≤ 64 in fp32 with slack) so the program is shape-static and
scan/vmap-safe. Eigenvalues come back *ascending* with matching eigenvector
columns — the ``jnp.linalg.eigh`` convention — so the kernel is a drop-in
for the LAPACK path (eigenvector sign/rotation within degenerate clusters
is implementation-defined in both).

On the CPU container the kernel runs in ``interpret=True`` mode (property
tests force it through ``ops.batched_small_eigh(force="jacobi")``); the
production CPU path stays on LAPACK via the ``ops`` wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

MAX_JACOBI_DIM = 64


def _round_robin_pairs(n: int):
    """Static parallel-Jacobi schedule: (n_steps, n_pairs) index arrays of
    disjoint (p, q) pairs covering every unordered pair once per sweep
    (circle method; odd n plays against a phantom seat that is filtered
    out, keeping n_pairs static across steps)."""
    m = n if n % 2 == 0 else n + 1          # phantom seat for odd n
    seats = list(range(m))
    steps_p, steps_q = [], []
    for _ in range(m - 1):
        ps, qs = [], []
        for i in range(m // 2):
            a, b = seats[i], seats[m - 1 - i]
            if a < n and b < n:             # drop phantom pairings
                ps.append(min(a, b))
                qs.append(max(a, b))
        steps_p.append(ps)
        steps_q.append(qs)
        # rotate all seats but the first
        seats = [seats[0]] + [seats[-1]] + seats[1:-1]
    return np.asarray(steps_p, np.int32), np.asarray(steps_q, np.int32)


def _schedule_onehots(n: int):
    """One-hot pair embeddings P, Q of shape (n_steps, n, n_pairs) for the
    round-robin schedule — static constants baked into the program."""
    p_idx, q_idx = _round_robin_pairs(n)
    n_steps, n_pairs = p_idx.shape
    p = np.zeros((n_steps, n, n_pairs), np.float32)
    q = np.zeros((n_steps, n, n_pairs), np.float32)
    for s in range(n_steps):
        p[s, p_idx[s], np.arange(n_pairs)] = 1.0
        q[s, q_idx[s], np.arange(n_pairs)] = 1.0
    return p, q


def _jacobi_sweeps(a, p_oh, q_oh, sweeps: int):
    """Run ``sweeps`` full parallel-Jacobi sweeps on a (bb, n, n) symmetric
    stack. Returns (diag, V) with A ≈ V diag(diag) Vᵀ, unsorted."""
    bb, n, _ = a.shape
    n_steps = p_oh.shape[0]
    eye = jnp.eye(n, dtype=jnp.float32)
    v0 = jnp.broadcast_to(eye, (bb, n, n))

    def step(s, carry):
        a, v = carry
        idx = s % n_steps
        pm = jax.lax.dynamic_index_in_dim(p_oh, idx, keepdims=False)
        qm = jax.lax.dynamic_index_in_dim(q_oh, idx, keepdims=False)
        app = jnp.einsum("nk,bnm,mk->bk", pm, a, pm)
        aqq = jnp.einsum("nk,bnm,mk->bk", qm, a, qm)
        apq = jnp.einsum("nk,bnm,mk->bk", pm, a, qm)
        theta = 0.5 * jnp.arctan2(2.0 * apq, aqq - app)
        # exact-zero off-diagonals (converged / phantom pairs) must rotate
        # by 0, not the π/2 swap arctan2(0, negative) would produce
        theta = jnp.where(apq == 0.0, 0.0, theta)
        c = jnp.cos(theta)
        s_ = jnp.sin(theta)
        j = (eye[None]
             + jnp.einsum("nk,bk,mk->bnm", pm, c - 1.0, pm)
             + jnp.einsum("nk,bk,mk->bnm", qm, c - 1.0, qm)
             + jnp.einsum("nk,bk,mk->bnm", pm, s_, qm)
             - jnp.einsum("nk,bk,mk->bnm", qm, s_, pm))
        aj = jnp.einsum("bnm,bml->bnl", a, j)
        a = jnp.einsum("bmn,bml->bnl", j, aj)
        a = 0.5 * (a + jnp.swapaxes(a, -1, -2))   # pin symmetry drift
        v = jnp.einsum("bnm,bml->bnl", v, j)
        return a, v

    a, v = jax.lax.fori_loop(0, sweeps * n_steps, step,
                             (a.astype(jnp.float32), v0))
    diag = jnp.einsum("bnn->bn", a)
    return diag, v


def _kernel(a_ref, p_ref, q_ref, lam_out, vec_out, *, sweeps):
    a = a_ref[...].astype(jnp.float32)
    diag, v = _jacobi_sweeps(a, p_ref[...], q_ref[...], sweeps)
    lam_out[...] = diag
    vec_out[...] = v


@functools.partial(jax.jit, static_argnames=("sweeps", "block_b",
                                             "interpret"))
def jacobi_eigh(a, *, sweeps: int = 12, block_b: int = 8,
                interpret: bool = False):
    """Eigendecomposition of a (..., n, n) symmetric stack, n ≤ 64.

    Returns ``(lam, vec)`` with eigenvalues ascending and ``a ≈ vec @
    diag(lam) @ vecᵀ`` per batch element — the ``jnp.linalg.eigh``
    convention. The batch is tiled ``block_b`` matrices per grid cell; the
    trailing partial tile is masked by Pallas block clipping.
    """
    n = a.shape[-1]
    if a.shape[-2] != n:
        raise ValueError(f"square matrices required, got {a.shape}")
    if n > MAX_JACOBI_DIM:
        raise ValueError(f"jacobi_eigh handles n <= {MAX_JACOBI_DIM}, "
                         f"got n={n} (use jnp.linalg.eigh)")
    lead = a.shape[:-2]
    a3 = a.reshape((-1, n, n)).astype(jnp.float32)
    b = a3.shape[0]
    bb = min(block_b, b)
    p_oh, q_oh = _schedule_onehots(n)
    n_steps, _, n_pairs = p_oh.shape
    lam, vec = pl.pallas_call(
        functools.partial(_kernel, sweeps=sweeps),
        grid=(pl.cdiv(b, bb),),
        in_specs=[pl.BlockSpec((bb, n, n), lambda i: (i, 0, 0)),
                  pl.BlockSpec((n_steps, n, n_pairs), lambda i: (0, 0, 0)),
                  pl.BlockSpec((n_steps, n, n_pairs), lambda i: (0, 0, 0))],
        out_specs=[pl.BlockSpec((bb, n), lambda i: (i, 0)),
                   pl.BlockSpec((bb, n, n), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, n), jnp.float32),
                   jax.ShapeDtypeStruct((b, n, n), jnp.float32)],
        interpret=interpret,
    )(a3, jnp.asarray(p_oh), jnp.asarray(q_oh))
    order = jnp.argsort(lam, axis=-1)
    lam = jnp.take_along_axis(lam, order, axis=-1)
    vec = jnp.take_along_axis(vec, order[:, None, :], axis=-1)
    return lam.reshape(lead + (n,)), vec.reshape(lead + (n, n))
