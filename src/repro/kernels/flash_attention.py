"""Blockwise (flash) attention Pallas TPU kernel with GQA + sliding window.

Grid: (batch, q_heads, Lq/block_q). Per grid step the kernel holds one query
tile (block_q, D) and streams the KV sequence for the matching KV head
(GQA: kv_head = q_head // group) through VMEM in block_k chunks with the
online-softmax recurrence:

    m_new = max(m, rowmax(s));  p = exp(s - m_new)
    l     = e^{m-m_new} l + rowsum(p)
    acc   = e^{m-m_new} acc + p v

Causal and sliding-window masks are applied from absolute positions
(q_offset = Lk - Lq supports decode-style suffix queries). Tiles are
MXU-aligned: block_q/block_k multiples of 128 when the sequence allows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, window,
                  block_k, q_offset):
    bq, d = q_ref.shape
    lk = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale        # (bq, D)

    qi = pl.program_id(2)
    q_pos = q_offset + qi * bq + jax.lax.iota(jnp.int32, bq)   # absolute

    n_kv = lk // block_k

    def body(j, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None))
                    ).astype(jnp.float32)             # (bk, D)
        v = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None))
                    ).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        k_pos = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((bq, block_k), jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    block_q=128, block_k=128, interpret=False):
    """q (B, Lq, H, D), k/v (B, Lk, Hkv, D) with H % Hkv == 0.

    Returns (B, Lq, H, D). Suffix-aligned causal masking: query position i
    maps to absolute position (Lk - Lq) + i.
    """
    b, lq, h, d = q.shape
    lk, hkv = k.shape[1], k.shape[2]
    groups = h // hkv
    scale = float(scale if scale is not None else 1.0 / (d ** 0.5))
    bq = min(block_q, lq)
    bk = min(block_k, lk)
    assert lq % bq == 0 and lk % bk == 0

    # (B, L, H, D) -> (B, H, L, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, block_k=bk, q_offset=lk - lq)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, lq // bq),
        in_specs=[
            pl.BlockSpec((None, None, bq, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, lk, d),
                         lambda bi, hi, qi, g=groups: (bi, hi // g, 0, 0)),
            pl.BlockSpec((None, None, lk, d),
                         lambda bi, hi, qi, g=groups: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, bq, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
