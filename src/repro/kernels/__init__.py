"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §3):

* ``galore_adamw`` — the paper's optimizer step, fused project → moment
  update → precondition → project-back in one VMEM-resident pass.
* ``flash_attention`` — blockwise GQA attention (train/prefill hot-spot).
* ``rwkv6_scan`` — chunked WKV recurrence with VMEM-persistent state.
* ``lowrank_linear`` — lift-free factored weight read: one fused pass for
  ``scale·(x@W) + split-matmul rank-r delta`` (the federated client forward).
* ``batched_eigh`` — parallel-Jacobi eigensolver for the (B, r, r) SPD
  stacks of the batched 𝒮 path (r ≤ 64; LAPACK fallback on CPU).

``ops`` holds the jit'd public wrappers (interpret=True on CPU); ``ref``
holds the pure-jnp oracles the tests assert against.
"""
from . import ops, ref
from .ops import (batched_small_eigh, flash_attention, galore_adamw_step,
                  galore_precond_step, lowrank_linear, rwkv6_scan)

__all__ = ["ops", "ref", "batched_small_eigh", "flash_attention",
           "galore_adamw_step", "galore_precond_step", "lowrank_linear",
           "rwkv6_scan"]
