"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §3):

* ``galore_adamw`` — the paper's optimizer step, fused project → moment
  update → precondition → project-back in one VMEM-resident pass.
* ``flash_attention`` — blockwise GQA attention (train/prefill hot-spot).
* ``rwkv6_scan`` — chunked WKV recurrence with VMEM-persistent state.

``ops`` holds the jit'd public wrappers (interpret=True on CPU); ``ref``
holds the pure-jnp oracles the tests assert against.
"""
from . import ops, ref
from .ops import (flash_attention, galore_adamw_step, galore_precond_step,
                  rwkv6_scan)

__all__ = ["ops", "ref", "flash_attention", "galore_adamw_step",
           "galore_precond_step", "rwkv6_scan"]
