from .base import (GradientTransformation, apply_updates, chain,
                   clip_by_global_norm, global_norm, scale_by_learning_rate)
from .adamw import (AdamState, MomentumState, adam, adamw, add_decayed_weights,
                    scale_by_adam, scale_by_momentum, sgd)
from .schedule import constant, cosine_with_warmup, linear_warmup_frac

__all__ = [
    "GradientTransformation", "apply_updates", "chain", "clip_by_global_norm",
    "global_norm", "scale_by_learning_rate", "AdamState", "MomentumState",
    "adam", "adamw", "add_decayed_weights", "scale_by_adam",
    "scale_by_momentum", "sgd", "constant", "cosine_with_warmup",
    "linear_warmup_frac",
]
