"""Minimal gradient-transformation protocol (optax is not installed).

A ``GradientTransformation`` is an ``(init, update)`` pair:

    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

All transformations are pure pytree->pytree functions, jit/scan-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


@dataclasses.dataclass(frozen=True)
class GradientTransformation:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates, is_leaf=lambda x: x is None)


def chain(*txs: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like optax.chain)."""

    def init(params):
        return tuple(tx.init(params) for tx in txs)

    def update(grads, state, params=None):
        new_state = []
        for tx, s in zip(txs, state):
            grads, s = tx.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


class ScaleByLrState(NamedTuple):
    count: jnp.ndarray


def scale_by_learning_rate(lr, flip_sign: bool = True) -> GradientTransformation:
    """lr may be a float or a schedule(step)->lr."""
    sign = -1.0 if flip_sign else 1.0

    def init(params):
        del params
        return ScaleByLrState(count=jnp.zeros([], jnp.int32))

    def update(grads, state, params=None):
        del params
        step_lr = lr(state.count) if callable(lr) else lr
        updates = jax.tree_util.tree_map(lambda g: sign * step_lr * g, grads)
        return updates, ScaleByLrState(count=state.count + 1)

    return GradientTransformation(init, update)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros([])
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    """Norm-wise gradient clipping — implements Assumption 3.8 (bounded G)."""

    def init(params):
        del params
        return ClipState()

    def update(grads, state, params=None):
        del params
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)
