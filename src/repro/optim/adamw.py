"""AdamW / Adam / SGD / momentum — the paper's local training operators 𝒯.

These mirror Algorithms 2-4 in Appendix A. States are explicit NamedTuples so
the federated layer can read/write them (state synchronization protocol 𝒮
needs direct access to the second moment v).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .base import GradientTransformation


class AdamState(NamedTuple):
    count: jnp.ndarray
    m: object   # pytree like params, fp32
    v: object   # pytree like params, fp32


def _tree_zeros_f32(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                  bias_correction: bool = True) -> GradientTransformation:
    """Adam preconditioning (Algorithm 4, lines 8-10)."""

    def init(params):
        return AdamState(count=jnp.zeros([], jnp.int32),
                         m=_tree_zeros_f32(params), v=_tree_zeros_f32(params))

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        grads32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree_util.tree_map(lambda mu, g: b1 * mu + (1 - b1) * g, state.m, grads32)
        v = jax.tree_util.tree_map(lambda nu, g: b2 * nu + (1 - b2) * g * g, state.v, grads32)
        if bias_correction:
            c1 = 1 - b1 ** count.astype(jnp.float32)
            c2 = 1 - b2 ** count.astype(jnp.float32)
        else:
            c1 = c2 = 1.0
        updates = jax.tree_util.tree_map(
            lambda mu, nu: (mu / c1) / (jnp.sqrt(nu / c2) + eps), m, v)
        return updates, AdamState(count=count, m=m, v=v)

    return GradientTransformation(init, update)


class WeightDecayState(NamedTuple):
    pass


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    """Decoupled weight decay (AdamW): adds wd * params to the update."""

    def init(params):
        del params
        return WeightDecayState()

    def update(grads, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        updates = jax.tree_util.tree_map(
            lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        return updates, state

    return GradientTransformation(init, update)


class MomentumState(NamedTuple):
    momentum: object


def scale_by_momentum(beta: float = 0.9) -> GradientTransformation:
    """Heavy-ball momentum (Algorithm 3): v <- beta*v + g; update = v."""

    def init(params):
        return MomentumState(momentum=_tree_zeros_f32(params))

    def update(grads, state, params=None):
        del params
        buf = jax.tree_util.tree_map(
            lambda b, g: beta * b + g.astype(jnp.float32), state.momentum, grads)
        return buf, MomentumState(momentum=buf)

    return GradientTransformation(init, update)


def adamw(learning_rate, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01,
          clip_norm: Optional[float] = None) -> GradientTransformation:
    from .base import chain, clip_by_global_norm, scale_by_learning_rate
    txs = []
    if clip_norm is not None:
        txs.append(clip_by_global_norm(clip_norm))
    txs += [scale_by_adam(b1, b2, eps),
            add_decayed_weights(weight_decay),
            scale_by_learning_rate(learning_rate)]
    return chain(*txs)


def adam(learning_rate, b1=0.9, b2=0.999, eps=1e-8,
         clip_norm: Optional[float] = None) -> GradientTransformation:
    return adamw(learning_rate, b1, b2, eps, weight_decay=0.0, clip_norm=clip_norm)


def sgd(learning_rate, momentum: Optional[float] = None,
        clip_norm: Optional[float] = None) -> GradientTransformation:
    from .base import chain, clip_by_global_norm, scale_by_learning_rate
    txs = []
    if clip_norm is not None:
        txs.append(clip_by_global_norm(clip_norm))
    if momentum is not None:
        txs.append(scale_by_momentum(momentum))
    txs.append(scale_by_learning_rate(learning_rate))
    return chain(*txs)
