"""Learning-rate schedules (paper Appendix G uses cosine with warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def schedule(step):
        del step
        return jnp.asarray(lr, jnp.float32)
    return schedule


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.0):
    """Linear warmup to peak_lr, cosine decay to final_frac*peak_lr."""
    warmup_steps = max(int(warmup_steps), 1)
    decay_steps = max(int(total_steps) - warmup_steps, 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / warmup_steps, 1.0)
        t = jnp.clip((step - warmup_steps) / decay_steps, 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule


def linear_warmup_frac(peak_lr: float, warmup_frac: float, total_steps: int,
                       final_frac: float = 0.0):
    """Paper-style: warmup given as a fraction of total steps (e.g. 0.06)."""
    return cosine_with_warmup(peak_lr, int(warmup_frac * total_steps),
                              total_steps, final_frac)
