from .rules import ShardingRules, path_of

__all__ = ["ShardingRules", "path_of"]
