"""Sharding rules: param/state tree paths -> PartitionSpec.

Megatron-style tensor parallelism over the ``model`` axis plus FSDP-style
weight sharding over the ``data`` axis (ZeRO-3; XLA inserts the per-layer
all-gathers). The ``pod`` axis is pure data/client parallelism — parameters
replicate across pods, so the only cross-pod traffic is the gradient /
federated-aggregation all-reduce, matching the paper's round structure.

Every rule degrades gracefully: an axis is only assigned to a dimension it
divides, so any (arch × mesh) combination lowers. Rules:

  COL  (d_in, d_out)        -> P(fsdp, model)       wq/wk/wv/w_gate/w_up/...
  ROW  (d_in, d_out)        -> P(model, fsdp)       wo/w_down/out_proj/...
  EXP  (E, d_in, d_out)     -> P(model, fsdp, None) expert-parallel MoE
  EMB  (V, D)               -> P(model, fsdp)       embeddings / lm head
  REPL                      -> P()                  norms, biases, routers

Stacked scan-block leaves get a leading None. GaLore states follow their
block's rule on the ambient dim (basis (n, r) of a COL block shards n over
model iff the block's n was model-sharded; projected buffers (m, r) follow m).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# path-suffix -> rule name
_RULES: Tuple[Tuple[str, str], ...] = (
    (r"embed/w$", "emb"),
    (r"lm_head/w$", "emb_t"),
    (r"moe/router$", "repl"),
    (r"moe/w_(gate|up)$", "exp_col"),
    (r"moe/w_down$", "exp_row"),
    (r"shared/w_(gate|up)$", "col"),
    (r"shared/w_down$", "row"),
    (r"(attn/w[qkv]|attn/q_a|attn/q_b|attn/kv_a|attn/kv_b)$", "col"),
    (r"attn/wo$", "row"),
    (r"mlp/w_(gate|up)$", "col"),
    (r"mlp/w_down$", "row"),
    (r"mamba/(in_proj|dt_proj)$", "col"),
    (r"mamba/(out_proj|x_proj)$", "row"),
    (r"mamba/conv_w$", "conv"),
    (r"mamba/(a_log|d_skip)$", "inner_vec"),
    (r"tmix/(wr|wk|wv|wg|maa_w1|decay_w1)$", "col"),
    (r"tmix/(wo|maa_w2|decay_w2)$", "row_last2"),
    (r"cmix/(wk|wr)$", "col"),
    (r"cmix/wv$", "row"),
)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return dim % size == 0


def _guard(shape, mesh: Mesh, spec_dims) -> P:
    """Drop any axis that does not divide its dimension."""
    out = []
    for dim, axes in zip(shape, spec_dims):
        out.append(axes if _fits(dim, mesh, axes) else None)
    return P(*out)


def path_of(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


class ShardingRules:
    """Resolves PartitionSpecs against a concrete mesh.

    data_axis: FSDP/weight-sharding axis name(s); model_axis: TP axis;
    batch_axes: axes used for the batch dim of activations/inputs
    (('pod','data') on the multi-pod mesh).
    """

    def __init__(self, mesh: Mesh, data_axis: str = "data",
                 model_axis: str = "model", fsdp: bool = True):
        self.mesh = mesh
        self.data_axis = data_axis
        self.model_axis = model_axis
        self.fsdp = fsdp
        self.batch_axes = tuple(n for n in ("pod", "data") if n in mesh.shape)

    # ---------------------------------------------------------- params -----
    def _rule_spec(self, rule: str, shape) -> P:
        d, m = (self.data_axis if self.fsdp else None), self.model_axis
        lead = len(shape) - 2
        if rule == "exp_col" or rule == "exp_row":
            lead = len(shape) - 3
        pre = (None,) * max(lead, 0)
        tail2 = shape[-2:]
        if rule == "col":
            return _guard(shape, self.mesh, pre + (d, m))
        if rule == "row":
            return _guard(shape, self.mesh, pre + (m, d))
        if rule == "row_last2":
            return _guard(shape, self.mesh, pre + (m, None))
        if rule == "exp_col":
            return _guard(shape, self.mesh, pre + (m, d, None))
        if rule == "exp_row":
            return _guard(shape, self.mesh, pre + (m, None, d))
        if rule == "emb":
            return _guard(shape, self.mesh, (m, d))
        if rule == "emb_t":
            return _guard(shape, self.mesh, (d, m))
        if rule == "conv":
            return _guard(shape, self.mesh, pre + (None, m))
        if rule == "inner_vec":
            # a_log (..., d_inner, d_state): shard d_inner; d_skip (..., d_inner)
            if len(shape) >= 2 and shape[-1] < shape[-2]:
                return _guard(shape, self.mesh,
                              (None,) * (len(shape) - 2) + (m, None))
            return _guard(shape, self.mesh,
                          (None,) * (len(shape) - 1) + (m,))
        return P()

    def param_rule(self, path_str: str) -> str:
        for pat, rule in _RULES:
            if re.search(pat, path_str):
                return rule
        return "repl"

    def param_spec(self, path_str: str, shape) -> P:
        return self._rule_spec(self.param_rule(path_str), shape)

    def params_shardings(self, params: PyTree) -> PyTree:
        def one(path, leaf):
            spec = self.param_spec(path_of(path), leaf.shape)
            return NamedSharding(self.mesh, spec)
        return jax.tree_util.tree_map_with_path(one, params)

    # -------------------------------------------------- optimizer states ---
    def galore_state_shardings(self, params: PyTree, opt_state: PyTree) -> PyTree:
        """GaLore/Adam states inherit the ambient-dim sharding of their block:
        for a COL block (d_in, d_out) with right basis (d_out, r), the basis
        shards d_out over model; projected (d_in, r) buffers shard d_in over
        fsdp. Dense moments mirror the param spec. Scalars replicate."""
        from ..core.galore import DenseMoments, GaloreBlockState, GaloreState

        param_leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        specs = [self.param_spec(path_of(p), leaf.shape)
                 for p, leaf in param_leaves]

        def shard_states(opt):
            if isinstance(opt, GaloreState):
                blk_leaves, treedef = jax.tree_util.tree_flatten(
                    opt.blocks, is_leaf=lambda x: isinstance(
                        x, (GaloreBlockState, DenseMoments)))
                out = []
                for (pth, leaf), st in zip(param_leaves, blk_leaves):
                    spec = self.param_spec(path_of(pth), leaf.shape)
                    dims = list(spec) + [None] * (leaf.ndim - len(spec))
                    if isinstance(st, GaloreBlockState):
                        lead = tuple(dims[:-2])
                        row_ax, col_ax = dims[-2], dims[-1]
                        right = st.m.shape[-1] == st.basis.shape[-1] and \
                            st.m.shape[-2] == leaf.shape[-2]
                        if right:
                            basis_spec = _guard(st.basis.shape, self.mesh,
                                                lead + (col_ax, None))
                            buf_spec = _guard(st.m.shape, self.mesh,
                                              lead + (row_ax, None))
                        else:
                            basis_spec = _guard(st.basis.shape, self.mesh,
                                                lead + (row_ax, None))
                            buf_spec = _guard(st.m.shape, self.mesh,
                                              lead + (None, col_ax))
                        out.append(GaloreBlockState(
                            basis=NamedSharding(self.mesh, basis_spec),
                            m=NamedSharding(self.mesh, buf_spec),
                            v=NamedSharding(self.mesh, buf_spec)))
                    else:
                        out.append(DenseMoments(
                            m=NamedSharding(self.mesh, _guard(
                                st.m.shape, self.mesh, dims[:st.m.ndim])),
                            v=NamedSharding(self.mesh, _guard(
                                st.v.shape, self.mesh, dims[:st.v.ndim]))))
                blocks = jax.tree_util.tree_unflatten(treedef, out)
                return GaloreState(
                    count=NamedSharding(self.mesh, P()),
                    seed=NamedSharding(self.mesh, P()),
                    blocks=blocks)
            # generic states (clip counters, lr count, adam moments on the
            # trainable tree): mirror param spec when shapes match, else repl.
            return jax.tree_util.tree_map(
                lambda x: NamedSharding(self.mesh, P()), opt)

        if isinstance(opt_state, tuple) and not hasattr(opt_state, "_fields"):
            return tuple(shard_states(s) for s in opt_state)
        return shard_states(opt_state)

    # ------------------------------------------------------- activations ---
    def batch_spec(self, shape) -> P:
        """Inputs (B, ...): shard batch over (pod, data) when divisible."""
        return _guard(shape, self.mesh,
                      (self.batch_axes,) + (None,) * (len(shape) - 1))

    def data_shardings(self, batch: PyTree) -> PyTree:
        return jax.tree_util.tree_map(
            lambda x: NamedSharding(self.mesh, self.batch_spec(x.shape)), batch)

    # ---------------------------------------------------- decode states ----
    def decode_state_shardings(self, state: PyTree) -> PyTree:
        """Decode-state layout (§Perf iteration C):

        KV caches (nb, B, S, ...) shard batch over (pod,data) and the CACHE
        SLOTS over model — flash-decoding-style sequence parallelism. The
        attention contraction over slots then reduces with a tiny psum of
        per-shard softmax statistics instead of all-gathering the cache
        (the baseline layout sharded head_dim, which SPMD could only realize
        by all-gathering the whole cache every step: 2 GiB/layer for
        command-r decode_32k). Recurrent states (no slot dim) shard batch
        over (pod,data) and their largest feature dim over model."""
        mesh = self.mesh
        m = self.model_axis

        def one(path, leaf):
            shape = leaf.shape
            dims = [None] * len(shape)
            if len(shape) >= 2:
                batch_dim = 1 if len(shape) > 1 else 0
                if _fits(shape[batch_dim], mesh, self.batch_axes):
                    dims[batch_dim] = self.batch_axes
                # cache slots (dim 2 of (nb, B, S, ...)) over model; the pos
                # buffer (nb, B, S) follows the same slot sharding
                if len(shape) >= 3 and shape[2] % mesh.shape[m] == 0 \
                        and shape[2] >= mesh.shape[m]:
                    dims[2] = m
                else:
                    # recurrent state: largest trailing dim over model
                    for cand in range(len(shape) - 1, batch_dim, -1):
                        if dims[cand] is None and \
                                shape[cand] % mesh.shape[m] == 0 and \
                                shape[cand] >= mesh.shape[m]:
                            dims[cand] = m
                            break
            return NamedSharding(mesh, P(*dims))

        return jax.tree_util.tree_map_with_path(one, state)
