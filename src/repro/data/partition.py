"""Dirichlet non-IID client partitioning (paper Appendix H).

For a K-class task, each client's label distribution is sampled
``p_i ~ Dir(α·1_K)``; examples are allocated accordingly. Smaller α ⇒ more
skewed clients (the paper's severe setting is α = 0.5). For generative tasks
the paper treats the question "type" as the label — our synthetic LM tasks do
the same with latent cluster ids.
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_label_partition(labels: np.ndarray, n_clients: int,
                              alpha: float, seed: int = 0,
                              min_per_client: int = 1) -> List[np.ndarray]:
    """Return per-client index arrays partitioning ``labels``."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    client_idx: List[list] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        props = rng.dirichlet(alpha * np.ones(n_clients))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            client_idx[ci].extend(part.tolist())
    out = []
    all_idx = np.arange(len(labels))
    for ci in range(n_clients):
        idx = np.asarray(client_idx[ci], dtype=np.int64)
        if len(idx) < min_per_client:   # top up starved clients uniformly
            extra = rng.choice(all_idx, size=min_per_client - len(idx),
                               replace=False)
            idx = np.concatenate([idx, extra])
        rng.shuffle(idx)
        out.append(idx)
    return out


def iid_partition(n_examples: int, n_clients: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_examples)
    return [np.asarray(part) for part in np.array_split(idx, n_clients)]


def heterogeneity_stats(labels: np.ndarray, parts: List[np.ndarray]) -> dict:
    """Diagnostics: per-client class histograms + mean TV distance to the
    global distribution (a direct measure of the paper's drift c_i)."""
    labels = np.asarray(labels)
    classes = np.unique(labels)
    global_p = np.array([(labels == c).mean() for c in classes])
    tvs = []
    hists = []
    for idx in parts:
        li = labels[idx]
        p = np.array([(li == c).mean() if len(li) else 0.0 for c in classes])
        hists.append(p)
        tvs.append(0.5 * np.abs(p - global_p).sum())
    return {"mean_tv": float(np.mean(tvs)), "per_client_tv": tvs,
            "hists": np.stack(hists)}
