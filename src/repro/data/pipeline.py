"""Client batching pipeline: task + partition -> per-round stacked batches.

The federated engine consumes a pytree with leading axes (K clients, T local
steps, batch, ...). ``FederatedBatcher`` cycles each client's local shard
(with reshuffling per epoch) so the same protocol drives IID and Dirichlet
partitions.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .partition import dirichlet_label_partition, iid_partition
from .synthetic import TaskData


class FederatedBatcher:
    def __init__(self, task: TaskData, n_clients: int, batch_size: int,
                 alpha: Optional[float] = None, seed: int = 0):
        """alpha=None -> IID; else Dirichlet(alpha) label partition."""
        self.task = task
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed + 1)
        if alpha is None:
            self.parts = iid_partition(len(task.tokens), n_clients, seed)
        else:
            self.parts = dirichlet_label_partition(task.class_ids, n_clients,
                                                   alpha, seed)
        self._cursors = [0] * n_clients

    def _next_idx(self, client: int, n: int) -> np.ndarray:
        part = self.parts[client]
        out = []
        c = self._cursors[client]
        while n > 0:
            if c >= len(part):
                self.rng.shuffle(part)
                c = 0
            take = min(n, len(part) - c)
            out.append(part[c:c + take])
            c += take
            n -= take
        self._cursors[client] = c
        return np.concatenate(out)

    def round_batches(self, local_steps: int,
                      clients: Optional[List[int]] = None) -> Dict:
        """-> dict of arrays with leading (K, T, B) axes."""
        clients = clients if clients is not None else range(len(self.parts))
        toks, labs, embs = [], [], []
        for ci in clients:
            idx = self._next_idx(ci, local_steps * self.batch_size)
            idx = idx.reshape(local_steps, self.batch_size)
            toks.append(self.task.tokens[idx])
            labs.append(self.task.labels[idx])
            if self.task.embeds is not None:
                embs.append(self.task.embeds[idx])
        batch = {"tokens": np.stack(toks), "labels": np.stack(labs)}
        if embs:
            batch["embeds"] = np.stack(embs)
        return batch

    def sample_clients(self, k: int) -> List[int]:
        """Partial participation: uniform k-of-M (paper protocol K=5/50)."""
        return sorted(self.rng.choice(len(self.parts), size=k,
                                      replace=False).tolist())

    def eval_batch(self, n: int, seed: int = 123) -> Dict:
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(self.task.tokens), size=n, replace=False)
        batch = {"tokens": self.task.tokens[idx], "labels": self.task.labels[idx]}
        if self.task.embeds is not None:
            batch["embeds"] = self.task.embeds[idx]
        return batch
