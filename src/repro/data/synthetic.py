"""Synthetic federated tasks (offline stand-ins for GLUE / DomainNet /
MetaMathQA; DESIGN.md §8 assumption 1).

Every task has learnable structure and a *label* for Dirichlet partitioning:

* ``seq_classification`` — class-conditioned unigram token sequences; the
  model must emit the class token at the last position (GLUE analogue).
* ``markov_lm`` — a mixture of random Markov chains; the chain id is the
  "type" label (MetaMathQA analogue, Appendix H treats type as label).
* ``patch_classification`` — stub patch embeddings with class prototypes +
  class token target (DomainNet/ViT analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class TaskData:
    tokens: np.ndarray            # (N, L) int32
    labels: np.ndarray            # (N, L) int32, -1 masked
    class_ids: np.ndarray         # (N,) partitioning label
    embeds: Optional[np.ndarray] = None   # (N, F, D) for patch tasks


def seq_classification(n_examples: int, n_classes: int, seq_len: int,
                       vocab: int, seed: int = 0,
                       signal: float = 3.0) -> TaskData:
    rng = np.random.default_rng(seed)
    cls = rng.integers(0, n_classes, n_examples)
    # Class-conditioned unigram distributions over the content vocabulary.
    content_vocab = vocab - n_classes          # last ids reserved for labels
    logits = rng.normal(size=(n_classes, content_vocab))
    boost = rng.integers(0, content_vocab, (n_classes, max(2, content_vocab // 16)))
    for c in range(n_classes):
        logits[c, boost[c]] += signal
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    tokens = np.stack([rng.choice(content_vocab, size=seq_len, p=probs[c])
                       for c in cls]).astype(np.int32)
    labels = np.full((n_examples, seq_len), -1, np.int32)
    labels[:, -1] = content_vocab + cls        # predict the class token
    return TaskData(tokens=tokens, labels=labels, class_ids=cls)


def markov_lm(n_examples: int, n_types: int, seq_len: int, vocab: int,
              seed: int = 0, concentration: float = 0.1) -> TaskData:
    rng = np.random.default_rng(seed)
    types = rng.integers(0, n_types, n_examples)
    trans = rng.dirichlet(concentration * np.ones(vocab), size=(n_types, vocab))
    tokens = np.empty((n_examples, seq_len), np.int32)
    for i, ty in enumerate(types):
        t = rng.integers(0, vocab)
        for j in range(seq_len):
            tokens[i, j] = t
            t = rng.choice(vocab, p=trans[ty, t])
    labels = np.concatenate([tokens[:, 1:],
                             np.full((n_examples, 1), -1, np.int32)], axis=1)
    return TaskData(tokens=tokens, labels=labels, class_ids=types)


def patch_classification(n_examples: int, n_classes: int, n_patches: int,
                         d_model: int, vocab: int, seed: int = 0,
                         signal: float = 2.0, text_len: int = 4) -> TaskData:
    rng = np.random.default_rng(seed)
    cls = rng.integers(0, n_classes, n_examples)
    protos = rng.normal(size=(n_classes, d_model))
    embeds = (rng.normal(size=(n_examples, n_patches, d_model))
              + signal * protos[cls][:, None, :]).astype(np.float32)
    tokens = np.zeros((n_examples, text_len), np.int32)   # BOS-style prompt
    labels = np.full((n_examples, text_len), -1, np.int32)
    labels[:, -1] = cls % vocab
    return TaskData(tokens=tokens, labels=labels, class_ids=cls,
                    embeds=embeds)


def accuracy_from_logits(logits_last: np.ndarray, labels_last: np.ndarray
                         ) -> float:
    return float((logits_last.argmax(-1) == labels_last).mean())
