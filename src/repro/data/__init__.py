from .partition import (dirichlet_label_partition, heterogeneity_stats,
                        iid_partition)
from .pipeline import FederatedBatcher
from .synthetic import (TaskData, accuracy_from_logits, markov_lm,
                        patch_classification, seq_classification)

__all__ = [
    "dirichlet_label_partition", "heterogeneity_stats", "iid_partition",
    "FederatedBatcher", "TaskData", "accuracy_from_logits", "markov_lm",
    "patch_classification", "seq_classification",
]
