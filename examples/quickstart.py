"""Quickstart: federated GaLore fine-tuning in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a reduced qwen1.5 backbone, partitions a synthetic classification
task across 4 non-IID clients (Dirichlet α=0.5), and runs 5 FedGaLore rounds:
GaLoreAdamW clients + FedAvg aggregation + AJIVE second-moment sync.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.fed import FedConfig, FedEngine
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M


def main():
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    task = seq_classification(n_examples=1024, n_classes=4, seq_len=16,
                              vocab=cfg.vocab_size)
    clients = FederatedBatcher(task, n_clients=4, batch_size=8, alpha=0.5)

    engine = FedEngine(
        FedConfig(method="fedgalore", rank=4, lr=3e-3, local_steps=4),
        loss_fn=lambda p, b: M.loss_fn(p, cfg, b),
        params=params,
        target_fn=galore_target_fn(cfg))

    eval_b = clients.eval_batch(256)
    for rnd in range(5):
        batches = {k: jnp.asarray(v)
                   for k, v in clients.round_batches(4).items()}
        metrics = engine.run_round(batches)
        logits, _ = M.forward(engine.global_params(), cfg,
                              jnp.asarray(eval_b["tokens"]))
        acc = (np.asarray(logits[:, -1]).argmax(-1)
               == eval_b["labels"][:, -1]).mean()
        print(f"round {rnd}: local_loss={metrics['mean_final_loss']:.3f} "
              f"val_acc={acc:.3f}")


if __name__ == "__main__":
    main()
