"""Multi-adapter serving example: one compiled decode batch, many tenants.

Wraps a base model's target projections with per-tenant factored deltas
(`MultiAdapterDelta` tables via `launch/adapters.py`), then serves a
heterogeneous batch — every row applying its own adapter over one shared
base GEMM — through the fused-scan decoder, and finally drives the same
adapters through `SlotServer` continuous batching (requests retire
mid-stream, queued tenants admitted into freed slots).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.launch import adapters as adapters_lib
from repro.launch.serve import Request, SlotServer, generate_scan
from repro.models import model as M

ARCHS = ["qwen1.5-0.5b", "rwkv6-1.6b"]
N_ADAPTERS = 8
BATCH, PROMPT, NEW = 8, 24, 16


def main():
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(key, cfg)

        # N distinct tenants in one factor table; decode rows pick theirs
        # by id — one compiled program serves them all.
        served = adapters_lib.demo_wrap(params, cfg, N_ADAPTERS, rank=4,
                                        key=jax.random.fold_in(key, 1))
        prompts = jax.random.randint(jax.random.fold_in(key, 2),
                                     (BATCH, PROMPT), 0, cfg.vocab_size)
        ids = jnp.arange(BATCH, dtype=jnp.int32) % N_ADAPTERS

        out = generate_scan(served, cfg, prompts, NEW, PROMPT + NEW,
                            adapters=ids)          # compile warmup
        t0 = time.time()
        out = generate_scan(served, cfg, prompts, NEW, PROMPT + NEW,
                            adapters=ids)
        jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"{arch:16s} scan decode: batch={BATCH} tenants={N_ADAPTERS} "
              f"+{NEW} tokens in {dt:5.2f}s ({BATCH * NEW / dt:7.1f} tok/s) "
              f"sample={out[0, -4:].tolist()}")

        # Continuous batching: 2x-oversubscribed tenant requests through
        # half the slots — finished rows retire, the queue backfills.
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, PROMPT),
                        max_new=NEW, adapter=i % N_ADAPTERS)
                for i in range(BATCH)]
        server = SlotServer(served, cfg, slots=BATCH // 2,
                            cache_len=PROMPT + NEW, segment=4)
        stats = server.run(reqs)["stats"]
        print(f"{'':16s} continuous: {len(reqs)} requests through "
              f"{BATCH // 2} slots, {stats['segments']} segments, "
              f"decode {stats['decode_tok_s']:7.1f} tok/s "
              f"(prefill {stats['prefill_tok_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
