"""Batched serving example: prefill a batch of prompts and decode new tokens
with KV-cache / recurrent-state reuse, across three architecture families
(GQA dense, sliding-window dense, attention-free RWKV).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.launch.serve import generate
from repro.models import model as M

ARCHS = ["qwen1.5-0.5b", "starcoder2-7b", "rwkv6-1.6b"]


def main():
    key = jax.random.PRNGKey(0)
    for arch in ARCHS:
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(key, cfg)
        prompts = jax.random.randint(jax.random.fold_in(key, 1), (4, 24), 0,
                                     cfg.vocab_size)
        t0 = time.time()
        out = generate(params, cfg, prompts, new_tokens=16, cache_len=64,
                       temperature=0.8, key=key)
        dt = time.time() - t0
        print(f"{arch:20s} family={cfg.family:6s} "
              f"batch=4 prompt=24 +16 tokens in {dt:5.1f}s "
              f"({4 * 16 / dt:6.1f} tok/s)  sample={out[0, -6:].tolist()}")


if __name__ == "__main__":
    main()
