"""Multi-pod dry-run demo: lower + compile the FedGaLore train step for one
assigned architecture on the production meshes (256-chip pod and 2×256
multi-pod) and print the memory / cost / collective analysis.

    PYTHONPATH=src python examples/multipod_dryrun_demo.py [arch]
"""
import sys

from repro.launch import dryrun  # sets XLA_FLAGS before jax init


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "granite-moe-1b-a400m"
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import TrainSpec

    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        print(f"== {arch} train_4k on mesh {dict(mesh.shape)} ==")
        dryrun.analyze_combination(arch, "train_4k", mesh,
                                   TrainSpec(rank=64))


if __name__ == "__main__":
    main()
