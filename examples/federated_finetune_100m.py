"""End-to-end driver: federated fine-tuning of a ~125M-parameter backbone
(paper-roberta-like: 12L, d=768 — RoBERTa-base scale, the paper's NLU
setting) for a few hundred local steps total, comparing FedGaLore against a
federated-LoRA baseline under non-IID data.

    PYTHONPATH=src python examples/federated_finetune_100m.py \
        [--rounds 50] [--method fedgalore] [--alpha 0.5]

Reduce --rounds for a quick run; 50 rounds × 4 local steps = 200 optimizer
steps per client stream (the "few hundred steps" end-to-end budget).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.fed import FedConfig, FedEngine
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--method", default="fedgalore")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config("paper-roberta-like")   # 12L d=768 — ~125M params
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params={n_params/1e6:.0f}M")

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    task = seq_classification(4096, 8, args.seq, cfg.vocab_size)
    clients = FederatedBatcher(task, args.clients, args.batch,
                               alpha=args.alpha)

    engine = FedEngine(
        FedConfig(method=args.method, rank=8, lr=1e-4,
                  local_steps=args.local_steps),
        loss_fn=lambda p, b: M.loss_fn(p, cfg, b),
        params=params, target_fn=galore_target_fn(cfg))

    eval_b = clients.eval_batch(128)
    t_start = time.time()
    for rnd in range(args.rounds):
        t0 = time.time()
        batches = {k: jnp.asarray(v)
                   for k, v in clients.round_batches(args.local_steps).items()}
        metrics = engine.run_round(batches)
        if rnd % 5 == 0 or rnd == args.rounds - 1:
            gp = engine.global_params()
            logits, _ = M.forward(gp, cfg, jnp.asarray(eval_b["tokens"]))
            acc = float((np.asarray(logits[:, -1]).argmax(-1)
                         == eval_b["labels"][:, -1]).mean())
            val = float(M.loss_fn(gp, cfg, {k: jnp.asarray(v)
                                            for k, v in eval_b.items()}))
            print(json.dumps({"round": rnd,
                              "local_loss": round(metrics["mean_final_loss"], 4),
                              "val_loss": round(val, 4), "val_acc": acc,
                              "round_sec": round(time.time() - t0, 1)}),
                  flush=True)
    print(f"total: {args.rounds} rounds, "
          f"{args.rounds * args.local_steps} local steps/client, "
          f"{time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
