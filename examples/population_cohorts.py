"""Planet-scale cohorts: a 10⁴-client virtual population with faults.

    PYTHONPATH=src python examples/population_cohorts.py

Each round samples an 8-client cohort out of a 10,000-client population
(Dirichlet α=0.5 shards), injects dropout and straggler faults, and runs
the masked fused FedGaLore round. Straggler contributions land 1–2 rounds
stale through the FedBuff-style buffer; every client's rank-r factored
state (accumulator R_i + projected moments ṽ_i, O(r(m+n)) per client)
sticks in a spill-to-disk store — the resident window here is 8 shards of
512 clients, everything colder lives on disk through the crash-safe
checkpoint writer. The drift observatory prints the projected-moment
divergence 𝒮 is absorbing each round.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.fed import FedConfig, FedEngine
from repro.core.population import ParticipationConfig, PopulationRunner
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M

POPULATION = 10_000
COHORT = 8


def main():
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    task = seq_classification(n_examples=2048, n_classes=4, seq_len=16,
                              vocab=cfg.vocab_size)
    batcher = FederatedBatcher(task, n_clients=POPULATION, batch_size=8,
                               alpha=0.5)

    pcfg = ParticipationConfig(population=POPULATION, dropout_rate=0.25,
                               straggler_rate=0.25, max_staleness=2,
                               staleness_decay=0.5, seed=17)
    engine = FedEngine(
        FedConfig(method="fedgalore", rank=4, lr=3e-3, local_steps=4,
                  participation=pcfg),
        loss_fn=lambda p, b: M.loss_fn(p, cfg, b),
        params=params,
        target_fn=galore_target_fn(cfg))

    def batches_for(ids, _round):
        b = batcher.round_batches(4, clients=[int(i) for i in ids])
        return {k: jnp.asarray(v) for k, v in b.items()}

    store_dir = tempfile.mkdtemp(prefix="population_store_")
    runner = PopulationRunner(engine, batches_for, cohort=COHORT, pcfg=pcfg,
                              store_dir=store_dir, shard_size=512,
                              max_resident_shards=8)

    eval_b = batcher.eval_batch(256)
    for rnd in range(8):
        rec = runner.run_round()
        logits, _ = M.forward(engine.global_params(), cfg,
                              jnp.asarray(eval_b["tokens"]))
        acc = (np.asarray(logits[:, -1]).argmax(-1)
               == eval_b["labels"][:, -1]).mean()
        print(f"round {rnd}: cohort={rec['plan'].clients.tolist()} "
              f"on-time={rec['participants']} dropped={rec['dropped']} "
              f"straggling={rec['straggling']} buffered={rec['buffered']} "
              f"stale_merged={rec['stale_merged']} "
              f"drift={rec['moment_divergence']:.3f} "
              f"loss={rec['mean_final_loss']:.3f} val_acc={acc:.3f}")
    runner.store.flush()
    print(f"store: {runner.store.n_shards} shards of {runner.store.shard_size} "
          f"clients, {runner.store.resident_bytes() / 2**20:.1f} MiB resident, "
          f"{runner.store.spills} spills / {runner.store.loads} loads "
          f"-> {store_dir}")


if __name__ == "__main__":
    main()
