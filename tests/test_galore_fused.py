"""Parity: the fused/bucketed scale_by_galore path vs the per-leaf reference
loop, and the Pallas (interpret-mode) kernel path, over a multi-block pytree
with right, left, and stacked 3-D blocks plus a dense (bias) leaf."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import galore as gal

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def tree():
    """right (32,16) ×2 (one shape bucket), left (8,24), stacked (3,16,16),
    dense bias — exercises every bucketing case at once."""
    params = {
        "a": jax.random.normal(KEY, (32, 16)),
        "b": jax.random.normal(jax.random.fold_in(KEY, 1), (8, 24)),
        "c": jax.random.normal(jax.random.fold_in(KEY, 2), (3, 16, 16)),
        "d": jax.random.normal(jax.random.fold_in(KEY, 3), (32, 16)),
        "bias": jnp.zeros((7,)),
    }
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.fold_in(KEY, 9), p.shape),
        params)
    return params, grads


def _run(cfg, params, grads, steps=7):
    tx = gal.scale_by_galore(cfg)
    st = tx.init(params)
    outs = []
    for _ in range(steps):
        u, st = tx.update(grads, st)
        outs.append(u)
    return outs, st


@pytest.mark.parametrize("refresh_mode", ["random", "auto"])
def test_bucketed_matches_reference_loop(tree, refresh_mode):
    params, grads = tree
    kw = dict(rank=4, refresh_every=3, adaptive_steps=1,
              refresh_mode=refresh_mode)
    u_f, st_f = _run(gal.GaloreConfig(fused=True, use_pallas=False, **kw),
                     params, grads)
    u_r, st_r = _run(gal.GaloreConfig(fused=False, **kw), params, grads)
    for uf, ur in zip(u_f, u_r):
        for k in params:
            assert jnp.allclose(uf[k], ur[k], atol=1e-5), k
    for k in ("a", "b", "c", "d"):
        # bucketed seeded refresh must reproduce the per-leaf bases exactly
        # (the server-broadcast-a-seed protocol depends on it)
        assert jnp.allclose(st_f.blocks[k].basis, st_r.blocks[k].basis,
                            atol=1e-6), k
        assert jnp.allclose(st_f.blocks[k].v, st_r.blocks[k].v, atol=1e-6), k


def test_pallas_path_matches_reference_loop(tree):
    params, grads = tree
    kw = dict(rank=4, refresh_every=3, adaptive_steps=1,
              refresh_mode="random")
    u_p, st_p = _run(gal.GaloreConfig(fused=True, use_pallas=True,
                                      pallas_block_rows=16, **kw),
                     params, grads, steps=4)
    u_r, st_r = _run(gal.GaloreConfig(fused=False, **kw), params, grads,
                     steps=4)
    for up, ur in zip(u_p, u_r):
        for k in params:
            assert jnp.allclose(up[k], ur[k], atol=1e-5), k
    for k in ("a", "b", "c", "d"):
        assert jnp.allclose(st_p.blocks[k].v, st_r.blocks[k].v, atol=1e-5), k


def test_fused_inside_jit_and_scan(tree):
    """The bucketed path must stay jit/scan-safe (the production round loop
    wraps it in lax.scan)."""
    params, grads = tree
    cfg = gal.GaloreConfig(rank=4, refresh_every=2, adaptive_steps=0,
                           refresh_mode="random", fused=True,
                           use_pallas=False)
    tx = gal.scale_by_galore(cfg)
    st = tx.init(params)

    @jax.jit
    def run(st):
        def step(carry, _):
            u, carry = tx.update(grads, carry)
            return carry, u["a"]
        return jax.lax.scan(step, st, None, length=5)

    st_out, us = run(st)
    assert us.shape[0] == 5
    assert not bool(jnp.any(jnp.isnan(us)))


def test_fed_engine_factored_matches_dense_sync():
    """FedEngine trajectories with factored_sync on/off coincide (shared-basis
    rounds use the factored 𝒮; the adaptive round-0 falls back to dense)."""
    key = jax.random.PRNGKey(0)
    from repro.core.fed import FedConfig, FedEngine

    params = {"w1": jax.random.normal(key, (24, 12)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1), (8, 20)),
              "b": jnp.zeros((12,))}

    def loss(p, batch):
        h = jnp.tanh(batch["x"] @ p["w1"] + p["b"])
        return jnp.mean((h[..., :8] @ p["w2"] - batch["y"]) ** 2)

    def batches(seed, k=4, t=2, b=4):
        kk = jax.random.PRNGKey(seed)
        return {"x": jax.random.normal(kk, (k, t, b, 24)),
                "y": jax.random.normal(jax.random.fold_in(kk, 1),
                                       (k, t, b, 20))}

    finals = {}
    for factored in (True, False):
        eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=1e-2,
                                  local_steps=2, factored_sync=factored),
                        loss, params, target_fn=lambda p, l: l.ndim == 2)
        for r in range(3):
            eng.run_round(batches(r))
        finals[factored] = eng.global_trainable
    for a, b in zip(jax.tree_util.tree_leaves(finals[True]),
                    jax.tree_util.tree_leaves(finals[False])):
        assert jnp.allclose(a, b, atol=1e-5)
