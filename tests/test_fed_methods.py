"""Every federated method runs end-to-end and learns a simple task."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.fed import METHODS, FedConfig, FedEngine


def _problem():
    kp = jax.random.PRNGKey(5)
    params = {"l1": {"w": 0.3 * jax.random.normal(kp, (8, 16)),
                     "b": jnp.zeros(16)},
              "l2": {"w": 0.3 * jax.random.normal(jax.random.fold_in(kp, 1),
                                                  (16, 4)),
                     "b": jnp.zeros(4)}}

    def loss(p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["l1"]["w"] + p["l1"]["b"])
        out = h @ p["l2"]["w"] + p["l2"]["b"]
        return jnp.mean((out - y) ** 2)

    kb = jax.random.PRNGKey(9)
    k_clients, t_steps = 4, 5
    x = jax.random.normal(kb, (k_clients, t_steps, 32, 8))
    w_true = 0.5 * jax.random.normal(jax.random.fold_in(kb, 1), (8, 4))
    y = jnp.einsum("ktbi,io->ktbo", x, w_true)
    return params, loss, (x, y)


@pytest.mark.parametrize("method", sorted(METHODS))
def test_method_learns(method):
    params, loss, batches = _problem()
    eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2, local_steps=5,
                              clip_norm=10.0),
                    loss, params)
    eval_b = (batches[0][0, 0], batches[1][0, 0])
    l0 = float(loss(eng.global_params(), eval_b))
    for _ in range(4):
        m = eng.run_round(batches)
    l1 = float(loss(eng.global_params(), eval_b))
    assert jnp.isfinite(l1)
    assert l1 < l0, f"{method}: {l0} -> {l1}"


def test_method_table_matches_paper_table1():
    """Table 1: optimizer / aggregation / sync combinations."""
    t = METHODS
    assert t["fedit"].optimizer == "adam" and t["fedit"].aggregation == "factor_avg"
    assert t["ffa_lora"].optimizer == "sgd"
    assert t["ffa_lora"].trainable == "lora_b"            # A frozen
    assert t["flora"].optimizer == "adamw"
    assert t["flora"].aggregation == "lift_merge"          # lift ΔW
    assert t["fr_lora"].aggregation == "lift_refac"        # lift ΔW
    assert t["fedgalore"].state_sync == "ajive"
    assert t["fedgalore_minus"].state_sync == "none"       # the ablation
    for name, spec in t.items():
        if name not in ("fedgalore", "fedgalore_avg", "fedgalore_avg_svd"):
            assert spec.state_sync == "none", name         # Table 1: Sync=No


def test_galore_state_synced_across_rounds():
    params, loss, batches = _problem()
    eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=1e-2,
                              local_steps=5), loss, params)
    eng.run_round(batches)
    assert eng.synced_v is not None
    leaves = [x for x in jax.tree_util.tree_leaves(eng.synced_v)
              if x is not None]
    assert leaves and all(jnp.all(jnp.isfinite(l)) for l in leaves)

    eng2 = FedEngine(FedConfig(method="fedgalore_minus", rank=4, lr=1e-2,
                               local_steps=5), loss, params)
    eng2.run_round(batches)
    assert eng2.synced_v is None
