import numpy as np
import pytest

from repro.data import (FederatedBatcher, dirichlet_label_partition,
                        heterogeneity_stats, iid_partition, markov_lm,
                        patch_classification, seq_classification)


def test_dirichlet_partition_covers_and_skews():
    labels = np.repeat(np.arange(8), 100)
    parts = dirichlet_label_partition(labels, 10, alpha=0.5, seed=0)
    assert sum(len(p) for p in parts) >= len(labels) * 0.99
    stats = heterogeneity_stats(labels, parts)
    assert stats["mean_tv"] > 0.2          # severe non-IID at alpha=0.5


def test_alpha_controls_heterogeneity():
    """Smaller alpha => larger TV distance to the global distribution
    (paper Appendix H / Figure 6)."""
    labels = np.repeat(np.arange(10), 200)
    tvs = []
    for alpha in (0.1, 1.0, 100.0):
        parts = dirichlet_label_partition(labels, 20, alpha, seed=1)
        tvs.append(heterogeneity_stats(labels, parts)["mean_tv"])
    assert tvs[0] > tvs[1] > tvs[2]


def test_iid_partition_balanced():
    parts = iid_partition(1000, 10, seed=0)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1


def test_seq_classification_learnable_structure():
    task = seq_classification(200, 4, 16, 64, seed=0)
    assert task.tokens.shape == (200, 16)
    assert (task.labels[:, :-1] == -1).all()
    assert set(np.unique(task.class_ids)) <= set(range(4))
    # label token encodes the class
    assert (task.labels[:, -1] == 60 + task.class_ids).all()


def test_markov_lm_types():
    task = markov_lm(50, 3, 12, 32, seed=0)
    assert task.tokens.shape == (50, 12)
    assert (task.labels[:, :-1] == task.tokens[:, 1:]).all()


def test_patch_classification_embeds():
    task = patch_classification(40, 5, 16, 32, vocab=100, seed=0)
    assert task.embeds.shape == (40, 16, 32)
    assert task.labels[:, -1].max() < 100


def test_batcher_shapes_and_cycling():
    task = seq_classification(64, 4, 8, 32, seed=0)
    b = FederatedBatcher(task, n_clients=4, batch_size=4, alpha=0.5, seed=0)
    batch = b.round_batches(local_steps=3)
    assert batch["tokens"].shape == (4, 3, 4, 8)
    assert batch["labels"].shape == (4, 3, 4, 8)
    # cycling: a tiny client shard can still fill many rounds
    for _ in range(10):
        b.round_batches(local_steps=3)


def test_batcher_partial_participation():
    task = seq_classification(64, 4, 8, 32, seed=0)
    b = FederatedBatcher(task, n_clients=10, batch_size=2, alpha=None, seed=0)
    clients = b.sample_clients(3)
    assert len(clients) == 3 and len(set(clients)) == 3
    batch = b.round_batches(2, clients)
    assert batch["tokens"].shape[0] == 3
