import jax
import jax.numpy as jnp
import pytest

from repro.core import lora


def test_lora_init_zero_delta():
    pair = lora.lora_init(jax.random.PRNGKey(0), (8, 16), 4)
    assert pair.b.shape == (8, 4) and pair.a.shape == (4, 16)
    assert jnp.allclose(lora.lora_delta(pair), 0.0)   # B starts at zero


def test_tree_lora_init_targets_only():
    params = {"attn": {"wq": jnp.zeros((8, 8))},
              "norm": {"scale": jnp.zeros((8,))}}
    ad = lora.tree_lora_init(jax.random.PRNGKey(0), params,
                             lambda p, l: "attn" in p, rank=2)
    assert isinstance(ad["attn"]["wq"], lora.LoraPair)
    assert ad["norm"]["scale"] is None


def test_apply_lora_additive():
    params = {"w": jnp.ones((4, 4))}
    pair = lora.LoraPair(a=jnp.ones((1, 4)), b=jnp.ones((4, 1)))
    out = lora.apply_lora(params, {"w": pair}, scale=2.0)
    assert jnp.allclose(out["w"], 1.0 + 2.0)


def test_rank_tail_energy_zero_for_lowrank():
    pair = lora.LoraPair(a=jax.random.normal(jax.random.PRNGKey(0), (2, 8)),
                         b=jax.random.normal(jax.random.PRNGKey(1), (8, 2)))
    delta = pair.b @ pair.a
    assert float(lora.rank_tail_energy(delta, 2)) < 1e-4
    assert float(lora.rank_tail_energy(delta, 1)) > 1e-3


def test_effective_rank():
    d = jnp.diag(jnp.array([5.0, 3.0, 1e-9, 0.0]))
    assert int(lora.effective_rank(d)) == 2


def test_svd_truncate_best_approx():
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (10, 10))
    pair = lora.svd_truncate(w, 3)
    err = jnp.linalg.norm(pair.b @ pair.a - w)
    assert jnp.allclose(err, lora.rank_tail_energy(w, 3), rtol=1e-4)
