import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, cache_len, get_config,
                           input_specs, list_configs, shape_variant,
                           smoke_variant)


def test_all_assigned_archs_registered():
    names = list_configs()
    for a in ASSIGNED_ARCHS:
        assert a in names


def test_exact_assigned_dimensions():
    """The configs must match the assignment table exactly."""
    c = get_config("granite-moe-1b-a400m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (24, 1024, 16, 8)
    assert (c.d_ff, c.vocab_size, c.n_experts, c.experts_per_token) == \
        (512, 49155, 32, 8)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads) == (60, 5120, 128)
    assert (c.kv_lora_rank, c.n_experts, c.experts_per_token,
            c.n_shared_experts) == (512, 160, 6, 2)
    c = get_config("command-r-35b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 8192, 64, 8, 22528, 256000)
    c = get_config("mistral-nemo-12b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 32, 8, 14336, 131072)
    c = get_config("qwen1.5-0.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == (24, 1024, 16, 16, 2816, 151936, True)
    c = get_config("pixtral-12b")
    assert c.family == "vlm" and c.frontend == "vision"
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.n_experts, c.experts_per_token) == \
        (72, 8192, 16, 2)
    assert c.attn_period == 8                       # 1:7 interleave
    c = get_config("starcoder2-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (32, 4608, 36, 4, 18432, 49152)
    c = get_config("musicgen-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == \
        (48, 1536, 24, 6144, 2048)
    c = get_config("rwkv6-1.6b")
    assert c.rwkv and (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (24, 2048, 7168, 65536)


def test_layer_kinds_jamba():
    c = get_config("jamba-1.5-large-398b")
    kinds = c.layer_kinds()
    attn_layers = [i for i, (m, _) in enumerate(kinds) if m == "attn"]
    assert len(attn_layers) == 9                    # 72 / 8
    assert all(i % 8 == 3 for i in attn_layers)
    moe_layers = [i for i, (_, f) in enumerate(kinds) if f == "moe"]
    assert len(moe_layers) == 36                    # every other layer
    assert c.block_period() == 8 and c.n_blocks() == 9


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_long_context_variant_subquadratic(arch):
    cfg = shape_variant(get_config(arch), SHAPES["long_500k"])
    assert cfg.sub_quadratic(), arch
    cl = cache_len(cfg, SHAPES["long_500k"])
    assert cl <= 8192 or cfg.rwkv            # ring buffer stays O(window)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_no_allocation(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    for leaf in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if SHAPES[shape].kind == "train":
        assert specs["tokens"].shape[0] == SHAPES[shape].global_batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_variant_constraints(arch):
    s = smoke_variant(get_config(arch))
    assert s.n_layers == 2
    assert s.d_model <= 512
    assert s.n_experts <= 4
    assert s.family == get_config(arch).family
