"""Lock-in suite for the batched-bucket 𝒮 and the one-round pipelined scan.

Two independent equivalences, each with its surviving oracle:

* **Bucketed 𝒮 ≡ per-leaf 𝒮** (`state_sync.map_sync_leaves`): shape-bucketed
  vmapped sync must reproduce the per-leaf loop (`bucketed=False`) for every
  protocol, both sides, stacked scan-block leaves, shared AND heterogeneous
  (transfer-Gram) bases, masked cohorts, and the robust-𝒜 round variants.
  On CPU the batched eigh is bit-identical, so tolerances are fp-noise tight.

* **Pipelined rounds ≡ sequential rounds**: the pipelined `run_rounds` scan
  (round k's 𝒮 deferred to the top of round k+1, post-scan drain) is a pure
  re-association of the sequential schedule — state-for-state identical
  results in both the engine (`core.fed`) and the sharded runtime
  (`fedsim.runtime`), with `pipeline_sync=False` as the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projector as proj
from repro.core import state_sync as sync
from repro.core.fed import FedConfig, FedEngine

PROTOCOLS = ["avg", "avg_svd", "ajive"]
GALORE_METHODS = ["fedgalore", "fedgalore_minus", "fedgalore_avg",
                  "fedgalore_avg_svd"]

KEY = jax.random.PRNGKey(11)


def _trees_close(a, b, atol):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        assert jnp.allclose(x, y, atol=atol), float(jnp.max(jnp.abs(x - y)))


# ------------------------------------------------ unit: map_sync_leaves -----

def _mixed_leaves(c=5, r=4):
    """A model-tree-like leaf list: two shape buckets with >1 member (the
    vmapped path), singleton buckets (the skip-vmap path), a left-side leaf,
    a stacked (C, nb, m, r) scan-block leaf pair, and a None (non-adapted)
    leaf. Per-client bases so the same list serves the hetero transfer-Gram
    path."""
    def v_right(key, m):
        return jnp.abs(jax.random.normal(key, (c, m, r))) + 0.1

    def v_left(key, n):
        return jnp.abs(jax.random.normal(key, (c, r, n))) + 0.1

    def b_stack(seed, dim):
        return jnp.stack([proj.random_basis(seed + i, dim, r)
                          for i in range(c)])

    k = [jax.random.fold_in(KEY, i) for i in range(8)]
    v_leaves = [v_right(k[0], 16), v_right(k[1], 16),       # bucket of 2
                v_right(k[2], 12),                          # singleton
                v_left(k[3], 24), v_left(k[4], 24),         # bucket of 2
                None,                                       # non-adapted
                jnp.abs(jax.random.normal(k[5], (c, 3, 16, r))) + 0.1,
                jnp.abs(jax.random.normal(k[6], (c, 3, 16, r))) + 0.1]
    b_leaves = [b_stack(0, 24), b_stack(10, 24),
                b_stack(20, 20),
                b_stack(30, 8), b_stack(40, 8),
                None,
                jnp.stack([b_stack(50 + j, 24) for j in range(3)], axis=1),
                jnp.stack([b_stack(80 + j, 24) for j in range(3)], axis=1)]
    return v_leaves, b_leaves


@pytest.mark.parametrize("protocol", PROTOCOLS)
@pytest.mark.parametrize("hetero", [False, True])
@pytest.mark.parametrize("masked", [False, True])
def test_map_sync_leaves_bucketed_matches_per_leaf(protocol, hetero, masked):
    """Bucketed vmapped 𝒮 ≡ per-leaf loop across mixed shape buckets, both
    sides, stacked leaves, None passthrough, shared and hetero bases, and
    the masked-cohort (`exclude_zero_weights`) contract."""
    c = 5
    v_leaves, b_leaves = _mixed_leaves(c)
    w = jnp.array([1.0, 2.0, 0.0, 1.0, 3.0]) if masked \
        else jnp.array([1.0, 2.0, 1.0, 1.0, 3.0])

    def leaf_fn(v_stack, bst):
        rank = bst.shape[-1]
        side = proj.RIGHT if v_stack.shape[-1] == rank else proj.LEFT
        if hetero:
            return sync.sync_block_hetero_factored(
                protocol, v_stack, bst, side, w, rank,
                exclude_zero_weights=masked)
        return sync.sync_block_synced_factored(
            protocol, v_stack, side, w, rank, exclude_zero_weights=masked)

    ref = sync.map_sync_leaves(leaf_fn, v_leaves, b_leaves, bucketed=False)
    out = sync.map_sync_leaves(leaf_fn, v_leaves, b_leaves, bucketed=True)
    assert out[5] is None and ref[5] is None
    for o, rf in zip(out, ref):
        if rf is None:
            assert o is None
            continue
        assert o.shape == rf.shape
        assert jnp.allclose(o, rf, atol=1e-6), float(jnp.max(jnp.abs(o - rf)))


def test_map_sync_leaves_rejects_nothing_on_all_none():
    out = sync.map_sync_leaves(lambda v, b: v, [None, None], [None, None])
    assert out == [None, None]


def test_ajive_sketch_route_matches_dense_oracle():
    """Large-cohort wide-block AJIVE (d > 64 and C·k > 64 → the sketched
    Rayleigh–Ritz joint basis) must still match the dense lift → 𝒮 →
    re-project oracle on a well-separated shared-signal stack, and the
    bucketed dispatch must be exact parity with the per-leaf call."""
    c, m, n, r = 20, 96, 24, 4
    basis = proj.random_basis(0, n, r)
    scale = jnp.linspace(6.0, 2.0, r)
    base = jax.random.normal(KEY, (m, r)) * scale[None, :]
    v_stack = jnp.stack([jnp.abs(base + 0.1 * jax.random.normal(
        jax.random.fold_in(KEY, i), (m, r))) for i in range(c)])
    w = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 99), (c,))) + 0.5

    fact = sync.sync_block_synced_factored("ajive", v_stack, proj.RIGHT, w, r)
    views = jnp.einsum("kmr,nr->kmn", v_stack, basis)
    dense = jnp.maximum(sync.project_state(
        sync.sync_lifted_views("ajive", views, w, r), basis, proj.RIGHT), 0.0)
    assert jnp.allclose(fact, dense, atol=1e-3), \
        float(jnp.max(jnp.abs(fact - dense)))

    out = sync.map_sync_leaves(
        lambda v, b: sync.sync_block_synced_factored(
            "ajive", v, proj.RIGHT, w, r),
        [v_stack, v_stack + 0.01], [jnp.zeros((c, n, r))] * 2, bucketed=True)
    assert jnp.allclose(out[0], fact, atol=1e-6)


# ----------------------------------------------------- engine (core.fed) ----

def _problem():
    """Two same-shape hidden layers so the engine's 𝒮 tree has a real
    multi-leaf shape bucket (plus the differently-shaped head)."""
    k1, k2, k3 = (jax.random.fold_in(KEY, i) for i in range(3))
    params = {"l1": {"w": 0.3 * jax.random.normal(k1, (8, 16)),
                     "b": jnp.zeros(16)},
              "l2": {"w": 0.3 * jax.random.normal(k2, (8, 16)),
                     "b": jnp.zeros(16)},
              "head": {"w": 0.3 * jax.random.normal(k3, (16, 4)),
                       "b": jnp.zeros(4)}}

    def loss(p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["l1"]["w"] + p["l1"]["b"]
                     + x @ p["l2"]["w"] + p["l2"]["b"])
        out = h @ p["head"]["w"] + p["head"]["b"]
        return jnp.mean((out - y) ** 2)

    return params, loss


def _round_batches(seed, k_rounds=None, k=4, t=5, b=16):
    kb = jax.random.PRNGKey(seed)
    lead = (k, t) if k_rounds is None else (k_rounds, k, t)
    x = jax.random.normal(kb, lead + (b, 8))
    w_true = 0.5 * jax.random.normal(jax.random.fold_in(kb, 1), (8, 4))
    return (x, jnp.einsum("...bi,io->...bo", x, w_true))


def _engine(method, **over):
    params, loss = _problem()
    cfg = dict(method=method, rank=4, lr=3e-2, local_steps=5, clip_norm=10.0,
               weight_decay=0.01)
    cfg.update(over)
    return FedEngine(FedConfig(**cfg), loss, params)


@pytest.mark.parametrize("method", GALORE_METHODS)
def test_engine_bucketed_sync_matches_per_leaf(method):
    """bucketed_sync=True ≡ bucketed_sync=False through full engine rounds —
    covers the adaptive round-0 hetero (transfer-Gram) 𝒮 and the shared-basis
    steady state, on a tree with a genuine multi-leaf shape bucket."""
    engines = {}
    for bucketed in (True, False):
        eng = _engine(method, bucketed_sync=bucketed)
        for r in range(2):
            eng.run_round(_round_batches(r))
        engines[bucketed] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=1e-6)
    if engines[False].synced_v is not None:
        _trees_close(engines[True].synced_v, engines[False].synced_v,
                     atol=1e-6)


@pytest.mark.parametrize("method", GALORE_METHODS)
def test_engine_pipelined_rounds_match_sequential(method):
    """Pipelined K-round scan ≡ sequential scan (pipeline_sync=False oracle)
    over K=5 rounds: global trainable, synced moments, and every per-round
    loss, for every GaLore method."""
    outs = {}
    for pipe in (True, False):
        eng = _engine(method, pipeline_sync=pipe)
        m = eng.run_rounds(_round_batches(3, k_rounds=5))
        outs[pipe] = (eng.global_trainable, eng.synced_v, m["local_loss"])
    for a, b in zip(outs[True], outs[False]):
        _trees_close(a, b, atol=1e-6)


def test_engine_pipelined_masked_rounds_match_sequential():
    """Per-round participation masks ride the pipelined scan: the deferred 𝒮
    must use the *previous* round's mask-zeroed weights (carried alongside
    the unsynced states), matching the sequential masked scan exactly."""
    k_rounds, c = 5, 4
    masks = np.ones((k_rounds, c), bool)
    masks[1, 0] = False
    masks[3, 2] = masks[3, 3] = False
    outs = {}
    for pipe in (True, False):
        eng = _engine("fedgalore", pipeline_sync=pipe)
        m = eng.run_rounds(_round_batches(5, k_rounds=k_rounds), masks=masks)
        outs[pipe] = (eng.global_trainable, eng.synced_v, m["local_loss"])
    for a, b in zip(outs[True], outs[False]):
        _trees_close(a, b, atol=1e-6)


@pytest.mark.parametrize("robust", ["norm_clip", "trimmed_mean", "geomedian"])
def test_engine_pipelined_robust_agg_matches_sequential(robust):
    """The guarded (robust-𝒜) scan pipelines too: skip_sync captures the
    post-guard effective weights, so deferring 𝒮 by one round changes
    nothing."""
    outs = {}
    for pipe in (True, False):
        eng = _engine("fedgalore", robust_agg=robust, pipeline_sync=pipe)
        m = eng.run_rounds(_round_batches(7, k_rounds=3))
        outs[pipe] = (eng.global_trainable, eng.synced_v, m["local_loss"])
    for a, b in zip(outs[True], outs[False]):
        _trees_close(a, b, atol=1e-6)


def test_engine_single_round_ignores_pipeline_flag():
    """run_round (one round) has nothing to overlap — pipeline_sync must not
    change its result vs the sequential engine."""
    engines = {}
    for pipe in (True, False):
        eng = _engine("fedgalore", pipeline_sync=pipe)
        for r in range(2):
            eng.run_round(_round_batches(r))
        engines[pipe] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=0.0)
    _trees_close(engines[True].synced_v, engines[False].synced_v, atol=0.0)


# ---------------------------------------------- sharded runtime (fedsim) ----

def _runtime_setup(c_clients=3):
    from repro.configs import get_config, smoke_variant
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=2, refresh_mode="random")

    def batches(seed, k_rounds=None):
        kk = jax.random.PRNGKey(seed)
        lead = ((c_clients, 2, 2, 8) if k_rounds is None
                else (k_rounds, c_clients, 2, 2, 8))
        toks = jax.random.randint(kk, lead, 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    return cfg, mesh, spec, batches


def test_runtime_bucketed_sync_matches_per_leaf():
    """ShardedFederation bucketed in-mesh 𝒮 ≡ the per-leaf loop on the real
    transformer tree (shared seeded bases)."""
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    feds = {b: ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                 bucketed_sync=b)
            for b in (True, False)}
    for r in range(2):
        bat = batches(r)
        feds[True].run_round(bat)
        feds[False].run_round(bat)
    _trees_close(feds[True].global_trainable, feds[False].global_trainable,
                 atol=1e-6)
    _trees_close(feds[True].opt_states, feds[False].opt_states, atol=1e-6)


def test_runtime_bucketed_hetero_sync_matches_per_leaf():
    """refresh_mode='svd' diverges the bases, so the bucketed 𝒮 runs the
    transfer-Gram hetero path under vmap — must match the per-leaf loop."""
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=2, refresh_mode="svd",
                     refresh_every=2)
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 2, 2, 8), 0,
                              cfg.vocab_size)
    bat = {"tokens": toks, "labels": toks}
    feds = {b: ShardedFederation(cfg, spec, mesh, 3, state_sync="ajive",
                                 bucketed_sync=b)
            for b in (True, False)}
    feds[True].run_round(bat)
    feds[False].run_round(bat)
    _trees_close(feds[True].global_trainable, feds[False].global_trainable,
                 atol=1e-6)
    _trees_close(feds[True].opt_states, feds[False].opt_states, atol=1e-6)


def test_runtime_pipelined_rounds_match_sequential():
    """Pipelined run_rounds ≡ sequential in the sharded runtime, unmasked
    and with per-round participation masks (the deferred 𝒮 carries each
    round's mask-zeroed weights)."""
    from repro.fedsim import ShardedFederation

    c, k_rounds = 3, 5
    cfg, mesh, spec, batches = _runtime_setup(c)
    bat = batches(7, k_rounds=k_rounds)
    masks = np.ones((k_rounds, c), bool)
    masks[0, 1] = False
    masks[2, 0] = False
    masks[4, 1] = masks[4, 2] = False
    for mk in (None, masks):
        outs = {}
        for pipe in (True, False):
            fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                    pipeline_sync=pipe)
            m = fed.run_rounds(bat, masks=mk)
            outs[pipe] = (fed.global_trainable, fed.opt_states, m["losses"])
        for a, b in zip(outs[True], outs[False]):
            _trees_close(a, b, atol=1e-6)


def test_runtime_quarantine_pipelines_and_matches_sequential():
    """Quarantine used to force the sequential scan (the screen rewrites
    effective weights inside the round, invisible to the deferred 𝒮). The
    raw round core now returns its post-screen weights (return_weights) and
    they ride the scan carry — so the quarantined scan pipelines AND
    matches the sequential oracle, unmasked and masked."""
    from repro.fedsim import ShardedFederation

    c, k_rounds = 3, 4
    cfg, mesh, spec, batches = _runtime_setup(c)
    fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                            quarantine=True, pipeline_sync=True)
    assert fed._pipeline_rounds()
    fed_off = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                pipeline_sync=False)
    assert not fed_off._pipeline_rounds()

    bat = batches(9, k_rounds=k_rounds)
    masks = np.ones((k_rounds, c), bool)
    masks[1, 0] = False
    masks[3, 2] = False
    for mk in (None, masks):
        outs = {}
        for pipe in (True, False):
            fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                    quarantine=True, quarantine_zmax=50.0,
                                    pipeline_sync=pipe)
            m = fed.run_rounds(bat, masks=mk)
            outs[pipe] = (fed.global_trainable, fed.opt_states, m["losses"])
        for a, b in zip(outs[True], outs[False]):
            _trees_close(a, b, atol=1e-6)
