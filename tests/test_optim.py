import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.optim.base import apply_updates, global_norm


def test_adamw_first_step_analytic():
    """After one step from zero state, bias-corrected Adam update == g/(|g|+eps)
    elementwise (sign-like)."""
    params = {"w": jnp.zeros((4,))}
    g = {"w": jnp.array([1.0, -2.0, 0.5, 0.0])}
    tx = optim.scale_by_adam(eps=1e-8)
    st = tx.init(params)
    u, st = tx.update(g, st, params)
    expect = g["w"] / (jnp.abs(g["w"]) + 1e-8)
    assert jnp.allclose(u["w"], expect, atol=1e-5)


def test_sgd_matches_manual():
    params = {"w": jnp.ones((3,))}
    tx = optim.sgd(0.1)
    st = tx.init(params)
    g = {"w": jnp.array([1.0, 2.0, 3.0])}
    u, st = tx.update(g, st, params)
    new = apply_updates(params, u)
    assert jnp.allclose(new["w"], params["w"] - 0.1 * g["w"])


def test_momentum_accumulates():
    params = {"w": jnp.zeros((1,))}
    tx = optim.scale_by_momentum(0.9)
    st = tx.init(params)
    g = {"w": jnp.ones((1,))}
    u1, st = tx.update(g, st, params)
    u2, st = tx.update(g, st, params)
    assert jnp.allclose(u2["w"], 1.9)        # v = 0.9*1 + 1


def test_clip_by_global_norm():
    tx = optim.clip_by_global_norm(1.0)
    st = tx.init(None)
    g = {"a": jnp.full((4,), 10.0)}
    u, _ = tx.update(g, st, None)
    assert float(global_norm(u)) <= 1.0 + 1e-5
    g_small = {"a": jnp.full((4,), 0.01)}
    u2, _ = tx.update(g_small, st, None)
    assert jnp.allclose(u2["a"], g_small["a"])   # below threshold: untouched


def test_weight_decay_decoupled():
    tx = optim.add_decayed_weights(0.1)
    st = tx.init(None)
    u, _ = tx.update({"w": jnp.zeros((2,))}, st, {"w": jnp.ones((2,))})
    assert jnp.allclose(u["w"], 0.1)


def test_cosine_schedule_endpoints():
    sched = optim.cosine_with_warmup(1.0, warmup_steps=10, total_steps=110)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-6
    assert float(sched(5)) == pytest.approx(0.5, abs=1e-6)
    assert float(sched(110)) < 1e-6
    # monotone decay after warmup
    vals = [float(sched(s)) for s in range(10, 111, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_converges_quadratic():
    target = jnp.array([3.0, -2.0])
    params = {"w": jnp.zeros((2,))}
    tx = optim.adamw(0.1, weight_decay=0.0)
    st = tx.init(params)
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        u, st = tx.update(g, st, params)
        params = apply_updates(params, u)
    assert jnp.allclose(params["w"], target, atol=1e-2)
