import jax
import jax.numpy as jnp
import pytest

from repro.core import galore as gal
from repro.core import projector as proj
from repro.optim.adamw import scale_by_adam
from repro.optim.base import apply_updates


def _loss(p, x):
    return jnp.sum((x @ p["w"]) ** 2) + jnp.sum(p["b"] ** 2)


@pytest.fixture
def setup():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (16, 16)),
              "b": jnp.zeros((16,))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))
    return params, x


def test_full_rank_galore_equals_adamw(setup):
    """With r = n and the IDENTITY basis, GaLore must reproduce dense Adam
    exactly (the projection becomes a no-op). The projector refreshes at step
    0, so we align both optimizers after one step, overwrite the basis with
    identity, copy Adam's moments into the projected buffers, and require the
    subsequent trajectories to coincide."""
    params, x = setup
    n = 16
    cfg = gal.GaloreConfig(rank=n, refresh_every=10**9, adaptive_steps=0,
                           refresh_mode="random")
    tx_g = gal.scale_by_galore(cfg, target_fn=lambda p, l: l.ndim == 2)
    tx_a = scale_by_adam()
    st_g = tx_g.init(params)
    st_a = tx_a.init(params)

    g0 = jax.grad(_loss)(params, x)
    _, st_g = tx_g.update(g0, st_g, params)      # triggers the step-0 refresh
    _, st_a = tx_a.update(g0, st_a, params)

    # Align: identity basis, Adam's moments, same counts.
    blocks = {"w": gal.GaloreBlockState(basis=jnp.eye(n),
                                        m=st_a.m["w"], v=st_a.v["w"]),
              "b": gal.DenseMoments(m=st_a.m["b"], v=st_a.v["b"])}
    st_g = gal.GaloreState(count=st_a.count, seed=st_g.seed, blocks=blocks)

    p_g, p_a = params, params
    for i in range(5):
        g_g = jax.grad(_loss)(p_g, x)
        g_a = jax.grad(_loss)(p_a, x)
        u_g, st_g = tx_g.update(g_g, st_g, p_g)
        u_a, st_a = tx_a.update(g_a, st_a, p_a)
        p_g = apply_updates(p_g, jax.tree_util.tree_map(lambda u: -0.01 * u, u_g))
        p_a = apply_updates(p_a, jax.tree_util.tree_map(lambda u: -0.01 * u, u_a))
    assert jnp.allclose(p_g["w"], p_a["w"], atol=1e-5)
    assert jnp.allclose(p_g["b"], p_a["b"], atol=1e-5)


def test_projected_state_shapes(setup):
    params, _ = setup
    cfg = gal.GaloreConfig(rank=4)
    st = gal.galore_init(cfg, params)
    assert st.blocks["w"].basis.shape == (16, 4)
    assert st.blocks["w"].m.shape == (16, 4)          # O(n·r), not O(n²)
    assert isinstance(st.blocks["b"], gal.DenseMoments)


def test_loss_decreases(setup):
    params, x = setup
    cfg = gal.GaloreConfig(rank=4, refresh_every=3, adaptive_steps=1)
    tx = gal.galore_adamw(cfg, 2e-3, 0.0)
    st = tx.init(params)
    l0 = _loss(params, x)
    for _ in range(40):
        g = jax.grad(_loss)(params, x)
        u, st = tx.update(g, st, params)
        params = apply_updates(params, u)
    assert float(_loss(params, x)) < float(l0)


def test_seeded_refresh_deterministic_across_replicas(setup):
    """Two 'clients' with the same seed must hold identical bases after a
    refresh — the server-broadcasts-a-seed protocol (Appendix D)."""
    params, x = setup
    cfg = gal.GaloreConfig(rank=4, refresh_every=2, adaptive_steps=0,
                           refresh_mode="random")
    tx = gal.galore_adamw(cfg, 1e-3, 0.0)

    def run(client_x):
        st = tx.init(params)
        p = params
        for _ in range(3):
            g = jax.grad(_loss)(p, client_x)
            u, st = tx.update(g, st, p)
            p = apply_updates(p, u)
        return gal.galore_state_of(st).blocks["w"].basis

    b1 = run(x)
    b2 = run(x * 2.0 + 1.0)    # different data, same seed
    assert jnp.allclose(b1, b2)


def test_stacked_equals_per_layer():
    """A stacked (nb, m, n) leaf must update exactly like nb separate 2-D
    leaves with the same per-layer keys."""
    key = jax.random.PRNGKey(2)
    nb, m, n, r = 3, 8, 8, 2
    w = jax.random.normal(key, (nb, m, n))
    g = jax.random.normal(jax.random.fold_in(key, 1), (nb, m, n))
    cfg = gal.GaloreConfig(rank=r, refresh_every=10**9, refresh_mode="random")

    tx = gal.scale_by_galore(cfg)
    st = tx.init({"w": w})
    u_stacked, st2 = tx.update({"w": g}, st, None)

    # manual per-layer using the same bases
    bases = st.blocks["w"].basis
    for i in range(nb):
        gt = g[i] @ bases[i]
        mm = 0.1 * gt
        vv = 0.001 * gt * gt
        c1 = 1 - 0.9
        c2 = 1 - 0.999
        ut = (mm / c1) / (jnp.sqrt(vv / c2) + cfg.eps)
        u_ref = ut @ bases[i].T
        assert jnp.allclose(u_stacked["w"][i], u_ref, rtol=1e-4, atol=1e-5)


def test_extract_and_install_v(setup):
    params, x = setup
    cfg = gal.GaloreConfig(rank=4)
    tx = gal.galore_adamw(cfg, 1e-3, 0.0)
    st = tx.init(params)
    g = jax.grad(_loss)(params, x)
    _, st = tx.update(g, st, params)
    gstate = gal.galore_state_of(st)
    v = gal.extract_projected_v(gstate)
    assert v["w"].shape == (16, 4)
    assert v["b"] is None
    new_v = jax.tree_util.tree_map(
        lambda t: t * 2 if t is not None else None, v,
        is_leaf=lambda t: t is None)
    g2 = gal.with_projected_v(gstate, new_v)
    assert jnp.allclose(g2.blocks["w"].v, 2 * gstate.blocks["w"].v)


def test_manual_refresh_reprojects(setup):
    params, x = setup
    cfg = gal.GaloreConfig(rank=4, refresh_mode="random")
    tx = gal.galore_adamw(cfg, 1e-3, 0.0)
    st = tx.init(params)
    g = jax.grad(_loss)(params, x)
    _, st = tx.update(g, st, params)
    gstate = gal.galore_state_of(st)
    refreshed = gal.manual_refresh(cfg, gstate, 7)
    assert not jnp.allclose(refreshed.blocks["w"].basis,
                            gstate.blocks["w"].basis)
    # v stays non-negative after the change-of-basis clamp
    assert float(jnp.min(refreshed.blocks["w"].v)) >= 0.0
    # buffers follow the Appendix A.1 transfer rule
    expect = proj.reproject(gstate.blocks["w"].m, gstate.blocks["w"].basis,
                            refreshed.blocks["w"].basis, proj.RIGHT)
    assert jnp.allclose(refreshed.blocks["w"].m, expect, atol=1e-5)
