"""Multi-tenant serving: batched adapter kernel, scan decode, slot batching.

Locks in the serving stack end to end: the scalar-prefetch batched
heterogeneous-adapter kernel against its gather+einsum oracle, per-request
parity of a mixed-adapter decode batch against merged-weight references,
scan-decode bit-identity with the eager loop, per-slot decode positions,
the AdapterStore wire format (ragged ranks, spill round-trip, cold rows =
pristine base), and SlotServer continuous batching (retire + admit) parity
with straight generation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.core import projector as proj
from repro.core.fed import merge_dense, split_trainable
from repro.core.population import ClientStateStore
from repro.kernels import ops, ref
from repro.launch import adapters as adapters_lib
from repro.launch.serve import Request, SlotServer, generate, generate_scan
from repro.models import layers
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _rand_tables(key, g, m, n, r, side, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    bdim, rshape = (n, (g, m, r)) if side == "right" else (m, (g, r, n))
    bases = jax.random.normal(ks[0], (g, bdim, r), dtype) / np.sqrt(bdim)
    rts = 0.1 * jax.random.normal(ks[1], rshape, dtype)
    scales = 1.0 + 0.1 * jax.random.normal(ks[2], (g,), jnp.float32)
    return bases, rts, scales


class TestBatchedKernel:
    @pytest.mark.parametrize("side,m,n", [("right", 96, 64), ("left", 48, 96)])
    @pytest.mark.parametrize("t", [1, 7, 16])   # 7: masked row tail
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, side, m, n, t, dtype):
        b, g, r = 5, 3, 4
        ks = jax.random.split(KEY, 2)
        x = jax.random.normal(ks[0], (b, t, m), dtype)
        w = jax.random.normal(ks[1], (m, n), dtype) / np.sqrt(m)
        bases, rts, scales = _rand_tables(jax.random.fold_in(KEY, 1),
                                          g, m, n, r, side, dtype)
        ids = jnp.array([0, 2, 1, 2, 0], jnp.int32)
        out_k = ops.lowrank_linear_batched(x, w, bases, rts, scales, ids,
                                           side=side, block_t=8)
        out_r = ref.lowrank_linear_batched_ref(x, w, bases, rts, scales,
                                               ids, side=side)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        assert out_k.shape == out_r.shape == (b, t, n)
        assert jnp.allclose(out_k.astype(jnp.float32),
                            out_r.astype(jnp.float32), atol=tol)

    def test_2d_x_and_duplicate_ids(self):
        b, m, n, g, r = 6, 32, 48, 2, 3
        x = jax.random.normal(KEY, (b, m))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (m, n)) / 6.0
        bases, rts, scales = _rand_tables(jax.random.fold_in(KEY, 2),
                                          g, m, n, r, "left")
        ids = jnp.array([1, 1, 1, 0, 0, 1], jnp.int32)   # duplicates
        out_k = ops.lowrank_linear_batched(x, w, bases, rts, scales, ids,
                                           side="left")
        out_r = ref.lowrank_linear_batched_ref(x, w, bases, rts, scales,
                                               ids, side="left")
        assert out_k.shape == (b, n)
        assert jnp.allclose(out_k, out_r, atol=1e-5)
        # duplicate rows with identical inputs see identical outputs
        same = jax.random.normal(jax.random.fold_in(KEY, 3), (m,))
        x2 = jnp.broadcast_to(same, (b, m))
        out2 = ops.lowrank_linear_batched(x2, w, bases, rts, scales, ids,
                                          side="left")
        assert jnp.allclose(out2[0], out2[1], atol=0)
        assert jnp.allclose(out2[3], out2[4], atol=0)

    @pytest.mark.parametrize("side", ["right", "left"])
    def test_ragged_ranks_zero_padded_exact(self, side):
        """A table padded from r_g to r_max applies the exact same delta:
        the zero columns/rows contribute exactly zero."""
        b, t, m, n, g = 3, 4, 40, 24, 2
        r_small, r_max = 2, 5
        x = jax.random.normal(KEY, (b, t, m))
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (m, n)) / 6.0
        bases, rts, scales = _rand_tables(jax.random.fold_in(KEY, 2),
                                          g, m, n, r_small, side)
        pad_b = [(0, 0)] * 3
        pad_b[2] = (0, r_max - r_small)
        pad_r = [(0, 0)] * 3
        pad_r[2 if side == "right" else 1] = (0, r_max - r_small)
        bases_p = jnp.pad(bases, pad_b)
        rts_p = jnp.pad(rts, pad_r)
        ids = jnp.array([0, 1, 0], jnp.int32)
        small = ops.lowrank_linear_batched(x, w, bases, rts, scales, ids,
                                           side=side)
        padded = ops.lowrank_linear_batched(x, w, bases_p, rts_p, scales,
                                            ids, side=side)
        assert jnp.array_equal(small, padded)


@pytest.fixture(scope="module")
def qwen():
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _adapter_fixture(cfg, params, g, rank=3, ragged=False):
    """An AdapterStore with g random tenants; returns (store, factors)."""
    tf = adapters_lib.serving_target_fn(cfg)
    store = adapters_lib.AdapterStore(params, tf, g, rank)
    rng = np.random.default_rng(7)
    factors = []
    for i in range(g):
        if ragged and i % 2:
            # draw at a smaller rank; the store zero-pads on write
            small = adapters_lib.AdapterStore(params, tf, 1, rank - 1)
            basis, rt = small.random_factors(rng, rt_scale=0.05)
        else:
            basis, rt = store.random_factors(rng, rt_scale=0.05)
        scale = 1.0 - 0.01 * i
        store.put(i, rt, basis, scale=scale)
        factors.append((basis, rt, scale))
    return store, factors


def _merged(params, cfg, basis, rt, scale):
    tf = adapters_lib.serving_target_fn(cfg)
    trainable, frozen = split_trainable(params, tf)

    def lift(w, b, r):
        w32 = w.astype(jnp.float32)
        if proj.proj_side(w.shape) == proj.RIGHT:
            d = jnp.einsum("...mr,...nr->...mn", jnp.asarray(r),
                           jnp.asarray(b))
        else:
            d = jnp.einsum("...mr,...rn->...mn", jnp.asarray(b),
                           jnp.asarray(r))
        return (scale * w32 + d).astype(w.dtype)

    return merge_dense(frozen, jax.tree_util.tree_map(lift, trainable,
                                                      basis, rt))


class TestHeteroAdapterServing:
    def test_16_adapters_match_per_request_reference(self, qwen):
        """One compiled decode batch serving 16 distinct adapters matches
        each row's single-adapter merged-weight reference <= 1e-5."""
        cfg, params = qwen
        g = b = 16
        store, factors = _adapter_fixture(cfg, params, g)
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (b, 8), 0,
                                     cfg.vocab_size)
        ids = jnp.arange(b, dtype=jnp.int32)
        state = M.init_decode_state(cfg, b, 16)
        with layers.adapter_ids(ids):
            logits, _ = M.prefill(served, cfg, prompts, state)
        for row in range(b):
            mp = _merged(params, cfg, *factors[row])
            st = M.init_decode_state(cfg, 1, 16)
            lg, _ = M.prefill(mp, cfg, prompts[row:row + 1], st)
            assert jnp.max(jnp.abs(logits[row] - lg[0])) <= 1e-5, row

    def test_generated_tokens_match_per_request(self, qwen):
        cfg, params = qwen
        g = 4
        store, factors = _adapter_fixture(cfg, params, g, ragged=True)
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(2), (g, 8), 0,
                                     cfg.vocab_size)
        ids = jnp.arange(g, dtype=jnp.int32)
        batch_out = generate_scan(served, cfg, prompts, 5, 16, adapters=ids)
        for row in range(g):
            mp = _merged(params, cfg, *factors[row])
            one = generate(mp, cfg, prompts[row:row + 1], 5, 16)
            assert jnp.array_equal(batch_out[row], one[0]), row

    def test_pallas_kernel_path_in_model(self, qwen):
        """dense() routed through the scalar-prefetch kernel (interpret)
        matches the default einsum-reference routing."""
        cfg, params = qwen
        store, _ = _adapter_fixture(cfg, params, 4)
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                     cfg.vocab_size)
        ids = jnp.array([2, 0, 3, 1], jnp.int32)
        state = M.init_decode_state(cfg, 4, 16)
        with layers.adapter_ids(ids):
            ref_logits, _ = M.prefill(served, cfg, prompts, state)
        state = M.init_decode_state(cfg, 4, 16)
        with layers.lowrank_pallas_override(True), layers.adapter_ids(ids):
            pal_logits, _ = M.prefill(served, cfg, prompts, state)
        assert jnp.max(jnp.abs(ref_logits - pal_logits)) <= 1e-4

    def test_errors(self, qwen):
        cfg, params = qwen
        store, _ = _adapter_fixture(cfg, params, 2)
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 4), 0,
                                     cfg.vocab_size)
        state = M.init_decode_state(cfg, 2, 8)
        with pytest.raises(ValueError, match="outside an adapter_ids"):
            M.prefill(served, cfg, prompts, state)
        with pytest.raises(ValueError, match="one id per decode row"):
            with layers.adapter_ids(jnp.zeros((3,), jnp.int32)):
                M.prefill(served, cfg, prompts, state)


class TestScanDecode:
    @pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b",
                                      "deepseek-v2-236b"])
    def test_scan_eager_greedy_bit_identity(self, arch):
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                     cfg.vocab_size)
        a = generate(params, cfg, prompts, 6, cache_len=16)
        b = generate_scan(params, cfg, prompts, 6, cache_len=16)
        assert jnp.array_equal(a, b)

    def test_scan_eager_with_adapters(self, qwen):
        cfg, params = qwen
        store, _ = _adapter_fixture(cfg, params, 3)
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(5), (3, 8), 0,
                                     cfg.vocab_size)
        ids = jnp.array([2, 0, 1], jnp.int32)
        a = generate(served, cfg, prompts, 5, 16, adapters=ids)
        b = generate_scan(served, cfg, prompts, 5, 16, adapters=ids)
        assert jnp.array_equal(a, b)

    def test_per_slot_positions_match_scalar(self):
        """decode_step with a (B,) t vector (all equal) is bit-identical
        to the scalar-t path — rope, MLA, and sinusoidal archs."""
        for arch in ("qwen1.5-0.5b", "deepseek-v2-236b", "musicgen-medium"):
            cfg = smoke_variant(get_config(arch))
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                         cfg.vocab_size)
            st = M.init_decode_state(cfg, 3, 12)
            logits, st = M.prefill(params, cfg, prompts, st)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            lg_s, _ = M.decode_step(params, cfg, tok, st)
            st_v = M.DecodeState(t=jnp.full((3,), st.t, jnp.int32),
                                 layers=st.layers)
            lg_v, _ = M.decode_step(params, cfg, tok, st_v)
            assert jnp.array_equal(lg_s, lg_v), arch


class TestAdapterStore:
    def test_spill_round_trip_and_ragged_pad(self, qwen, tmp_path):
        cfg, params = qwen
        tf = adapters_lib.serving_target_fn(cfg)
        store = adapters_lib.AdapterStore(params, tf, 6, 4,
                                          directory=str(tmp_path),
                                          shard_size=2,
                                          max_resident_shards=1)
        rng = np.random.default_rng(0)
        basis, rt = store.random_factors(rng)
        store.put(0, rt, basis, scale=0.9)
        # ragged: rank-2 factors into the rank-4 store
        small = adapters_lib.AdapterStore(params, tf, 1, 2)
        basis2, rt2 = small.random_factors(rng)
        store.put(5, rt2, basis2, scale=1.1)     # different shard -> spill
        store.flush()
        assert store.store.spills > 0
        rows = store.store.gather(np.array([0, 5]))
        b0 = jax.tree_util.tree_flatten(rows["basis"])[0][0]
        orig = jax.tree_util.tree_flatten(basis)[0][0]
        assert np.array_equal(b0[0], orig)
        b5 = jax.tree_util.tree_flatten(rows["basis"])[0][0][1]
        assert np.all(b5[..., 2:] == 0)          # zero-padded tail
        np.testing.assert_allclose(
            np.asarray(rows["scale_minus_1"]) + 1.0, [0.9, 1.1], rtol=1e-6)

    def test_cold_adapter_is_pristine_base(self, qwen):
        """An id that was never put decodes as the unmodified base model
        (zeros row => scale 1, delta 0)."""
        cfg, params = qwen
        tf = adapters_lib.serving_target_fn(cfg)
        store = adapters_lib.AdapterStore(params, tf, 2, 3)
        rng = np.random.default_rng(1)
        basis, rt = store.random_factors(rng)
        store.put(0, rt, basis, scale=0.8)       # id 1 stays cold
        served = store.wrap(params)
        prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 6), 0,
                                     cfg.vocab_size)
        state = M.init_decode_state(cfg, 2, 8)
        with layers.adapter_ids(jnp.array([1, 1], jnp.int32)):
            logits, _ = M.prefill(served, cfg, prompts, state)
        state = M.init_decode_state(cfg, 2, 8)
        base_logits, _ = M.prefill(params, cfg, prompts, state)
        assert jnp.max(jnp.abs(logits - base_logits)) <= 1e-4

    def test_from_client_state(self, qwen):
        """A trained population's sticky delta rows serve directly."""
        cfg, params = qwen
        tf = adapters_lib.serving_target_fn(cfg)
        ref_store = adapters_lib.AdapterStore(params, tf, 2, 3)
        rng = np.random.default_rng(2)
        basis, rt = ref_store.random_factors(rng)
        # population-side store: rows keyed "delta" in the trainable layout
        delta_tmpl = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, np.float32), rt)
        cstore = ClientStateStore(4, {"delta": delta_tmpl})
        cstore.scatter(np.array([2]), jax.tree_util.tree_map(
            lambda x: np.asarray(x)[None], rt))
        store = adapters_lib.AdapterStore.from_client_state(
            params, tf, cstore, basis, ids=[2], base_scale=0.95)
        assert store.n_adapters == 4
        served = store.wrap(params, ids=np.array([2]))
        merged = _merged(params, cfg, basis, rt, 0.95)
        prompts = jax.random.randint(jax.random.PRNGKey(7), (1, 6), 0,
                                     cfg.vocab_size)
        a = generate_scan(served, cfg, prompts, 4, 12,
                          adapters=jnp.zeros((1,), jnp.int32))
        b = generate_scan(merged, cfg, prompts, 4, 12)
        assert jnp.array_equal(a, b)


class TestSlotServer:
    @pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-1.6b"])
    def test_continuous_matches_straight_generate(self, arch):
        """Oversubscribed requests (mixed prompt lengths and budgets)
        through retire+admit equal per-request straight generation —
        attention (KV ring) and recurrent (RWKV state, fp32-promoted
        carry) families."""
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(4)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            8 if i % 2 else 6),
                        max_new=5 if i % 3 else 3)
                for i in range(7)]
        srv = SlotServer(params, cfg, slots=3, cache_len=16, segment=2)
        out = srv.run(reqs)
        assert out["stats"]["admitted"] == 7
        for r in reqs:
            ref_out = generate(params, cfg,
                               jnp.asarray(r.prompt, jnp.int32)[None],
                               r.max_new, 16)
            assert out["outputs"][r.rid] == \
                ref_out[0, -r.max_new:].tolist(), r.rid
        # all slots freed at the end
        assert not srv.active.any() and not srv.queue

    def test_eos_retires_mid_stream(self, qwen):
        cfg, params = qwen
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, cfg.vocab_size, 8)
        full = generate(params, cfg, jnp.asarray(prompt, jnp.int32)[None],
                        8, 16)[0, -8:].tolist()
        eos = full[3]                       # force an EOS at step 3
        srv = SlotServer(params, cfg, slots=2, cache_len=16, segment=3,
                         eos_id=eos)
        out = srv.run([Request(rid=0, prompt=prompt, max_new=8)])
        got = out["outputs"][0]
        stop = full.index(eos)
        assert got == full[:stop + 1]       # truncated at first EOS
        assert not srv.active.any()

    def test_adapters_in_slots(self, qwen):
        """Each slot applies its own adapter through admit/retire churn."""
        cfg, params = qwen
        store, factors = _adapter_fixture(cfg, params, 3)
        served = store.wrap(params)
        rng = np.random.default_rng(6)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 6),
                        max_new=4, adapter=i % 3) for i in range(5)]
        srv = SlotServer(served, cfg, slots=2, cache_len=12, segment=2)
        out = srv.run(reqs)
        for r in reqs:
            mp = _merged(params, cfg, *factors[r.adapter])
            ref_out = generate(mp, cfg,
                               jnp.asarray(r.prompt, jnp.int32)[None],
                               r.max_new, 12)
            assert out["outputs"][r.rid] == \
                ref_out[0, -r.max_new:].tolist(), r.rid
