"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


class TestGaloreKernel:
    @pytest.mark.parametrize("m,n,r", [(128, 128, 8), (256, 128, 32),
                                       (128, 256, 16), (512, 128, 64)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, n, r, dtype):
        ks = jax.random.split(KEY, 5)
        w = jax.random.normal(ks[0], (m, n), dtype)
        g = jax.random.normal(ks[1], (m, n), dtype)
        basis = jnp.linalg.qr(jax.random.normal(ks[2], (n, r)))[0]
        mm = 0.1 * jax.random.normal(ks[3], (m, r), jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[4], (m, r), jnp.float32))
        out_k = ops.galore_adamw_step(w, g, basis, mm, vv, 5.0,
                                      lr=1e-2, weight_decay=0.01)
        out_r = ref.galore_adamw_ref(w, g, basis, mm, vv, count=5.0,
                                     lr=1e-2, weight_decay=0.01)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        for a, b in zip(out_k, out_r):
            assert jnp.allclose(a.astype(jnp.float32),
                                b.astype(jnp.float32), atol=tol), (m, n, r)

    def test_block_rows_invariance(self):
        ks = jax.random.split(KEY, 5)
        m, n, r = 256, 128, 8
        w = jax.random.normal(ks[0], (m, n))
        g = jax.random.normal(ks[1], (m, n))
        basis = jnp.linalg.qr(jax.random.normal(ks[2], (n, r)))[0]
        mm = jnp.zeros((m, r)); vv = jnp.zeros((m, r))
        a = ops.galore_adamw_step(w, g, basis, mm, vv, 1.0, block_rows=64)
        b = ops.galore_adamw_step(w, g, basis, mm, vv, 1.0, block_rows=256)
        assert jnp.allclose(a[0], b[0], atol=1e-5)

    @pytest.mark.parametrize("m,block_rows", [(96, 64), (100, 32), (7, 8)])
    def test_odd_rows_masked_tail(self, m, block_rows):
        """Row counts that don't divide block_rows run on a ceil-div grid
        with a masked tail tile (regression for the old hard assert)."""
        n, r = 256, 8
        ks = jax.random.split(KEY, 5)
        w = jax.random.normal(ks[0], (m, n))
        g = jax.random.normal(ks[1], (m, n))
        basis = jnp.linalg.qr(jax.random.normal(ks[2], (n, r)))[0]
        mm = 0.1 * jax.random.normal(ks[3], (m, r), jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[4], (m, r), jnp.float32))
        out_k = ops.galore_adamw_step(w, g, basis, mm, vv, 5.0, lr=1e-2,
                                      weight_decay=0.01,
                                      block_rows=block_rows)
        out_r = ref.galore_adamw_ref(w, g, basis, mm, vv, count=5.0, lr=1e-2,
                                     weight_decay=0.01)
        for a, b in zip(out_k, out_r):
            assert jnp.allclose(a, b, atol=1e-5), (m, block_rows)

    @pytest.mark.parametrize("m,n,block", [(64, 200, 64), (32, 256, 128)])
    def test_left_projected_block(self, m, n, block):
        """Left blocks (m < n): basis (m, r), moments (r, n), column tiling."""
        r = 8
        ks = jax.random.split(KEY, 5)
        w = jax.random.normal(ks[0], (m, n))
        g = jax.random.normal(ks[1], (m, n))
        basis = jnp.linalg.qr(jax.random.normal(ks[2], (m, r)))[0]
        mm = 0.1 * jax.random.normal(ks[3], (r, n), jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[4], (r, n), jnp.float32))
        out_k = ops.galore_adamw_step(w, g, basis, mm, vv, 3.0, lr=1e-2,
                                      weight_decay=0.01, block_rows=block)
        gt = basis.T @ g
        m_new = 0.9 * mm + 0.1 * gt
        v_new = 0.999 * vv + 0.001 * gt * gt
        ut = (m_new / (1 - 0.9 ** 3.0)) / (
            jnp.sqrt(v_new / (1 - 0.999 ** 3.0)) + 1e-8)
        u = basis @ ut
        w_ref = w - 1e-2 * u - 1e-2 * 0.01 * w
        for a, b in zip(out_k, (w_ref, m_new, v_new)):
            assert jnp.allclose(a, b, atol=1e-5), (m, n, block)

    def test_stacked_3d_blocks(self):
        """Stacked scan blocks (nb, m, n) match per-layer 2-D calls."""
        nb, m, n, r = 3, 96, 128, 8
        ks = jax.random.split(KEY, 5)
        w = jax.random.normal(ks[0], (nb, m, n))
        g = jax.random.normal(ks[1], (nb, m, n))
        basis = jnp.stack([jnp.linalg.qr(jax.random.normal(
            jax.random.fold_in(ks[2], i), (n, r)))[0] for i in range(nb)])
        mm = 0.1 * jax.random.normal(ks[3], (nb, m, r), jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[4], (nb, m, r), jnp.float32))
        out = ops.galore_adamw_step(w, g, basis, mm, vv, 2.0, lr=1e-2,
                                    block_rows=64)
        for i in range(nb):
            exp = ref.galore_adamw_ref(w[i], g[i], basis[i], mm[i], vv[i],
                                       count=2.0, lr=1e-2)
            for a, b in zip(out, exp):
                assert jnp.allclose(a[i], b, atol=1e-5), i

    def test_precond_matches_full_step(self):
        """galore_precond_step returns the same moments and an update u with
        w - lr*u == the full step's weight output (weight_decay=0)."""
        m, n, r = 96, 256, 8
        ks = jax.random.split(KEY, 5)
        w = jax.random.normal(ks[0], (m, n))
        g = jax.random.normal(ks[1], (m, n))
        basis = jnp.linalg.qr(jax.random.normal(ks[2], (n, r)))[0]
        mm = 0.1 * jax.random.normal(ks[3], (m, r), jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[4], (m, r), jnp.float32))
        lr = 1e-2
        w_new, m_full, v_full = ops.galore_adamw_step(
            w, g, basis, mm, vv, 5.0, lr=lr, weight_decay=0.0, block_rows=64)
        u, m_pre, v_pre = ops.galore_precond_step(g, basis, mm, vv, 5.0,
                                                  block_rows=64)
        assert jnp.allclose(m_pre, m_full, atol=1e-6)
        assert jnp.allclose(v_pre, v_full, atol=1e-6)
        assert jnp.allclose(w - lr * u, w_new, atol=1e-5)

    @pytest.mark.parametrize("side,shape", [("right", (96, 256)),
                                            ("left", (256, 96))])
    def test_precond_projected_output(self, side, shape):
        """project_back=False returns ũ in the moment shape with
        lift(ũ) == the ambient u of the default path, same moments — the
        factored-delta client contract."""
        m, n = shape
        r = 8
        dim = n if side == "right" else m
        mv_shape = (m, r) if side == "right" else (r, n)
        ks = jax.random.split(KEY, 4)
        g = jax.random.normal(ks[0], (m, n))
        basis = jnp.linalg.qr(jax.random.normal(ks[1], (dim, r)))[0]
        mm = 0.1 * jax.random.normal(ks[2], mv_shape, jnp.float32)
        vv = 0.01 * jnp.abs(jax.random.normal(ks[3], mv_shape, jnp.float32))
        u, m_a, v_a = ops.galore_precond_step(g, basis, mm, vv, 5.0,
                                              block_rows=64)
        ut, m_p, v_p = ops.galore_precond_step(g, basis, mm, vv, 5.0,
                                               block_rows=64,
                                               project_back=False)
        assert ut.shape == mv_shape
        assert jnp.allclose(m_p, m_a, atol=1e-6)
        assert jnp.allclose(v_p, v_a, atol=1e-6)
        lifted = ut @ basis.T if side == "right" else basis @ ut
        assert jnp.allclose(lifted, u, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("lq,lk,h,hkv,d", [
        (128, 128, 4, 4, 64),      # MHA square
        (128, 256, 4, 2, 64),      # GQA + longer KV (decode-suffix style)
        (256, 256, 8, 2, 128),     # GQA 4:1, MXU-width head
    ])
    @pytest.mark.parametrize("window", [0, 64])
    def test_matches_ref(self, lq, lk, h, hkv, d, window):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, lq, h, d), jnp.float32)
        k = jax.random.normal(ks[1], (2, lk, hkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (2, lk, hkv, d), jnp.float32)
        o_k = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=64, block_k=64)
        o_r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        assert jnp.allclose(o_k, o_r, atol=2e-5), (lq, lk, h, hkv, d, window)

    def test_bf16(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.bfloat16)
        o_k = ops.flash_attention(q, k, v, block_q=64, block_k=64)
        o_r = ref.flash_attention_ref(q, k, v)
        assert jnp.allclose(o_k.astype(jnp.float32),
                            o_r.astype(jnp.float32), atol=3e-2)

    def test_matches_model_attention(self):
        """Kernel output == the model's einsum attention (same masking)."""
        from repro.models.attention import attend, causal_mask
        ks = jax.random.split(KEY, 3)
        b, l, h, d = 2, 128, 4, 64
        q = jax.random.normal(ks[0], (b, l, h, d))
        k = jax.random.normal(ks[1], (b, l, 2, d))
        v = jax.random.normal(ks[2], (b, l, 2, d))
        pos = jnp.arange(l)
        mask = causal_mask(pos, pos)[None]
        o_model = attend(q, k, v, mask)
        o_kernel = ops.flash_attention(q, k, v, block_q=64, block_k=64)
        assert jnp.allclose(o_model, o_kernel, atol=2e-5)


class TestRwkv6Kernel:
    @pytest.mark.parametrize("l,h,d,chunk", [(64, 2, 64, 32), (128, 4, 64, 64),
                                             (64, 1, 128, 64)])
    def test_matches_ref(self, l, h, d, chunk):
        ks = jax.random.split(KEY, 5)
        shape = (2, l, h, d)
        r = 0.5 * jax.random.normal(ks[0], shape)
        k = 0.5 * jax.random.normal(ks[1], shape)
        v = 0.5 * jax.random.normal(ks[2], shape)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], shape))
        u = 0.1 * jax.random.normal(ks[4], (h, d))
        y_k, s_k = ops.rwkv6_scan(r, k, v, w, u, chunk=chunk)
        y_r, s_r = ref.rwkv6_scan_ref(r, k, v, w, u)
        assert jnp.allclose(y_k, y_r, atol=1e-4)
        assert jnp.allclose(s_k, s_r, atol=1e-4)

    def test_initial_state_carried(self):
        ks = jax.random.split(KEY, 6)
        shape = (1, 32, 2, 64)
        r, k, v = (0.3 * jax.random.normal(ks[i], shape) for i in range(3))
        w = jax.nn.sigmoid(jax.random.normal(ks[3], shape))
        u = 0.1 * jax.random.normal(ks[4], (2, 64))
        s0 = 0.5 * jax.random.normal(ks[5], (1, 2, 64, 64))
        y_k, s_k = ops.rwkv6_scan(r, k, v, w, u, s0, chunk=32)
        y_r, s_r = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
        assert jnp.allclose(y_k, y_r, atol=1e-4)
        assert jnp.allclose(s_k, s_r, atol=1e-4)

    def test_kernel_matches_model_layer_math(self):
        """The kernel recurrence == the RWKV layer's scan recurrence."""
        from repro.models import rwkv as rw
        d_model = 128
        h = d_model // rw.HEAD_SIZE
        ks = jax.random.split(KEY, 5)
        shape = (1, 16, h, rw.HEAD_SIZE)
        r = 0.3 * jax.random.normal(ks[0], shape)
        k = 0.3 * jax.random.normal(ks[1], shape)
        v = 0.3 * jax.random.normal(ks[2], shape)
        w = jax.nn.sigmoid(jax.random.normal(ks[3], shape))
        u = 0.1 * jax.random.normal(ks[4], (h, rw.HEAD_SIZE))
        y_kernel, _ = ops.rwkv6_scan(r, k, v, w, u, chunk=16)
        y_ref, _ = ref.rwkv6_scan_ref(r, k, v, w, u)
        assert jnp.allclose(y_kernel, y_ref, atol=1e-4)
