"""Parity: the whole-round fused (one-dispatch, donated-buffer) federated
round — rank-r factored client deltas by default — and the scan-over-rounds
driver vs the dense-buffer oracles (the eager stage-by-stage reference and
the dense-stack fused round), plus chunk-streaming bit-identity and the
donation contract."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.fed import METHODS, FedConfig, FedEngine

KEY = jax.random.PRNGKey(5)


def _problem():
    params = {"l1": {"w": 0.3 * jax.random.normal(KEY, (8, 16)),
                     "b": jnp.zeros(16)},
              "l2": {"w": 0.3 * jax.random.normal(jax.random.fold_in(KEY, 1),
                                                  (16, 4)),
                     "b": jnp.zeros(4)}}

    def loss(p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["l1"]["w"] + p["l1"]["b"])
        out = h @ p["l2"]["w"] + p["l2"]["b"]
        return jnp.mean((out - y) ** 2)

    return params, loss


def _round_batches(seed, k_rounds=None, k=4, t=5, b=16):
    kb = jax.random.PRNGKey(seed)
    lead = (k, t) if k_rounds is None else (k_rounds, k, t)
    x = jax.random.normal(kb, lead + (b, 8))
    w_true = 0.5 * jax.random.normal(jax.random.fold_in(kb, 1), (8, 4))
    y = jnp.einsum("...bi,io->...bo", x, w_true)
    return (x, y)


def _trees_close(a, b, atol):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.allclose(la, lb, atol=atol), float(
            jnp.max(jnp.abs(la - lb)))


@pytest.mark.parametrize("method", sorted(METHODS))
def test_fused_round_matches_eager_reference(method):
    """3 rounds of the default fused round (factored client deltas for the
    GaLore methods) vs the eager dense-buffer reference (separately
    dispatched InitState / 𝒯 / 𝒜 / 𝒮, dense round-0 𝒮 oracle), for every
    fed method, with weight_decay > 0 (the scaled-base decay path) and the
    adaptive round-0 heterogeneous-basis case (round 0 is in the window).
    flora / fr_lora additionally exercise the frozen-mutating (lift) round
    variant, whose fused program threads the frozen base through its
    outputs."""
    params, loss = _problem()
    engines = {}
    for fused in (True, False):
        eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                  local_steps=5, clip_norm=10.0,
                                  weight_decay=0.01,
                                  fused_round=fused, factored_sync=fused),
                        loss, params)
        for r in range(3):
            m = eng.run_round(_round_batches(r))
            assert jnp.all(jnp.isfinite(m["local_loss"]))
        engines[fused] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=1e-5)
    _trees_close(engines[True].frozen, engines[False].frozen, atol=1e-5)
    if engines[False].synced_v is not None:
        _trees_close(engines[True].synced_v, engines[False].synced_v,
                     atol=1e-5)
    else:
        assert engines[True].synced_v is None


@pytest.mark.parametrize("method", ["fedgalore", "fedgalore_minus",
                                    "fedgalore_avg_svd"])
def test_factored_clients_match_dense_fused_round(method):
    """The rank-r factored client memory model vs the dense-stack fused round
    (factored_clients=False — the in-fused-path oracle): 3 rounds covering
    the adaptive round-0 per-client-basis aggregation and weight_decay > 0
    (decay carried by the scalar base_scale instead of the dense buffer)."""
    params, loss = _problem()
    engines = {}
    for factored in (True, False):
        eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                  local_steps=5, clip_norm=10.0,
                                  weight_decay=0.01,
                                  factored_clients=factored),
                        loss, params)
        assert eng._factored is factored
        for r in range(3):
            eng.run_round(_round_batches(r))
        engines[factored] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=1e-5)
    if engines[False].synced_v is not None:
        _trees_close(engines[True].synced_v, engines[False].synced_v,
                     atol=1e-5)


@pytest.mark.parametrize("method,chunk", [("fedgalore", 2),
                                          ("fedgalore", 1),
                                          ("fedavg_full", 2),
                                          ("fedit", 2)])
def test_chunked_round_bit_identical(method, chunk):
    """Cohort chunk streaming (client_chunk=B < C) must be BIT-identical to
    the single-chunk round (B=C): per-client work is independent and 𝒜/𝒮 run
    once on the full reassembled stacks, so the chunk size may change peak
    memory but never a single bit of the result. Covers the factored
    (fedgalore), dense (fedavg_full), and LoRA (fedit) client models."""
    params, loss = _problem()
    engines = {}
    for c in (None, chunk):
        eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                  local_steps=5, clip_norm=10.0,
                                  weight_decay=0.01, client_chunk=c),
                        loss, params)
        for r in range(2):
            eng.run_round(_round_batches(r))
        engines[c] = eng
    for la, lb in zip(jax.tree_util.tree_leaves(engines[None].global_trainable),
                      jax.tree_util.tree_leaves(engines[chunk].global_trainable)):
        assert jnp.array_equal(la, lb), float(jnp.max(jnp.abs(la - lb)))
    if engines[None].synced_v is not None:
        for la, lb in zip(jax.tree_util.tree_leaves(engines[None].synced_v),
                          jax.tree_util.tree_leaves(engines[chunk].synced_v)):
            assert jnp.array_equal(la, lb)


def test_client_chunk_must_divide_cohort():
    params, loss = _problem()
    eng = FedEngine(FedConfig(method="fedgalore", rank=4, local_steps=5,
                              client_chunk=3), loss, params)
    with pytest.raises(ValueError, match="must divide"):
        eng.run_round(_round_batches(0))


def test_factored_buffers_smaller_than_dense():
    """The persistent client buffers of the factored round are the rank-r
    accumulators — strictly smaller than the dense (C, m, n) weight stacks
    they replace (the C≈512 scaling lever)."""
    params, loss = _problem()
    sizes = {}
    for factored in (True, False):
        eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                                  local_steps=5,
                                  factored_clients=factored), loss, params)
        eng.run_round(_round_batches(0))
        sizes[factored] = eng.client_buffer_bytes()
    assert 0 < sizes[True] < sizes[False]


@pytest.mark.parametrize("method", ["fedgalore", "fr_lora"])
def test_scan_over_rounds_matches_per_round(method):
    """run_rounds (K rounds, ONE dispatch) ≡ K fused run_round calls —
    fr_lora covers the frozen-in-carry scan variant."""
    params, loss = _problem()
    eng_a = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                local_steps=5), loss, params)
    eng_b = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                local_steps=5), loss, params)
    rb = _round_batches(0, k_rounds=4)
    m = eng_a.run_rounds(rb)
    assert m["local_loss"].shape == (4, 4, 5)
    for r in range(4):
        mb = eng_b.run_round(jax.tree_util.tree_map(lambda x: x[r], rb))
        assert jnp.allclose(m["local_loss"][r], mb["local_loss"], atol=1e-6)
    _trees_close(eng_a.global_trainable, eng_b.global_trainable, atol=1e-6)
    _trees_close(eng_a.frozen, eng_b.frozen, atol=1e-6)
    _trees_close(eng_a.synced_v, eng_b.synced_v, atol=1e-6)
    assert eng_a.round_idx == eng_b.round_idx == 4


def test_donated_buffers_second_round_ok():
    """The fused round donates the stacked (C, …) client buffers; the engine
    must adopt each round's outputs so the next call never touches a donated
    (deleted) array. Also: run_round after run_rounds stays consistent."""
    params, loss = _problem()
    eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                              local_steps=5), loss, params)
    m0 = eng.run_round(_round_batches(0))
    m1 = eng.run_round(_round_batches(1))       # reuses donated buffers
    assert jnp.isfinite(m1["mean_final_loss"])
    eng.run_rounds(_round_batches(2, k_rounds=2))
    m3 = eng.run_round(_round_batches(3))       # back to the donated path
    assert jnp.isfinite(m3["mean_final_loss"])
    assert eng.round_idx == 5
    assert m0["mean_final_loss"] != m1["mean_final_loss"]


def test_fused_round_single_dispatch_program():
    """The whole round — InitState, T local steps, 𝒜, 𝒮 — must lower as one
    jitted call: after warmup, a round triggers no new trace."""
    params, loss = _problem()
    eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                              local_steps=5), loss, params)
    eng.run_round(_round_batches(0))    # round-0 trace (no synced_v)
    eng.run_round(_round_batches(1))    # steady-state trace (with synced_v)
    traced = eng._round_jitted()._cache_size()
    eng.run_round(_round_batches(2))
    assert eng._round_jitted()._cache_size() == traced


def _runtime_setup(c_clients=3):
    from repro.configs import get_config, smoke_variant
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=2, refresh_mode="random")

    def batches(seed, k_rounds=None):
        kk = jax.random.PRNGKey(seed)
        lead = ((c_clients, 2, 2, 8) if k_rounds is None
                else (k_rounds, c_clients, 2, 2, 8))
        toks = jax.random.randint(kk, lead, 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    return cfg, mesh, spec, batches


def test_sharded_runtime_fused_matches_eager():
    """ShardedFederation: the in-mesh 𝒮 (fused round, dense client stacks so
    the comparison is bit-level) must reproduce the legacy jit-𝒯𝒜 + host-𝒮
    round, and the scan driver must match per-round dispatch."""
    from repro.fedsim import ShardedFederation

    c_clients = 3
    cfg, mesh, spec, batches = _runtime_setup(c_clients)

    feds = {f: ShardedFederation(cfg, spec, mesh, c_clients,
                                 state_sync="ajive", fused_round=f,
                                 factored_clients=False)
            for f in (True, False)}
    for r in range(2):
        b = batches(r)
        mf = feds[True].run_round(b)
        me = feds[False].run_round(b)
        assert jnp.allclose(mf["losses"], me["losses"], atol=1e-6)
    _trees_close(feds[True].global_trainable, feds[False].global_trainable,
                 atol=1e-6)
    _trees_close(feds[True].opt_states, feds[False].opt_states, atol=1e-6)

    fed_s = ShardedFederation(cfg, spec, mesh, c_clients, state_sync="ajive")
    ms = fed_s.run_rounds(batches(7, k_rounds=2))
    assert ms["losses"].shape == (2, c_clients, 2)
    fed_p = ShardedFederation(cfg, spec, mesh, c_clients, state_sync="ajive")
    for r in range(2):
        fed_p.run_round(jax.tree_util.tree_map(
            lambda x: x[r], batches(7, k_rounds=2)))
    _trees_close(fed_s.global_trainable, fed_p.global_trainable, atol=1e-6)


def test_sharded_runtime_factored_matches_dense_clients():
    """The runtime's factored client memory model vs the dense per-client
    weight stacks (factored_clients=False): ≤5e-4 on the global trainable
    and the synced optimizer states, with the production weight_decay > 0
    riding the scaled base. Pinned to the transient-lift read
    (lift_free=False) so this isolates the PR-4 representation change; the
    lift-free read has its own oracle pair in test_liftfree.py. Tolerance is
    fp noise, not a representation gap: with the real (nb, m, n) projection
    weights now trained, early-step Adam (rsqrt of near-zero v) amplifies
    reduction-order differences between the mathematically identical
    paths past 1e-5: a 7e-9 single-step difference reaches ~2e-4 by round
    2 through coordinates where √v̂ ≈ eps (each step stays lr-bounded, so
    the drift is noise-shaped, not divergent). Losses stay 1e-5-tight."""
    from repro.fedsim import ShardedFederation

    c_clients = 3
    cfg, mesh, spec, batches = _runtime_setup(c_clients)
    assert spec.weight_decay > 0

    feds = {f: ShardedFederation(cfg, spec, mesh, c_clients,
                                 state_sync="ajive", factored_clients=f,
                                 lift_free=False)
            for f in (True, False)}
    for r in range(2):
        b = batches(r)
        mf = feds[True].run_round(b)
        md = feds[False].run_round(b)
        assert jnp.allclose(mf["losses"], md["losses"], atol=1e-5)
    _trees_close(feds[True].global_trainable, feds[False].global_trainable,
                 atol=5e-4)
    _trees_close(feds[True].opt_states, feds[False].opt_states, atol=5e-4)


def test_sharded_runtime_chunked_bit_identical():
    """client_chunk=B < C must be bit-identical to the single-chunk round in
    the sharded runtime too (same per-client programs, 𝒜/𝒮 on the full
    reassembled stacks)."""
    from repro.fedsim import ShardedFederation

    c_clients = 4
    cfg, mesh, spec, batches = _runtime_setup(c_clients)

    feds = {c: ShardedFederation(cfg, spec, mesh, c_clients,
                                 state_sync="ajive", client_chunk=c)
            for c in (None, 2)}
    for r in range(2):
        b = batches(r)
        feds[None].run_round(b)
        feds[2].run_round(b)
    for la, lb in zip(jax.tree_util.tree_leaves(feds[None].global_trainable),
                      jax.tree_util.tree_leaves(feds[2].global_trainable)):
        assert jnp.array_equal(la, lb)
    for la, lb in zip(jax.tree_util.tree_leaves(feds[None].opt_states),
                      jax.tree_util.tree_leaves(feds[2].opt_states)):
        assert jnp.array_equal(la, lb)


def test_sharded_runtime_svd_mode_hetero_sync_matches_dense_oracle():
    """refresh_mode='svd' diverges the client bases, so the in-mesh 𝒮 takes
    the heterogeneous-basis factored path and the factored clients' 𝒜
    contracts the per-client lifts; both must agree with the dense
    per-client round + dense-lift oracle (fused_round=False,
    factored_sync=False, factored_clients=False) to fp32 precision."""
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=2, refresh_mode="svd",
                     refresh_every=2)
    kk = jax.random.PRNGKey(3)
    toks = jax.random.randint(kk, (3, 2, 2, 8), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}

    fed_h = ShardedFederation(cfg, spec, mesh, 3, state_sync="ajive")
    fed_h.run_round(b)
    fed_d = ShardedFederation(cfg, spec, mesh, 3, state_sync="ajive",
                              fused_round=False, factored_sync=False,
                              factored_clients=False)
    fed_d.run_round(b)
    _trees_close(fed_h.global_trainable, fed_d.global_trainable, atol=1e-5)
    for a, d in zip(jax.tree_util.tree_leaves(fed_h.opt_states),
                    jax.tree_util.tree_leaves(fed_d.opt_states)):
        assert jnp.allclose(a.astype(jnp.float32), d.astype(jnp.float32),
                            atol=1e-5)
