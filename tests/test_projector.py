import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import projector as proj


def test_side_rule_std():
    assert proj.proj_side((16, 8)) == proj.RIGHT       # m >= n
    assert proj.proj_side((8, 8)) == proj.RIGHT        # square -> right
    assert proj.proj_side((8, 16)) == proj.LEFT
    assert proj.proj_side((4, 8, 16)) == proj.LEFT     # leading stacked dim


def test_basis_dim():
    assert proj.basis_dim((16, 8)) == 8
    assert proj.basis_dim((8, 16)) == 8


@settings(max_examples=20, deadline=None)
@given(dim=st.integers(8, 64), rank=st.integers(1, 8), seed=st.integers(0, 999))
def test_random_basis_orthonormal(dim, rank, seed):
    rank = min(rank, dim)
    b = proj.random_basis(seed, dim, rank)
    assert b.shape == (dim, rank)
    assert jnp.allclose(b.T @ b, jnp.eye(rank), atol=1e-5)


def test_random_basis_deterministic():
    a = proj.random_basis(42, 32, 4)
    b = proj.random_basis(42, 32, 4)
    c = proj.random_basis(43, 32, 4)
    assert jnp.array_equal(a, b)
    assert not jnp.allclose(a, c)


@pytest.mark.parametrize("shape", [(32, 16), (16, 32), (24, 24)])
def test_svd_basis_captures_top_subspace(shape):
    key = jax.random.PRNGKey(0)
    r = 4
    side = proj.proj_side(shape)
    # Build a matrix with known rank-r structure.
    u = jnp.linalg.qr(jax.random.normal(key, (shape[0], r)))[0]
    v = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                        (shape[1], r)))[0]
    g = u @ jnp.diag(jnp.array([10., 8., 6., 4.])) @ v.T
    basis = proj.svd_basis(g, r, side)
    # Projection through the basis should reconstruct g almost exactly.
    recon = proj.project_back(proj.project(g, basis, side), basis, side)
    assert float(jnp.linalg.norm(recon - g) / jnp.linalg.norm(g)) < 1e-4


@pytest.mark.parametrize("shape", [(64, 32), (32, 64)])
def test_rsvd_close_to_svd(shape):
    key = jax.random.PRNGKey(1)
    side = proj.proj_side(shape)
    g = jax.random.normal(key, shape)
    # low effective rank signal + small noise
    u, s, vt = jnp.linalg.svd(g, full_matrices=False)
    s = s.at[6:].multiply(0.01)
    g = (u * s) @ vt
    b_svd = proj.svd_basis(g, 4, side)
    b_rsvd = proj.rsvd_basis(g, 4, side, jax.random.PRNGKey(2), oversample=8)
    # compare captured energy, not the bases themselves
    e_svd = jnp.linalg.norm(proj.project(g, b_svd, side))
    e_rsvd = jnp.linalg.norm(proj.project(g, b_rsvd, side))
    assert float(e_rsvd) > 0.95 * float(e_svd)


@pytest.mark.parametrize("side,shape", [(proj.RIGHT, (16, 8)),
                                        (proj.LEFT, (8, 16))])
def test_project_roundtrip_in_subspace(side, shape):
    key = jax.random.PRNGKey(3)
    dim = proj.basis_dim(shape)
    basis = proj.random_basis(0, dim, 4)
    # A gradient already inside the subspace projects back exactly.
    coeff = jax.random.normal(key, (shape[0], 4) if side == proj.RIGHT
                              else (4, shape[1]))
    g = proj.project_back(coeff, basis, side)
    coeff2 = proj.project(g, basis, side)
    assert jnp.allclose(coeff, coeff2, atol=1e-5)


def test_reproject_identity_when_basis_unchanged():
    basis = proj.random_basis(0, 32, 4)
    buf = jax.random.normal(jax.random.PRNGKey(4), (16, 4))
    out = proj.reproject(buf, basis, basis, proj.RIGHT)
    assert jnp.allclose(out, buf, atol=1e-5)


def test_reproject_matches_lift_reproject():
    """Low-rank change-of-basis == lift to ambient then re-project."""
    b_old = proj.random_basis(0, 32, 4)
    b_new = proj.random_basis(1, 32, 4)
    buf = jax.random.normal(jax.random.PRNGKey(5), (16, 4))
    fast = proj.reproject(buf, b_old, b_new, proj.RIGHT)
    lifted = proj.project_back(buf, b_old, proj.RIGHT)
    slow = proj.project(lifted, b_new, proj.RIGHT)
    assert jnp.allclose(fast, slow, atol=1e-5)


def test_stacked_project_matches_per_layer():
    key = jax.random.PRNGKey(6)
    g = jax.random.normal(key, (3, 16, 8))
    bases = jnp.stack([proj.random_basis(i, 8, 4) for i in range(3)])
    stacked = proj.project(g, bases, proj.RIGHT)
    per = jnp.stack([proj.project(g[i], bases[i], proj.RIGHT)
                     for i in range(3)])
    assert jnp.allclose(stacked, per, atol=1e-6)


def test_stacked_keys_distinct():
    keys = proj.stacked_keys(jax.random.PRNGKey(0), 4)
    assert keys.shape[0] == 4
    flat = set(map(tuple, jax.device_get(keys).tolist()))
    assert len(flat) == 4
