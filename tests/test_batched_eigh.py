"""Property tests for the batched small-eigh kernel vs `jnp.linalg.eigh`.

The Pallas parallel-order Jacobi kernel (`kernels.batched_eigh.jacobi_eigh`)
must agree with LAPACK on random SPD (B, r, r) stacks — eigenvalues to fp32
precision, eigenvectors up to sign/rotation (checked via orthonormality and
reconstruction, which are basis-unique) — including the adversarial spectra
the sync path actually produces: near-degenerate clusters, exactly repeated
eigenvalues, and rank-deficient Grams (where the PR-1 eigenvalue-floor path
`ajive._inv_sqrt_rank_safe` must survive batching).

Runs the kernel in interpret mode (`force="jacobi"` routes through the
platform gate, which interprets on CPU). Hypothesis widens the input
distribution when installed; the parametrized cases below always run, so the
suite loses breadth but not coverage when it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ajive
from repro.kernels.batched_eigh import MAX_JACOBI_DIM
from repro.kernels.ops import batched_small_eigh

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _spd_stack(seed, b, n, rank=None):
    """Random SPD stack A = X Xᵀ (rank-limited when ``rank`` is given)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (b, n, rank or n)) / np.sqrt(n)
    return jnp.einsum("bik,bjk->bij", x, x)


def _check_against_lapack(a, atol_scale=5e-5):
    lam_j, vec_j = batched_small_eigh(a, force="jacobi")
    lam_r, _ = jnp.linalg.eigh(a)
    scale = float(jnp.max(jnp.abs(lam_r))) + 1e-6
    tol = atol_scale * scale
    # eigenvalues: ascending, matching LAPACK's
    assert jnp.allclose(lam_j, lam_r, atol=tol), \
        float(jnp.max(jnp.abs(lam_j - lam_r)))
    assert bool(jnp.all(jnp.diff(lam_j, axis=-1) >= -tol))
    # eigenvectors: orthonormal and reconstructing (sign/rotation-free checks)
    n = a.shape[-1]
    gram = jnp.einsum("bij,bik->bjk", vec_j, vec_j)
    assert jnp.allclose(gram, jnp.eye(n)[None], atol=1e-4)
    rec = jnp.einsum("bik,bk,bjk->bij", vec_j, lam_j, vec_j)
    assert jnp.allclose(rec, a, atol=tol), float(jnp.max(jnp.abs(rec - a)))


@pytest.mark.parametrize("n", [3, 8, 16, 33])
def test_jacobi_matches_lapack_random_spd(n):
    _check_against_lapack(_spd_stack(n, 4, n))


def test_jacobi_matches_lapack_at_max_dim():
    """The r ≤ 64 ceiling the sync path actually uses."""
    _check_against_lapack(_spd_stack(0, 2, MAX_JACOBI_DIM))


def test_jacobi_rank_deficient_stack():
    """Rank-3 8×8 Grams: the trailing eigenvalues must pin to ~0 (not drift
    negative past tolerance), exactly what the sync path's floor consumes."""
    a = _spd_stack(7, 4, 8, rank=3)
    _check_against_lapack(a)
    lam, _ = batched_small_eigh(a, force="jacobi")
    assert jnp.allclose(lam[..., :5], 0.0, atol=1e-5)


def test_jacobi_repeated_and_near_degenerate_spectra():
    """Exactly repeated (c·I) and ε-split clustered spectra — the rotation
    angle must collapse to 0 on converged pairs instead of oscillating."""
    n = 5
    key = jax.random.PRNGKey(3)
    q, _ = jnp.linalg.qr(jax.random.normal(key, (n, n)))
    spectra = jnp.stack([
        2.0 * jnp.ones(n),                                  # c·I
        jnp.array([1.0, 1.0 + 1e-6, 2.0, 2.0, 5.0]),        # ε-split cluster
        jnp.array([0.0, 0.0, 1.0, 1.0, 1.0]),               # repeated + null
    ])
    a = jnp.einsum("ij,bj,kj->bik", q, spectra, q)
    _check_against_lapack(a)
    lam, _ = batched_small_eigh(a, force="jacobi")
    assert jnp.allclose(lam, jnp.sort(spectra, axis=-1), atol=2e-5)


def test_default_cpu_path_is_lapack_bit_identical():
    """force=None on CPU must route to jnp.linalg.eigh unchanged — the
    pre-kernel behavior every existing test tolerance was set against."""
    a = _spd_stack(1, 3, 8)
    lam_d, vec_d = batched_small_eigh(a)
    lam_r, vec_r = jnp.linalg.eigh(a)
    assert jnp.array_equal(lam_d, lam_r) and jnp.array_equal(vec_d, vec_r)


def test_large_dim_falls_back_to_lapack():
    """n > MAX_JACOBI_DIM is out of the kernel's contract: the default route
    must fall back to LAPACK rather than raise."""
    a = _spd_stack(2, 2, MAX_JACOBI_DIM + 16)
    lam, _ = batched_small_eigh(a)
    lam_r, _ = jnp.linalg.eigh(a)
    assert jnp.array_equal(lam, lam_r)


def test_eigenvalue_floor_survives_batching():
    """PR-1's rank-safe inverse-sqrt floor under batching: the λ_max
    reference must stay *per-row* (rows with wildly different scales can't
    leak into each other's keep threshold), exact-null directions map to 0
    with no inf/nan, and the batched application is bit-identical to the
    per-row one. Then the same through the kernel-routed top-k chain on
    genuinely rank-deficient Grams."""
    # rows at very different scales, each with an exact-zero null tail
    lam_desc = jnp.array([[4.0, 1.0, 0.0, 0.0],
                          [1e6, 1e-3, 1e-12, 0.0],
                          [1e-4, 1e-5, 0.0, 0.0]], jnp.float32)
    inv = ajive._inv_sqrt_rank_safe(lam_desc)
    assert bool(jnp.all(jnp.isfinite(inv)))
    assert jnp.array_equal(inv[:, 2:], jnp.zeros((3, 2)))   # nulls → exact 0
    assert inv[1, 1] > 0.0          # 1e-3 ≫ 1e-10·1e6: kept despite row scale
    per = jnp.stack([ajive._inv_sqrt_rank_safe(l) for l in lam_desc])
    assert jnp.array_equal(inv, per)
    # same per-row reference for the eigenvector-column floor
    vec = jnp.broadcast_to(jnp.eye(4), (3, 4, 4))
    kept = ajive._keep_mask_cols(lam_desc, vec)
    assert jnp.array_equal(kept[:, :, 2:], jnp.zeros((3, 4, 2)))
    assert bool(jnp.all(kept[1, :, :2] == vec[1, :, :2]))
    # and through the batched kernel-routed top-k chain on rank-3 Grams:
    # everything downstream of the floor stays finite
    a = _spd_stack(9, 6, 8, rank=3)
    lam_k, vec_k = ajive._topk_eig_desc_stack(a, 4)
    assert bool(jnp.all(jnp.isfinite(ajive._inv_sqrt_rank_safe(lam_k))))
    assert bool(jnp.all(jnp.isfinite(ajive._keep_mask_cols(lam_k, vec_k))))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 16), b=st.integers(1, 4),
           seed=st.integers(0, 10**6))
    def test_jacobi_matches_lapack_property(n, b, seed):
        _check_against_lapack(_spd_stack(seed, b, n))

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(3, 12), rank=st.integers(1, 3),
           seed=st.integers(0, 10**6))
    def test_jacobi_rank_deficient_property(n, rank, seed):
        a = _spd_stack(seed, 2, n, rank=min(rank, n))
        _check_against_lapack(a)
