"""Hypothesis property tests over the robust-aggregation invariants:
client-permutation invariance, all-honest identity, and single-outlier
boundedness of the robust factored reductions — over shared AND hetero
(rotated per-client) bases, through both the operator layer the engine
uses (`aggregation.robust_factored_lift`) and the runtime's leaf-level
𝒮 reduce (`state_sync.sync_block_synced_factored`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation as agg
from repro.core import state_sync as sync_lib

jax.config.update("jax_platform_name", "cpu")

MODES = st.sampled_from(["trimmed_mean", "geomedian", "norm_clip"])
COORD_MODES = st.sampled_from(["trimmed_mean", "geomedian"])


def _stack(c, m, r, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(c, m, r)), jnp.float32)


def _weights(c, seed):
    rng = np.random.default_rng(seed + 1)
    w = rng.random(c).astype(np.float32) + 0.1
    return jnp.asarray(w / w.sum())


def _bases(c, n, r, seed, hetero):
    """Orthonormal bases; hetero=True rotates a shared subspace per client
    (worst case for coordinate-wise votes, exactly what re-basing fixes)."""
    rng = np.random.default_rng(seed + 2)
    b0, _ = np.linalg.qr(rng.normal(size=(n, r)))
    out = []
    for _ in range(c):
        q, _ = np.linalg.qr(rng.normal(size=(r, r)))
        out.append((b0 @ q if hetero else b0).astype(np.float32))
    return jnp.asarray(np.stack(out))


@settings(max_examples=15, deadline=None)
@given(c=st.integers(3, 6), mode=MODES, seed=st.integers(0, 10**6),
       hetero=st.booleans())
def test_reduce_client_permutation_invariance(c, mode, seed, hetero):
    """Robust 𝒜 must not care about client ordering: permuting the stack
    and weights together leaves the lifted result unchanged."""
    stack = _stack(c, 5, 3, seed)
    w = _weights(c, seed)
    bases = _bases(c, 5, 3, seed, hetero)
    perm = np.random.default_rng(seed + 3).permutation(c)
    a = agg.robust_factored_lift(stack, bases, "right", w, mode,
                                 hetero=hetero)
    b = agg.robust_factored_lift(stack[perm], bases[perm], "right",
                                 w[perm], mode, hetero=hetero)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(3, 6), seed=st.integers(0, 10**6), hetero=st.booleans())
def test_all_honest_identity(c, seed, hetero):
    """All-honest identity: trim=0 trimmed-mean IS the weighted mean, so
    the robust lift coincides with the plain mode='none' lift; norm_clip
    on identical-norm rows clips nothing."""
    stack = _stack(c, 5, 3, seed)
    w = _weights(c, seed)
    bases = _bases(c, 5, 3, seed, hetero)
    ref = agg.robust_factored_lift(stack, bases, "right", w, "none",
                                   hetero=hetero)
    got = agg.robust_factored_lift(stack, bases, "right", w,
                                   "trimmed_mean", hetero=hetero, trim=0.0)
    if hetero:
        # Re-based trim=0 mean equals the per-client lift-then-average
        # only through the shared projector: compare in coordinates.
        ref = agg.robust_factored_reduce(
            agg.rebase_factored_stack(stack, bases, "right"), w, "none")
        got = agg.robust_factored_reduce(
            agg.rebase_factored_stack(stack, bases, "right"), w,
            "trimmed_mean", trim=0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
    same = jnp.asarray(np.broadcast_to(np.asarray(stack[0]),
                                       stack.shape))
    clipped = agg.robust_factored_reduce(same, w, "norm_clip")
    np.testing.assert_allclose(np.asarray(clipped), np.asarray(stack[0]),
                               atol=2e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(c=st.integers(4, 7), mode=COORD_MODES, seed=st.integers(0, 10**6),
       scale=st.floats(10.0, 1e4), hetero=st.booleans())
def test_single_outlier_boundedness(c, mode, seed, scale, hetero):
    """One attacker scaled arbitrarily against an identical honest majority:
    the coordinate-wise robust lifts stay within a constant of the honest
    point, independent of the attack scale (shared or rotated bases)."""
    rng = np.random.default_rng(seed)
    honest = rng.normal(size=(5, 3)).astype(np.float32)
    bases = _bases(c, 5, 3, seed, hetero)
    b0 = np.asarray(bases[0])
    rows = []
    for i in range(c):
        bi = np.asarray(bases[i])
        coord = honest @ (b0.T @ bi)  # the same ambient point, own basis
        rows.append(coord * (scale if i == c - 1 else 1.0))
    stack = jnp.asarray(np.stack(rows))
    w = jnp.full((c,), 1.0 / c)
    out = np.asarray(agg.robust_factored_lift(
        stack, bases, "right", w, mode, hetero=hetero, trim=0.3,
        iters=32))
    ref = honest @ b0.T                    # the honest majority, lifted
    bound = 0.5 * np.abs(ref).max() + 1e-3
    assert np.abs(out - ref).max() < bound, (mode, scale)


@settings(max_examples=10, deadline=None)
@given(c=st.integers(4, 7), mode=COORD_MODES, seed=st.integers(0, 10**6),
       scale=st.floats(100.0, 1e5))
def test_sync_block_robust_bounds_poisoned_moments(c, mode, seed, scale):
    """The 𝒮 boundary both engines call: robust='none' is EXACTLY the plain
    weighted mean over the projected-moment stack (bitwise), and a robust
    mode keeps one poisoned moment upload from dragging the synced state
    beyond the honest hull."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.random((c, 5, 3)), jnp.float32)
    wr = _weights(c, seed)
    plain = sync_lib.sync_block_synced_factored("avg", v, "right", wr)
    none_mode = sync_lib.sync_block_synced_factored("avg", v, "right", wr,
                                                    robust="none")
    assert jnp.array_equal(plain, none_mode)
    # Uniform weights for the attack half: the single attacker's mass stays
    # under the trim window / geomedian breakdown point by construction.
    w = jnp.full((c,), 1.0 / c)
    poisoned = v.at[c - 1].mul(scale)
    guarded = np.asarray(sync_lib.sync_block_synced_factored(
        "avg", poisoned, "right", w, robust=mode, trim=0.3, iters=32))
    # Scale-independent bound: honest values are O(1), the attack is 1e2+.
    bound = 5.0 * np.abs(np.asarray(v[:-1])).max() + 1.0
    assert np.abs(guarded).max() <= bound, (mode, scale, guarded.max())
    assert np.isfinite(guarded).all()
