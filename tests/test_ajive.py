import jax
import jax.numpy as jnp
import pytest

from repro.core.ajive import ajive, ajive_sync


def _make_views(key, k_views=6, n=48, m=48, r=5, drift_rank=2, noise=0.05,
                drift_scale=3.0):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(k1, (n, r)))[0]
    v = jnp.linalg.qr(jax.random.normal(k2, (m, r)))[0]
    joint = u @ jnp.diag(jnp.linspace(10.0, 6.0, r)) @ v.T
    views = []
    for i in range(k_views):
        ki = jax.random.fold_in(k3, i)
        a, b, c = jax.random.split(ki, 3)
        indiv = (jnp.linalg.qr(jax.random.normal(a, (n, drift_rank)))[0]
                 @ (drift_scale * jax.random.normal(b, (drift_rank, m))))
        views.append(joint + indiv + noise * jax.random.normal(c, (n, m)))
    return jnp.stack(views), joint


def test_decomposition_shapes():
    views, _ = _make_views(jax.random.PRNGKey(0))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    assert res.joint.shape == views.shape
    assert res.individual.shape == views.shape
    assert res.noise.shape == views.shape
    assert res.joint_basis.shape == (48, 5)
    # X = J + I + E exactly by construction
    recon = res.joint + res.individual + res.noise
    assert jnp.allclose(recon, views, atol=1e-4)


def test_joint_recovery_beats_naive_average():
    views, joint = _make_views(jax.random.PRNGKey(1))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    err_ajive = jnp.linalg.norm(res.joint_mean - joint) / jnp.linalg.norm(joint)
    err_naive = jnp.linalg.norm(jnp.mean(views, 0) - joint) / jnp.linalg.norm(joint)
    assert float(err_ajive) < float(err_naive)


def test_joint_basis_orthonormal():
    views, _ = _make_views(jax.random.PRNGKey(2))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    gram = res.joint_basis.T @ res.joint_basis
    assert jnp.allclose(gram, jnp.eye(5), atol=1e-4)


def test_rank_estimation_path_runs():
    views, _ = _make_views(jax.random.PRNGKey(3))
    res, est = ajive(views, signal_ranks=7, joint_rank=None,
                     key=jax.random.PRNGKey(0), center=False,
                     return_rank_diag=True)
    assert int(est) >= 1          # some joint structure must be found
    assert res.joint_basis.shape[1] <= 7


def test_ajive_sync_weighted():
    views, joint = _make_views(jax.random.PRNGKey(4))
    w = jnp.array([1, 1, 1, 1, 1, 10.0])
    out = ajive_sync(views, rank=5, weights=w)
    assert out.shape == joint.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_more_clients_improve_recovery():
    """Appendix F: AJIVE error decreases with the number of views."""
    errs = []
    for k_views in (3, 12):
        views, joint = _make_views(jax.random.PRNGKey(5), k_views=k_views)
        res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
        errs.append(float(jnp.linalg.norm(res.joint_mean - joint)
                          / jnp.linalg.norm(joint)))
    assert errs[1] < errs[0]
