import jax
import jax.numpy as jnp
import pytest

from repro.core import projector as proj
from repro.core.ajive import (ajive, ajive_sync, ajive_sync_factored,
                              ajive_sync_hetero_factored)


def _make_views(key, k_views=6, n=48, m=48, r=5, drift_rank=2, noise=0.05,
                drift_scale=3.0):
    k1, k2, k3 = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(k1, (n, r)))[0]
    v = jnp.linalg.qr(jax.random.normal(k2, (m, r)))[0]
    joint = u @ jnp.diag(jnp.linspace(10.0, 6.0, r)) @ v.T
    views = []
    for i in range(k_views):
        ki = jax.random.fold_in(k3, i)
        a, b, c = jax.random.split(ki, 3)
        indiv = (jnp.linalg.qr(jax.random.normal(a, (n, drift_rank)))[0]
                 @ (drift_scale * jax.random.normal(b, (drift_rank, m))))
        views.append(joint + indiv + noise * jax.random.normal(c, (n, m)))
    return jnp.stack(views), joint


def test_decomposition_shapes():
    views, _ = _make_views(jax.random.PRNGKey(0))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    assert res.joint.shape == views.shape
    assert res.individual.shape == views.shape
    assert res.noise.shape == views.shape
    assert res.joint_basis.shape == (48, 5)
    # X = J + I + E exactly by construction
    recon = res.joint + res.individual + res.noise
    assert jnp.allclose(recon, views, atol=1e-4)


def test_joint_recovery_beats_naive_average():
    views, joint = _make_views(jax.random.PRNGKey(1))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    err_ajive = jnp.linalg.norm(res.joint_mean - joint) / jnp.linalg.norm(joint)
    err_naive = jnp.linalg.norm(jnp.mean(views, 0) - joint) / jnp.linalg.norm(joint)
    assert float(err_ajive) < float(err_naive)


def test_joint_basis_orthonormal():
    views, _ = _make_views(jax.random.PRNGKey(2))
    res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
    gram = res.joint_basis.T @ res.joint_basis
    assert jnp.allclose(gram, jnp.eye(5), atol=1e-4)


def test_rank_estimation_path_runs():
    views, _ = _make_views(jax.random.PRNGKey(3))
    res, est = ajive(views, signal_ranks=7, joint_rank=None,
                     key=jax.random.PRNGKey(0), center=False,
                     return_rank_diag=True)
    assert int(est) >= 1          # some joint structure must be found
    assert res.joint_basis.shape[1] <= 7


def test_ajive_sync_weighted():
    views, joint = _make_views(jax.random.PRNGKey(4))
    w = jnp.array([1, 1, 1, 1, 1, 10.0])
    out = ajive_sync(views, rank=5, weights=w)
    assert out.shape == joint.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_more_clients_improve_recovery():
    """Appendix F: AJIVE error decreases with the number of views."""
    errs = []
    for k_views in (3, 12):
        views, joint = _make_views(jax.random.PRNGKey(5), k_views=k_views)
        res = ajive(views, signal_ranks=7, joint_rank=5, center=False)
        errs.append(float(jnp.linalg.norm(res.joint_mean - joint)
                          / jnp.linalg.norm(joint)))
    assert errs[1] < errs[0]


# ------------------------------------------------------ factored fast path --

def _make_projected_views(key, side, c_views=6, m=48, n=32, r=8):
    """Random rank-r projected moments ṽ with shared structure + drift, plus
    the shared orthonormal lifting basis. O(1) magnitudes and a graded
    spectrum keep fp32 SVD noise well inside the 1e-5 parity tolerance."""
    k1, k2 = jax.random.split(key)
    dim = n if side == "right" else m
    basis = proj.random_basis(0, dim, r)
    scale = jnp.linspace(1.6, 0.8, r)
    if side == "right":
        shared = jax.random.normal(k1, (m, r)) * scale[None, :]
        vs = [shared + 0.08 * jax.random.normal(jax.random.fold_in(k2, i),
                                                (m, r))
              for i in range(c_views)]
    else:
        shared = scale[:, None] * jax.random.normal(k1, (r, n))
        vs = [shared + 0.08 * jax.random.normal(jax.random.fold_in(k2, i),
                                                (r, n))
              for i in range(c_views)]
    return jnp.stack(vs), basis


def _lift(v_stack, basis, side):
    if side == "right":
        return jnp.einsum("cmr,nr->cmn", v_stack, basis)
    return jnp.einsum("mr,crn->cmn", basis, v_stack)


@pytest.mark.parametrize("side", ["right", "left"])
def test_factored_matches_dense_on_rank_r_views(side):
    """ajive_sync_factored lifted with the shared basis must equal the dense
    ajive_sync on the lifted views (the retained oracle) to ≤1e-5."""
    v_stack, basis = _make_projected_views(jax.random.PRNGKey(0), side)
    views = _lift(v_stack, basis, side)
    dense = ajive_sync(views, rank=8)
    fact = ajive_sync_factored(v_stack, rank=8, side=side)
    lifted = (jnp.einsum("mr,nr->mn", fact, basis) if side == "right"
              else basis @ fact)
    assert jnp.allclose(lifted, dense, atol=1e-5, rtol=1e-5)


def test_factored_weighted_matches_dense():
    v_stack, basis = _make_projected_views(jax.random.PRNGKey(1), "right")
    w = jnp.array([1, 1, 2, 1, 1, 3.0])
    dense = ajive_sync(_lift(v_stack, basis, "right"), rank=8, weights=w)
    fact = ajive_sync_factored(v_stack, rank=8, weights=w)
    assert jnp.allclose(jnp.einsum("mr,nr->mn", fact, basis), dense,
                        atol=1e-5)


def test_factored_stacked_blocks():
    """Stacked scan blocks (C, nb, m, r) vmap over the layer dim."""
    stacks = [_make_projected_views(jax.random.PRNGKey(i), "right")
              for i in range(2)]
    v4 = jnp.stack([s[0] for s in stacks], axis=1)       # (C, nb, m, r)
    out = ajive_sync_factored(v4, rank=8)
    assert out.shape == (2, 48, 8)
    for i, (v_stack, basis) in enumerate(stacks):
        single = ajive_sync_factored(v_stack, rank=8)
        assert jnp.allclose(out[i], single, atol=1e-6)


def test_factored_never_materializes_dense(monkeypatch):
    """The factored path must not call the dense ajive pipeline at all."""
    import repro.core.ajive as aj

    def boom(*a, **k):
        raise AssertionError("dense ajive called from factored path")

    monkeypatch.setattr(aj, "ajive", boom)
    v_stack, _ = _make_projected_views(jax.random.PRNGKey(2), "right")
    out = aj.ajive_sync_factored(v_stack, rank=8)
    assert out.shape == (48, 8)


# ---------------------------------------- heterogeneous-basis factored -----

def _hetero_bases(key, c_views, dim, r):
    return jnp.stack([
        jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, i),
                                        (dim, r)))[0]
        for i in range(c_views)])


def _lift_hetero(v_stack, b_stack, side):
    if side == "right":
        return jnp.einsum("cmr,cnr->cmn", v_stack, b_stack)
    return jnp.einsum("cmr,crn->cmn", b_stack, v_stack)


@pytest.mark.parametrize("side", ["right", "left"])
def test_hetero_factored_matches_dense_per_client_lift(side):
    """ajive_sync_hetero_factored ≡ dense AJIVE on per-client-lifted views,
    re-projected onto the client-0 basis (the adaptive round-0 oracle)."""
    v_stack, _ = _make_projected_views(jax.random.PRNGKey(3), side)
    dim = 32 if side == "right" else 48
    b_stack = _hetero_bases(jax.random.PRNGKey(11), v_stack.shape[0], dim, 8)
    w = jnp.array([1, 1, 2, 1, 1, 3.0])
    views = _lift_hetero(v_stack, b_stack, side)
    dense = ajive_sync(views, rank=8, weights=w)
    dense_proj = (dense @ b_stack[0] if side == "right"
                  else b_stack[0].T @ dense)
    fact = ajive_sync_hetero_factored(v_stack, b_stack, rank=8, weights=w,
                                      side=side)
    assert fact.shape == v_stack.shape[1:]
    assert jnp.allclose(fact, dense_proj, atol=1e-5), float(
        jnp.max(jnp.abs(fact - dense_proj)))


def test_hetero_factored_never_materializes_dense(monkeypatch):
    import repro.core.ajive as aj

    def boom(*a, **k):
        raise AssertionError("dense ajive called from hetero factored path")

    monkeypatch.setattr(aj, "ajive", boom)
    v_stack, _ = _make_projected_views(jax.random.PRNGKey(4), "right")
    b_stack = _hetero_bases(jax.random.PRNGKey(12), v_stack.shape[0], 32, 8)
    out = aj.ajive_sync_hetero_factored(v_stack, b_stack, rank=8)
    assert out.shape == (48, 8)
