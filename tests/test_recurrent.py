"""Mamba + RWKV6 layer-level tests: recurrence correctness + decode parity."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import mamba as mb
from repro.models import rwkv as rw

KEY = jax.random.PRNGKey(0)


class TestMamba:
    D = 32

    def _setup(self):
        p = mb.mamba_init(KEY, self.D)
        x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, 1), (2, 10, self.D))
        return p, x

    def test_forward_shape(self):
        p, x = self._setup()
        out = mb.mamba_forward(p, x, d_model=self.D)
        assert out.shape == x.shape
        assert not bool(jnp.any(jnp.isnan(out)))

    def test_decode_matches_forward(self):
        p, x = self._setup()
        out_full, state_full = mb.mamba_forward(p, x, d_model=self.D,
                                                return_state=True)
        st = mb.mamba_state_init(2, self.D, dtype=jnp.float32)
        outs = []
        for t in range(x.shape[1]):
            o, st = mb.mamba_decode(p, x[:, t:t + 1], st, d_model=self.D)
            outs.append(o)
        out_dec = jnp.concatenate(outs, axis=1)
        assert jnp.allclose(out_dec, out_full, atol=2e-2)
        assert jnp.allclose(st.h, state_full.h, atol=2e-2)

    def test_state_continuation(self):
        """forward(x) == forward(x[:5]) then forward(x[5:], state)."""
        p, x = self._setup()
        out_full = mb.mamba_forward(p, x, d_model=self.D)
        _, st = mb.mamba_forward(p, x[:, :5], d_model=self.D,
                                 return_state=True)
        st = mb.MambaState(conv=st.conv.astype(jnp.float32), h=st.h)
        out2, _ = mb.mamba_forward(p, x[:, 5:], st, d_model=self.D,
                                   return_state=True)
        assert jnp.allclose(out2, out_full[:, 5:], atol=2e-2)


class TestRwkv:
    D = 128   # 2 heads of 64

    def _setup(self):
        tm = rw.time_mix_init(KEY, self.D)
        cm = rw.channel_mix_init(jax.random.fold_in(KEY, 1), self.D, 256)
        x = 0.5 * jax.random.normal(jax.random.fold_in(KEY, 2), (2, 8, self.D))
        return tm, cm, x

    def test_time_mix_shapes(self):
        tm, _, x = self._setup()
        st = rw.rwkv_state_init(2, self.D)
        out = rw.time_mix_forward(tm, x, st, self.D)
        assert out.shape == x.shape

    def test_time_mix_decode_parity(self):
        tm, _, x = self._setup()
        st0 = rw.rwkv_state_init(2, self.D, dtype=jnp.float32)
        full = rw.time_mix_forward(tm, x, st0, self.D)
        st = st0
        outs = []
        for t in range(x.shape[1]):
            o, st = rw.time_mix_forward(tm, x[:, t:t + 1], st, self.D,
                                        return_state=True)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        assert jnp.allclose(dec, full, atol=1e-3)

    def test_channel_mix_decode_parity(self):
        _, cm, x = self._setup()
        st0 = rw.rwkv_state_init(2, self.D, dtype=jnp.float32)
        full = rw.channel_mix_forward(cm, x, st0)
        st = st0
        outs = []
        for t in range(x.shape[1]):
            o, st = rw.channel_mix_forward(cm, x[:, t:t + 1], st,
                                           return_state=True)
            outs.append(o)
        assert jnp.allclose(jnp.concatenate(outs, 1), full, atol=1e-3)

    def test_decay_in_unit_interval(self):
        tm, _, x = self._setup()
        decay = tm["decay_base"] + jnp.tanh(
            x.astype(jnp.float32) @ tm["decay_w1"]) @ tm["decay_w2"]
        w = jnp.exp(-jnp.exp(decay))
        assert float(w.min()) > 0.0 and float(w.max()) < 1.0
