"""Planet-scale participation layer: seeded cohort/fault plans, the masked
fused round (full-participation bit-identity, dropout ≡ restricted-cohort
reweighting), bounded stale aggregation (k=0 ≡ synchronous), and the
spill-to-disk client-state store surviving a truncated mid-spill crash."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_fed_round_fused import _problem, _round_batches, _runtime_setup

from repro.core import population as pop
from repro.core.fed import FedConfig, FedEngine


def _engine(method="fedgalore", **over):
    params, loss = _problem()
    kw = dict(method=method, rank=4, lr=3e-2, local_steps=5,
              clip_norm=10.0, weight_decay=0.01)
    kw.update(over)
    return FedEngine(FedConfig(**kw), loss, params)


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.array_equal(la, lb), float(jnp.max(jnp.abs(la - lb)))


# ------------------------------------------------------------- fault plans --

def test_cohort_plan_deterministic_in_config_and_round():
    pcfg = pop.ParticipationConfig(population=64, dropout_rate=0.3,
                                   straggler_rate=0.4, max_staleness=3,
                                   seed=7)
    for r in range(5):
        a = pop.sample_cohort(pcfg, 8, r)
        b = pop.sample_cohort(pcfg, 8, r)     # call order must not matter
        assert np.array_equal(a.clients, b.clients)
        assert np.array_equal(a.delays, b.delays)
        assert a.clients.shape == (8,)
        assert len(np.unique(a.clients)) == 8          # without replacement
        assert a.clients.max() < 64
        assert a.delays.min() >= -1 and a.delays.max() <= 3
        assert np.array_equal(a.mask, a.delays == 0)
        assert a.mask.any()                   # >= 1 on-time participant


def test_cohort_plan_draw_order_invariance():
    """Disabling staleness must not perturb the upstream sample/dropout
    draws: the (straggler_rate=x, max_staleness=0) plan equals the
    (straggler_rate=0, max_staleness=k) plan exactly."""
    base = dict(population=32, dropout_rate=0.25, seed=3)
    for r in range(6):
        a = pop.sample_cohort(pop.ParticipationConfig(
            straggler_rate=0.6, max_staleness=0, **base), 8, r)
        b = pop.sample_cohort(pop.ParticipationConfig(
            straggler_rate=0.0, max_staleness=4, **base), 8, r)
        assert np.array_equal(a.clients, b.clients)
        assert np.array_equal(a.delays, b.delays)
        assert not (a.delays > 0).any()


def test_cohort_plan_rejects_population_smaller_than_cohort():
    with pytest.raises(ValueError, match="population"):
        pop.sample_cohort(pop.ParticipationConfig(population=3), 4, 0)


# ----------------------------------------------------- masked fused round ---

def test_full_participation_mask_bit_identical_engine():
    """An all-true mask must short-circuit onto the UNMASKED compiled
    program — bit-identity by construction, not numerics."""
    eng_m, eng_p = _engine(), _engine()
    for r in range(2):
        b = _round_batches(r)
        mm = eng_m.run_round(b, mask=np.ones(4, bool))
        mp = eng_p.run_round(b)
        assert np.array_equal(np.asarray(mm["local_loss"]),
                              np.asarray(mp["local_loss"]))
    _leaves_equal(eng_m.global_trainable, eng_p.global_trainable)
    _leaves_equal(eng_m.synced_v, eng_p.synced_v)


def test_mask_dropping_every_client_raises():
    eng = _engine()
    with pytest.raises(ValueError, match="participant"):
        eng.run_round(_round_batches(0), mask=np.zeros(4, bool))


def test_dropout_renormalization_matches_restricted_cohort():
    """A masked C=4 round (one client dropped: zero effective weight in 𝒜,
    excluded from the AJIVE joint basis) must match the C=3 round over just
    the survivors — the eager-reweighting semantics of dropout."""
    mask = np.array([True, True, True, False])
    eng4, eng3 = _engine(), _engine()
    for r in range(2):
        b4 = _round_batches(r)
        b3 = jax.tree_util.tree_map(lambda x: x[:3], b4)
        m4 = eng4.run_round(b4, mask=mask)
        m3 = eng3.run_round(b3)
        assert np.allclose(np.asarray(m4["local_loss"])[:3],
                           np.asarray(m3["local_loss"]), atol=1e-5)
    for la, lb in zip(jax.tree_util.tree_leaves(eng4.global_trainable),
                      jax.tree_util.tree_leaves(eng3.global_trainable)):
        assert jnp.allclose(la, lb, atol=1e-5), float(jnp.max(jnp.abs(la - lb)))
    for la, lb in zip(jax.tree_util.tree_leaves(eng4.synced_v),
                      jax.tree_util.tree_leaves(eng3.synced_v)):
        assert jnp.allclose(la, lb, atol=1e-5), float(jnp.max(jnp.abs(la - lb)))


def test_masked_scan_matches_sequential_masked_rounds():
    """run_rounds(masks=) — per-round effective weights riding the scan as
    xs — must reproduce K sequential run_round(mask=) calls."""
    masks = np.array([[True, True, True, True],
                      [True, False, True, True],
                      [True, True, False, False]])
    eng_s, eng_q = _engine(), _engine()
    rb = _round_batches(0, k_rounds=3)
    ms = eng_s.run_rounds(rb, masks=masks)
    for r in range(3):
        mq = eng_q.run_round(jax.tree_util.tree_map(lambda x: x[r], rb),
                             mask=masks[r])
        assert np.allclose(np.asarray(ms["local_loss"][r]),
                           np.asarray(mq["local_loss"]), atol=1e-5)
    for la, lb in zip(jax.tree_util.tree_leaves(eng_s.global_trainable),
                      jax.tree_util.tree_leaves(eng_q.global_trainable)):
        assert jnp.allclose(la, lb, atol=1e-5), float(jnp.max(jnp.abs(la - lb)))


# -------------------------------------------------------- population runner --

def _runner(eng, pcfg, **kw):
    return pop.PopulationRunner(
        eng, lambda ids, r: _round_batches(r), cohort=4, pcfg=pcfg, **kw)


def test_staleness_zero_is_exactly_synchronous():
    """max_staleness=0 disables buffering entirely (delay-0 ≡ on-time), so
    the PopulationRunner with no dropout is bit-identical to bare engine
    rounds: the full-participation plan short-circuits to the unmasked
    program."""
    eng_r = _engine()
    runner = _runner(eng_r, pop.ParticipationConfig(
        straggler_rate=0.9, max_staleness=0, seed=5))
    eng_p = _engine()
    for r in range(3):
        rec = runner.run_round()
        assert rec["participants"] == 4
        assert rec["buffered"] == 0 and rec["stale_merged"] == 0
        mp = eng_p.run_round(_round_batches(r))
        assert np.array_equal(np.asarray(rec["local_loss"]),
                              np.asarray(mp["local_loss"]))
    _leaves_equal(eng_r.global_trainable, eng_p.global_trainable)
    _leaves_equal(eng_r.synced_v, eng_p.synced_v)


def test_population_runner_faulted_rounds(tmp_path):
    """End-to-end fault injection: dropped clients, buffered stragglers
    landing at their due round, drift observatory recording, sticky rows
    scattered for the live clients only."""
    pcfg = pop.ParticipationConfig(population=16, dropout_rate=0.25,
                                   straggler_rate=0.5, max_staleness=2,
                                   seed=11)
    eng = _engine()
    runner = _runner(eng, pcfg, store_dir=str(tmp_path), shard_size=4,
                     max_resident_shards=2)
    out = runner.run_rounds(6)
    hist = out["history"]
    assert len(hist) == 6
    planned_stragglers = sum(
        int((pop.sample_cohort(pcfg, 4, r, 16).delays > 0).sum())
        for r in range(6))
    assert planned_stragglers > 0          # seed 11 does produce stragglers
    merged = sum(h["stale_merged"] for h in hist)
    assert merged > 0                      # ... and they land
    assert merged + len(runner.buffer) == planned_stragglers
    for h in hist:
        assert h["participants"] >= 1
        assert np.isfinite(h["mean_final_loss"])
        assert h["moment_divergence"] >= 0.0
        if h["stale_merged"]:
            assert 0.0 < h["stale_weight_err"] < 1.0
    for leaf in jax.tree_util.tree_leaves(eng.global_trainable):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # Live clients got sticky rows; flush spilled the dirty shards.
    assert (runner.store.last_round >= 0).any()
    assert runner.store.spills > 0


def test_population_runner_requires_fused_factored():
    eng = _engine(fused_round=False, factored_sync=False)
    with pytest.raises(ValueError, match="fused"):
        _runner(eng, pop.ParticipationConfig())


# ------------------------------------------------------ client-state store --

def _store_template():
    return {"delta": np.zeros((3, 2), np.float32),
            "v": np.zeros((5,), np.float32)}


def test_store_gather_scatter_roundtrip_with_spill(tmp_path):
    """10⁴ clients through a 4-shard resident window: every scattered row
    reads back exactly, cold clients read zeros, and a second store on the
    same directory sees the flushed rows (persistence)."""
    n = 10_000
    rng = np.random.default_rng(0)
    store = pop.ClientStateStore(n, _store_template(), str(tmp_path),
                                 shard_size=256, max_resident_shards=4)
    ids = np.sort(rng.choice(n, size=200, replace=False))
    rows = {"delta": rng.normal(size=(200, 3, 2)).astype(np.float32),
            "v": rng.normal(size=(200, 5)).astype(np.float32)}
    store.scatter(ids, rows, round_idx=3)
    assert store.spills > 0                # the LRU window forced spills
    got = store.gather(ids)
    np.testing.assert_array_equal(got["delta"], rows["delta"])
    np.testing.assert_array_equal(got["v"], rows["v"])
    cold = store.gather(np.setdiff1d(np.arange(300), ids)[:50])
    assert not cold["delta"].any() and not cold["v"].any()
    assert (store.last_round[ids] == 3).all()

    store.flush()
    reopened = pop.ClientStateStore(n, _store_template(), str(tmp_path),
                                    shard_size=256, max_resident_shards=4)
    got2 = reopened.gather(ids)
    np.testing.assert_array_equal(got2["delta"], rows["delta"])


def test_store_truncated_spill_falls_back_cold(tmp_path):
    """A spill cut short mid-write (simulated by truncating the shard's npz
    payload) must read back as cold zeros — not crash the run — while
    intact shards are untouched."""
    store = pop.ClientStateStore(64, _store_template(), str(tmp_path),
                                 shard_size=16, max_resident_shards=8)
    ids = np.arange(64)
    rows = {"delta": np.ones((64, 3, 2), np.float32),
            "v": np.ones((64, 5), np.float32)}
    store.scatter(ids, rows)
    store.flush()
    victim = os.path.join(str(tmp_path), "clients_00000001.npz")
    sz = os.path.getsize(victim)
    with open(victim, "r+b") as f:
        f.truncate(sz // 2)

    reopened = pop.ClientStateStore(64, _store_template(), str(tmp_path),
                                    shard_size=16, max_resident_shards=8)
    got = reopened.gather(ids)
    assert not got["delta"][16:32].any()       # crashed shard: cold zeros
    assert got["delta"][:16].all()             # neighbors intact
    assert got["delta"][32:].all()


def test_store_spill_requires_directory():
    with pytest.raises(ValueError, match="spill"):
        pop.ClientStateStore(64, _store_template(), directory=None,
                             shard_size=16, max_resident_shards=2)


# ------------------------------------------------------- staleness buffer ---

def test_staleness_buffer_pops_by_due_round():
    buf = pop.StalenessBuffer()
    mk = lambda cid, due: pop.StaleEntry(
        client_id=cid, birth_round=0, due_round=due, weight=0.25, decay=0.5,
        base_scale=1.0, deltas={"w": np.ones(2)}, bases=None, v_rows=None)
    buf.push(mk(1, 2))
    buf.push(mk(2, 1))
    buf.push(mk(3, 3))
    assert len(buf) == 3 and buf.pending_rounds == [1, 2, 3]
    due = buf.pop_due(2)
    assert sorted(e.client_id for e in due) == [1, 2]
    assert len(buf) == 1 and buf.pending_rounds == [3]


# ------------------------------------------------------- drift observatory --

def test_moment_divergence_zero_when_rows_match_bar():
    bar = {"w": np.full((3, 4), 2.0), "skip": None}
    rows = {"w": np.broadcast_to(bar["w"], (5, 3, 4)).copy(), "skip": None}
    assert pop.moment_divergence(rows, bar) == pytest.approx(0.0, abs=1e-9)
    rows2 = {"w": rows["w"] + 1.0, "skip": None}
    d = pop.moment_divergence(rows2, bar)
    # all rows offset by 1: dispersion sqrt(12)/||v̄|| = sqrt(12)/sqrt(48)
    assert d == pytest.approx(0.5, rel=1e-6)


def test_tree_rel_err():
    a = {"x": np.ones(4), "none": None}
    b = {"x": np.ones(4), "none": None}
    assert pop.tree_rel_err(a, b) == pytest.approx(0.0, abs=1e-12)
    a2 = {"x": np.ones(4) * 1.1, "none": None}
    assert pop.tree_rel_err(a2, b) == pytest.approx(0.1, rel=1e-6)


# ------------------------------------------------------------ runtime path --

def test_sharded_runtime_participation_layer():
    """ShardedFederation: all-true mask bit-identical to the unmasked round;
    sample_round_mask honors the ParticipationConfig; the masked scan driver
    matches sequential masked rounds."""
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    pcfg = pop.ParticipationConfig(dropout_rate=0.5, seed=9)

    fed_m = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                              participation=pcfg)
    fed_p = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
    b = batches(0)
    mm = fed_m.run_round(b, mask=np.ones(c, bool))    # short-circuit
    mp = fed_p.run_round(b)
    assert np.array_equal(np.asarray(mm["losses"]), np.asarray(mp["losses"]))
    for la, lb in zip(jax.tree_util.tree_leaves(fed_m.global_trainable),
                      jax.tree_util.tree_leaves(fed_p.global_trainable)):
        assert jnp.array_equal(la, lb)

    masks = np.stack([fed_m.sample_round_mask(r) for r in (1, 2)])
    assert masks.shape == (2, c)
    assert masks.any(axis=1).all()            # every round has a participant
    # seeded + pure in (config, round): re-sampling gives the same masks
    assert np.array_equal(masks[0], fed_m.sample_round_mask(1))

    if not masks.all():                       # exercise the masked program
        fed_s = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                  participation=pcfg)
        ms = fed_s.run_rounds(batches(7, k_rounds=2), masks=masks)
        fed_q = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                  participation=pcfg)
        for r in range(2):
            mq = fed_q.run_round(jax.tree_util.tree_map(
                lambda x: x[r], batches(7, k_rounds=2)), mask=masks[r])
            assert np.allclose(np.asarray(ms["losses"][r]),
                               np.asarray(mq["losses"]), atol=1e-5)
        for la, lb in zip(jax.tree_util.tree_leaves(fed_s.global_trainable),
                          jax.tree_util.tree_leaves(fed_q.global_trainable)):
            assert jnp.allclose(la, lb, atol=1e-5)
