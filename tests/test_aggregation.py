import jax
import jax.numpy as jnp
import pytest

from repro.core import aggregation as agg
from repro.core.lora import LoraPair, rank_tail_energy, svd_truncate


@pytest.fixture
def adapters():
    key = jax.random.PRNGKey(0)
    k = 4
    pairs = LoraPair(
        a=jax.random.normal(key, (k, 2, 16)),
        b=jax.random.normal(jax.random.fold_in(key, 1), (k, 8, 2)))
    return {"w": pairs, "bias": None}


def test_weighted_average_convexity():
    """Lemma 4.1: the aggregate stays inside the convex hull."""
    xs = {"w": jnp.stack([jnp.full((4, 4), float(i)) for i in range(5)])}
    out = agg.weighted_average(xs, jnp.ones(5))
    assert float(xs["w"].min()) <= float(out["w"].min())
    assert float(out["w"].max()) <= float(xs["w"].max())
    assert jnp.allclose(out["w"], 2.0)


def test_factor_average_is_biased_vs_lift(adapters):
    """ΔW̄_factor = (Σp̃B)(Σp̃A) ≠ Σp̃ BA — the update-space-mismatch bias."""
    w = jnp.ones(4)
    fac = agg.factor_average(adapters, w)["w"]
    lift = agg.lift_average(adapters, w)["w"]
    fac_delta = fac.b @ fac.a
    assert not jnp.allclose(fac_delta, lift, atol=1e-3)


def test_lift_average_rank_can_exceed_r(adapters):
    """Rank of the lifted average grows up to K·r (paper §4.1)."""
    lift = agg.lift_average(adapters, jnp.ones(4))["w"]
    tail = rank_tail_energy(lift, 2)          # energy beyond rank 2
    assert float(tail) > 1e-3                 # off-manifold component exists


def test_lift_average_equals_mean_of_lifts(adapters):
    w = jnp.array([1.0, 2.0, 3.0, 4.0])
    lift = agg.lift_average(adapters, w)["w"]
    wn = w / w.sum()
    manual = sum(wn[i] * adapters["w"].b[i] @ adapters["w"].a[i]
                 for i in range(4))
    assert jnp.allclose(lift, manual, atol=1e-4)


def test_lora_fair_refines_toward_mean_lift(adapters):
    w = jnp.ones(4)
    fac = agg.factor_average(adapters, w)["w"]
    fair = agg.lora_fair_refine(adapters, w, scale=1.0)["w"]
    lift = agg.lift_average(adapters, w, scale=1.0)["w"]
    err_fac = jnp.linalg.norm(fac.b @ fac.a - lift)
    err_fair = jnp.linalg.norm(fair.b @ fair.a - lift)
    assert float(err_fair) <= float(err_fac) + 1e-5


def test_fr_lora_merge_preserves_mean_delta(adapters):
    base = {"w": jnp.zeros((8, 16)), "bias": jnp.zeros(3)}
    w = jnp.ones(4)
    merged = agg.fr_lora_merge(base, adapters, w, scale=1.0)
    lift = agg.lift_average(adapters, w, scale=1.0)["w"]
    assert jnp.allclose(merged["w"], lift, atol=1e-4)
    assert jnp.allclose(merged["bias"], 0.0)


def test_truncate_to_rank():
    key = jax.random.PRNGKey(2)
    d = jax.random.normal(key, (16, 16))
    out = agg.truncate_to_rank({"w": d}, 4)["w"]
    s = jnp.linalg.svd(out, compute_uv=False)
    assert float(s[4]) < 1e-4                 # rank ≤ 4
    # Eckart-Young optimality: truncation error == tail energy
    assert jnp.allclose(jnp.linalg.norm(out - d), rank_tail_energy(d, 4),
                        rtol=1e-4)


def test_svd_truncate_roundtrip():
    key = jax.random.PRNGKey(3)
    pair = LoraPair(a=jax.random.normal(key, (3, 16)),
                    b=jax.random.normal(jax.random.fold_in(key, 1), (8, 3)))
    delta = pair.b @ pair.a
    refac = svd_truncate(delta, 3)
    assert jnp.allclose(refac.b @ refac.a, delta, atol=1e-4)
