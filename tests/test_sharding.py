"""Sharding rules + small-mesh lowering (the dry-run's little sibling).

Rule resolution is tested against an AbstractMesh (no devices needed); the
numerical sharded-vs-unsharded equivalence runs in a subprocess with
``--xla_force_host_platform_device_count`` so the main pytest process keeps
its single CPU device (per the dry-run isolation requirement).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import model as M
from repro.sharding.rules import ShardingRules


def _abstract_mesh(shape, names):
    """AbstractMesh across jax versions: 0.4.x takes ((name, size), ...)
    pairs; 0.5+ takes (shape, names). No devices needed either way."""
    try:
        return jax.sharding.AbstractMesh(shape, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, shape)))


@pytest.fixture(scope="module")
def mesh():
    return _abstract_mesh((4, 2), ("data", "model"))


def test_param_rules(mesh):
    rules = ShardingRules(mesh, fsdp=True)
    assert rules.param_rule("blocks/0/attn/wq") == "col"
    assert rules.param_rule("blocks/0/attn/wo") == "row"
    assert rules.param_rule("blocks/0/moe/w_gate") == "exp_col"
    assert rules.param_rule("blocks/0/moe/router") == "repl"
    assert rules.param_rule("embed/w") == "emb"
    assert rules.param_rule("blocks/0/norm1/scale") == "repl"
    assert rules.param_rule("blocks/0/cmix/wv") == "row"
    assert rules.param_rule("blocks/0/tmix/wk") == "col"
    assert rules.param_rule("blocks/0/mamba/in_proj") == "col"
    assert rules.param_rule("blocks/0/mamba/x_proj") == "row"


def test_specs_divisibility_guard(mesh):
    rules = ShardingRules(mesh, fsdp=True)
    spec = rules.param_spec("blocks/0/attn/wq", (3, 7, 6))
    for dim, axes in zip((3, 7, 6), list(spec) + [None] * 3):
        if axes is not None:
            size = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                size *= mesh.shape[a]
            assert dim % size == 0


def test_col_row_assignment(mesh):
    rules = ShardingRules(mesh, fsdp=True)
    spec = rules.param_spec("blocks/0/attn/wq", (6, 8, 8))
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    spec = rules.param_spec("blocks/0/attn/wo", (6, 8, 8))
    assert spec == jax.sharding.PartitionSpec(None, "model", "data")
    # fsdp off: data axis never appears on params
    rules_tp = ShardingRules(mesh, fsdp=False)
    spec = rules_tp.param_spec("blocks/0/attn/wq", (6, 8, 8))
    assert spec == jax.sharding.PartitionSpec(None, None, "model")


def test_params_shardings_tree(mesh):
    cfg = smoke_variant(get_config("granite-moe-1b-a400m"))
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    sh = ShardingRules(mesh).params_shardings(params)
    assert len(jax.tree_util.tree_leaves(sh)) == \
        len(jax.tree_util.tree_leaves(params))


def test_decode_state_shardings(mesh):
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    state = jax.eval_shape(lambda: M.init_decode_state(cfg, 4, 32))
    sh = ShardingRules(mesh).decode_state_shardings(state)
    assert jax.tree_util.tree_leaves(sh)


_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp
from repro.configs import get_config, smoke_variant
from repro.models import model as M
from repro.sharding.rules import ShardingRules

arch = sys.argv[1]
cfg = smoke_variant(get_config(arch))
params = M.init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                          cfg.vocab_size)
ref, _ = M.forward(params, cfg, toks)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = ShardingRules(mesh)
p_sh = jax.device_put(params, rules.params_shardings(params))
t_sh = jax.device_put(toks, rules.data_shardings(toks))
with mesh:
    out, _ = jax.jit(lambda p, t: M.forward(p, cfg, t))(p_sh, t_sh)
err = float(jnp.max(jnp.abs(ref - out)))
assert err < 2e-2, (arch, err)
print(arch, "ok", err)
"""


@pytest.mark.parametrize("arch", [
    "qwen1.5-0.5b",
    pytest.param("granite-moe-1b-a400m", marks=pytest.mark.xfail(
        reason="pre-existing: sharded MoE forward diverges (~0.9 max err) "
               "under expert sharding on the 8-fake-device CPU mesh; "
               "tracked in ROADMAP")),
    "rwkv6-1.6b",
])
def test_sharded_forward_matches_single_device(arch):
    """Numerical equivalence under SPMD sharding (subprocess, 8 fake devices,
    one arch per process so one arch's failure doesn't mask the others)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", _SUBPROC_SCRIPT, arch], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout + r.stderr
