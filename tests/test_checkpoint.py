import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, restore, save


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    save(str(tmp_path), 3, tree)
    out = restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


def test_latest_step(tmp_path):
    tree = {"x": jnp.zeros((1,))}
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5


def test_save_is_atomic_no_tmp_residue(tmp_path):
    """The writer stages through tmp files + os.replace: after a completed
    save, only the final payload + manifest exist (a crash mid-write leaves
    a stray *.tmp*, never a half-written file under the final name)."""
    import os
    save(str(tmp_path), 2, {"x": jnp.ones((3,))})
    names = sorted(os.listdir(str(tmp_path)))
    assert names == ["ckpt_00000002.json", "ckpt_00000002.npz"]


def test_restore_truncated_payload_raises(tmp_path):
    """A payload cut short mid-write must fail loudly at restore (not deep
    inside np.load), pointing at latest_step for recovery."""
    import os
    tree = {"x": jnp.arange(4096, dtype=jnp.float32)}
    path = save(str(tmp_path), 7, tree)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(FileNotFoundError, match="truncated"):
        restore(str(tmp_path), 7, tree)


def test_latest_step_skips_truncated_and_missing_payloads(tmp_path):
    """latest_step only reports steps whose payload passes the zip CRC
    validation: a truncated newest step (crash mid-spill) falls back to the
    last complete one; a manifest with no payload at all is ignored."""
    import os
    tree = {"x": jnp.arange(4096, dtype=jnp.float32)}
    save(str(tmp_path), 1, tree)
    p5 = save(str(tmp_path), 5, tree)
    with open(p5, "r+b") as f:
        f.truncate(os.path.getsize(p5) // 2)
    assert latest_step(str(tmp_path)) == 1
    save(str(tmp_path), 9, tree)
    os.remove(os.path.join(str(tmp_path), "ckpt_00000009.npz"))
    assert latest_step(str(tmp_path)) == 1


def test_restores_namedtuple_state(tmp_path):
    from repro.core.galore import GaloreConfig, galore_init
    params = {"w": jnp.ones((8, 8))}
    st = galore_init(GaloreConfig(rank=2), params)
    save(str(tmp_path), 0, st, name="opt")
    out = restore(str(tmp_path), 0, st, name="opt")
    assert type(out) is type(st)
    assert jnp.allclose(out.blocks["w"].basis, st.blocks["w"].basis)
