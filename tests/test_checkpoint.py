import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, restore, save


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    save(str(tmp_path), 3, tree)
    out = restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


def test_latest_step(tmp_path):
    tree = {"x": jnp.zeros((1,))}
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5


def test_restores_namedtuple_state(tmp_path):
    from repro.core.galore import GaloreConfig, galore_init
    params = {"w": jnp.ones((8, 8))}
    st = galore_init(GaloreConfig(rank=2), params)
    save(str(tmp_path), 0, st, name="opt")
    out = restore(str(tmp_path), 0, st, name="opt")
    assert type(out) is type(st)
    assert jnp.allclose(out.blocks["w"].basis, st.blocks["w"].basis)
