import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import gc_steps, latest_step, restore, save


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    save(str(tmp_path), 3, tree)
    out = restore(str(tmp_path), 3, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        assert jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32))


def test_latest_step(tmp_path):
    tree = {"x": jnp.zeros((1,))}
    assert latest_step(str(tmp_path)) is None
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5


def test_save_is_atomic_no_tmp_residue(tmp_path):
    """The writer stages through tmp files + os.replace: after a completed
    save, only the final payload + manifest exist (a crash mid-write leaves
    a stray *.tmp*, never a half-written file under the final name)."""
    import os
    save(str(tmp_path), 2, {"x": jnp.ones((3,))})
    names = sorted(os.listdir(str(tmp_path)))
    assert names == ["ckpt_00000002.json", "ckpt_00000002.npz"]


def test_restore_truncated_payload_raises(tmp_path):
    """A payload cut short mid-write must fail loudly at restore (not deep
    inside np.load), pointing at latest_step for recovery."""
    import os
    tree = {"x": jnp.arange(4096, dtype=jnp.float32)}
    path = save(str(tmp_path), 7, tree)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(FileNotFoundError, match="truncated"):
        restore(str(tmp_path), 7, tree)


def test_latest_step_skips_truncated_and_missing_payloads(tmp_path):
    """latest_step only reports steps whose payload passes the zip CRC
    validation: a truncated newest step (crash mid-spill) falls back to the
    last complete one; a manifest with no payload at all is ignored."""
    import os
    tree = {"x": jnp.arange(4096, dtype=jnp.float32)}
    save(str(tmp_path), 1, tree)
    p5 = save(str(tmp_path), 5, tree)
    with open(p5, "r+b") as f:
        f.truncate(os.path.getsize(p5) // 2)
    assert latest_step(str(tmp_path)) == 1
    save(str(tmp_path), 9, tree)
    os.remove(os.path.join(str(tmp_path), "ckpt_00000009.npz"))
    assert latest_step(str(tmp_path)) == 1


def test_keep_last_gc_retains_newest_valid(tmp_path):
    """``save(keep_last=k)`` prunes to the k newest steps with a valid
    payload (manifest removed alongside)."""
    import os
    tree = {"x": jnp.arange(16, dtype=jnp.float32)}
    for s in (1, 3, 5, 7):
        save(str(tmp_path), s, tree, keep_last=2)
    npzs = sorted(f for f in os.listdir(str(tmp_path))
                  if f.endswith(".npz"))
    assert npzs == ["ckpt_00000005.npz", "ckpt_00000007.npz"]
    assert not any(f == "ckpt_00000001.json" or f == "ckpt_00000003.json"
                   for f in os.listdir(str(tmp_path)))
    for s in (5, 7):
        out = restore(str(tmp_path), s, tree)
        assert jnp.array_equal(out["x"], tree["x"])


def test_gc_never_deletes_newest_valid_payload(tmp_path):
    """Retention must key on *validity*, not recency: when the newest steps
    are truncated (crash mid-spill), GC keeps the newest RESTORABLE payload
    and collects the dead newer steps — a dead step can never be restored,
    so deleting the last valid one instead would strand recovery."""
    import os
    tree = {"x": jnp.arange(4096, dtype=jnp.float32)}
    save(str(tmp_path), 2, tree)
    for s in (5, 8):
        p = save(str(tmp_path), s, tree)
        with open(p, "r+b") as f:
            f.truncate(os.path.getsize(p) // 2)
    gc_steps(str(tmp_path), keep_last=1)
    npzs = sorted(f for f in os.listdir(str(tmp_path))
                  if f.endswith(".npz"))
    assert npzs == ["ckpt_00000002.npz"]       # newest VALID survives
    assert latest_step(str(tmp_path)) == 2
    out = restore(str(tmp_path), 2, tree)
    assert jnp.array_equal(out["x"], tree["x"])
    with pytest.raises(ValueError, match="keep_last"):
        gc_steps(str(tmp_path), keep_last=0)


def test_restore_rejects_nonfinite_payload(tmp_path):
    """A structurally-valid payload carrying NaN/inf is corrupted state —
    restore must refuse it instead of feeding poison back into the
    federation (opt-out via reject_nonfinite=False for forensics)."""
    tree = {"w": jnp.ones((4,), jnp.float32),
            "steps": jnp.arange(4, dtype=jnp.int32)}
    bad = {"w": jnp.asarray([1.0, np.nan, 3.0, np.inf], jnp.float32),
           "steps": tree["steps"]}
    save(str(tmp_path), 4, bad)
    with pytest.raises(ValueError, match="non-finite"):
        restore(str(tmp_path), 4, tree)
    out = restore(str(tmp_path), 4, tree, reject_nonfinite=False)
    assert np.isnan(np.asarray(out["w"])[1])
    # Finite payloads (including integer leaves) restore untouched.
    save(str(tmp_path), 6, tree)
    out = restore(str(tmp_path), 6, tree)
    assert jnp.array_equal(out["steps"], tree["steps"])


def test_restores_namedtuple_state(tmp_path):
    from repro.core.galore import GaloreConfig, galore_init
    params = {"w": jnp.ones((8, 8))}
    st = galore_init(GaloreConfig(rank=2), params)
    save(str(tmp_path), 0, st, name="opt")
    out = restore(str(tmp_path), 0, st, name="opt")
    assert type(out) is type(st)
    assert jnp.allclose(out.blocks["w"].basis, st.blocks["w"].basis)
