import jax
import jax.numpy as jnp
import pytest

from repro.core import projector as proj
from repro.core import state_sync as sync


@pytest.fixture
def block():
    key = jax.random.PRNGKey(0)
    k, n, m, r = 5, 24, 16, 4
    basis = proj.random_basis(0, n, r)                 # shared (seeded) basis
    # ground-truth lifted second moment with shared structure
    shared = jnp.abs(jax.random.normal(key, (m, n)))
    v_stack = []
    for i in range(k):
        ki = jax.random.fold_in(key, i)
        drift = 0.5 * jnp.abs(jax.random.normal(ki, (m, n)))
        v_stack.append((shared + drift) @ basis)       # projected view (m, r)
    return jnp.stack(v_stack), basis, shared


def test_lift_views_shapes(block):
    v_stack, basis, _ = block
    views = sync.lift_views(v_stack, basis, proj.RIGHT)
    assert views.shape == (5, 16, 24)


def test_sync_none(block):
    v_stack, basis, _ = block
    assert sync.sync_block("none", v_stack, basis, basis, proj.RIGHT) is None


def test_sync_avg_is_mean(block):
    v_stack, basis, _ = block
    out = sync.SYNC_PROTOCOLS["avg"](v_stack, basis, proj.RIGHT)
    manual = jnp.mean(sync.lift_views(v_stack, basis, proj.RIGHT), axis=0)
    assert jnp.allclose(out, manual, atol=1e-5)


@pytest.mark.parametrize("protocol", ["avg", "avg_svd", "ajive"])
def test_sync_block_end_to_end(block, protocol):
    v_stack, basis, _ = block
    new_basis = proj.random_basis(1, 24, 4)
    out = sync.sync_block(protocol, v_stack, basis, new_basis, proj.RIGHT,
                          rank=4)
    assert out.shape == v_stack.shape[1:]
    assert float(jnp.min(out)) >= 0.0          # ṽ init must stay non-negative
    assert not bool(jnp.any(jnp.isnan(out)))


def test_left_side_roundtrip():
    key = jax.random.PRNGKey(1)
    k, m, n, r = 3, 8, 24, 4                   # left block: m < n
    basis = proj.random_basis(0, m, r)
    v_stack = jnp.abs(jax.random.normal(key, (k, r, n)))
    views = sync.lift_views(v_stack, basis, proj.LEFT)
    assert views.shape == (k, m, n)
    back = sync.project_state(views[0], basis, proj.LEFT)
    assert back.shape == (r, n)


# ------------------------------------------------------ factored fast path --

def _structured_stack(key, side, k=5, m=16, n=24, r=4):
    """Projected moments with a graded shared signal (well-separated spectrum
    so the dense and factored joint projectors agree to fp32 precision)."""
    scale = jnp.linspace(5.0, 2.0, r)
    shape = (m, r) if side == proj.RIGHT else (r, n)
    base = jax.random.normal(key, shape) * (
        scale[None, :] if side == proj.RIGHT else scale[:, None])
    return jnp.stack([jnp.abs(base + 0.2 * jax.random.normal(
        jax.random.fold_in(key, i), shape)) for i in range(k)])


@pytest.mark.parametrize("side", [proj.RIGHT, proj.LEFT])
@pytest.mark.parametrize("protocol", ["avg", "avg_svd", "ajive"])
def test_sync_block_factored_matches_dense(side, protocol):
    """sync_block_factored == sync_block (lift → 𝒮 → re-project oracle) for
    every protocol, both sides, including the old→new basis transfer."""
    r, dim = 4, 24
    v_stack = _structured_stack(jax.random.PRNGKey(0), side, r=r)
    old_b = proj.random_basis(0, dim, r)
    new_b = proj.random_basis(1, dim, r)
    w = jnp.array([1, 2, 1, 1, 3.0])
    dense = sync.sync_block(protocol, v_stack, old_b, new_b, side,
                            weights=w, rank=r)
    fact = sync.sync_block_factored(protocol, v_stack, old_b, new_b, side,
                                    weights=w, rank=r)
    assert fact.shape == dense.shape
    assert jnp.allclose(fact, dense, atol=1e-5)
    assert float(jnp.min(fact)) >= 0.0


def test_sync_block_factored_none():
    v_stack = _structured_stack(jax.random.PRNGKey(0), proj.RIGHT)
    b = proj.random_basis(0, 24, 4)
    assert sync.sync_block_factored("none", v_stack, b, b, proj.RIGHT) is None


def test_synced_factored_projected_shape():
    """sync_block_synced_factored returns the round-k-basis projected state
    (the uplink shape) — no ambient dimension anywhere."""
    v_stack = _structured_stack(jax.random.PRNGKey(2), proj.RIGHT)
    out = sync.sync_block_synced_factored("ajive", v_stack, proj.RIGHT,
                                          rank=4)
    assert out.shape == v_stack.shape[1:]


# ------------------------------------------ heterogeneous-basis factored ----

def _hetero_bases(key, k, dim, r):
    """Per-client orthonormal bases that genuinely diverge (the adaptive
    round-0 / svd-refresh case)."""
    return jnp.stack([proj.random_basis(jax.random.fold_in(key, i), dim, r)
                      for i in range(k)])


def _dense_hetero_oracle(protocol, v_stack, b_stack, side, w, rank):
    """The dense per-client lift 𝒮 (what the engine's eager round-0 and the
    runtime's factored_sync=False path execute): lift each client with its
    own basis, sync, re-project onto the client-0 basis."""
    v32 = v_stack.astype(jnp.float32)
    b32 = b_stack.astype(jnp.float32)
    if side == proj.RIGHT:
        views = jnp.einsum("kmr,knr->kmn", v32, b32)
    else:
        views = jnp.einsum("kmr,krn->kmn", b32, v32)
    lifted = sync.sync_lifted_views(protocol, views, w, rank)
    return sync.project_state(lifted, b_stack[0], side)


@pytest.mark.parametrize("side", [proj.RIGHT, proj.LEFT])
@pytest.mark.parametrize("protocol", ["avg", "avg_svd", "ajive"])
def test_hetero_factored_matches_dense_lift(side, protocol):
    """sync_block_hetero_factored ≡ the dense per-client lift oracle to ≤1e-5
    for every protocol and both sides — the r×r transfer-Gram path replaces
    the last dense (C, m, n) 𝒮."""
    r, dim, k = 4, 24, 5
    v_stack = _structured_stack(jax.random.PRNGKey(3), side, k=k, r=r)
    b_stack = _hetero_bases(jax.random.PRNGKey(7), k, dim, r)
    w = jnp.array([1, 2, 1, 1, 3.0])
    dense = _dense_hetero_oracle(protocol, v_stack, b_stack, side, w, r)
    fact = sync.sync_block_hetero_factored(protocol, v_stack, b_stack, side,
                                           weights=w, rank=r)
    assert fact.shape == dense.shape == v_stack.shape[1:]
    assert jnp.allclose(fact, dense, atol=1e-5), float(
        jnp.max(jnp.abs(fact - dense)))


@pytest.mark.parametrize("protocol", ["avg", "avg_svd", "ajive"])
def test_hetero_factored_shared_bases_degenerates(protocol):
    """With every client on the same basis the hetero path must agree with
    the shared-basis factored sync (the transfer Grams become identity)."""
    r, dim, k = 4, 24, 5
    v_stack = _structured_stack(jax.random.PRNGKey(4), proj.RIGHT, k=k, r=r)
    basis = proj.random_basis(0, dim, r)
    b_stack = jnp.broadcast_to(basis, (k,) + basis.shape)
    shared = sync.sync_block_synced_factored(protocol, v_stack, proj.RIGHT,
                                             rank=r)
    het = sync.sync_block_hetero_factored(protocol, v_stack, b_stack,
                                          proj.RIGHT, rank=r)
    assert jnp.allclose(het, shared, atol=1e-5)


def test_hetero_factored_stacked_blocks():
    """Stacked scan blocks (C, nb, ·, r) vmap over the layer dim."""
    r, dim, k, nb = 4, 24, 5, 2
    v4 = jnp.stack([_structured_stack(jax.random.PRNGKey(i), proj.RIGHT,
                                      k=k, r=r) for i in range(nb)], axis=1)
    b4 = jnp.stack([_hetero_bases(jax.random.PRNGKey(10 + i), k, dim, r)
                    for i in range(nb)], axis=1)
    out = sync.sync_block_hetero_factored("ajive", v4, b4, proj.RIGHT, rank=r)
    assert out.shape == v4.shape[1:]
    for i in range(nb):
        single = sync.sync_block_hetero_factored("ajive", v4[:, i], b4[:, i],
                                                 proj.RIGHT, rank=r)
        assert jnp.allclose(out[i], single, atol=1e-6)


def test_hetero_factored_none():
    v_stack = _structured_stack(jax.random.PRNGKey(5), proj.RIGHT)
    b_stack = _hetero_bases(jax.random.PRNGKey(6), 5, 24, 4)
    assert sync.sync_block_hetero_factored("none", v_stack, b_stack,
                                           proj.RIGHT) is None
