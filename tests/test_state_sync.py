import jax
import jax.numpy as jnp
import pytest

from repro.core import projector as proj
from repro.core import state_sync as sync


@pytest.fixture
def block():
    key = jax.random.PRNGKey(0)
    k, n, m, r = 5, 24, 16, 4
    basis = proj.random_basis(0, n, r)                 # shared (seeded) basis
    # ground-truth lifted second moment with shared structure
    shared = jnp.abs(jax.random.normal(key, (m, n)))
    v_stack = []
    for i in range(k):
        ki = jax.random.fold_in(key, i)
        drift = 0.5 * jnp.abs(jax.random.normal(ki, (m, n)))
        v_stack.append((shared + drift) @ basis)       # projected view (m, r)
    return jnp.stack(v_stack), basis, shared


def test_lift_views_shapes(block):
    v_stack, basis, _ = block
    views = sync.lift_views(v_stack, basis, proj.RIGHT)
    assert views.shape == (5, 16, 24)


def test_sync_none(block):
    v_stack, basis, _ = block
    assert sync.sync_block("none", v_stack, basis, basis, proj.RIGHT) is None


def test_sync_avg_is_mean(block):
    v_stack, basis, _ = block
    out = sync.SYNC_PROTOCOLS["avg"](v_stack, basis, proj.RIGHT)
    manual = jnp.mean(sync.lift_views(v_stack, basis, proj.RIGHT), axis=0)
    assert jnp.allclose(out, manual, atol=1e-5)


@pytest.mark.parametrize("protocol", ["avg", "avg_svd", "ajive"])
def test_sync_block_end_to_end(block, protocol):
    v_stack, basis, _ = block
    new_basis = proj.random_basis(1, 24, 4)
    out = sync.sync_block(protocol, v_stack, basis, new_basis, proj.RIGHT,
                          rank=4)
    assert out.shape == v_stack.shape[1:]
    assert float(jnp.min(out)) >= 0.0          # ṽ init must stay non-negative
    assert not bool(jnp.any(jnp.isnan(out)))


def test_left_side_roundtrip():
    key = jax.random.PRNGKey(1)
    k, m, n, r = 3, 8, 24, 4                   # left block: m < n
    basis = proj.random_basis(0, m, r)
    v_stack = jnp.abs(jax.random.normal(key, (k, r, n)))
    views = sync.lift_views(v_stack, basis, proj.LEFT)
    assert views.shape == (k, m, n)
    back = sync.project_state(views[0], basis, proj.LEFT)
    assert back.shape == (r, n)
