"""End-to-end behaviour tests for the full system."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.fed import FedConfig, FedEngine
from repro.data import FederatedBatcher, seq_classification
from repro.launch.steps import galore_target_fn
from repro.models import model as M


def _run_federation(method, alpha, rounds=8, seed=0):
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    task = seq_classification(512, 4, 16, cfg.vocab_size, seed=seed)
    batcher = FederatedBatcher(task, n_clients=4, batch_size=8, alpha=alpha,
                               seed=seed)

    def loss(p, batch):
        return M.loss_fn(p, cfg, batch)

    eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2, local_steps=8,
                              seed=seed),
                    loss, params, target_fn=galore_target_fn(cfg))
    for _ in range(rounds):
        batches = {k: jnp.asarray(v)
                   for k, v in batcher.round_batches(8).items()}
        eng.run_round(batches)
    gp = eng.global_params()
    eval_b = batcher.eval_batch(128)
    logits, _ = M.forward(gp, cfg, jnp.asarray(eval_b["tokens"]))
    acc = float((np.asarray(logits[:, -1]).argmax(-1)
                 == eval_b["labels"][:, -1]).mean())
    return acc


def test_fedgalore_learns_iid():
    # The paper's target modules freeze the (tied) output embedding, so the
    # 2-layer smoke model must align hidden states with frozen class rows —
    # chance over the full vocab is ~0.002; ≥0.3 on 4 classes is clear
    # learning within the 64-step budget.
    acc = _run_federation("fedgalore", alpha=None)
    assert acc > 0.3, acc


def test_fedgalore_learns_noniid():
    acc = _run_federation("fedgalore", alpha=0.5)
    assert acc > 0.2, acc


def test_train_launcher_cli(tmp_path):
    out = tmp_path / "hist.json"
    from repro.launch import train as train_mod
    hist = train_mod.main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--method", "fedgalore",
        "--rounds", "2", "--clients", "3", "--local-steps", "2",
        "--batch", "4", "--seq", "16", "--examples", "256",
        "--alpha", "0.5", "--out", str(out)])
    assert len(hist) == 2
    assert all(np.isfinite(h["val_loss"]) for h in hist)
    assert json.loads(out.read_text())


def test_serve_launcher_cli(capsys):
    from repro.launch import serve as serve_mod
    serve_mod.main(["--arch", "rwkv6-1.6b", "--smoke", "--batch", "2",
                    "--prompt-len", "8", "--new-tokens", "4"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["tokens_per_sec"] > 0
    assert len(out["sample_row"]) == 4


def test_generate_deterministic_greedy():
    from repro.launch.serve import generate
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    a = generate(params, cfg, prompts, 4, cache_len=16)
    b = generate(params, cfg, prompts, 4, cache_len=16)
    assert jnp.array_equal(a, b)


def test_checkpoint_resume_consistency(tmp_path):
    from repro.checkpoint import restore, save
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    save(str(tmp_path), 0, params)
    params2 = restore(str(tmp_path), 0, params)
    toks = jnp.zeros((1, 8), jnp.int32)
    a, _ = M.forward(params, cfg, toks)
    b, _ = M.forward(params2, cfg, toks)
    assert jnp.allclose(a, b)
