"""Defense-in-depth rounds: in-round quarantine of corrupted factored
contributions (all-honest bit-identity, NaN/scale attacks ≈ masked-round
parity), robust factored aggregation operators (norm-clip / trimmed-mean /
geomedian on rank-r stacks), seeded corruption plans, bounded staleness
buffers, crash-resumable snapshots, and the drift tripwire's
rollback-and-replay path."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_fed_round_fused import _problem, _round_batches, _runtime_setup

from repro.core import aggregation as agg
from repro.core import population as pop
from repro.core.fed import FedConfig, FedEngine


def _engine(**over):
    params, loss = _problem()
    kw = dict(method="fedgalore", rank=4, lr=3e-2, local_steps=5,
              clip_norm=10.0, weight_decay=0.01)
    kw.update(over)
    return FedEngine(FedConfig(**kw), loss, params)


def _runner(eng, pcfg=None, **kw):
    return pop.PopulationRunner(eng, lambda ids, r: _round_batches(r),
                                cohort=4, pcfg=pcfg, **kw)


def _leaves_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.array_equal(la, lb), float(jnp.max(jnp.abs(la - lb)))


def _finite_tree(t):
    for leaf in jax.tree_util.tree_leaves(t):
        assert np.isfinite(np.asarray(leaf)).all()


# ------------------------------------------------- robust operator units ----

def test_client_sq_norms_ignores_nonfinite():
    stack = jnp.asarray([[1.0, 2.0], [np.nan, 3.0], [np.inf, 1.0]])
    n = np.asarray(agg.client_sq_norms(stack))
    np.testing.assert_allclose(n, [5.0, 9.0, 1.0])


def test_weighted_quantile_median():
    x = jnp.asarray([1.0, 5.0, 3.0])
    w = jnp.asarray([1.0, 1.0, 1.0]) / 3
    assert float(agg.weighted_quantile(x, w, 0.5)) == 3.0
    # Skewed mass pulls the median onto the heavy sample.
    w2 = jnp.asarray([0.8, 0.1, 0.1])
    assert float(agg.weighted_quantile(x, w2, 0.5)) == 1.0


def test_median_norm_clip_caps_outlier_only():
    stack = jnp.stack([jnp.ones((3, 2)), jnp.ones((3, 2)),
                       100.0 * jnp.ones((3, 2))])
    w = jnp.full((3,), 1 / 3)
    c = np.asarray(agg.median_norm_clip_factors(stack, w))
    np.testing.assert_allclose(c[:2], 1.0)
    assert c[2] == pytest.approx(1.0 / 100.0, rel=1e-5)


def test_trimmed_mean_zero_trim_is_weighted_mean():
    rng = np.random.default_rng(0)
    stack = jnp.asarray(rng.normal(size=(5, 4, 3)), jnp.float32)
    w = jnp.asarray(rng.random(5), jnp.float32)
    w = w / w.sum()
    got = agg.robust_factored_reduce(stack, w, "trimmed_mean", trim=0.0)
    ref = jnp.einsum("c,c...->...", w, stack)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def test_trimmed_mean_and_geomedian_resist_outlier():
    honest = jnp.ones((4, 3, 2))
    stack = jnp.concatenate([honest, 1e4 * jnp.ones((1, 3, 2))])
    w = jnp.full((5,), 0.2)
    for mode in ("trimmed_mean", "geomedian"):
        out = np.asarray(agg.robust_factored_reduce(stack, w, mode,
                                                    trim=0.25))
        assert np.abs(out - 1.0).max() < 0.1, (mode, out)
    # The plain mean is dragged three orders of magnitude away.
    mean = np.asarray(jnp.einsum("c,c...->...", w, stack))
    assert mean.min() > 1e3


def test_robust_reduce_excludes_zero_weight_rows():
    stack = jnp.stack([jnp.ones((2, 2)), 3.0 * jnp.ones((2, 2)),
                       1e6 * jnp.ones((2, 2))])
    w = jnp.asarray([0.5, 0.5, 0.0])
    for mode in ("trimmed_mean", "geomedian"):
        out = np.asarray(agg.robust_factored_reduce(stack, w, mode,
                                                    trim=0.0))
        assert out.max() < 10.0, (mode, out)


def test_screen_factored_clients_flags_nonfinite_and_outliers():
    d = {"a": jnp.ones((4, 3, 2))}
    v = {"a": jnp.ones((4, 3, 2))}
    scales = jnp.ones((4,))
    w = jnp.full((4,), 0.25)
    keep = np.asarray(agg.screen_factored_clients(d, v, scales, w))
    assert keep.all()
    bad_d = {"a": d["a"].at[1].set(jnp.nan).at[2].mul(1e4)}
    keep = np.asarray(agg.screen_factored_clients(bad_d, v, scales, w,
                                                  zmax=6.0))
    np.testing.assert_array_equal(keep, [True, False, False, True])


def test_quarantine_weights_allpass_untouched_partial_renormalized():
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    out = agg.quarantine_weights(w, jnp.ones((4,), bool))
    assert jnp.array_equal(out, w)          # bitwise: no renorm round-off
    keep = jnp.asarray([True, False, True, False])
    out = np.asarray(agg.quarantine_weights(w, keep))
    np.testing.assert_allclose(out, [0.25, 0.0, 0.75, 0.0], atol=1e-6)
    # All-fail degrades to the original weights (skip-round semantics).
    out = agg.quarantine_weights(w, jnp.zeros((4,), bool))
    assert jnp.array_equal(out, w)


# ------------------------------------------------ guarded engine rounds -----

def test_guarded_round_honest_bit_identity_engine():
    """quarantine=True with an all-honest cohort must reproduce the
    unguarded engine bit-for-bit — the screen, the weight fold, and the
    moment reinstall are exact float identities, not numerics."""
    eng_q, eng_p = _engine(quarantine=True), _engine()
    for r in range(3):
        b = _round_batches(r)
        mq = eng_q.run_round(b)
        mp = eng_p.run_round(b)
        assert jnp.array_equal(mq["local_loss"], mp["local_loss"])
    _leaves_equal(eng_q.global_trainable, eng_p.global_trainable)
    _leaves_equal(eng_q.synced_v, eng_p.synced_v)


def test_all_ones_attack_canonicalizes_to_unattacked():
    """An explicit all-ones attack operand short-circuits onto the plain
    program (no guarded compile, bit-identical outputs)."""
    eng_a, eng_p = _engine(), _engine()
    for r in range(2):
        b = _round_batches(r)
        ma = eng_a.run_round(b, attack=np.ones(4, np.float32))
        mp = eng_p.run_round(b)
        assert jnp.array_equal(ma["local_loss"], mp["local_loss"])
    _leaves_equal(eng_a.global_trainable, eng_p.global_trainable)
    assert eng_a._round_guard_jit is None   # guarded program never built


@pytest.mark.parametrize("attack_val", [np.nan, 100.0],
                         ids=["nan", "scale"])
def test_quarantine_matches_masked_round(attack_val):
    """A quarantined attacker ≈ the same client masked out: the screen
    zeroes its contribution and renormalizes the survivors. allclose (not
    bitwise) because the masked path renormalizes eagerly on the host."""
    eng_a, eng_m = _engine(quarantine=True), _engine()
    attack = np.ones(4, np.float32)
    attack[1] = attack_val
    mask = np.ones(4, bool)
    mask[1] = False
    for r in range(2):
        b = _round_batches(r)
        eng_a.run_round(b, attack=attack)
        eng_m.run_round(b, mask=mask)
    _finite_tree(eng_a.global_trainable)
    for la, lb in zip(jax.tree_util.tree_leaves(eng_a.global_trainable),
                      jax.tree_util.tree_leaves(eng_m.global_trainable)):
        assert jnp.allclose(la, lb, atol=1e-5), float(
            jnp.max(jnp.abs(la - lb)))


def test_robust_agg_bounds_scale_attack():
    """Under a 100× norm attack on one client, trimmed-mean aggregation
    stays near the honest trajectory while mode 'none' is dragged away."""
    honest = _engine()
    plain = _engine()
    robust = _engine(robust_agg="trimmed_mean", robust_trim=0.3)
    attack = np.ones(4, np.float32)
    attack[2] = 100.0
    for r in range(2):
        b = _round_batches(r)
        honest.run_round(b)
        plain.run_round(b, attack=attack)
        robust.run_round(b, attack=attack)
    err_plain = pop.tree_rel_err(plain.global_trainable,
                                 honest.global_trainable)
    err_robust = pop.tree_rel_err(robust.global_trainable,
                                  honest.global_trainable)
    assert err_robust < 0.1 * err_plain, (err_robust, err_plain)
    _finite_tree(robust.global_trainable)


def test_guarded_round_requires_factored_clients():
    with pytest.raises(ValueError, match="factored"):
        _engine(quarantine=True, factored_clients=False)
    eng = _engine(factored_clients=False)
    attack = np.ones(4, np.float32)
    attack[0] = -1.0          # all-ones canonicalizes away; this cannot
    with pytest.raises(ValueError, match="factored"):
        eng.run_round(_round_batches(0), attack=attack)


# ---------------------------------------------------- corruption plans ------

def test_corruption_plan_deterministic_and_on_time_only():
    pcfg = pop.ParticipationConfig(population=32, dropout_rate=0.2,
                                   straggler_rate=0.3, max_staleness=2,
                                   corrupt_rate=0.4, seed=11)
    saw = 0
    for r in range(8):
        a = pop.sample_cohort(pcfg, 8, r)
        b = pop.sample_cohort(pcfg, 8, r)
        assert np.array_equal(a.corrupt, b.corrupt)
        assert not a.corrupt[~a.mask].any()      # only on-time corrupted
        assert (a.mask & (a.corrupt == 0)).any()  # >= 1 honest on-time
        saw += int((a.corrupt != 0).sum())
    assert saw > 0


def test_corruption_draw_order_invariance():
    """Enabling the adversary must not perturb the upstream fault draws."""
    base = dict(population=32, dropout_rate=0.25, straggler_rate=0.3,
                max_staleness=3, seed=4)
    for r in range(6):
        a = pop.sample_cohort(pop.ParticipationConfig(**base), 8, r)
        b = pop.sample_cohort(pop.ParticipationConfig(
            corrupt_rate=0.5, **base), 8, r)
        assert np.array_equal(a.clients, b.clients)
        assert np.array_equal(a.delays, b.delays)


def test_fully_adversarial_config_raises():
    with pytest.raises(ValueError, match="honest"):
        pop.sample_cohort(pop.ParticipationConfig(corrupt_rate=1.0), 4, 0)
    with pytest.raises(ValueError, match="corrupt mode"):
        pop.sample_cohort(pop.ParticipationConfig(
            corrupt_rate=0.5, corrupt_modes=("bitflip",)), 4, 0)


def test_corruption_pardon_keeps_one_honest():
    """At corrupt_rate just under 1, rounds where every on-time client drew
    corrupted still keep one pardoned honest participant."""
    pcfg = pop.ParticipationConfig(corrupt_rate=0.999, seed=0)
    for r in range(6):
        plan = pop.sample_cohort(pcfg, 4, r)
        assert (plan.mask & (plan.corrupt == 0)).any()


def test_corruption_multipliers_mapping():
    pcfg = pop.ParticipationConfig(corrupt_rate=0.5,
                                   corrupt_modes=("nan", "sign_flip",
                                                  "scale"),
                                   attack_scale=50.0)
    plan = pop.CohortPlan(round_idx=0, clients=np.arange(4),
                          mask=np.ones(4, bool),
                          delays=np.zeros(4, np.int64),
                          corrupt=np.asarray([0, 1, 2, 3]))
    m = pop.corruption_multipliers(plan, pcfg)
    assert m[0] == 1.0 and np.isnan(m[1]) and m[2] == -1.0 and m[3] == 50.0
    honest = plan._replace(corrupt=np.zeros(4, np.int64))
    assert pop.corruption_multipliers(honest, pcfg) is None
    assert pop.corruption_multipliers(plan._replace(corrupt=None),
                                      pcfg) is None


def test_corrupted_rounds_stay_finite_end_to_end():
    """NaN adversaries on up to half the cohort: the quarantined runner's
    loss/drift records and global state stay finite, and corrupted clients
    never scatter poisoned rows into the store."""
    pcfg = pop.ParticipationConfig(corrupt_rate=0.5, corrupt_modes=("nan",),
                                   seed=5)
    run = _runner(_engine(quarantine=True), pcfg)
    out = run.run_rounds(4)
    assert sum(r["corrupted"] for r in out["history"]) > 0
    for rec in out["history"]:
        assert np.isfinite(rec["mean_final_loss"])
        assert np.isfinite(rec["moment_divergence"])
    _finite_tree(run.engine.global_trainable)
    _finite_tree(run.store.gather(np.arange(4)))


# ------------------------------------------------- staleness buffer caps ----

def _entry(cid, due):
    return pop.StaleEntry(client_id=cid, birth_round=0, due_round=due,
                          weight=0.25, decay=0.5, base_scale=1.0,
                          deltas={"a": np.ones(2, np.float32)}, bases=None,
                          v_rows=None)


def test_staleness_buffer_evicts_earliest_due_at_capacity():
    buf = pop.StalenessBuffer(capacity=2)
    assert buf.push(_entry(0, due=5)) is None
    assert buf.push(_entry(1, due=3)) is None
    evicted = buf.push(_entry(2, due=4))
    assert evicted is not None and evicted.client_id == 1   # earliest due
    assert buf.evictions == 1 and len(buf) == 2
    assert sorted(e.client_id for e in buf._entries) == [0, 2]
    # FIFO tie-break on equal due rounds.
    evicted = buf.push(_entry(3, due=4))
    assert evicted.client_id == 2
    with pytest.raises(ValueError, match="capacity"):
        pop.StalenessBuffer(capacity=0)


def test_full_buffer_never_blocks_on_time_clients():
    """With a capacity-1 buffer under a straggler-heavy plan, on-time
    contributions bypass the buffer entirely (delay-0 ≡ synchronous) and
    rounds keep landing; overflow shows up only as recorded evictions."""
    pcfg = pop.ParticipationConfig(straggler_rate=0.6, max_staleness=3,
                                   seed=2)
    run = _runner(_engine(), pcfg, buffer_capacity=1)
    out = run.run_rounds(5)
    assert len(run.buffer) <= 1
    assert sum(r["stale_evicted"] for r in out["history"]) > 0
    assert sum(r["straggling"] for r in out["history"]) > 0
    for rec in out["history"]:
        assert np.isfinite(rec["mean_final_loss"])
    _finite_tree(run.engine.global_trainable)


# ---------------------------------------------- snapshots: kill & resume ----

def test_snapshot_kill_resume_loss_parity(tmp_path):
    """Kill-and-resume: a fresh runner restored from the latest snapshot
    replays the remaining rounds with loss-curve parity against the
    uninterrupted run, and retention keeps only ``snapshot_keep``."""
    snap = str(tmp_path / "snaps")
    pc = pop.ParticipationConfig(dropout_rate=0.2, straggler_rate=0.3,
                                 max_staleness=2, seed=9)
    ra = _runner(_engine(), pc, snapshot_dir=snap, snapshot_every=1,
                 snapshot_keep=2)
    ra.run_rounds(3)

    rb = _runner(_engine(), pc, snapshot_dir=snap)
    step = rb.restore()
    assert step == 3 and rb.engine.round_idx == 3
    assert len(rb.history) == 3

    ra.run_rounds(3)
    rb.run_rounds(3)
    ref = [r["mean_final_loss"] for r in ra.history[3:]]
    got = [r["mean_final_loss"] for r in rb.history[3:]]
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    np.testing.assert_allclose(
        [r["moment_divergence"] for r in rb.history[3:]],
        [r["moment_divergence"] for r in ra.history[3:]], rtol=1e-5,
        atol=1e-8)
    assert len([f for f in os.listdir(snap) if f.endswith(".npz")]) == 2


def test_snapshot_restores_staleness_buffer(tmp_path):
    """In-flight stale entries survive the crash: the restored buffer merges
    the same due updates the uninterrupted run does."""
    snap = str(tmp_path / "snaps")
    pc = pop.ParticipationConfig(straggler_rate=0.6, max_staleness=3, seed=2)
    ra = _runner(_engine(), pc, snapshot_dir=snap, snapshot_every=1)
    ra.run_rounds(2)
    assert len(ra.buffer) > 0                  # something is in flight
    rb = _runner(_engine(), pc, snapshot_dir=snap)
    rb.restore()
    assert len(rb.buffer) == len(ra.buffer)
    ra.run_rounds(3)
    rb.run_rounds(3)
    assert ([r["stale_merged"] for r in ra.history]
            == [r["stale_merged"] for r in rb.history])
    np.testing.assert_allclose(
        [r["mean_final_loss"] for r in rb.history[2:]],
        [r["mean_final_loss"] for r in ra.history[2:]], rtol=1e-6)


def test_restore_without_snapshot_raises(tmp_path):
    run = _runner(_engine(), snapshot_dir=str(tmp_path / "empty"))
    with pytest.raises(FileNotFoundError):
        run.restore()
    run2 = _runner(_engine())
    with pytest.raises(ValueError, match="snapshot_dir"):
        run2.snapshot()


# ------------------------------------------------------- drift tripwire -----

def test_tripwire_rolls_back_and_replays_without_offenders():
    """NaN adversaries with in-round quarantine OFF: the drift tripwire
    detects the poisoned round, rolls the federation back, screens the
    harvested uplink host-side, and replays with the offenders quarantined
    — no warning, finite state."""
    pcfg = pop.ParticipationConfig(corrupt_rate=0.5, corrupt_modes=("nan",),
                                   seed=5)
    run = _runner(_engine(), pcfg, drift_tripwire=1e6, tripwire_retries=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        recs = [run.run_round() for _ in range(3)]
    assert any(r["tripwire_replays"] > 0 for r in recs)
    for rec in recs:
        assert np.isfinite(rec["mean_final_loss"])
        assert rec["tripwire_quarantined"] >= rec["tripwire_replays"]
    _finite_tree(run.engine.global_trainable)
    # history mirrors the replayed (clean) rounds, one record per round
    assert len(run.history) == 3


def test_tripwire_degrades_with_warning_when_out_of_retries():
    pcfg = pop.ParticipationConfig(corrupt_rate=0.5, corrupt_modes=("nan",),
                                   seed=5)
    run = _runner(_engine(), pcfg, drift_tripwire=1e6, tripwire_retries=0)
    with pytest.warns(UserWarning, match="tripwire"):
        rec = run.run_round()
    assert rec["tripwire_replays"] == 0


def test_tripwire_noop_on_honest_rounds():
    """An armed tripwire over honest rounds must not replay or warn, and
    the trajectory must match the unarmed runner exactly."""
    pc = pop.ParticipationConfig(dropout_rate=0.2, seed=3)
    ra = _runner(_engine(), pc, drift_tripwire=1e6, loss_tripwire=1e6)
    rb = _runner(_engine(), pc)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        for _ in range(3):
            ra.run_round()
            rb.run_round()
    assert all(r["tripwire_replays"] == 0 for r in ra.history)
    _leaves_equal(ra.engine.global_trainable, rb.engine.global_trainable)


# --------------------------------------------------- runtime bit-identity ---

def test_sharded_runtime_quarantine_honest_bit_identity():
    """ShardedFederation with quarantine=True over an honest cohort must
    match the unguarded runtime bit-for-bit (same identities as the
    engine: exact screen no-op + untouched weights). zmax is pinned high
    enough that the *verdict* passes everyone: the 3-client random-token
    smoke cohort legitimately disperses past the default 6× median norm,
    and a passing screen — not the verdict policy — is the exactness
    contract under test (a failing verdict is quarantine doing its job)."""
    from repro.fedsim import ShardedFederation

    c_clients = 3
    cfg, mesh, spec, batches = _runtime_setup(c_clients)
    fed_q = ShardedFederation(cfg, spec, mesh, c_clients,
                              state_sync="ajive", quarantine=True,
                              quarantine_zmax=50.0)
    fed_p = ShardedFederation(cfg, spec, mesh, c_clients,
                              state_sync="ajive")
    for r in range(2):
        b = batches(r)
        mq = fed_q.run_round(b)
        mp = fed_p.run_round(b)
        assert jnp.array_equal(mq["losses"], mp["losses"])
    _leaves_equal(fed_q.global_trainable, fed_p.global_trainable)


def test_sharded_runtime_rejects_robust_dense_clients():
    from repro.fedsim import ShardedFederation

    cfg, mesh, spec, _ = _runtime_setup(3)
    fed = ShardedFederation(cfg, spec, mesh, 3, state_sync="ajive",
                            factored_clients=False, quarantine=True)
    with pytest.raises(ValueError, match="factored"):
        fed.run_round({"tokens": np.zeros((3, 2, 2, 8), np.int32),
                       "labels": np.zeros((3, 2, 2, 8), np.int32)})


# ---------------------------------------- basis-coherent hetero robustness --

def _orthonormal(m, r, seed):
    q, _ = np.linalg.qr(np.random.default_rng(seed).normal(size=(m, r)))
    return np.asarray(q, np.float32)


def test_rebase_shared_basis_is_identity():
    """All clients on one orthonormal basis: the transfer Grams are exact
    identities and re-basing returns the stack unchanged (up to fp32)."""
    rng = np.random.default_rng(1)
    b = _orthonormal(6, 3, 0)
    bases = jnp.asarray(np.broadcast_to(b, (4,) + b.shape))
    right = jnp.asarray(rng.normal(size=(4, 5, 3)), jnp.float32)
    out = agg.rebase_factored_stack(right, bases, "right")
    np.testing.assert_allclose(np.asarray(out), np.asarray(right), atol=1e-5)
    left = jnp.asarray(rng.normal(size=(4, 3, 5)), jnp.float32)
    out = agg.rebase_factored_stack(left, bases, "left")
    np.testing.assert_allclose(np.asarray(out), np.asarray(left), atol=1e-5)


def test_rebase_aligns_rotated_bases():
    """Clients observing the SAME ambient update through rotated bases
    (Bᵢ = B₀Qᵢ spans the same subspace) disagree coordinate-wise; after
    re-basing onto client 0's basis every honest row coincides with R₀ —
    the property that makes coordinate-wise votes basis-coherent."""
    rng = np.random.default_rng(3)
    m, n, r, c = 7, 5, 3, 4
    b0 = _orthonormal(n, r, 0)
    ambient = rng.normal(size=(m, n)).astype(np.float32)
    bases, coords = [], []
    for i in range(c):
        q, _ = np.linalg.qr(rng.normal(size=(r, r)))
        bi = b0 @ q.astype(np.float32)
        bases.append(bi)
        coords.append(ambient @ bi)                      # side 'right'
    stack = jnp.asarray(np.stack(coords))
    out = np.asarray(agg.rebase_factored_stack(
        stack, jnp.asarray(np.stack(bases)), "right"))
    ref = ambient @ bases[0]       # everything lands on client 0's basis
    for i in range(c):
        np.testing.assert_allclose(out[i], ref, atol=1e-4)


def test_robust_hetero_lift_basis_coherent_outlier():
    """Rotated honest bases + one 100x attacker: the coordinate-wise robust
    modes re-base first and recover the honest ambient update, while the
    plain hetero mean is dragged."""
    rng = np.random.default_rng(4)
    m, n, r, c = 7, 5, 3, 5
    b0 = _orthonormal(n, r, 0)
    ambient = rng.normal(size=(m, n)).astype(np.float32)
    honest_lift = (ambient @ b0) @ b0.T                  # P-projected update
    bases, coords = [], []
    for i in range(c):
        q, _ = np.linalg.qr(rng.normal(size=(r, r)))
        bi = b0 @ q.astype(np.float32)
        bases.append(bi)
        coords.append(ambient @ bi * (100.0 if i == c - 1 else 1.0))
    stack = jnp.asarray(np.stack(coords))
    bstack = jnp.asarray(np.stack(bases))
    w = jnp.full((c,), 1.0 / c)
    for mode in ("trimmed_mean", "geomedian"):
        out = np.asarray(agg.robust_factored_lift(
            stack, bstack, "right", w, mode, hetero=True, trim=0.25))
        err = np.abs(out - honest_lift).max()
        assert err < 0.05 * np.abs(honest_lift).max(), (mode, err)
    dragged = np.asarray(agg.robust_factored_lift(
        stack, bstack, "right", w, "none", hetero=True))
    assert np.abs(dragged - honest_lift).max() > np.abs(honest_lift).max()


def test_robust_hetero_lift_matches_shared_on_shared_bases():
    """hetero=True with identical bases must agree with the shared-basis
    robust lift: re-basing through identity Grams is a no-op."""
    rng = np.random.default_rng(5)
    b = _orthonormal(6, 3, 1)
    bases = jnp.asarray(np.broadcast_to(b, (4,) + b.shape))
    stack = jnp.asarray(rng.normal(size=(4, 5, 3)), jnp.float32)
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    for mode in ("trimmed_mean", "geomedian", "norm_clip"):
        het = np.asarray(agg.robust_factored_lift(
            stack, bases, "right", w, mode, hetero=True))
        shared = np.asarray(agg.robust_factored_lift(
            stack, bases, "right", w, mode, hetero=False))
        np.testing.assert_allclose(het, shared, atol=1e-5, err_msg=mode)


# ----------------------------------------------------------- robust 𝒮 ------

def test_robust_sync_bounds_moment_drag():
    """A 100x scale attack poisons the projected-moment stacks feeding 𝒮;
    with robust_agg='trimmed_mean' the synced moments stay near the honest
    trajectory instead of being dragged with the plain weighted mean."""
    honest = _engine()
    plain = _engine()
    robust = _engine(robust_agg="trimmed_mean", robust_trim=0.3)
    attack = np.ones(4, np.float32)
    attack[2] = 100.0
    for r in range(2):
        b = _round_batches(r)
        honest.run_round(b)
        plain.run_round(b, attack=attack)
        robust.run_round(b, attack=attack)
    err_plain = pop.tree_rel_err(plain.synced_v, honest.synced_v)
    err_robust = pop.tree_rel_err(robust.synced_v, honest.synced_v)
    assert err_robust < 0.5 * err_plain, (err_robust, err_plain)
    _finite_tree(robust.synced_v)


def test_robust_round0_hetero_bounds_scale_attack():
    """Round 0 runs per-client SVD bases (the adaptive refresh): the robust
    modes must already bound the attack there via transfer-Gram re-basing
    — the round where the old fallback degraded to median-norm clips."""
    honest, plain = _engine(), _engine()
    robust = _engine(robust_agg="geomedian")
    attack = np.ones(4, np.float32)
    attack[1] = 100.0
    b = _round_batches(0)
    honest.run_round(b)
    plain.run_round(b, attack=attack)
    robust.run_round(b, attack=attack)
    err_plain = pop.tree_rel_err(plain.global_trainable,
                                 honest.global_trainable)
    err_robust = pop.tree_rel_err(robust.global_trainable,
                                  honest.global_trainable)
    assert err_robust < 0.1 * err_plain, (err_robust, err_plain)
    _finite_tree(robust.global_trainable)
    _finite_tree(robust.synced_v)


# -------------------------------------------------- seeded attack schedule --

def test_corruption_schedule_matches_per_round_multipliers():
    """corruption_schedule is exactly the per-round corruption_multipliers
    sequence (the shared operand source for engine/runtime parity grids),
    and start_round windows align with the full schedule."""
    pcfg = pop.ParticipationConfig(corrupt_rate=0.5, corrupt_modes=("scale",),
                                   attack_scale=37.0, seed=3)
    sched = pop.corruption_schedule(pcfg, 4, 6)
    assert len(sched) == 6
    for k, m in enumerate(sched):
        ref = pop.corruption_multipliers(pop.sample_cohort(pcfg, 4, k), pcfg)
        if ref is None:
            assert m is None
        else:
            np.testing.assert_array_equal(m, ref)
    assert any(m is not None for m in sched)
    tail = pop.corruption_schedule(pcfg, 4, 3, start_round=3)
    for a, b in zip(sched[3:], tail):
        if a is None:
            assert b is None
        else:
            np.testing.assert_array_equal(a, b)


# ----------------------------------------------- runtime attack parity ------

def test_sharded_runtime_all_ones_attack_short_circuits():
    """run_round(attack=ones) must canonicalize onto the plain program:
    bit-identical outputs and no guarded compile."""
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    fed_a = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
    fed_p = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
    for r in range(2):
        b = batches(r)
        ma = fed_a.run_round(b, attack=np.ones(c, np.float32))
        mp = fed_p.run_round(b)
        assert jnp.array_equal(ma["losses"], mp["losses"])
    _leaves_equal(fed_a.global_trainable, fed_p.global_trainable)
    assert fed_a._round_masked is None      # guarded program never built


@pytest.mark.parametrize("attack_val", [np.nan, 1e4], ids=["nan", "scale"])
def test_sharded_runtime_quarantine_matches_masked_round(attack_val):
    """Runtime attack parity with the engine's contract: a quarantined
    attacker ~ the same client masked out of the round."""
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    kw = dict(state_sync="ajive", quarantine=True, quarantine_zmax=50.0)
    fed_a = ShardedFederation(cfg, spec, mesh, c, **kw)
    fed_m = ShardedFederation(cfg, spec, mesh, c, **kw)
    attack = np.ones(c, np.float32)
    attack[1] = attack_val
    mask = np.ones(c, bool)
    mask[1] = False
    for r in range(2):
        b = batches(r)
        fed_a.run_round(b, attack=attack)
        fed_m.run_round(b, mask=mask)
    _finite_tree(fed_a.global_trainable)
    for la, lb in zip(jax.tree_util.tree_leaves(fed_a.global_trainable),
                      jax.tree_util.tree_leaves(fed_m.global_trainable)):
        assert jnp.allclose(la, lb, atol=1e-5), float(
            jnp.max(jnp.abs(la - lb)))


def test_sharded_runtime_attack_requires_fused_round():
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    fed = ShardedFederation(cfg, spec, mesh, c, fused_round=False)
    attack = np.ones(c, np.float32)
    attack[0] = -1.0            # all-ones would canonicalize away
    with pytest.raises(ValueError, match="fused_round"):
        fed.run_round(batches(0), attack=attack)


def test_sharded_runtime_robust_sync_bounds_scale_attack():
    """Runtime robust-𝒮 parity with the engine: under a scale attack the
    trimmed-mean federation tracks the honest trajectory closer than the
    undefended one, and stays finite."""
    from repro.fedsim import ShardedFederation

    c = 3
    cfg, mesh, spec, batches = _runtime_setup(c)
    honest = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
    plain = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
    robust = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                               robust_agg="trimmed_mean", robust_trim=0.34)
    attack = np.ones(c, np.float32)
    attack[2] = 100.0
    for r in range(2):
        b = batches(r)
        honest.run_round(b)
        plain.run_round(b, attack=attack)
        robust.run_round(b, attack=attack)
    err_plain = pop.tree_rel_err(plain.global_trainable,
                                 honest.global_trainable)
    err_robust = pop.tree_rel_err(robust.global_trainable,
                                  honest.global_trainable)
    assert err_robust < err_plain, (err_robust, err_plain)
    _finite_tree(robust.global_trainable)
