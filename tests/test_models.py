"""Per-arch smoke tests (reduced variants) + decode/forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_variant
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, n_text=16):
    batch = {"tokens": jax.random.randint(KEY, (b, n_text), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (b, n_text), 0, cfg.vocab_size)}
    if cfg.frontend_tokens:
        batch["embeds"] = jax.random.normal(
            KEY, (b, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """The assignment's required smoke test: reduced variant, one forward +
    one train-grad step, shape + NaN assertions."""
    cfg = smoke_variant(get_config(arch))
    assert cfg.n_layers == 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, batch["tokens"], batch.get("embeds"))
    total = 16 + cfg.frontend_tokens
    assert logits.shape == (2, total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch))(params)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn) and float(gn) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = smoke_variant(get_config(arch))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)   # no MoE drops
    params = M.init_params(KEY, cfg)
    b, n_text = 2, 12
    toks = jax.random.randint(KEY, (b, n_text), 0, cfg.vocab_size)
    embeds = (jax.random.normal(KEY, (b, cfg.frontend_tokens, cfg.d_model))
              if cfg.frontend_tokens else None)
    logits_full, _ = M.forward(params, cfg, toks, embeds)
    st = M.init_decode_state(cfg, b, 64)
    lp, st = M.prefill(params, cfg, toks[:, :-1], st, embeds)
    assert float(jnp.max(jnp.abs(lp - logits_full[:, -2, :]))) < 2e-2
    ld, st = M.decode_step(params, cfg, toks[:, -1], st)
    assert float(jnp.max(jnp.abs(ld - logits_full[:, -1, :]))) < 2e-2
    assert int(st.t) == n_text + cfg.frontend_tokens


def test_unrolled_matches_scanned():
    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    params = M.init_params(KEY, cfg)
    batch = _batch(cfg)
    l_scan, _ = M.forward(params, cfg, batch["tokens"])
    cfg_u = dataclasses.replace(cfg, unroll_blocks=True)
    l_unroll, _ = M.forward(params, cfg_u, batch["tokens"])
    assert jnp.allclose(l_scan, l_unroll, atol=1e-4)


def test_sliding_window_restricts_context():
    cfg = smoke_variant(get_config("mistral-nemo-12b"))
    cfg_win = dataclasses.replace(cfg, sliding_window=4)
    params = M.init_params(KEY, cfg_win)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    logits_a, _ = M.forward(params, cfg_win, toks)
    # Perturbing a token > window before the last position must not change
    # the last position's logits.
    toks_b = toks.at[0, 2].set((toks[0, 2] + 1) % cfg.vocab_size)
    logits_b, _ = M.forward(params, cfg_win, toks_b)
    assert jnp.allclose(logits_a[0, -1], logits_b[0, -1], atol=1e-4)
    # ...while a full-attention model does change.
    cfg_full = dataclasses.replace(cfg, sliding_window=0)
    params_f = M.init_params(KEY, cfg_full)
    la, _ = M.forward(params_f, cfg_full, toks)
    lb, _ = M.forward(params_f, cfg_full, toks_b)
    assert not jnp.allclose(la[0, -1], lb[0, -1], atol=1e-4)


def test_ring_buffer_decode_beyond_cache():
    """Sliding-window decode with cache == window: decoding past the cache
    size must keep working (ring overwrite) and stay NaN-free."""
    cfg = smoke_variant(get_config("starcoder2-7b"))
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = M.init_params(KEY, cfg)
    st = M.init_decode_state(cfg, 1, 8)       # cache = window
    tok = jnp.zeros((1,), jnp.int32)
    for i in range(20):                        # 2.5× past the cache size
        logits, st = M.decode_step(params, cfg, tok, st)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(st.t) == 20


def test_param_count_analytic_close_to_actual():
    for arch in ("qwen1.5-0.5b", "granite-moe-1b-a400m", "rwkv6-1.6b"):
        cfg = smoke_variant(get_config(arch))
        params = M.init_params(KEY, cfg)
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, arch


def test_full_config_param_counts():
    """The headline sizes match the assigned model cards (±20%)."""
    expect = {"deepseek-v2-236b": 236e9, "jamba-1.5-large-398b": 398e9,
              "command-r-35b": 35e9, "mistral-nemo-12b": 12e9,
              "starcoder2-7b": 7e9, "rwkv6-1.6b": 1.6e9,
              "granite-moe-1b-a400m": 1.3e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.8 * n < got < 1.25 * n, f"{arch}: {got:.2e} vs {n:.2e}"
