"""Hypothesis property tests over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import aggregation as agg
from repro.core import projector as proj
from repro.core.lora import LoraPair, rank_tail_energy
from repro.data.partition import dirichlet_label_partition
from repro.models import moe

jax.config.update("jax_platform_name", "cpu")

dims = st.integers(4, 32)


@settings(max_examples=15, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 10**6))
def test_projection_is_contraction(m, n, seed):
    """‖project(g)‖_F ≤ ‖g‖_F for any orthonormal basis (Pythagoras)."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (m, n))
    side = proj.proj_side((m, n))
    r = min(4, m, n)
    basis = proj.random_basis(seed, proj.basis_dim((m, n)), r)
    gt = proj.project(g, basis, side)
    assert float(jnp.linalg.norm(gt)) <= float(jnp.linalg.norm(g)) + 1e-4


@settings(max_examples=15, deadline=None)
@given(m=dims, n=dims, seed=st.integers(0, 10**6))
def test_project_back_preserves_subspace_energy(m, n, seed):
    """project_back is an isometry on coefficients: ‖ũP‖_F = ‖ũ‖_F."""
    key = jax.random.PRNGKey(seed)
    side = proj.proj_side((m, n))
    r = min(4, m, n)
    basis = proj.random_basis(seed, proj.basis_dim((m, n)), r)
    coeff_shape = (m, r) if side == proj.RIGHT else (r, n)
    ut = jax.random.normal(key, coeff_shape)
    u = proj.project_back(ut, basis, side)
    assert np.isclose(float(jnp.linalg.norm(u)), float(jnp.linalg.norm(ut)),
                      rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(k=st.integers(2, 6), seed=st.integers(0, 10**6),
       w_raw=st.lists(st.floats(0.1, 10.0), min_size=6, max_size=6))
def test_fedavg_convex_hull(k, seed, w_raw):
    """Lemma 4.1: weighted averages stay in the elementwise convex hull."""
    key = jax.random.PRNGKey(seed)
    xs = {"w": jax.random.normal(key, (k, 5, 5))}
    w = jnp.asarray(w_raw[:k])
    out = agg.weighted_average(xs, w)["w"]
    lo = jnp.min(xs["w"], axis=0) - 1e-5
    hi = jnp.max(xs["w"], axis=0) + 1e-5
    assert bool(jnp.all(out >= lo) and jnp.all(out <= hi))


@settings(max_examples=10, deadline=None)
@given(k=st.integers(2, 5), r=st.integers(1, 3), seed=st.integers(0, 10**6))
def test_factor_avg_rank_bounded_lift_not(k, r, seed):
    """Factor averaging stays rank ≤ r; lift averaging generally exceeds it
    (update-space mismatch, §4.1)."""
    key = jax.random.PRNGKey(seed)
    m = n = 12
    ad = {"w": LoraPair(a=jax.random.normal(key, (k, r, n)),
                        b=jax.random.normal(jax.random.fold_in(key, 1),
                                            (k, m, r)))}
    w = jnp.ones(k)
    fac = agg.factor_average(ad, w)["w"]
    tail_fac = rank_tail_energy(fac.b @ fac.a, r)
    assert float(tail_fac) < 1e-4
    lift = agg.lift_average(ad, w)["w"]
    if k * r <= min(m, n):       # rank can actually grow
        assert float(rank_tail_energy(lift, r)) >= 0.0


@settings(max_examples=10, deadline=None)
@given(n_tokens=st.integers(4, 64), e=st.integers(2, 8),
       topk=st.integers(1, 3), seed=st.integers(0, 10**6))
def test_moe_route_invariants(n_tokens, e, topk, seed):
    topk = min(topk, e)
    key = jax.random.PRNGKey(seed)
    router = jax.random.normal(key, (8, e))
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_tokens, 8))
    gates, idx, aux = moe.route(router, x, topk)
    assert bool(jnp.all(gates >= 0))
    assert np.allclose(np.asarray(jnp.sum(gates, -1)), 1.0, atol=1e-5)
    assert int(idx.max()) < e
    assert float(aux) >= 0.99     # Switch aux loss lower bound is ~1


@settings(max_examples=8, deadline=None)
@given(n_classes=st.integers(2, 10), n_clients=st.integers(2, 12),
       seed=st.integers(0, 1000))
def test_dirichlet_partition_is_a_partition(n_classes, n_clients, seed):
    labels = np.repeat(np.arange(n_classes), 40)
    parts = dirichlet_label_partition(labels, n_clients, 0.5, seed=seed,
                                      min_per_client=0)
    allidx = np.concatenate([p for p in parts if len(p)])
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6), steps=st.integers(1, 5))
def test_galore_update_stays_in_span(seed, steps):
    """Without refresh, every GaLore update lies in the basis row-span."""
    from repro.core import galore as gal
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (12, 12))}
    cfg = gal.GaloreConfig(rank=3, refresh_every=10**9, refresh_mode="random")
    tx = gal.scale_by_galore(cfg)
    st_ = tx.init(params)
    basis = st_.blocks["w"].basis            # (12, 3)
    for i in range(steps):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (12, 12))}
        u, st_ = tx.update(g, st_, params)
    # residual after projecting the update onto the span must vanish
    u_w = u["w"]
    proj_u = u_w @ basis @ basis.T
    assert float(jnp.linalg.norm(u_w - proj_u)) < 1e-4 * max(
        1.0, float(jnp.linalg.norm(u_w)))
