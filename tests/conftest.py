"""Shared pytest fixtures.

Deliberately does NOT force a host device count — the dry-run
(repro.launch.dryrun) is the only place that fakes 512 devices; tests that
need a small mesh spawn a subprocess (see test_sharding.py).
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="session")
def _cpu_only():
    assert jax.default_backend() == "cpu"
    yield
