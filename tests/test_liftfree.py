"""Lift-free factored rounds: the delta-context forward (split-matmul
weight read), the projected-cotangent VJP (gradients arrive in rank-r
coordinates, clipping via exact dense-norm probes), kernel-vs-reference
parity, engine/runtime lift-free ≡ transient-lift parity for all GaLore
methods, the jaxpr shape probe (zero dense m×n lift GEMMs / gradient
cotangents), and LoRA methods' indifference to the delta context."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import galore as gal
from repro.core import projector as proj
from repro.core.fed import METHODS, FedConfig, FedEngine
from repro.kernels import ops as kops
from repro.kernels.ref import lowrank_linear_ref
from repro.models import layers

KEY = jax.random.PRNGKey(11)

GALORE_METHODS = [m for m, s in METHODS.items()
                  if s.optimizer == "galore_adamw"]
LORA_METHODS = ["fedit", "ffa_lora", "lora_fair"]


# ------------------------------------------------------------- kernel -------

@pytest.mark.parametrize("side,shape,r", [
    ("right", (16, 8), 3),          # m >= n: basis (n, r), rt (m, r)
    ("left", (8, 16), 3),           # m < n:  basis (m, r), rt (r, n)
    ("right", (33, 16), 4),         # odd row count: masked tail tile
    ("left", (16, 33), 4),
])
def test_lowrank_linear_kernel_matches_ref(side, shape, r):
    m, n = shape
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (5, m))
    w = jax.random.normal(ks[1], (m, n))
    basis = jax.random.normal(ks[2], ((n if side == "right" else m), r))
    rt = jax.random.normal(ks[3], ((m, r) if side == "right" else (r, n)))
    got = kops.lowrank_linear(x, w, basis, rt, 0.9, side=side, block_rows=8)
    want = lowrank_linear_ref(x, w, basis, rt, 0.9, side=side)
    assert jnp.allclose(got, want, atol=1e-5), float(
        jnp.max(jnp.abs(got - want)))


def test_lowrank_linear_kernel_leading_dims_and_side_inference():
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (2, 3, 12))          # (..., t, m)
    w = jax.random.normal(ks[1], (12, 6))
    basis = jax.random.normal(ks[2], (6, 2))
    rt = jax.random.normal(ks[3], (12, 2))
    got = kops.lowrank_linear(x, w, basis, rt, 1.0)   # side inferred: right
    want = lowrank_linear_ref(x, w, basis, rt, 1.0, side="right")
    assert got.shape == (2, 3, 6)
    assert jnp.allclose(got, want, atol=1e-5)


def test_lowrank_linear_ref_equals_materialized_weight():
    """The split matmul IS x @ (scale·W + lift) — per side."""
    for side, (m, n) in (("right", (10, 6)), ("left", (6, 10))):
        ks = jax.random.split(jax.random.fold_in(KEY, ord(side[0])), 4)
        x = jax.random.normal(ks[0], (4, m))
        w = jax.random.normal(ks[1], (m, n))
        basis = jax.random.normal(ks[2], ((n if side == "right" else m), 3))
        rt = jax.random.normal(ks[3], ((m, 3) if side == "right" else (3, n)))
        lifted = (rt @ basis.T if side == "right" else basis @ rt)
        want = x @ (0.7 * w + lifted)
        got = lowrank_linear_ref(x, w, basis, rt, 0.7, side=side)
        assert jnp.allclose(got, want, atol=1e-4)


# -------------------------------------------- projected-cotangent VJP -------

@pytest.mark.parametrize("side,shape", [("right", (12, 7)),
                                        ("left", (7, 12))])
def test_liftfree_vjp_matches_transient_ad(side, shape):
    """grad wrt R̃ through the delta context == project(dense grad, B) from
    AD through the materialized weight, and the norm-probe cotangent is the
    exact squared dense-gradient norm — per side."""
    m, n = shape
    r = 3
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (6, m))
    w = jax.random.normal(ks[1], (m, n))
    basis = jax.random.normal(ks[2], ((n if side == "right" else m), r))
    rt = 0.1 * jax.random.normal(ks[3], ((m, r) if side == "right"
                                         else (r, n)))
    tgt = jax.random.normal(ks[4], (6, n))
    scale = jnp.asarray(0.95)

    def loss_liftfree(rt, nsq):
        y = layers.lowrank_apply(side, False, x, w, basis, rt, nsq, scale)
        return jnp.sum(jnp.tanh(y - tgt))

    (drt, dnsq) = jax.grad(loss_liftfree, argnums=(0, 1))(rt, jnp.zeros(()))

    def loss_transient(w_eff):
        return jnp.sum(jnp.tanh(x @ w_eff - tgt))

    lifted = (rt @ basis.T if side == "right" else basis @ rt)
    g_dense = jax.grad(loss_transient)(scale * w + lifted)
    want_drt = proj.project(g_dense, basis, side)
    assert jnp.allclose(drt, want_drt, atol=1e-5), float(
        jnp.max(jnp.abs(drt - want_drt)))
    assert jnp.allclose(dnsq, jnp.sum(g_dense * g_dense), rtol=1e-5)


def test_liftfree_read_vjp_bias_style_leaf():
    """Non-matmul consumption (stacked bias blocks added to activations):
    the leaf-read VJP still returns the projected cotangent and ‖∂y‖²."""
    m, n, r = 2, 9, 2                   # skinny left block, like (nb, d)
    ks = jax.random.split(KEY, 4)
    w = jax.random.normal(ks[0], (m, n))
    basis = jax.random.normal(ks[1], (m, r))
    rt = 0.1 * jax.random.normal(ks[2], (r, n))
    dl = layers.LowRankDelta(w=w, basis=basis, rt=rt, nsq=jnp.zeros(()),
                             scale=jnp.asarray(1.0))
    h = jax.random.normal(ks[3], (4, m, n))

    def loss_of(rt, nsq):
        d = dl._replace(rt=rt, nsq=nsq)
        return jnp.sum(jnp.sin(h + d))          # __radd__ -> read()
    drt, dnsq = jax.grad(loss_of, argnums=(0, 1))(rt, jnp.zeros(()))

    def loss_dense(w_eff):
        return jnp.sum(jnp.sin(h + w_eff))
    g_dense = jax.grad(loss_dense)(w + basis @ rt)
    assert jnp.allclose(drt, proj.project(g_dense, basis, "left"), atol=1e-5)
    assert jnp.allclose(dnsq, jnp.sum(g_dense * g_dense), rtol=1e-5)


def test_sqnorm_gram_tiled_matches_direct():
    """The tiled token-Gram norm probe (t > tile: scanned row tiles with a
    zero-padded tail) equals the single-Gram value and the direct
    ‖xᵀdy‖²."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (37, 5))
    dy = jax.random.normal(ks[1], (37, 4))
    direct = jnp.sum((x.T @ dy) ** 2)
    one_gram = layers._sqnorm_gram(x, dy)
    tiled = layers._sqnorm_gram(x, dy, tile=8)       # 5 tiles, padded tail
    assert jnp.allclose(one_gram, direct, rtol=1e-5)
    assert jnp.allclose(tiled, direct, rtol=1e-5)


def test_dense_is_plain_matmul_for_plain_weights():
    x = jax.random.normal(KEY, (3, 5))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (5, 4))
    assert jnp.array_equal(layers.dense(x, w), x @ w)


# ------------------------------------------------------ engine parity -------

def _problem():
    params = {"l1": {"w": 0.3 * jax.random.normal(KEY, (8, 16)),
                     "b": jnp.zeros(16)},
              "l2": {"w": 0.3 * jax.random.normal(jax.random.fold_in(KEY, 1),
                                                  (16, 4)),
                     "b": jnp.zeros(4)}}

    def loss(p, batch):
        x, y = batch
        # Raw `x @ w` on purpose: LowRankDelta.__rmatmul__ must make
        # arbitrary losses lift-free without edits.
        h = jnp.tanh(x @ p["l1"]["w"] + p["l1"]["b"])
        out = h @ p["l2"]["w"] + p["l2"]["b"]
        return jnp.mean((out - y) ** 2)

    return params, loss


def _round_batches(seed, k=4, t=5, b=6):
    kb = jax.random.PRNGKey(seed)
    x = jax.random.normal(kb, (k, t, b, 8))
    w_true = 0.5 * jax.random.normal(jax.random.fold_in(kb, 1), (8, 4))
    return (x, jnp.einsum("...bi,io->...bo", x, w_true))


def _trees_close(a, b, atol):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert jnp.allclose(la, lb, atol=atol), float(
            jnp.max(jnp.abs(la - lb)))


@pytest.mark.parametrize("method", sorted(GALORE_METHODS))
def test_liftfree_matches_transient_lift_all_galore_methods(method):
    """3 rounds lift-free ≡ transient-lift ≤ 1e-5, per GaLore method, with
    an ACTIVE global-norm clip (clip_norm=0.5 — the dense-norm probes must
    reproduce the dense path's clip factor exactly) and weight decay. The
    toy covers both projection sides (l1 (8,16) left, l2 (16,4) right) and
    the adaptive round-0 transient cond."""
    params, loss = _problem()
    engines = {}
    for lf in (True, False):
        eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                  local_steps=5, clip_norm=0.5,
                                  weight_decay=0.01, lift_free=lf),
                        loss, params)
        assert eng._lift_free is lf
        for r in range(3):
            m = eng.run_round(_round_batches(r))
            assert jnp.all(jnp.isfinite(m["local_loss"]))
        engines[lf] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=1e-5)
    if engines[False].synced_v is not None:
        _trees_close(engines[True].synced_v, engines[False].synced_v,
                     atol=1e-5)
    else:
        assert engines[True].synced_v is None


def test_liftfree_scan_over_rounds_matches_per_round():
    """run_rounds drives the lift-free round (incl. the round-0 transient
    cond) identically to per-round dispatch."""
    params, loss = _problem()
    eng_a = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                                local_steps=5), loss, params)
    eng_b = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                                local_steps=5), loss, params)
    rb3 = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), _round_batches(0), _round_batches(1),
        _round_batches(2))
    m = eng_a.run_rounds(rb3)
    for r in range(3):
        mb = eng_b.run_round(_round_batches(r))
        assert jnp.allclose(m["local_loss"][r], mb["local_loss"], atol=1e-6)
    _trees_close(eng_a.global_trainable, eng_b.global_trainable, atol=1e-6)


@pytest.mark.parametrize("method", LORA_METHODS + ["fedavg_full"])
def test_lora_and_dense_methods_untouched_by_delta_context(method):
    """The delta context only engages for factored GaLore clients: LoRA and
    dense methods must be BIT-identical under lift_free True/False."""
    params, loss = _problem()
    engines = {}
    for lf in (True, False):
        eng = FedEngine(FedConfig(method=method, rank=4, lr=3e-2,
                                  local_steps=3, lift_free=lf), loss, params)
        assert eng._lift_free is False
        for r in range(2):
            eng.run_round(_round_batches(r))
        engines[lf] = eng
    for la, lb in zip(jax.tree_util.tree_leaves(engines[True].global_trainable),
                      jax.tree_util.tree_leaves(engines[False].global_trainable)):
        assert jnp.array_equal(la, lb)


def test_liftfree_chunked_bit_identical():
    """Chunk streaming composes with the lift-free local phase bit-for-bit."""
    params, loss = _problem()
    engines = {}
    for chunk in (None, 2):
        eng = FedEngine(FedConfig(method="fedgalore", rank=4, lr=3e-2,
                                  local_steps=5, client_chunk=chunk),
                        loss, params)
        for r in range(2):
            eng.run_round(_round_batches(r))
        engines[chunk] = eng
    for la, lb in zip(jax.tree_util.tree_leaves(engines[None].global_trainable),
                      jax.tree_util.tree_leaves(engines[2].global_trainable)):
        assert jnp.array_equal(la, lb)


def test_liftfree_forward_kernel_path_matches_jnp():
    """dense() under lowrank_pallas_override(True) routes the forward
    through the fused Pallas kernel (interpret mode on CPU) — same rounds,
    fp32-close results."""
    params, loss = _problem()
    engines = {}
    for pallas in (True, False):
        with layers.lowrank_pallas_override(pallas):
            eng = FedEngine(FedConfig(method="fedgalore_minus", rank=4,
                                      lr=3e-2, local_steps=3), loss, params)
            for r in range(2):
                eng.run_round(_round_batches(r))
        engines[pallas] = eng
    _trees_close(engines[True].global_trainable,
                 engines[False].global_trainable, atol=1e-5)


# ------------------------------------------------------- jaxpr probe --------

def _dot_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            acc.add(tuple(eqn.outvars[0].aval.shape))
        for v in eqn.params.values():
            for sub in _as_jaxprs(v):
                _dot_shapes(sub, acc)
    return acc


def _as_jaxprs(v):
    if hasattr(v, "jaxpr") and hasattr(v, "consts"):    # ClosedJaxpr
        return [v.jaxpr]
    if hasattr(v, "eqns"):                              # Jaxpr
        return [v]
    if isinstance(v, (list, tuple)):
        out = []
        for x in v:
            out.extend(_as_jaxprs(x))
        return out
    return []


def _local_step_dot_shapes(lift_free: bool):
    """All dot_general output shapes in ONE compiled local training phase
    (the T-step scan for one client) of the factored round. rank=3 keeps
    every projected-space shape (m,3)/(3,n) distinct from the dense (m,n)
    target shapes the probe asserts on."""
    params, loss = _problem()
    eng = FedEngine(FedConfig(method="fedgalore_minus", rank=3, lr=3e-2,
                              local_steps=2, clip_norm=0.5,
                              weight_decay=0.01, lift_free=lift_free),
                    loss, params)
    st0 = eng._init_state0(jnp.asarray(1, jnp.int32), None,
                           eng.global_trainable)
    d0 = gal.zero_client_deltas(gal.galore_state_of(st0))
    batches = jax.tree_util.tree_map(lambda x: x[0], _round_batches(0, t=2))
    fn = (eng._local_train_liftfree_one if lift_free
          else eng._local_train_factored_one)
    jaxpr = jax.make_jaxpr(
        lambda d, s, b: fn(d, s, b, eng.frozen, eng.global_trainable))(
        d0, st0, batches)
    return _dot_shapes(jaxpr.jaxpr, set())


def test_liftfree_local_step_has_no_dense_mn_gemm():
    """The acceptance probe: the lift-free local phase lowers ZERO
    dot_generals with a dense (m, n) target-leaf output — no lift GEMM, no
    dense gradient cotangent, no dense projection. The transient-lift oracle
    (positive control) lowers several."""
    target_shapes = {(8, 16), (16, 4)}          # the toy's target leaves
    lf = _local_step_dot_shapes(lift_free=True)
    assert not (lf & target_shapes), lf & target_shapes
    transient = _local_step_dot_shapes(lift_free=False)
    assert transient & target_shapes            # the oracle does lift


# ------------------------------------------------------ runtime parity ------

def test_sharded_runtime_liftfree_matches_transient():
    """ShardedFederation lift-free (default) vs the transient-lift oracle
    (lift_free=False) on the smoke transformer: same per-round losses and
    ≤5e-4 state agreement after 2 rounds. The two formulations are
    mathematically identical; early-step Adam (√v̂ ≈ eps coordinates)
    amplifies reduction-order noise to ~4e-5 measured — each step stays
    lr-bounded, so the drift is noise-shaped, not divergent."""
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=2, refresh_mode="random")

    def batches(seed):
        kk = jax.random.PRNGKey(seed)
        toks = jax.random.randint(kk, (3, 2, 2, 8), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    feds = {lf: ShardedFederation(cfg, spec, mesh, 3, state_sync="ajive",
                                  lift_free=lf)
            for lf in (True, False)}
    for r in range(2):
        b = batches(r)
        mf = feds[True].run_round(b)
        mt = feds[False].run_round(b)
        assert jnp.allclose(mf["losses"], mt["losses"], atol=1e-4)
    for la, lb in zip(jax.tree_util.tree_leaves(feds[True].global_trainable),
                      jax.tree_util.tree_leaves(feds[False].global_trainable)):
        assert jnp.allclose(la.astype(jnp.float32), lb.astype(jnp.float32),
                            atol=5e-4)
    for la, lb in zip(jax.tree_util.tree_leaves(feds[True].opt_states),
                      jax.tree_util.tree_leaves(feds[False].opt_states)):
        assert jnp.allclose(la.astype(jnp.float32), lb.astype(jnp.float32),
                            atol=5e-4)
