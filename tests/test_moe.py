import jax
import jax.numpy as jnp
import pytest

from repro.models import moe
from repro.models.layers import ACTS


def _dense_oracle(p, x, k, act="silu"):
    """Per-token dense mixture: run every expert, combine top-k gates."""
    n, d = x.shape
    gates, top_idx, _ = moe.route(p["router"], x, k)
    outs = []
    for e in range(p["router"].shape[1]):
        h = ACTS[act](x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        outs.append(h @ p["w_down"][e])
    outs = jnp.stack(outs, 1)                     # (N, E, D)
    sel = jnp.take_along_axis(outs, top_idx[..., None], axis=1)
    return jnp.sum(sel * gates[..., None], axis=1)


def test_moe_matches_dense_oracle_without_drops():
    key = jax.random.PRNGKey(0)
    d, e, f, k = 16, 4, 32, 2
    p = moe.moe_init(key, d, e, f)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))
    out, aux = moe.moe_forward(p, x, k=k, capacity_factor=16.0)
    ref = _dense_oracle(p, x.reshape(-1, d), k).reshape(2, 8, d)
    assert jnp.allclose(out, ref, atol=1e-4)
    assert jnp.isfinite(aux)


def test_capacity_drops_tokens_gracefully():
    key = jax.random.PRNGKey(1)
    d, e, f, k = 8, 2, 16, 1
    p = moe.moe_init(key, d, e, f)
    x = jax.random.normal(key, (1, 32, d))
    out, _ = moe.moe_forward(p, x, k=k, capacity_factor=0.25)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_shared_experts_added():
    key = jax.random.PRNGKey(2)
    d, e, f = 8, 2, 16
    p = moe.moe_init(key, d, e, f, n_shared=1)
    assert "shared" in p
    x = jax.random.normal(key, (1, 4, d))
    out, _ = moe.moe_forward(p, x, k=1, capacity_factor=8.0)
    p2 = {k2: v for k2, v in p.items() if k2 != "shared"}
    out2, _ = moe.moe_forward(p2, x, k=1, capacity_factor=8.0)
    assert not jnp.allclose(out, out2)


def test_aux_loss_balanced_is_one():
    """Perfectly uniform router => Switch aux loss -> ~1 (its minimum)."""
    d, e = 8, 4
    p = moe.moe_init(jax.random.PRNGKey(3), d, e, 16)
    p["router"] = jnp.zeros((d, e))               # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 64, d))
    _, aux = moe.moe_forward(p, x, k=1, capacity_factor=8.0)
    assert 0.9 < float(aux) < 1.3


def test_route_gates_normalized():
    p = moe.moe_init(jax.random.PRNGKey(5), 8, 4, 16)
    x = jax.random.normal(jax.random.PRNGKey(6), (10, 8))
    gates, idx, _ = moe.route(p["router"], x, 2)
    assert jnp.allclose(jnp.sum(gates, -1), 1.0, atol=1e-5)
    assert int(idx.max()) < 4


def test_capacity_helper():
    assert moe.capacity(64, 4, 2, 1.25) % 8 == 0
    assert moe.capacity(1, 160, 6, 1.25) >= 6     # decode: at least k slots
