"""Fused-vs-dense hot paths: GaLoreAdamW step and AJIVE second-moment sync.

Two comparisons at paper-scale shapes (1024×4096 target blocks, r=8, C=8):

1. **Optimizer step** — the fused/bucketed ``scale_by_galore`` vs the dense
   per-leaf reference loop (the retained oracle). The headline metric is
   **time-to-first-update** (trace + compile + step 1): the reference loop's
   traced program scales linearly with leaf count (a QR/refresh cond chain
   per leaf), which is exactly what shape bucketing removes. Steady-state
   step time is also reported, both against the reference loop and against a
   stage-separated dense round-trip execution (each optimizer stage its own
   dispatch with materialized intermediates — the HBM-round-trip execution
   model the fused TPU kernel removes; on a CPU host the steady-state gap is
   bandwidth-limited, so the bytes-moved estimate is reported alongside).

2. **AJIVE sync** — ``ajive_sync_factored`` on the (C, ·, r) projected
   moments vs the dense ``ajive_sync`` on lifted (C, m, n) views (per-view
   dense SVDs + (m, m) joint projector).

Each row reports wall-clock and an estimated bytes-moved ratio (fp32 HBM
traffic of the dominant arrays), and asserts parity between the compared
implementations. Emits ``name,us_per_call,derived`` CSV via ``common.emit``
plus a JSON artifact.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import galore as gal
from repro.core import projector as proj
from repro.core.ajive import ajive_sync, ajive_sync_factored
from .common import emit, timed


# ------------------------------------------------------------- optimizer ----

def _make_tree(key, n_blocks, m, n):
    params = {f"w{i}": jax.random.normal(jax.random.fold_in(key, i), (m, n))
              for i in range(n_blocks)}
    grads = {k: jax.random.normal(jax.random.fold_in(key, 100 + i), (m, n))
             for i, k in enumerate(params)}
    return params, grads


def _galore_cfg(rank, **kw):
    return gal.GaloreConfig(rank=rank, refresh_every=10 ** 9,
                            adaptive_steps=0, refresh_mode="random", **kw)


def bench_optimizer_step(n_blocks=24, m=1024, n=4096, rank=8, iters=3):
    """Fused/bucketed step vs the dense per-leaf reference loop (and a
    stage-separated dense round-trip for the steady-state comparison)."""
    key = jax.random.PRNGKey(0)
    params, grads = _make_tree(key, n_blocks, m, n)
    side = proj.proj_side((m, n))

    # --- time-to-first-update (trace + compile + step 1), both paths ------
    first, steady, out_states = {}, {}, {}
    for name, cfg in (("fused", _galore_cfg(rank, fused=True,
                                            use_pallas=False)),
                      ("dense_loop", _galore_cfg(rank, fused=False))):
        tx = gal.scale_by_galore(cfg)
        st = tx.init(params)
        jax.block_until_ready(st)
        upd = jax.jit(tx.update)
        t0 = time.perf_counter()
        out_states[name], st1 = jax.block_until_ready(upd(grads, st))
        first[name] = time.perf_counter() - t0
        # steady state: count > 0 so the step-0 refresh is out of the timing
        _, steady[name] = timed(
            lambda upd=upd, st1=st1: upd(grads, st1), warmup=0, iters=iters)

    u_fused = out_states["fused"]
    err_loop = max(float(jnp.max(jnp.abs(u_fused[k]
                                         - out_states["dense_loop"][k])))
                   for k in params)
    assert err_loop <= 1e-5, f"fused/loop optimizer parity broke: {err_loop}"

    cfg = _galore_cfg(rank, fused=True, use_pallas=False)
    tx = gal.scale_by_galore(cfg)
    st = tx.init(params)
    dt_fused = steady["fused"]

    # Dense round-trip reference: one dispatch per optimizer stage, dense
    # intermediates materialized between them (device-synced), per leaf.
    gstate = gal.galore_state_of(st)
    bases = {k: gstate.blocks[k].basis for k in params}
    ms = {k: gstate.blocks[k].m for k in params}
    vs = {k: gstate.blocks[k].v for k in params}
    b1, b2, eps, c = cfg.b1, cfg.b2, cfg.eps, 1.0
    p_project = jax.jit(lambda g, b: proj.project(g, b, side))
    p_moments = jax.jit(lambda gt, mm, vv: (b1 * mm + (1 - b1) * gt,
                                            b2 * vv + (1 - b2) * gt * gt))
    p_dir = jax.jit(lambda mm, vv: (mm / (1 - b1 ** c))
                    / (jnp.sqrt(vv / (1 - b2 ** c)) + eps))
    p_back = jax.jit(lambda ut, b: proj.project_back(ut, b, side))

    def dense_roundtrip():
        outs = {}
        for k in params:
            gt = jax.block_until_ready(p_project(grads[k], bases[k]))
            m2, v2 = p_moments(gt, ms[k], vs[k])
            jax.block_until_ready((m2, v2))
            ut = jax.block_until_ready(p_dir(m2, v2))
            outs[k] = jax.block_until_ready(p_back(ut, bases[k]))
        return outs

    u_dense, dt_roundtrip = timed(dense_roundtrip, warmup=1, iters=iters)
    err = max(float(jnp.max(jnp.abs(u_fused[k] - u_dense[k])))
              for k in params)
    assert err <= 1e-5, f"fused/dense optimizer parity broke: {err}"

    # fp32 bytes of the dominant arrays. Dense round-trip re-reads/writes the
    # (m, n) gradient-sized buffers between stages; fused reads g once and
    # writes u once, everything else is O(dim·r).
    mn = 4 * m * n
    r_bytes = 4 * rank * max(m, n)
    dense_bytes = n_blocks * (4 * mn + 10 * r_bytes)
    fused_bytes = n_blocks * (2 * mn + 6 * r_bytes)

    # Headline: time-to-first-update — trace+compile scales with leaf count
    # in the dense loop, with bucket count in the fused path.
    speedup_first = first["dense_loop"] / first["fused"]
    emit(f"galore_fused/step_first_update_{n_blocks}x{m}x{n}",
         first["fused"] * 1e6,
         f"speedup_vs_dense={speedup_first:.2f}x;"
         f"dense_first={first['dense_loop'] * 1e6:.0f}us;"
         f"parity_err={max(err, err_loop):.2e}")
    emit(f"galore_fused/step_steady_{n_blocks}x{m}x{n}", dt_fused * 1e6,
         f"loop={steady['dense_loop'] * 1e6:.0f}us;"
         f"roundtrip={dt_roundtrip * 1e6:.0f}us;"
         f"bytes_ratio={dense_bytes / fused_bytes:.2f}")
    return {"fused_first_s": first["fused"],
            "dense_first_s": first["dense_loop"],
            "speedup_first_update": speedup_first,
            "fused_steady_s": dt_fused,
            "dense_loop_steady_s": steady["dense_loop"],
            "dense_roundtrip_steady_s": dt_roundtrip,
            "parity_err": max(err, err_loop),
            "dense_bytes": dense_bytes, "fused_bytes": fused_bytes}


def bench_compile_scaling(n_blocks=48, m=256, n=1024, rank=8):
    """Trace-size win: bucketed vs per-leaf-loop jit compile time."""
    key = jax.random.PRNGKey(1)
    params, grads = _make_tree(key, n_blocks, m, n)
    rows = {}
    for name, cfg in (("bucketed", _galore_cfg(rank, fused=True,
                                               use_pallas=False)),
                      ("per_leaf_loop", _galore_cfg(rank, fused=False))):
        tx = gal.scale_by_galore(cfg)
        st = tx.init(params)
        upd = jax.jit(tx.update)
        t0 = time.perf_counter()
        jax.block_until_ready(upd(grads, st))
        rows[name] = time.perf_counter() - t0
    ratio = rows["per_leaf_loop"] / rows["bucketed"]
    emit(f"galore_fused/compile_bucketed_{n_blocks}leaves",
         rows["bucketed"] * 1e6, f"loop_ratio={ratio:.2f}x")
    return {"bucketed_s": rows["bucketed"],
            "per_leaf_loop_s": rows["per_leaf_loop"], "ratio": ratio}


# ------------------------------------------------------------------ ajive ---

def bench_ajive_sync(c_views=8, m=1024, n=4096, rank=8, iters=2):
    """Factored (C, m, r) sync vs dense lifted (C, m, n) AJIVE."""
    key = jax.random.PRNGKey(2)
    side = proj.proj_side((m, n))
    dim = proj.basis_dim((m, n))
    basis = proj.random_basis(0, dim, rank)
    scale = jnp.linspace(1.6, 0.8, rank)
    if side == proj.RIGHT:
        shared = jax.random.normal(key, (m, rank)) * scale[None, :]
        v_stack = jnp.stack([jnp.abs(shared + 0.1 * jax.random.normal(
            jax.random.fold_in(key, i), (m, rank)))
            for i in range(c_views)])
        views = jnp.einsum("cmr,nr->cmn", v_stack, basis)
    else:
        shared = scale[:, None] * jax.random.normal(key, (rank, n))
        v_stack = jnp.stack([jnp.abs(shared + 0.1 * jax.random.normal(
            jax.random.fold_in(key, i), (rank, n)))
            for i in range(c_views)])
        views = jnp.einsum("mr,crn->cmn", basis, v_stack)

    fact_fn = jax.jit(lambda v: ajive_sync_factored(v, rank=rank, side=side))
    fact, dt_fact = timed(fact_fn, v_stack, warmup=1, iters=iters)
    dense_fn = jax.jit(lambda v: ajive_sync(v, rank=rank))
    dense, dt_dense = timed(dense_fn, views, warmup=1, iters=iters)

    lifted = (jnp.einsum("mr,nr->mn", fact, basis) if side == proj.RIGHT
              else basis @ fact)
    err = float(jnp.max(jnp.abs(lifted - dense)))
    scale_ref = float(jnp.max(jnp.abs(dense))) + 1e-12
    assert err <= 1e-5 * max(1.0, scale_ref), \
        f"factored/dense ajive parity broke: {err}"

    # Dense touches the (C, m, n) views across three phases plus the (m, m)
    # projector; factored never leaves the (C, max(m,n), r) coefficients.
    dense_bytes = 4 * (3 * c_views * m * n + m * m)
    fact_bytes = 4 * (3 * c_views * max(m, n) * rank)
    speedup = dt_dense / dt_fact
    emit(f"galore_fused/ajive_factored_c{c_views}_{m}x{n}", dt_fact * 1e6,
         f"speedup_vs_dense={speedup:.2f}x;bytes_ratio="
         f"{dense_bytes / fact_bytes:.2f};parity_err={err:.2e}")
    emit(f"galore_fused/ajive_dense_c{c_views}_{m}x{n}", dt_dense * 1e6,
         f"bytes={dense_bytes:.3e}")
    return {"factored_s": dt_fact, "dense_s": dt_dense, "speedup": speedup,
            "parity_err": err, "dense_bytes": dense_bytes,
            "factored_bytes": fact_bytes}


def main(paper_scale: bool = True):
    rows = {
        "optimizer": bench_optimizer_step(
            n_blocks=24, m=1024, n=4096) if paper_scale
        else bench_optimizer_step(n_blocks=8, m=256, n=512),
        "compile": bench_compile_scaling(),
        "ajive": bench_ajive_sync(
            c_views=8, m=1024, n=4096) if paper_scale
        else bench_ajive_sync(c_views=8, m=256, n=512),
    }
    with open("bench_galore_fused.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
