"""Table 2: per-round client↔server traffic + state memory per method.

Analytic accounting for one adapted block W ∈ R^{n×n} at rank r, PLUS
measured payload bytes from the reference engine's actual uplink structures.
Validates the paper's claim: FedGaLore's extra uplink is exactly one n×r
buffer per block (the projected ṽ) — same order as LoRA factors, far below
dense n×n states.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from repro.core import galore as gal
from repro.core.fed import FedConfig, FedEngine, METHODS
from .common import emit


def analytic(n=1024, r=8, bytes_per=2):
    lora_factors = 2 * n * r * bytes_per            # A and B
    rows = {
        "fedit": {"uplink": lora_factors, "opt_state": 2 * 2 * n * r * 2},
        "ffa_lora": {"uplink": n * r * bytes_per, "opt_state": 0},
        "flora": {"uplink": lora_factors, "opt_state": 2 * 2 * n * r * 2},
        "fedavg_full": {"uplink": n * n * bytes_per,
                        "opt_state": 2 * n * n * 4},
        "fedgalore": {"uplink": n * r * bytes_per      # factorized update
                      + n * r * 4                       # ṽ fp32
                      + 4,                              # seed
                      "opt_state": 2 * n * r * 4},
    }
    return rows


def measured(seed=0):
    """Run one FedGaLore round on a tiny model; measure the real ṽ payload."""
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (64, 64)), "b": jnp.zeros(64)}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    eng = FedEngine(FedConfig(method="fedgalore", rank=8, lr=1e-3,
                              local_steps=2), loss, params)
    x = jax.random.normal(key, (3, 2, 4, 64))
    y = jnp.zeros((3, 2, 4, 64))
    eng.run_round((x, y))
    v_bytes = sum(l.size * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(eng.synced_v)
                  if l is not None)
    delta_bytes = sum(l.size * l.dtype.itemsize for l in
                      jax.tree_util.tree_leaves(eng.global_trainable))
    return {"v_payload_bytes": int(v_bytes),
            "update_bytes": int(delta_bytes),
            "expected_v": 64 * 8 * 4}


def main():
    rows = {"analytic_n1024_r8": analytic(), "measured_n64_r8": measured()}
    a = rows["analytic_n1024_r8"]
    ratio = a["fedgalore"]["uplink"] / a["fedavg_full"]["uplink"]
    emit("comm/fedgalore_vs_full", 0.0,
         f"uplink_ratio={ratio:.4f};v_payload_ok="
         f"{rows['measured_n64_r8']['v_payload_bytes'] == rows['measured_n64_r8']['expected_v']}")
    assert ratio < 0.05          # LoRA-like, far below dense
    with open("bench_comm.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
