"""§Roofline: derive the three roofline terms per (arch × shape) from the
dry-run's compiled artifacts (dryrun_single.json).

  compute    = FLOPs_per_device / peak_bf16
  memory     = HBM_bytes_per_device / hbm_bw
  collective = ici_traffic_per_device / (links × link_bw)

Notes on sourcing (see EXPERIMENTS.md §Roofline for caveats):
  * cost_analysis of the SPMD-partitioned module is per-device; no extra
    division by chip count.
  * FLOPs/bytes come from the unrolled twin (XLA counts while bodies once).
  * collective bytes are summed RESULT-buffer sizes of every collective op
    in the post-SPMD HLO; ring traffic ≈ result for all-gather,
    2× reduced size for all-reduce, 1× for all-to-all/permute. We apply
    those multipliers and divide by 4 ICI links per chip (v5e 2D torus).
  * MODEL_FLOPS = 6·N_active·tokens (per device share) — the useful-compute
    yardstick; ratio < 1 of HLO flops indicates remat/capacity/dispatch
    overhead.

Usage: PYTHONPATH=src python -m benchmarks.roofline [dryrun_single.json]
"""
from __future__ import annotations

import json
import sys

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_variant
from repro.launch.mesh import TPU_V5E

PEAK = TPU_V5E["peak_bf16_flops"]
HBM = TPU_V5E["hbm_bw"]
ICI = TPU_V5E["ici_bw"]
LINKS = 4          # v5e: 2D torus, 4 ICI links per chip

# effective wire-traffic multiplier per collective kind (ring algorithms)
TRAFFIC_MULT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def model_flops_per_device(arch: str, shape_name: str, chips: int = 256) -> float:
    cfg = shape_variant(get_config(arch), SHAPES[shape_name])
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / chips


def roofline_terms(entry: dict) -> dict:
    flops = max(entry["flops"], 0.0)
    hbm_bytes = max(entry["bytes_accessed"], 0.0)
    coll = entry["collective_bytes"]
    wire = sum(TRAFFIC_MULT[k] * max(v, 0) for k, v in coll.items()
               if k in TRAFFIC_MULT)
    t_compute = flops / PEAK
    t_memory = hbm_bytes / HBM
    t_coll = wire / (LINKS * ICI)
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dom[0],
            "bound_s": dom[1], "wire_bytes": wire}


def analyze(path: str = "dryrun_single.json", chips: int = 256):
    data = json.load(open(path))
    rows = []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            key = next((k for k in data if k.startswith(f"{arch}@{shape}@")),
                       None)
            if key is None or "error" in data[key]:
                continue
            terms = roofline_terms(data[key])
            mf = model_flops_per_device(arch, shape, chips)
            rows.append({
                "arch": arch, "shape": shape, **terms,
                "model_flops": mf,
                "useful_ratio": mf / max(data[key]["flops"], 1.0),
                "hlo_flops": data[key]["flops"],
                "mem_temp_gb": (data[key]["memory"]["temp_bytes"] or 0) / 2**30,
            })
    return rows


def print_table(rows):
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'coll_s':>10s} {'dominant':>10s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['t_compute']:10.3e} "
              f"{r['t_memory']:10.3e} {r['t_collective']:10.3e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f}")


def main(argv=None):
    path = (argv or sys.argv[1:] or ["dryrun_single.json"])[0]
    rows = analyze(path)
    print_table(rows)
    with open("roofline.json", "w") as f:
        json.dump(rows, f, indent=1)
    # Hillclimb candidate selection (§Perf): worst useful ratio, most
    # collective-bound, most representative of the paper (train_4k pair).
    by_useful = sorted((r for r in rows if r["shape"] == "train_4k"),
                       key=lambda r: r["useful_ratio"])
    by_coll = sorted(rows, key=lambda r: -(r["t_collective"]
                                           / max(r["bound_s"], 1e-30)))
    print("\nworst useful-compute (train):",
          [f"{r['arch']}@{r['shape']}" for r in by_useful[:3]])
    print("most collective-dominated:",
          [f"{r['arch']}@{r['shape']}" for r in by_coll[:3]])
    return rows


if __name__ == "__main__":
    main()
