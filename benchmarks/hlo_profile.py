"""Dry-run 'profiler': per-op breakdown of the post-SPMD HLO.

No wall-clock exists on this container, so the profile is structural: every
instruction's output-buffer bytes grouped by opcode, plus the top individual
collectives / dots / fusions with their shapes. This is what the §Perf
hypothesis loop reads instead of a trace.

Usage:
  PYTHONPATH=src python -m benchmarks.hlo_profile --arch command-r-35b \
      --shape decode_32k [--mesh single] [--top 15]
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

_SHAPE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+ = "
                       r"(?:\(([^)]*)\)|(\w+)\[([\d,]*)\]\S*)\s+([\w-]+)")
_INNER_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}


def _bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def profile_text(hlo: str, top: int = 15):
    by_op = defaultdict(int)
    biggest = []
    for line in hlo.splitlines():
        m = _SHAPE_RE.match(line)
        if not m:
            continue
        tup, dtype, dims, op = m.groups()
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            continue
        if tup is not None:
            size = sum(_bytes(d, s) for d, s in _INNER_SHAPE.findall(tup))
            shape_str = "(tuple)"
        else:
            size = _bytes(dtype, dims)
            shape_str = f"{dtype}[{dims}]"
        by_op[op] += size
        biggest.append((size, op, shape_str, line.strip()[:140]))
    biggest.sort(reverse=True)
    return by_op, biggest[:top]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--unroll", action="store_true", default=True)
    ap.add_argument("--blocks", type=int, default=1,
                    help="depth_blocks for the unrolled twin")
    args = ap.parse_args(argv)

    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import TrainSpec

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered = dryrun.lower_combination(args.arch, args.shape, mesh,
                                       TrainSpec(rank=64), unroll=True,
                                       depth_blocks=args.blocks)
    compiled = lowered.compile()
    by_op, biggest = profile_text(compiled.as_text(), args.top)

    print(f"== {args.arch}@{args.shape}@{args.mesh} "
          f"(unrolled, {args.blocks} block(s)) ==")
    print("\n-- output bytes by opcode --")
    for op, size in sorted(by_op.items(), key=lambda kv: -kv[1])[:20]:
        print(f"  {op:24s} {size / 2**30:10.3f} GiB")
    print(f"\n-- top {args.top} single ops --")
    for size, op, shape, line in biggest:
        print(f"  {size / 2**30:8.3f} GiB {op:16s} {shape:28s} {line[:90]}")
    cost = compiled.cost_analysis()
    print(f"\nflops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")


if __name__ == "__main__":
    main()
