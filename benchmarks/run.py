"""Run every paper-table benchmark. Prints ``name,us_per_call,derived`` CSV.

One benchmark per paper artifact:
  §5/App. A.1   -> bench_galore_fused     (fused vs dense hot paths)
  Tables 3/4/5  -> bench_fed_methods      (IID vs Dirichlet-0.5 across methods)
  Table 6/Fig3ab-> bench_landscape        (kinetic-trap basin fractions)
  Fig 3c        -> bench_interpolation    (client-model loss barriers)
  Fig 1 right   -> bench_state_mismatch   (local vs global progress)
  Fig 4/App. D  -> bench_projector_schedule
  Fig 5/App. F  -> bench_ajive_recovery
  Table 7       -> bench_ajive_latency
  Table 2       -> bench_comm
  §Roofline     -> roofline (reads dryrun_single.json when present)
  Round fusion  -> bench_round_e2e (eager vs fused vs scan-over-rounds)
  Serving       -> bench_serve (scan decode, hetero adapters, slot batching)
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def _env_hygiene() -> None:
    """Launcher hygiene, applied BEFORE jax initializes (mirrors the shell
    block in scripts/ci.sh): tcmalloc preload can't be done from in-process
    (LD_PRELOAD is read at exec), but the allocator threshold, C++ log
    level, and XLA host-device plumbing are env-var driven and honored at
    first jax import."""
    os.environ.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                          "60000000000")
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    flags = []
    host_devices = os.environ.get("REPRO_HOST_DEVICES")
    if host_devices:
        flags.append(f"--xla_force_host_platform_device_count={host_devices}")
    # Opt-in only: rejected by CPU builds of XLA (unknown-flag error).
    if os.environ.get("REPRO_STEP_MARKERS") == "1":
        flags.append("--xla_step_marker_location=1")
    if flags:
        prev = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (prev + " " + " ".join(flags)).strip()


def main() -> None:
    _env_hygiene()
    from . import (bench_ajive_latency, bench_ajive_recovery, bench_comm,
                   bench_fed_methods, bench_galore_fused, bench_interpolation,
                   bench_landscape, bench_participation,
                   bench_projector_schedule, bench_round_e2e, bench_serve,
                   bench_state_mismatch)

    print("name,us_per_call,derived")
    suites = [
        ("galore_fused", bench_galore_fused.main),
        ("round_e2e", bench_round_e2e.main),
        ("serve", bench_serve.main),
        ("ajive_latency", bench_ajive_latency.main),
        ("ajive_recovery", bench_ajive_recovery.main),
        ("comm", bench_comm.main),
        ("landscape", bench_landscape.main),
        ("projector_schedule", bench_projector_schedule.main),
        ("state_mismatch", bench_state_mismatch.main),
        ("interpolation", bench_interpolation.main),
        ("fed_methods", bench_fed_methods.main),
        ("participation", bench_participation.main),
    ]
    failures = []
    for name, fn in suites:
        t0 = time.time()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)

    if os.path.exists("dryrun_single.json"):
        from . import roofline
        rows = roofline.analyze("dryrun_single.json")
        for r in rows:
            print(f"roofline/{r['arch']}@{r['shape']},"
                  f"{r['bound_s'] * 1e6:.1f},"
                  f"dominant={r['dominant']};useful={r['useful_ratio']:.2f}")
    else:
        print("# roofline skipped: run repro.launch.dryrun --all first",
              file=sys.stderr)

    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
