"""Fig 1 (right) analogue: optimizer-state mismatch.

With local adaptive optimizers and NO state synchronization, client training
loss keeps decreasing while global validation improves little — the
local/global mismatch the paper attributes to unsynchronized second moments.
We contrast FedGaLore⁻ (sync none) with FedGaLore (AJIVE sync) under
Dirichlet(0.1) heterogeneity and report the local-vs-global gap.
"""
from __future__ import annotations

import json
import time

import numpy as np

from .common import emit, run_federated_trial


def main(rounds=10, seed=0):
    out = {}
    t0 = time.perf_counter()
    for method in ("fedgalore_minus", "fedgalore"):
        r = run_federated_trial(method, alpha=0.1, rounds=rounds,
                                lr=5e-3, seed=seed)
        local_drop = r["local_curve"][0] - r["local_curve"][-1]
        val_drop = r["val_curve"][0] - r["val_curve"][-1]
        out[method] = {
            "local_loss_drop": float(local_drop),
            "val_loss_drop": float(val_drop),
            "mismatch_ratio": float(local_drop / max(val_drop, 1e-6)),
            "final_acc": r["acc"],
        }
    dt = time.perf_counter() - t0
    emit("state_mismatch", dt / (2 * rounds) * 1e6,
         (f"nosync_ratio={out['fedgalore_minus']['mismatch_ratio']:.2f};"
          f"ajive_ratio={out['fedgalore']['mismatch_ratio']:.2f};"
          f"nosync_acc={out['fedgalore_minus']['final_acc']:.3f};"
          f"ajive_acc={out['fedgalore']['final_acc']:.3f}"))
    with open("bench_state_mismatch.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
