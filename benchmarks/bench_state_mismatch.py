"""Fig 1 (right) analogue: optimizer-state mismatch.

With local adaptive optimizers and NO state synchronization, client training
loss keeps decreasing while global validation improves little — the
local/global mismatch the paper attributes to unsynchronized second moments.
We contrast FedGaLore⁻ (sync none) with FedGaLore (AJIVE sync) under
Dirichlet(0.1) heterogeneity and report the local-vs-global gap.

The partial-participation leg re-runs FedGaLore with 25% per-round dropout
through the population layer and reports the projected-moment divergence of
the surviving cohort around the synced v̄ — the same
``core.population.moment_divergence`` metric (one code path) that
``bench_participation`` sweeps across its whole fault grid.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.population import ParticipationConfig

from .common import emit, run_federated_trial


def main(rounds=10, seed=0):
    out = {}
    t0 = time.perf_counter()
    for method in ("fedgalore_minus", "fedgalore"):
        r = run_federated_trial(method, alpha=0.1, rounds=rounds,
                                lr=5e-3, seed=seed)
        local_drop = r["local_curve"][0] - r["local_curve"][-1]
        val_drop = r["val_curve"][0] - r["val_curve"][-1]
        out[method] = {
            "local_loss_drop": float(local_drop),
            "val_loss_drop": float(val_drop),
            "mismatch_ratio": float(local_drop / max(val_drop, 1e-6)),
            "final_acc": r["acc"],
        }
    # Partial participation: drift of the surviving cohort's moments around
    # the synced state (population.moment_divergence — shared with
    # bench_participation's sweep).
    rp = run_federated_trial(
        "fedgalore", alpha=0.1, rounds=rounds, lr=5e-3, seed=seed,
        participation=ParticipationConfig(dropout_rate=0.25,
                                          seed=seed + 100))
    out["fedgalore_partial"] = {
        "dropout_rate": 0.25,
        "final_acc": rp["acc"],
        "drift_curve": [float(x) for x in rp["drift_curve"]],
        "mean_moment_divergence": float(np.mean(rp["drift_curve"])),
    }
    dt = time.perf_counter() - t0
    emit("state_mismatch", dt / (3 * rounds) * 1e6,
         (f"nosync_ratio={out['fedgalore_minus']['mismatch_ratio']:.2f};"
          f"ajive_ratio={out['fedgalore']['mismatch_ratio']:.2f};"
          f"nosync_acc={out['fedgalore_minus']['final_acc']:.3f};"
          f"ajive_acc={out['fedgalore']['final_acc']:.3f};"
          f"partial_drift="
          f"{out['fedgalore_partial']['mean_moment_divergence']:.3f}"))
    with open("bench_state_mismatch.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
