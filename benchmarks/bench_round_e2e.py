"""Whole-round fusion: wall-clock per federated round, both engines.

Measures the three execution models of the round loop (Algorithm 1):

  eager  — the stage-by-stage reference: separately dispatched InitState,
           jitted local training, eager 𝒜 + 𝒮 between jit boundaries
           (FedEngine ``fused_round=False``; ShardedFederation
           ``fused_round=False`` = jit-𝒯𝒜 + host-𝒮).
  fused  — the whole round as ONE jitted, buffer-donated program.
  scan   — K rounds as ONE ``lax.scan`` dispatch (``run_rounds``).

Reports seconds/round and rounds/sec across client counts for the reference
FedEngine (multi-block toy problem, two workload regimes) and the SPMD
ShardedFederation (smoke transformer on a host mesh). The acceptance numbers
— fused vs eager at C=8 and scan vs per-round fused dispatch at K=10 — land
in the JSON.

Regimes: fusing the round wins on two distinct axes, measured separately.
``compute`` (wider blocks, more local steps) shows the eager→fused win: the
eager round pays O(clients·leaves) host dispatches that fusion collapses
into one program. ``dispatch`` (small blocks, T=1 — the ROADMAP's
many-small-federated-scenarios serving regime) additionally shows the
fused→scan win: once the round is a single program, per-round dispatch +
host metric sync is the remaining overhead, and the K-round scan amortizes
it to one dispatch per sweep.

Cohort sweep (``bench_cohort``): the factored-client memory model's scaling
axis. Sweeps C ∈ {8, 64, 512} through the chunk-streamed fused round on a
wide-block problem, reporting wall-clock alongside **peak client-buffer
bytes** (the persistent per-client round state the factored representation
shrinks from O(C·m·n) to O(C·r(m+n))), against the retired dense-stack model
at C=8. Acceptance: the C=512 factored round completes with client buffers
within 4× the old C=8 dense configuration, and factored-vs-dense round
parity ≤ 1e-5 at C=8.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core.fed import FedConfig, FedEngine
from .common import emit

SCAN_ROUNDS = 10        # K for the scan-over-rounds acceptance number

ENGINE_REGIMES = {
    # regime -> (n_blocks, width, local_steps, batch)
    "compute": (4, 48, 2, 4),
    "dispatch": (2, 16, 1, 2),
}


def _engine_problem(n_blocks, width):
    """A multi-block toy model (several same-shape target matrices + biases)
    so the eager round pays realistic per-leaf dispatch costs."""
    key = jax.random.PRNGKey(0)
    params = {}
    for i in range(n_blocks):
        params[f"w{i}"] = 0.2 * jax.random.normal(
            jax.random.fold_in(key, i), (width, width))
        params[f"b{i}"] = jnp.zeros((width,))
    params["head"] = 0.2 * jax.random.normal(
        jax.random.fold_in(key, 99), (width, 8))

    def loss(p, batch):
        x, y = batch
        h = x
        for i in range(n_blocks):
            h = jnp.tanh(h @ p[f"w{i}"] + p[f"b{i}"])
        return jnp.mean((h @ p["head"] - y) ** 2)

    def batches(seed, k_clients, t_steps, b, k_rounds=None):
        kk = jax.random.PRNGKey(seed)
        lead = ((k_clients, t_steps) if k_rounds is None
                else (k_rounds, k_clients, t_steps))
        x = jax.random.normal(kk, lead + (b, width))
        y = jax.random.normal(jax.random.fold_in(kk, 1), lead + (b, 8))
        return (x, y)

    return params, loss, batches


def _best_of(fn, reps=3):
    return min(fn() for _ in range(reps))


def _time_rounds(run_one, n_rounds):
    t0 = time.perf_counter()
    for r in range(n_rounds):
        run_one(r)
    return (time.perf_counter() - t0) / n_rounds


def bench_engine(clients, regime="dispatch", rounds_timed=10, rank=4,
                 reps=5):
    n_blocks, width, local_steps, b = ENGINE_REGIMES[regime]
    params, loss, batches = _engine_problem(n_blocks, width)
    rows = []
    for c in clients:
        per = {"engine": "FedEngine", "regime": regime, "clients": c,
               "local_steps": local_steps, "width": width,
               "n_blocks": n_blocks}
        for mode in ("eager", "fused"):
            # eager = the strongest stage-by-stage baseline (PR-1 state:
            # factored 𝒮, bucketed GaLore) so the speedup isolates round
            # fusion, not the factored-vs-dense sync win.
            eng = FedEngine(FedConfig(method="fedgalore", rank=rank, lr=1e-2,
                                      local_steps=local_steps,
                                      fused_round=(mode == "fused")),
                            loss, params)
            for r in range(2):          # compile both traces + adaptive r0
                eng.run_round(batches(r, c, local_steps, b))
            bs = [batches(10 + r, c, local_steps, b) for r in range(3)]
            jax.block_until_ready(bs)
            n = rounds_timed if mode == "fused" else max(rounds_timed // 3, 2)

            def loop(eng=eng, bs=bs, n=n):
                t0 = time.perf_counter()
                for r in range(n):
                    eng.run_round(bs[r % 3])
                return (time.perf_counter() - t0) / n
            per[f"{mode}_s"] = _best_of(loop, reps if mode == "fused" else 1)
        # scan-over-rounds: K rounds in one dispatch
        eng = FedEngine(FedConfig(method="fedgalore", rank=rank, lr=1e-2,
                                  local_steps=local_steps), loss, params)
        rb = batches(0, c, local_steps, b, k_rounds=SCAN_ROUNDS)
        eng.run_rounds(rb)              # compile

        def scan_loop(eng=eng, rb=rb):
            t0 = time.perf_counter()
            eng.run_rounds(rb)
            return (time.perf_counter() - t0) / SCAN_ROUNDS
        per["scan_s"] = _best_of(scan_loop, reps)
        per["scan_rounds"] = SCAN_ROUNDS
        per["fused_speedup"] = per["eager_s"] / per["fused_s"]
        per["scan_speedup_vs_fused"] = per["fused_s"] / per["scan_s"]
        rows.append(per)
        tag = f"round_e2e/engine_{regime}_c{c}"
        emit(f"{tag}_eager", per["eager_s"] * 1e6,
             f"rounds_per_s={1.0 / per['eager_s']:.1f}")
        emit(f"{tag}_fused", per["fused_s"] * 1e6,
             f"speedup={per['fused_speedup']:.2f}x")
        emit(f"{tag}_scan", per["scan_s"] * 1e6,
             f"vs_fused={per['scan_speedup_vs_fused']:.2f}x")
    return rows


COHORT_CLIENTS = (8, 64, 512)
COHORT_WIDTH = 512      # wide blocks: the regime where O(m·n) vs O(r(m+n))
COHORT_RANK = 4         # per-client state is the whole story
COHORT_CHUNK = 32       # B: dense transient working set bounded by 32 clients


def _tree_maxerr(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def bench_cohort(clients=COHORT_CLIENTS, rounds_timed=2):
    """Cohort-size sweep of the factored chunk-streamed round (fedgalore,
    T=1) vs the retired dense-stack client model at C=8: wall-clock + peak
    client-buffer bytes + factored-vs-dense parity."""
    n_blocks, width, local_steps, b = 2, COHORT_WIDTH, 1, 2
    params, loss, batches = _engine_problem(n_blocks, width)

    def make(factored, chunk=None):
        # Cohort size comes from the batch leading dim at run_round time.
        return FedEngine(FedConfig(method="fedgalore", rank=COHORT_RANK,
                                   lr=1e-2, local_steps=local_steps,
                                   factored_clients=factored,
                                   client_chunk=chunk), loss, params)

    def run(eng, c, n_rounds, offset=0):
        t0 = time.perf_counter()
        for r in range(n_rounds):
            eng.run_round(batches(offset + r, c, local_steps, b))
        return (time.perf_counter() - t0) / n_rounds

    rows = []
    # The old configuration: dense per-client weight stacks, C=8, one chunk.
    dense8 = make(factored=False)
    run(dense8, 8, 2)                                  # compile + round 1
    dense8_s = run(dense8, 8, rounds_timed, offset=10)
    dense8_bytes = dense8.client_buffer_bytes()
    rows.append({"engine": "FedEngine", "sweep": "cohort", "clients": 8,
                 "client_model": "dense", "chunk": None,
                 "round_s": dense8_s, "client_buffer_bytes": dense8_bytes})
    emit("round_e2e/cohort_c8_dense", dense8_s * 1e6,
         f"buffer_bytes={dense8_bytes}")

    # Factored-vs-dense parity at C=8 (identical batches, 2 rounds).
    fact8 = make(factored=True)
    dense8b = make(factored=False)
    for r in range(2):
        fact8.run_round(batches(r, 8, local_steps, b))
        dense8b.run_round(batches(r, 8, local_steps, b))
    parity = max(_tree_maxerr(fact8.global_trainable, dense8b.global_trainable),
                 _tree_maxerr(fact8.synced_v, dense8b.synced_v))

    for c in clients:
        eng = make(factored=True, chunk=min(COHORT_CHUNK, c))
        run(eng, c, 2)
        sec = run(eng, c, rounds_timed, offset=10)
        nbytes = eng.client_buffer_bytes()
        rows.append({"engine": "FedEngine", "sweep": "cohort", "clients": c,
                     "client_model": "factored", "chunk": min(COHORT_CHUNK, c),
                     "round_s": sec, "client_buffer_bytes": nbytes,
                     "buffer_vs_c8_dense": nbytes / dense8_bytes})
        emit(f"round_e2e/cohort_c{c}_factored", sec * 1e6,
             f"buffer_bytes={nbytes} "
             f"vs_c8_dense={nbytes / dense8_bytes:.2f}x")
    c512 = next(r for r in rows if r["clients"] == max(clients)
                and r["client_model"] == "factored")
    return rows, {
        "cohort_cmax": max(clients),
        "cohort_cmax_round_s": c512["round_s"],
        "cohort_cmax_buffer_bytes": c512["client_buffer_bytes"],
        "c8_dense_buffer_bytes": dense8_bytes,
        "cohort_buffer_ratio_cmax_vs_c8_dense": c512["buffer_vs_c8_dense"],
        "factored_parity_c8": parity,
    }


def bench_runtime(clients, local_steps=2, rounds_timed=3):
    from repro.configs import get_config, smoke_variant
    from repro.fedsim import ShardedFederation
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import TrainSpec

    cfg = smoke_variant(get_config("qwen1.5-0.5b"))
    mesh = make_host_mesh(1)
    spec = TrainSpec(rank=4, lr=1e-3, local_steps=local_steps,
                     refresh_mode="random")

    def batches(seed, c, k_rounds=None, b=2, seq=8):
        kk = jax.random.PRNGKey(seed)
        lead = ((c, local_steps, b, seq) if k_rounds is None
                else (k_rounds, c, local_steps, b, seq))
        toks = jax.random.randint(kk, lead, 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    rows = []
    for c in clients:
        per = {"engine": "ShardedFederation", "clients": c,
               "local_steps": local_steps}
        for mode in ("eager", "fused"):
            fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive",
                                    fused_round=(mode == "fused"))
            # two warmup rounds: round 2's inputs carry round 1's output
            # shardings, so the steady-state executable exists before timing
            for r in range(2):
                fed.run_round(batches(r, c))
            bs = [batches(10 + r, c) for r in range(2)]
            per[f"{mode}_s"] = _best_of(
                lambda: _time_rounds(lambda r: fed.run_round(bs[r % 2]),
                                     rounds_timed), 2)
        fed = ShardedFederation(cfg, spec, mesh, c, state_sync="ajive")
        rb = batches(0, c, k_rounds=SCAN_ROUNDS)
        for _ in range(2):                          # compile + steady state
            fed.run_rounds(rb)

        def scan_loop(fed=fed, rb=rb):
            t0 = time.perf_counter()
            fed.run_rounds(rb)
            return (time.perf_counter() - t0) / SCAN_ROUNDS
        per["scan_s"] = _best_of(scan_loop, 2)
        per["scan_rounds"] = SCAN_ROUNDS
        per["fused_speedup"] = per["eager_s"] / per["fused_s"]
        per["scan_speedup_vs_fused"] = per["fused_s"] / per["scan_s"]
        rows.append(per)
        emit(f"round_e2e/runtime_c{c}_eager", per["eager_s"] * 1e6,
             f"rounds_per_s={1.0 / per['eager_s']:.1f}")
        emit(f"round_e2e/runtime_c{c}_fused", per["fused_s"] * 1e6,
             f"speedup={per['fused_speedup']:.2f}x")
        emit(f"round_e2e/runtime_c{c}_scan", per["scan_s"] * 1e6,
             f"vs_fused={per['scan_speedup_vs_fused']:.2f}x")
    return rows


def main(clients=(4, 8, 16), out_path="bench_round_e2e.json",
         include_runtime=True, smoke=False):
    if smoke:
        clients = tuple(c for c in clients if c <= 8) or (4, 8)
    rows = bench_engine(clients, regime="compute")
    rows += bench_engine(clients, regime="dispatch")
    cohort_rows, cohort_acc = bench_cohort()
    rows += cohort_rows
    if include_runtime:
        rows += bench_runtime(clients if not smoke else (4,))

    def row(regime, c):
        return next(r for r in rows if r["engine"] == "FedEngine"
                    and r.get("regime") == regime and r["clients"] == c)

    c8c, c8d = row("compute", 8), row("dispatch", 8)
    result = {
        "rows": rows,
        # fused-vs-eager from the compute regime (the O(clients·leaves)
        # eager dispatches it collapses); scan-vs-per-round-dispatch from
        # the dispatch-bound serving regime it amortizes.
        "acceptance": {
            "fused_speedup_c8": c8c["fused_speedup"],
            "scan_speedup_vs_fused_k10_c8": c8d["scan_speedup_vs_fused"],
            "scan_speedup_vs_fused_k10_by_clients": {
                str(c): row("dispatch", c)["scan_speedup_vs_fused"]
                for c in clients},
            "scan_speedup_vs_eager_k10_c8": c8d["eager_s"] / c8d["scan_s"],
            **cohort_acc,
        },
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="bench_round_e2e.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI perf tracking")
    ap.add_argument("--no-runtime", action="store_true")
    args = ap.parse_args()
    main(out_path=args.out, include_runtime=not args.no_runtime,
         smoke=args.smoke)
